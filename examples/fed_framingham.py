"""End-to-end driver: the paper's full experiment — all five models,
all sampling strategies, communication ledger, fed-SMOTE sync, DP — on the
synthetic Framingham twin with 3 virtual hospitals.

Run:  PYTHONPATH=src python examples/fed_framingham.py [--fast]
"""
import argparse

import numpy as np

from repro.core import fed_hist as FH
from repro.core import feature_extract as FE
from repro.core import parametric as P
from repro.core import tree_subset as TS
from repro.data import framingham as F
from repro.data import sampling as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    n_rounds = 8 if args.fast else 25
    n_trees = 30 if args.fast else 100

    ds = F.synthesize()
    tr, te = F.train_test_split(ds)
    clients = [(c.x, c.y) for c in F.partition_clients(tr, 3)]
    test = (te.x, te.y)
    print(f"Framingham twin: {len(ds.y)} records, "
          f"{ds.y.mean()*100:.1f}% CHD+, 3 hospitals x "
          f"{len(clients[0][1])} records\n")

    print("-- parametric pipeline (FedAvg / FedProx) --")
    logreg_params = None
    for model in ["logreg", "svm", "mlp"]:
        cfg = P.FedParametricConfig(
            model=model, rounds=n_rounds, local_steps=40,
            lr={"logreg": 0.05, "svm": 0.02, "mlp": 0.01}[model],
            sampling="ros",
            fedprox_mu=0.01 if model == "mlp" else 0.0)
        params, comm, hist, timer = P.train_federated(clients, cfg,
                                                      test=test)
        if model == "logreg":
            logreg_params = params
        m = hist[-1]
        print(f"  {model:7s} ROS: F1={m['f1']:.3f} P={m['precision']:.3f} "
              f"R={m['recall']:.3f} AUC={m['roc_auc']:.3f}  "
              f"comm={comm.total_mb():.2f}MB "
              f"agg={timer.total_s*1e3:.0f}ms")

    print("\n-- aggregation strategies (registry) on logreg/ROS --")
    for strat in ["fedavg", "fedavg_weighted", "fedavgm", "fedadam"]:
        cfg = P.FedParametricConfig(model="logreg", rounds=n_rounds,
                                    local_steps=40, lr=0.05,
                                    sampling="ros", strategy=strat)
        _, _, hist, _ = P.train_federated(clients, cfg, test=test)
        print(f"  {strat:15s}: F1={hist[-1]['f1']:.3f} "
              f"R={hist[-1]['recall']:.3f}")

    print("\n-- parametric + secure aggregation + DP(eps=0.5) --")
    cfg = P.FedParametricConfig(model="logreg", rounds=n_rounds,
                                local_steps=40, lr=0.05, sampling="ros",
                                secure_agg=True, dp_epsilon=0.5,
                                dp_clip=2.0)
    _, _, hist, _ = P.train_federated(clients, cfg, test=test)
    print(f"  logreg +DP: F1={hist[-1]['f1']:.3f} (privacy costs accuracy)")

    print("\n-- non-parametric pipeline --")
    full = TS.FedForestConfig(trees_per_client=n_trees, subset=n_trees,
                              sampling="smote")
    sub = TS.FedForestConfig(trees_per_client=n_trees,
                             subset=max(n_trees * 3 // 10, 3),
                             sampling="smote")
    m1, c1, t1 = TS.train_federated_rf(clients, full)
    m2, c2, t2 = TS.train_federated_rf(clients, sub)
    e1, e2 = (TS.evaluate_rf(m, te.x, te.y) for m in (m1, m2))
    print(f"  RF dense : F1={e1['f1']:.3f} uplink={c1.uplink_mb():.2f}MB")
    print(f"  RF subset: F1={e2['f1']:.3f} uplink={c2.uplink_mb():.2f}MB "
          f"(Theorem 1: |dF1|={abs(e1['f1']-e2['f1']):.3f} <= 0.03?)")

    xcfg = FE.FedXGBConfig(num_rounds=20 if args.fast else 50,
                           sampling="smote")
    d, cd, _ = FE.train_federated_xgb(clients, xcfg)
    fe, cf, _ = FE.train_federated_xgb_fe(clients, xcfg)
    ed = FE.evaluate_fed_xgb(d, te.x, te.y)
    ef = FE.evaluate_fe(fe, te.x, te.y)
    print(f"  XGB dense: F1={ed['f1']:.3f} uplink={cd.uplink_mb():.2f}MB")
    print(f"  XGB f.ext: F1={ef['f1']:.3f} uplink={cf.uplink_mb():.2f}MB "
          f"({cd.uplink_mb()/max(cf.uplink_mb(),1e-9):.1f}x reduction)")

    print("\n-- histogram-aggregation federated GBDT (fed_hist) --")
    # shared federated bins + shipped histograms: exactly centralized
    # GBDT on the pooled shards, a third point on the comm/F1 curve
    hcfg = FH.FedHistConfig(num_rounds=20 if args.fast else 50,
                            depth=4, n_bins=32, sampling="smote")
    hm, ch, th = FH.train_federated_xgb_hist(clients, hcfg)
    eh = FH.evaluate_fed_hist(hm, te.x, te.y)
    print(f"  XGB hist : F1={eh['f1']:.3f} uplink={ch.uplink_mb():.2f}MB "
          f"(== centralized on union; growth {th.total_s:.1f}s)")
    hcfg_dp = FH.FedHistConfig(num_rounds=20 if args.fast else 50,
                               depth=4, n_bins=32, sampling="smote",
                               secure_agg=True, dp_epsilon=0.5)
    hm2, _, _ = FH.train_federated_xgb_hist(clients, hcfg_dp)
    eh2 = FH.evaluate_fed_hist(hm2, te.x, te.y)
    print(f"  XGB hist + secure-agg + DP(eps=0.5): F1={eh2['f1']:.3f} "
          f"(noisy histograms cost accuracy)")

    print("\n-- scenario diversity (FedRuntime axes) --")
    # partial participation + layered transport on the parametric
    # pipeline; site-shifted shards for fed_hist (docs/EXPERIMENTS.md
    # §Scenarios)
    for part, trans in [("full", "plain"), ("uniform:2", "plain"),
                        ("dropout:0.3:0.5", "plain"),
                        ("full", "full_stack")]:
        cfg = P.FedParametricConfig(model="logreg", rounds=n_rounds,
                                    local_steps=40, lr=0.05,
                                    sampling="ros", participation=part,
                                    transport=trans, dp_clip=2.0)
        _, comm, hist, _ = P.train_federated(clients, cfg, test=test)
        f1 = hist[-1]["f1"] if hist else float("nan")
        print(f"  logreg part={part:15s} transport={trans:10s}: "
              f"F1={f1:.3f} ledger={comm.total_mb():.2f}MB")
    from repro.data import partition as DP
    site = [(c.x, c.y) for c in DP.partition_dataset("site", tr, 3,
                                                     seed=2)]
    hcfg_site = FH.FedHistConfig(num_rounds=n_rounds, depth=4, n_bins=32,
                                 participation="uniform:2")
    hm3, ch3, _ = FH.train_federated_xgb_hist(site, hcfg_site)
    eh3 = FH.evaluate_fed_hist(hm3, te.x, te.y)
    print(f"  fed_hist site-shift + uniform:2: F1={eh3['f1']:.3f} "
          f"uplink={ch3.uplink_mb():.2f}MB")

    print("\n-- serve: export bundles -> bucketed scoring engine --")
    # the inference half: every trained artifact round-trips through a
    # self-describing ModelBundle, then serves through the bucketed
    # engine (Pallas forest-inference kernel on the tree kinds)
    from repro.core.metrics import binary_metrics
    from repro.serve import bundle as B
    from repro.serve.engine import ScoringEngine
    exported = {
        "parametric": B.pack("parametric", logreg_params, model="logreg"),
        "tree_subset": B.pack("tree_subset", m2),
        "feature_extract": B.pack("feature_extract", fe),
        "fed_hist": B.pack("fed_hist", hm),
    }
    for kind, bundle in exported.items():
        path = f"results/serve/example/{kind}"
        nbytes = B.save_bundle(path, bundle)
        engine = ScoringEngine(B.load_bundle(path),
                               bucket_sizes=(64, 256, 1024))
        engine.warmup(te.x.shape[1])
        probs = engine.score(te.x)
        em = binary_metrics(probs > 0.5, te.y, scores=probs)
        st = engine.stats()
        print(f"  {kind:16s}: bundle={nbytes/1024:5.1f}KiB  "
              f"F1={em['f1']:.3f} AUC={em['roc_auc']:.3f}  "
              f"{st['rows_per_s']:,.0f} rows/s p50={st['p50_ms']:.2f}ms "
              f"p99={st['p99_ms']:.2f}ms")
    # compose the zoo into one calibrated ensemble (Platt on train data)
    ens_engine = ScoringEngine(list(exported.values()),
                               bucket_sizes=(64, 256, 1024))
    ens_engine.calibrate(tr.x, tr.y)
    probs = ens_engine.score(te.x)
    em = binary_metrics(probs > 0.5, te.y, scores=probs)
    print(f"  4-model ensemble + Platt: F1={em['f1']:.3f} "
          f"AUC={em['roc_auc']:.3f} Brier={em['brier']:.3f} "
          f"(a={ens_engine.calibration[0]:.2f})")

    print("\n-- federated SMOTE sync vs local SMOTE (skewed non-IID) --")
    skewed = F.partition_clients(tr, 3, alpha=0.3)
    sk_clients = [(c.x, c.y) for c in skewed]
    stats = S.aggregate_stats([S.minority_stats(x, y)
                               for x, y in sk_clients])
    for name, fs in [("local smote", None), ("fed smote", stats)]:
        cfg = TS.FedForestConfig(trees_per_client=n_trees // 2,
                                 subset=n_trees // 2,
                                 sampling="smote" if fs is None
                                 else "fed_smote")
        m, _, _ = TS.train_federated_rf(sk_clients, cfg, fed_stats=fs)
        e = TS.evaluate_rf(m, te.x, te.y)
        print(f"  {name:12s}: recall={e['recall']:.3f} F1={e['f1']:.3f}")


if __name__ == "__main__":
    main()
