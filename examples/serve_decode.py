"""Serving example: batched prefill + decode across architecture families
(dense KV cache, SSM O(1) state, hybrid both, enc-dec cross-attention).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import serve

for arch in ["phi3_mini", "mamba2_13b", "hymba_15b", "whisper_medium"]:
    print(f"--- {arch} ---")
    gen = serve(arch, smoke=True, batch=2, prompt_len=16, gen_len=12)
    print(f"  generated: {gen[0].tolist()}\n")
