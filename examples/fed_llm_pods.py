"""Federated LM training across pods (hospitals) — the paper's protocols
applied to the assigned architectures.

Compares aggregation regimes on non-IID pod data:
  dense FedAvg | top-k update-subset (Theorem-1 analog) | int8 stochastic
  rounding | top-k + sampler sync (fed-SMOTE analog: pods share
  domain-mixture statistics) — plus any server strategy from the
  registry via --strategy (fedavg, fedavg_weighted, fedprox, fedavgm,
  fedadam).

Run:  PYTHONPATH=src python examples/fed_llm_pods.py [--arch qwen3_4b]
"""
import argparse

from repro.core.strategies import STRATEGIES
from repro.launch.fed_train import simulate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--pods", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--local-steps", type=int, default=6)
    ap.add_argument("--strategy", default="fedavg",
                    choices=sorted(STRATEGIES))
    args = ap.parse_args()

    common = dict(n_pods=args.pods, rounds=args.rounds,
                  local_steps=args.local_steps, batch=2, seq=128,
                  non_iid_alpha=0.3, verbose=False, seed=0,
                  strategy=args.strategy)

    print(f"=== {args.arch} (reduced), {args.pods} pods, "
          f"{args.rounds} rounds x {args.local_steps} local steps ===\n")
    dense = simulate(args.arch, **common)
    print(f"dense FedAvg      : loss {dense['loss_history'][0]:.3f} -> "
          f"{dense['loss_history'][-1]:.3f}, "
          f"uplink {dense['uplink_mb']:.2f} MB")
    topk = simulate(args.arch, compression="topk", rho=0.05, **common)
    print(f"top-k rho=0.05    : loss {topk['loss_history'][0]:.3f} -> "
          f"{topk['loss_history'][-1]:.3f}, "
          f"uplink {topk['uplink_mb']:.2f} MB "
          f"({dense['uplink_mb']/topk['uplink_mb']:.1f}x less)")
    q8 = simulate(args.arch, compression="int8_sr", **common)
    print(f"int8 stoch. round : loss {q8['loss_history'][0]:.3f} -> "
          f"{q8['loss_history'][-1]:.3f}, "
          f"uplink {q8['uplink_mb']:.2f} MB "
          f"({dense['uplink_mb']/q8['uplink_mb']:.1f}x less)")
    synced = simulate(args.arch, compression="topk", rho=0.05,
                      sync_sampler=True, **common)
    print(f"top-k + sync      : loss {synced['loss_history'][0]:.3f} -> "
          f"{synced['loss_history'][-1]:.3f} "
          f"(sampler-sync = fed-SMOTE analog)")
    print("\nTheorem-1 generalization: structured update subsets cut "
          "federation bandwidth ~rho x with bounded loss drift.")


if __name__ == "__main__":
    main()
