"""Quickstart: the three layers of the framework in ~60 lines.

  1. The paper's core — federated CVD prediction on the Framingham twin
     (tree-subset sampling + federated SMOTE).
  2. The substrate — train a reduced assigned architecture for a few steps.
  3. The scale-out — pods-as-clients federated LM round with update-subset
     compression (Theorem 1, generalized).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import tree_subset as TS
from repro.core.metrics import binary_metrics
from repro.data import framingham as F
from repro.launch.fed_train import simulate
from repro.launch.train import train

# ---- 1. FedCVD++ core: federated Random Forest with tree-subset sampling --
print("=== 1. Federated RF on the Framingham twin ===")
ds = F.synthesize()                       # 4,238 records, 15.2% CHD+
tr, te = F.train_test_split(ds)
clients = [(c.x, c.y) for c in F.partition_clients(tr, n_clients=3)]

full = TS.FedForestConfig(trees_per_client=50, subset=50, sampling="smote")
sub = TS.FedForestConfig(trees_per_client=50, subset=15, sampling="smote")
m_full, comm_full, _ = TS.train_federated_rf(clients, full)
m_sub, comm_sub, _ = TS.train_federated_rf(clients, sub)
f_full = TS.evaluate_rf(m_full, te.x, te.y)
f_sub = TS.evaluate_rf(m_sub, te.x, te.y)
print(f"  dense ship : F1={f_full['f1']:.3f} "
      f"uplink={comm_full.uplink_mb():.2f} MB")
print(f"  tree-subset: F1={f_sub['f1']:.3f} "
      f"uplink={comm_sub.uplink_mb():.2f} MB "
      f"({100*(1-comm_sub.uplink_mb()/comm_full.uplink_mb()):.0f}% less)")

# ---- 2. Substrate: train a reduced assigned arch -------------------------
print("\n=== 2. Train reduced qwen3-4b for 40 steps ===")
params, losses = train("qwen3_4b", smoke=True, steps=40, batch=4, seq=64,
                       lr=2e-3, log_every=20)

# ---- 3. Scale-out: federated LM pods with top-k update compression -------
print("\n=== 3. Two federated pods, top-k compressed rounds ===")
out = simulate("phi3_mini", n_pods=2, rounds=3, local_steps=3, batch=2,
               seq=64, compression="topk", rho=0.05, verbose=True)
print(f"  uplink with rho=0.05 top-k: {out['uplink_mb']:.2f} MB")
print("\nquickstart complete.")
