"""Optimizers, FedProx, schedules, checkpoint roundtrip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.optim import adam, adamw, cosine_schedule, fedprox_grad, sgd


def _quadratic_converges(opt, lr, steps=150):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state = opt.update(grads, state, params, lr)
    return float(jnp.max(jnp.abs(params["w"] - target)))


def test_optimizers_converge_on_quadratic():
    assert _quadratic_converges(sgd(), 0.1) < 1e-3
    assert _quadratic_converges(sgd(momentum=0.9), 0.02) < 1e-3
    assert _quadratic_converges(adam(), 0.1) < 1e-2
    assert _quadratic_converges(adamw(weight_decay=0.0), 0.1) < 1e-2


def test_fedprox_pulls_towards_global():
    params = {"w": jnp.asarray([2.0])}
    glob = {"w": jnp.asarray([0.0])}
    g0 = {"w": jnp.asarray([0.0])}
    g = fedprox_grad(g0, params, glob, mu=0.5)
    assert float(g["w"][0]) == 1.0  # mu * (theta - theta_g)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 100, warmup=10)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(10)), 1.0, atol=0.02)
    assert float(lr(100)) < 0.01
    assert float(lr(55)) > float(lr(90))


def test_checkpoint_roundtrip_and_validation():
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32),
                  "d": jnp.asarray(2.5, jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.msgpack.zst")
        nb = save_pytree(path, tree)
        assert nb > 0
        out = load_pytree(path, tree)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        # shape-mismatch template rejected
        bad = {"a": jnp.zeros((4, 3)), "b": tree["b"]}
        try:
            load_pytree(path, bad)
            raise AssertionError("expected shape mismatch")
        except ValueError:
            pass
