"""Randomized kernel oracle grid: for every kernel family the three
routes a caller can take — the Pallas kernel in interpret mode, the
pure-jnp reference, and the jitted XLA path — must agree on random
inputs.  The fast tier runs a small seeded sample per family; the
exhaustive grid is tier 2 (``slow``).

This complements tests/test_kernels.py (hand-picked shapes per kernel)
with one uniform randomized contract: ``pallas_interpret == ref ==
jit(xla)`` within per-family tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import cases, choice, for_cases, ints

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.forest_infer.fused import (fused_forest_score_pallas,
                                              fused_forest_score_ref)
from repro.kernels.forest_infer.kernel import forest_infer_pallas
from repro.kernels.forest_infer.ref import forest_infer_ref
from repro.kernels.hist.kernel import hist_pallas
from repro.kernels.hist.ref import hist_ref
from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_ref
from repro.models.attention import chunked_attention
from repro.models.ssm import ssd_chunked
from repro.trees.growth import Tree

RNG = jax.random.PRNGKey(7)


def _agree(interp, ref, xla, atol, label):
    """The oracle contract: all three routes within atol of the ref."""
    interp, ref, xla = (np.asarray(v, np.float32)
                        for v in (interp, ref, xla))
    np.testing.assert_allclose(interp, ref, atol=atol, rtol=0,
                               err_msg=f"{label}: interpret vs ref")
    np.testing.assert_allclose(xla, ref, atol=atol, rtol=0,
                               err_msg=f"{label}: jit(xla) vs ref")


def _forest(key, T, depth, F):
    """Random dense-heap forest with valid routing; feature -1 marks
    no-split nodes so dead-branch handling is exercised too."""
    n_int = 2 ** depth - 1
    ks = [jax.random.fold_in(key, i) for i in range(3)]
    return Tree(
        feature=jax.random.randint(ks[0], (T, n_int), -1, F),
        threshold=jax.random.normal(ks[1], (T, n_int)),
        leaf=jax.random.normal(ks[2], (T, n_int + 1)),
        gain=jnp.zeros((T, F)))


# --- hist ---------------------------------------------------------------------

HIST_CASES = cases(8, seed=11, n=ints(33, 2500), F=ints(1, 20),
                   nb=choice(16, 32, 64), block_n=choice(128, 256, 1024),
                   block_f=choice(2, 4, 8))


@pytest.mark.slow
@for_cases(HIST_CASES)
def test_hist_oracle(n, F, nb, block_n, block_f):
    key = jax.random.fold_in(RNG, n)
    ks = [jax.random.fold_in(key, i) for i in range(3)]
    bins = jax.random.randint(ks[0], (n, F), 0, nb)
    g = jax.random.normal(ks[1], (n,))
    h = jax.random.uniform(ks[2], (n,))
    ref = hist_ref(bins, g, h, nb)
    interp = hist_pallas(bins, g, h, nb, block_n=block_n,
                         block_f=block_f, interpret=True)
    xla = jax.jit(lambda b, gg, hh: hist_ref(b, gg, hh, nb))(bins, g, h)
    _agree(interp, ref, xla, 2e-4, f"hist n={n} F={F}")


@for_cases(HIST_CASES[:2])
def test_hist_oracle_fast(n, F, nb, block_n, block_f):
    test_hist_oracle.body(n, F, nb, block_n, block_f)


# --- forest_infer -------------------------------------------------------------

FOREST_CASES = cases(8, seed=13, T=ints(1, 24), depth=ints(1, 6),
                     n=ints(5, 700), F=ints(2, 16),
                     block_n=choice(64, 128, 256))


@pytest.mark.slow
@for_cases(FOREST_CASES)
def test_forest_infer_oracle(T, depth, n, F, block_n):
    forest = _forest(jax.random.fold_in(RNG, T * 1000 + n), T, depth, F)
    x = jax.random.normal(jax.random.fold_in(RNG, n), (n, F))
    ref = forest_infer_ref(forest.feature, forest.threshold, forest.leaf,
                           x)
    interp = forest_infer_pallas(forest.feature, forest.threshold,
                                 forest.leaf, x, block_n=block_n,
                                 interpret=True)
    xla = jax.jit(lambda q: forest_infer_ref(
        forest.feature, forest.threshold, forest.leaf, q))(x)
    # traversal picks one leaf per (tree, row): comparisons + one-hot
    # contractions are exact, so the three routes agree bit-for-bit
    _agree(interp, ref, xla, 0.0, f"forest T={T} d={depth} n={n}")


@for_cases(FOREST_CASES[:2])
def test_forest_infer_oracle_fast(T, depth, n, F, block_n):
    test_forest_infer_oracle.body(T, depth, n, F, block_n)


# --- fused forest scoring -----------------------------------------------------

FUSED_CASES = cases(8, seed=17, T=ints(2, 24), depth=ints(1, 5),
                    n=ints(5, 600), F=ints(2, 12),
                    mode=choice("vote", "margin"),
                    platt=choice(None, (1.5, -0.3)))


@pytest.mark.slow
@for_cases(FUSED_CASES)
def test_fused_forest_score_oracle(T, depth, n, F, mode, platt):
    forest = _forest(jax.random.fold_in(RNG, T * 31 + depth), T, depth, F)
    x = jax.random.normal(jax.random.fold_in(RNG, n + 1), (n, F))
    kw = dict(mode=mode, lr=0.3, base=-0.1, platt=platt)
    ref = fused_forest_score_ref(forest.feature, forest.threshold,
                                 forest.leaf, x, **kw)
    interp = fused_forest_score_pallas(forest.feature, forest.threshold,
                                       forest.leaf, x, block_n=128,
                                       interpret=True, **kw)
    xla = jax.jit(lambda q: fused_forest_score_ref(
        forest.feature, forest.threshold, forest.leaf, q, **kw))(x)
    # documented fused tolerance (kernels/forest_infer/fused.py): counts
    # are exact but the final division / tree-sequential sum can differ
    # from XLA's pairwise reduction by ~1 ulp on probabilities
    _agree(interp, ref, xla, 1e-6, f"fused {mode} T={T} n={n}")
    assert interp.shape == (n,)


@for_cases(FUSED_CASES[:3])
def test_fused_forest_score_oracle_fast(T, depth, n, F, mode, platt):
    test_fused_forest_score_oracle.body(T, depth, n, F, mode, platt)


# --- flash attention ----------------------------------------------------------

ATTN_CASES = cases(6, seed=19, B=ints(1, 2), T=choice(32, 64, 96),
                   H=choice(1, 2, 4), dh=choice(16, 32),
                   causal=choice(True, False))


@pytest.mark.slow
@for_cases(ATTN_CASES)
def test_attention_oracle(B, T, H, dh, causal):
    ks = [jax.random.fold_in(RNG, 100 + i) for i in range(3)]
    q = jax.random.normal(ks[0], (B, T, H, dh))
    k = jax.random.normal(ks[1], (B, T, H, dh))
    v = jax.random.normal(ks[2], (B, T, H, dh))
    ref = attention_ref(q, k, v, causal=causal)
    interp = flash_attention(q, k, v, causal=causal, block_q=32,
                             block_kv=32, interpret=True)
    xla = jax.jit(lambda a, b, c: chunked_attention(
        a, b, c, causal=causal, kv_chunk=32))(q, k, v)
    _agree(interp, ref, xla, 1e-5, f"attention T={T} causal={causal}")


@for_cases(ATTN_CASES[:2])
def test_attention_oracle_fast(B, T, H, dh, causal):
    test_attention_oracle.body(B, T, H, dh, causal)


# --- ssd ----------------------------------------------------------------------

SSD_CASES = cases(5, seed=23, B=ints(1, 2), T=choice(32, 64),
                  H=choice(2, 4), P=choice(16, 32), N=choice(8, 16),
                  Q=choice(16, 32))


@pytest.mark.slow
@for_cases(SSD_CASES)
def test_ssd_oracle(B, T, H, P, N, Q):
    ks = [jax.random.fold_in(RNG, 200 + i) for i in range(5)]
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.5
    b = jax.random.normal(ks[3], (B, T, 1, N)) * 0.3
    c = jax.random.normal(ks[4], (B, T, 1, N)) * 0.3
    y_ref, s_ref = ssd_ref(x, dt, a_log, b, c, Q)
    y_int, s_int = ssd_pallas(x, dt, a_log, b, c, Q, interpret=True)
    y_xla, s_xla = jax.jit(lambda *a: ssd_chunked(*a, Q))(x, dt, a_log,
                                                          b, c)
    scale = float(jnp.max(jnp.abs(y_ref))) + 1e-6
    _agree(y_int / scale, y_ref / scale, y_xla / scale, 1e-4,
           f"ssd T={T} N={N}")
    _agree(s_int, s_ref, s_xla, 1e-3, f"ssd state T={T} N={N}")


@for_cases(SSD_CASES[:1])
def test_ssd_oracle_fast(B, T, H, P, N, Q):
    test_ssd_oracle.body(B, T, H, P, N, Q)
