"""Sharded federated engine (``repro.core.runtime.ShardedFedRuntime``):
parity against the per-client engine at the documented tolerance,
hierarchical-silo == flat-mean invariance, per-tier ledger math from
metadata only (no device-to-host gather on the hot path), and the
``fed_train`` CLI plumbing.  The real 8-device mesh runs in a
subprocess (tier 2), mirroring tests/test_multidevice.py."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import parametric as P
from repro.core.comm import CommLog, get_transport, pytree_bytes
from repro.core.runtime import ShardedFedRuntime
from repro.data import cohort as C

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _max_dev(a, b):
    return max(float(np.max(np.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _cfg(**kw):
    base = dict(model="logreg", rounds=4, local_steps=6, lr=0.05)
    base.update(kw)
    return P.FedParametricConfig(**base)


# --- parity -----------------------------------------------------------------

def test_sharded_matches_per_client_engine():
    """Null-mesh flat sharded run == the per-client python-loop engine
    within PARITY_ATOL (same clients, same rounds, same strategy)."""
    xs, ys = C.build_cohort("framingham_like:12:32", seed=0)
    cfg = _cfg()
    p_sh, comm_sh, _, _ = P.train_federated_sharded((xs, ys), cfg)
    clients = [(xs[i], ys[i]) for i in range(len(xs))]
    p_loop, comm_loop, _, _ = P.train_federated(clients, cfg)
    assert _max_dev(p_sh, p_loop) <= ShardedFedRuntime.PARITY_ATOL
    # same bytes per round too: flat star == the per-client ledger sum
    assert comm_sh.total_bytes("up") == comm_loop.total_bytes("up")


def test_silo_tree_matches_flat_mean():
    """Hierarchical silo aggregation == flat mean under equal shards,
    for every silo count dividing n_clients."""
    xs, ys = C.build_cohort("framingham_like:24:16", seed=1)
    cfg = _cfg(rounds=3)
    ref, *_ = P.train_federated_sharded((xs, ys), cfg, silos=1)
    for silos in (2, 4, 8, 24):
        got, *_ = P.train_federated_sharded((xs, ys), cfg, silos=silos)
        assert _max_dev(got, ref) <= ShardedFedRuntime.PARITY_ATOL, silos


def test_server_strategy_state_inside_jit():
    """A stateful server optimizer (fedadam) runs inside the jitted
    round and still matches the per-client engine."""
    xs, ys = C.build_cohort("framingham_like:8:16", seed=2)
    cfg = _cfg(strategy="fedadam", rounds=3)
    p_sh, *_ = P.train_federated_sharded((xs, ys), cfg, silos=4)
    clients = [(xs[i], ys[i]) for i in range(len(xs))]
    p_loop, *_ = P.train_federated(clients, cfg)
    assert _max_dev(p_sh, p_loop) <= 1e-5  # adam eps amplifies slightly


def test_eval_history_and_cohort_spec_input():
    params, comm, hist, timer = P.train_federated_sharded(
        "framingham_like:16:16", _cfg(rounds=2),
        test=C.cohort_testset(0, 256))
    assert len(hist) == 2 and {"f1", "round"} <= set(hist[0])
    assert timer.total_s > 0


# --- tiered ledger ----------------------------------------------------------

def test_tier_bytes_math():
    """edge carries n_clients payloads, wan carries n_silos partials;
    both directions, exact byte counts from shape metadata."""
    n, silos, rounds = 16, 4, 3
    xs, ys = C.build_cohort(f"framingham_like:{n}:8", seed=0)
    cfg = _cfg(rounds=rounds)
    _, comm, _, _ = P.train_federated_sharded((xs, ys), cfg, silos=silos)
    import repro.models.tabular as tabular
    params = tabular.MODELS["logreg"]["init"](jax.random.PRNGKey(0),
                                              xs.shape[-1])
    pb = pytree_bytes(params) + get_transport("plain").frame_overhead
    up = comm.per_tier_bytes("up")
    down = comm.per_tier_bytes("down")
    assert up == {"edge": rounds * n * pb, "wan": rounds * silos * pb}
    assert down == {"edge": rounds * n * pb, "wan": rounds * silos * pb}


def test_flat_star_is_all_wan():
    xs, ys = C.build_cohort("framingham_like:8:8", seed=0)
    _, comm, _, _ = P.train_federated_sharded((xs, ys), _cfg(rounds=2))
    assert set(comm.per_tier_bytes("up")) == {"wan"}


def test_untiered_events_report_as_star():
    log = CommLog()
    log.log(0, "c0", "up", 100, "update")
    log.log(0, "c0", "up", 50, "update", tier="edge")
    assert log.per_tier_bytes("up") == {"star": 100, "edge": 50}
    # legacy event dicts are unchanged by the tier extension
    assert "tier" not in log.events[0] and log.events[1]["tier"] == "edge"


def test_tier_plan_is_metadata_only(monkeypatch):
    """The ledger plan must never gather device data to host: it works
    on purely abstract ShapeDtypeStructs, and a full run never calls
    jax.device_get."""
    rt = ShardedFedRuntime(n_clients=8, rounds=1, n_silos=4)
    local_fn = P.build_local_delta("logreg", 2, 0.05)
    import repro.models.tabular as tabular
    params = tabular.MODELS["logreg"]["init"](jax.random.PRNGKey(0), 15)
    axs = jax.ShapeDtypeStruct((8, 4, 15), np.float32)
    ays = jax.ShapeDtypeStruct((8, 4), np.float32)
    plan = rt._tier_plan(local_fn, params, axs, ays)   # no real arrays
    assert len(plan) == 4 and {e[4] for e in plan} == {"edge", "wan"}

    def boom(*a, **k):
        raise AssertionError("device_get on the sharded hot path")
    monkeypatch.setattr(jax, "device_get", boom)
    xs, ys = C.build_cohort("framingham_like:8:4", seed=0)
    rt2 = ShardedFedRuntime(n_clients=8, rounds=2, n_silos=4)
    rt2.run(local_fn, params, xs, ys)
    assert len(rt2.comm.events) == 8  # 4 tier events x 2 rounds


# --- validation -------------------------------------------------------------

def test_silos_must_divide_clients():
    with pytest.raises(ValueError, match="divide"):
        ShardedFedRuntime(n_clients=10, rounds=1, n_silos=3)


def test_float_transports_rejected():
    with pytest.raises(ValueError):
        ShardedFedRuntime(n_clients=4, rounds=1, transport="sparse")
    ShardedFedRuntime(n_clients=4, rounds=1, transport="framed")  # ok


def test_unsupported_axes_rejected():
    xs, ys = C.build_cohort("framingham_like:4:8", seed=0)
    for kw in (dict(sampling="smote"), dict(participation="uniform:2"),
               dict(schedule="async:2")):
        with pytest.raises(ValueError):
            P.train_federated_sharded((xs, ys), _cfg(**kw))


def test_cli_mesh_requires_cohort():
    from repro.launch.fed_train import simulate_parametric
    with pytest.raises(ValueError, match="cohort"):
        simulate_parametric(mesh="host", verbose=False)
    with pytest.raises(ValueError, match="cohort"):
        simulate_parametric(silos=4, verbose=False)


def test_cli_cohort_path():
    from repro.launch.fed_train import simulate_parametric
    out = simulate_parametric(cohort="framingham_like:16:16", silos=4,
                              rounds=2, local_steps=4, verbose=False)
    assert {"edge", "wan"} == set(out["comm"].per_tier_bytes("up"))
    assert 0.0 <= out["metrics"]["f1"] <= 1.0


def test_mesh_spec_registry():
    from repro.launch.mesh import MESHES, get_fed_mesh
    assert {"single", "host"} <= set(MESHES)
    assert get_fed_mesh(None) is None
    assert get_fed_mesh("single") is None
    with pytest.raises(KeyError):
        get_fed_mesh("nope")
    with pytest.raises(ValueError):
        get_fed_mesh("host:999")   # more devices than exist


# --- real 8-device mesh (subprocess, tier 2) --------------------------------

SCRIPT_MESH = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core import parametric as P
from repro.core.runtime import ShardedFedRuntime
from repro.data.cohort import build_cohort
assert jax.device_count() == 8
xs, ys = build_cohort("framingham_like:64:16", seed=0)
cfg = P.FedParametricConfig(model="logreg", rounds=3, local_steps=5,
                            lr=0.05)
pm, comm, _, _ = P.train_federated_sharded((xs, ys), cfg, mesh="host",
                                           silos=8)
pn, *_ = P.train_federated_sharded((xs, ys), cfg, mesh=None, silos=8)
d = max(float(np.max(np.abs(a - b)))
        for a, b in zip(jax.tree.leaves(pm), jax.tree.leaves(pn)))
assert d <= ShardedFedRuntime.PARITY_ATOL, d
assert set(comm.per_tier_bytes("up")) == {"edge", "wan"}
print("MESH-OK")
"""


@pytest.mark.slow
def test_mesh_parity_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT_MESH], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH-OK" in out.stdout
