"""HLO analysis: collective parsing, byte accounting, roofline terms."""
import numpy as np

from repro.launch.hlo_analysis import (CollectiveStats, fused_memory_bytes,
                                       parse_collectives, roofline_terms)

HLO = """
HloModule jit_step

%fused_computation {
  %param_0 = f32[128,256]{1,0} parameter(0)
  ROOT %m = f32[128,256]{1,0} multiply(%param_0, %param_0)
}

ENTRY %main (p0: f32[128,256], p1: bf16[64]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = bf16[64]{0} parameter(1)
  %ag = f32[128,1024]{1,0} all-gather(%p0), replica_groups=[32,4]<=[128], dimensions={1}
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[32,256]{1,0} reduce-scatter(%p0), replica_groups=[2,4]<=[8], dimensions={0}
  %a2a = bf16[64]{0} all-to-all(%p1), replica_groups=[8,2]<=[16]
  %cp = bf16[64]{0} collective-permute(%p1), source_target_pairs={{0,1}}
  %dot.1 = f32[128,128]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  ROOT %out = f32[128,256]{1,0} multiply(%p0, %p0)
}
"""


def test_parse_collectives_kinds_and_bytes():
    st = parse_collectives(HLO)
    ag = 128 * 1024 * 4
    ar = 128 * 256 * 4
    rs = 32 * 256 * 4
    a2a = 64 * 2
    cp = 64 * 2
    assert st.bytes_by_kind["all-gather"] == ag
    assert st.bytes_by_kind["all-reduce"] == ar
    assert st.bytes_by_kind["reduce-scatter"] == rs
    assert st.bytes_by_kind["all-to-all"] == a2a
    assert st.bytes_by_kind["collective-permute"] == cp
    # ring model: ar x2, rs x(group-1)=3, others x1
    assert st.wire_bytes == ag + 2 * ar + 3 * rs + a2a + cp
    assert st.count_by_kind["all-reduce"] == 1


def test_async_pairs_counted_once():
    txt = """ENTRY %e {
  %s = f32[16]{0} all-gather-start(%x), replica_groups=[2,2]<=[4]
  %d = f32[16]{0} all-gather-done(%s)
}"""
    st = parse_collectives(txt)
    assert st.count_by_kind.get("all-gather", 0) == 1


def test_fused_memory_counts_entry_params_once():
    b = fused_memory_bytes(HLO)
    # entry params (once, even though the fusion re-declares parameter 0)
    p = 128 * 256 * 4 + 64 * 2
    root = 128 * 256 * 4
    dot = 128 * 128 * 4 + 2 * (128 * 256 * 4)
    colls = (128 * 1024 * 4 + 128 * 256 * 4 + 32 * 256 * 4 + 128 + 128)
    assert b == p + root + dot + colls


def test_roofline_terms_dominance():
    t = roofline_terms(1e15, 1e12, 1e11, peak_flops=197e12, hbm_bw=819e9,
                       ici_bw=50e9, fused_bytes=5e11)
    assert t["dominant"] == "compute_s"
    np.testing.assert_allclose(t["compute_s"], 1e15 / 197e12)
    np.testing.assert_allclose(t["memory_fused_s"], 5e11 / 819e9)
    t2 = roofline_terms(1e12, 1e12, 1e13, peak_flops=197e12, hbm_bw=819e9,
                        ici_bw=50e9)
    assert t2["dominant"] == "collective_s"
    assert t2["collective_s_1link"] == 4 * t2["collective_s"]
