"""Histogram-aggregation federated tree engine: federated-binning merge,
fed_hist ≡ centralized GBDT over shared bins, client-batched histogram
and tree-engine parity, privacy hooks, ledger accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fed_hist as FH
from repro.core import feature_extract as FE
from repro.core import tree_subset as TS
from repro.core.comm import CommLog
from repro.data import framingham as F
from repro.kernels.hist.ops import gradient_histogram
from repro.trees import binning, gbdt
from repro.trees.growth import fed_hist_bytes, grow_tree, grow_tree_fed

RNG = np.random.default_rng(7)


def _clients(n=700, k=3, alpha=0.5, seed=0):
    """Uneven (non-IID) client shards + a test split."""
    ds = F.synthesize(n=n, seed=seed)
    tr, te = F.train_test_split(ds)
    cs = [(c.x, c.y) for c in F.partition_clients(tr, k, alpha=alpha)]
    return cs, te


# --- federated binning --------------------------------------------------------

def test_merged_edges_match_centralized_quantiles():
    """Server-merged sketch edges ≈ centralized quantiles of the union."""
    xs = [RNG.normal(size=(n, 5)).astype(np.float32) * s + m
          for n, s, m in [(900, 1.0, 0.0), (1400, 2.0, 1.0),
                          (300, 0.5, -2.0)]]
    edges = binning.fed_fit_bins(xs, 32, sketch_size=512)
    cen = binning.fit_bins(jnp.asarray(np.concatenate(xs)), 32)
    sd = float(np.concatenate(xs).std())
    assert float(jnp.max(jnp.abs(edges - cen))) < 0.05 * sd
    # edges ascending per feature
    assert float(jnp.min(jnp.diff(edges, axis=1))) >= 0.0


def test_merge_is_count_weighted():
    """A 10x larger client must dominate the merged quantiles."""
    big = RNG.normal(size=(2000, 3)).astype(np.float32)
    small = (RNG.normal(size=(200, 3)) + 50).astype(np.float32)
    edges = binning.merge_sketches(
        [binning.quantile_sketch(jnp.asarray(big), 256),
         binning.quantile_sketch(jnp.asarray(small), 256)], 16)
    # ~91% of mass is the big client: the median edge sits near its data
    med = float(edges[0, 7])
    assert med < 5.0, med


def test_fed_fit_bins_logs_sketch_and_edge_bytes():
    comm = CommLog()
    xs = [RNG.normal(size=(n, 4)).astype(np.float32) for n in (100, 300)]
    edges = binning.fed_fit_bins(xs, 16, sketch_size=64, comm=comm)
    per = comm.per_what_bytes()
    assert per["quantile-sketch"] == 2 * (4 * 64 * 4 + 4)
    assert per["shared-edges"] == 2 * edges.size * 4
    assert comm.total_bytes("up") == per["quantile-sketch"]


# --- client-batched histogram kernel -----------------------------------------

def test_batched_hist_matches_per_client_loop():
    """(C, n, F) input ≡ per-client loop, on both impl routes."""
    bins = jnp.asarray(RNG.integers(0, 16, size=(3, 257, 5)), jnp.int32)
    g = jnp.asarray(RNG.normal(size=(3, 257)), jnp.float32)
    h = jnp.asarray(RNG.uniform(0.1, 1, size=(3, 257)), jnp.float32)
    for impl in ("xla", "pallas_interpret"):
        batched = gradient_histogram(bins, g, h, 16, impl=impl)
        loop = jnp.stack([gradient_histogram(bins[c], g[c], h[c], 16,
                                             impl=impl)
                          for c in range(3)])
        assert batched.shape == (3, 5, 16, 2)
        np.testing.assert_allclose(np.asarray(batched), np.asarray(loop),
                                   atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(gradient_histogram(bins, g, h, 16, impl="xla")),
        np.asarray(gradient_histogram(bins, g, h, 16,
                                      impl="pallas_interpret")),
        atol=1e-4)


# --- federated growth ≡ centralized growth -----------------------------------

def test_grow_tree_fed_equals_centralized_on_union():
    sizes = [160, 100, 130]
    xs = [jnp.asarray(RNG.normal(size=(n, 6)), jnp.float32)
          for n in sizes]
    ys = [jnp.asarray((RNG.random(n) > 0.7).astype(np.float32))
          for n in sizes]
    edges = binning.fed_fit_bins(xs, 16, sketch_size=512)
    ux, uy = jnp.concatenate(xs), jnp.concatenate(ys)
    p = jnp.full_like(uy, 0.5)
    cen = grow_tree(binning.apply_bins(ux, edges), edges, p - uy,
                    p * (1 - p), jnp.ones_like(uy), depth=4, n_bins=16)
    n_max = max(sizes)
    pad = lambda a: jnp.pad(a, [(0, n_max - a.shape[0])]
                            + [(0, 0)] * (a.ndim - 1))
    bins_c = jnp.stack([pad(binning.apply_bins(x, edges)) for x in xs])
    y_c = jnp.stack([pad(y) for y in ys])
    w_c = jnp.stack([pad(jnp.ones(n, jnp.float32)) for n in sizes])
    pc = jnp.full(y_c.shape, 0.5)
    for batch in (True, False):
        fed = grow_tree_fed(bins_c, edges, pc - y_c, pc * (1 - pc), w_c,
                            depth=4, n_bins=16, batch_clients=batch)
        np.testing.assert_array_equal(np.asarray(fed.feature),
                                      np.asarray(cen.feature))
        np.testing.assert_allclose(np.asarray(fed.threshold),
                                   np.asarray(cen.threshold), atol=1e-6)
        np.testing.assert_allclose(np.asarray(fed.leaf),
                                   np.asarray(cen.leaf), atol=1e-5)


def test_fed_hist_matches_centralized_gbdt_and_ledger():
    """The acceptance bar: fed_hist GBDT ≡ centralized GBDT on the union
    of shards over the same shared bins, with histogram bytes accounted
    in the ledger."""
    R_ = 4  # boosting rounds (tier-1 budget; parity holds per round)
    clients, te = _clients(n=500)
    cfg = FH.FedHistConfig(num_rounds=R_, depth=4, n_bins=32,
                           sketch_size=256, seed=0)
    model, comm, _ = FH.train_federated_xgb_hist(clients, cfg)
    # centralized twin: same shared edges, pooled shards
    ux = np.concatenate([x for x, _ in clients])
    uy = np.concatenate([y for _, y in clients])
    edges = binning.fed_fit_bins([x for x, _ in clients], 32,
                                 sketch_size=256)
    cen = gbdt.fit_binned(jnp.asarray(ux), jnp.asarray(uy),
                          binning.apply_bins(jnp.asarray(ux), edges),
                          edges, jnp.ones(len(uy), jnp.float32),
                          num_rounds=R_, depth=4, n_bins=32)
    mf = np.asarray(gbdt.predict_margin(model, jnp.asarray(te.x)))
    mc = np.asarray(gbdt.predict_margin(cen, jnp.asarray(te.x)))
    np.testing.assert_allclose(mf, mc, atol=1e-3)
    f1_fed = FH.evaluate_fed_hist(model, te.x, te.y)["f1"]
    f1_cen = FH.evaluate_fed_hist(cen, te.x, te.y)["f1"]
    assert f1_fed == f1_cen
    # ledger: per client per boosting round, exactly the per-level
    # (F, 2^level * n_bins, 2) fp32 histograms
    per_tree = fed_hist_bytes(15, 32, 4)
    hist_events = [e for e in comm.events
                   if e["what"] == "grad-hess-histograms"]
    assert len(hist_events) == len(clients) * R_
    assert all(e["bytes"] == per_tree for e in hist_events)
    assert comm.per_what_bytes()["grad-hess-histograms"] == \
        per_tree * len(clients) * R_
    # sample-count independence: histogram uplink depends on
    # (F, n_bins, depth) only
    assert per_tree == sum(15 * 2 ** lv * 32 * 2 * 4 for lv in range(4))


def test_fed_hist_engines_agree():
    # n=500 avoids a split-gain tie where the two engines' argmax order
    # legitimately diverges (parity is to numerical tolerance)
    clients, te = _clients(n=500)
    outs = {}
    for engine in ("batched", "sequential"):
        cfg = FH.FedHistConfig(num_rounds=2, depth=3, n_bins=16,
                               engine=engine, seed=0)
        model, comm, _ = FH.train_federated_xgb_hist(clients, cfg)
        outs[engine] = (model, comm.total_bytes())
    mb, ms = outs["batched"][0], outs["sequential"][0]
    np.testing.assert_array_equal(np.asarray(mb.forest.feature),
                                  np.asarray(ms.forest.feature))
    np.testing.assert_allclose(np.asarray(mb.forest.leaf),
                               np.asarray(ms.forest.leaf), atol=1e-5)
    assert outs["batched"][1] == outs["sequential"][1]


def test_fed_hist_privacy_hooks():
    """Secure-agg masks cancel in the sum (model ≈ unmasked); DP noise
    actually perturbs the grown trees."""
    clients, te = _clients(n=350)
    base_cfg = FH.FedHistConfig(num_rounds=2, depth=3, n_bins=16, seed=0)
    plain, _, _ = FH.train_federated_xgb_hist(clients, base_cfg)
    sec_cfg = FH.FedHistConfig(num_rounds=2, depth=3, n_bins=16, seed=0,
                               secure_agg=True)
    sec, _, _ = FH.train_federated_xgb_hist(clients, sec_cfg)
    m_plain = np.asarray(gbdt.predict_margin(plain, jnp.asarray(te.x)))
    m_sec = np.asarray(gbdt.predict_margin(sec, jnp.asarray(te.x)))
    np.testing.assert_allclose(m_sec, m_plain, atol=1e-2)
    dp_cfg = FH.FedHistConfig(num_rounds=2, depth=3, n_bins=16, seed=0,
                              dp_epsilon=0.5, dp_sensitivity=1.0)
    dp, _, _ = FH.train_federated_xgb_hist(clients, dp_cfg)
    m_dp = np.asarray(gbdt.predict_margin(dp, jnp.asarray(te.x)))
    assert float(np.max(np.abs(m_dp - m_plain))) > 1e-3


# --- batched client-axis engines for the C2/C3 pipelines ----------------------

def test_rf_engine_batched_matches_sequential():
    """Identical forests and ledger bytes from both engines (uneven,
    resampled shards included)."""
    clients, _ = _clients(n=400)
    out = {}
    for engine in ("sequential", "batched"):
        cfg = TS.FedForestConfig(trees_per_client=4, subset=3, depth=3,
                                 n_bins=16, engine=engine, seed=0,
                                 sampling="ros")
        model, comm, _ = TS.train_federated_rf(clients, cfg)
        out[engine] = (model, comm.total_bytes())
    ms, mb = out["sequential"][0], out["batched"][0]
    np.testing.assert_array_equal(np.asarray(ms.forest.feature),
                                  np.asarray(mb.forest.feature))
    np.testing.assert_allclose(np.asarray(ms.forest.threshold),
                               np.asarray(mb.forest.threshold), atol=1e-6)
    np.testing.assert_allclose(np.asarray(ms.forest.leaf),
                               np.asarray(mb.forest.leaf), atol=1e-5)
    assert out["sequential"][1] == out["batched"][1]


def test_xgb_engine_batched_matches_sequential():
    """Dense fed-XGB and the C3 feature-extraction pipeline: same trees,
    same selected features, same ledger bytes under both engines."""
    clients, te = _clients(n=350)
    res = {}
    for engine in ("sequential", "batched"):
        cfg = FE.FedXGBConfig(num_rounds=2, depth=3, shallow_depth=2,
                              n_bins=16, engine=engine, seed=0)
        dense, comm_d, _ = FE.train_federated_xgb(clients, cfg)
        fe, comm_f, _ = FE.train_federated_xgb_fe(clients, cfg)
        res[engine] = (dense, comm_d.total_bytes(), fe,
                       comm_f.total_bytes())
    ds_, db = res["sequential"][0], res["batched"][0]
    for a, b in zip(ds_.models, db.models):
        np.testing.assert_array_equal(np.asarray(a.forest.feature),
                                      np.asarray(b.forest.feature))
        np.testing.assert_allclose(np.asarray(a.forest.leaf),
                                   np.asarray(b.forest.leaf), atol=1e-5)
        assert abs(a.base_margin - b.base_margin) < 1e-6
    assert res["sequential"][1] == res["batched"][1]
    fs, fb = res["sequential"][2], res["batched"][2]
    assert [t.tolist() for t in fs.top_features] == \
        [t.tolist() for t in fb.top_features]
    for a, b in zip(fs.trees, fb.trees):
        np.testing.assert_array_equal(np.asarray(a.forest.feature),
                                      np.asarray(b.forest.feature))
    assert res["sequential"][3] == res["batched"][3]
    # and both engines predict identically
    np.testing.assert_array_equal(FE.predict_fe(fs, te.x),
                                  FE.predict_fe(fb, te.x))


def test_engine_rejects_unknown_names():
    clients, _ = _clients(n=300)
    import pytest
    with pytest.raises(ValueError):
        TS.train_federated_rf(clients, TS.FedForestConfig(
            trees_per_client=2, subset=2, depth=2, engine="threads"))
    with pytest.raises(ValueError):
        FE.train_federated_xgb(clients, FE.FedXGBConfig(
            num_rounds=1, depth=2, engine="threads"))
    with pytest.raises(ValueError):
        FH.train_federated_xgb_hist(clients, FH.FedHistConfig(
            num_rounds=1, depth=2, engine="threads"))
