"""FedRuntime: exact parity with the pre-runtime pipelines under
iid + full participation + plain transport, partial-participation
ledger semantics, straggler/stale handling, and the layered transport
stack (composition, presets, validation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm as CM
from repro.core import parametric as P
from repro.core import privacy
from repro.core.comm import CommLog, Timer, get_transport, pytree_bytes
from repro.core.metrics import binary_metrics
from repro.core.participation import get_participation
from repro.core.strategies import get_strategy
from repro.data import framingham as F


def _clients(n=500, k=3, seed=1):
    ds = F.synthesize(n=n, seed=seed)
    tr, te = F.train_test_split(ds)
    return [(c.x, c.y) for c in F.partition_clients(tr, k)], (te.x, te.y)


# --- parity: runtime parametric == the pre-runtime round loop -----------------

def _legacy_train(clients, cfg, test=None):
    """The PR-1 parametric round loop, verbatim — the parity oracle."""
    comm = CommLog()
    timer = Timer()
    spec = P.tabular.MODELS[cfg.model]
    strat = get_strategy(cfg.strategy)
    mu = cfg.fedprox_mu if cfg.fedprox_mu > 0 else strat.client_mu
    clients = [(P._prep(cfg.model, x), y) for x, y in clients]
    if test is not None:
        test = (P._prep(cfg.model, test[0]), test[1])
    clients, _ = P._fed_sampling(clients, cfg.sampling, cfg.seed, comm)
    ws = strat.norm_weights([len(y) for _, y in clients])
    rng = jax.random.PRNGKey(cfg.seed)
    gp = spec["init"](rng, clients[0][0].shape[1])
    sst = strat.init_state(gp)
    history = []
    for r in range(cfg.rounds):
        updates = []
        for i, (x, y) in enumerate(clients):
            comm.log(r, f"c{i}", "down", pytree_bytes(gp), "model")
            local = P._local_train(cfg.model, gp, x, y, cfg.local_steps,
                                   cfg.lr, global_params=gp, mu=mu)
            update = jax.tree.map(lambda a, b: a - b, local, gp)
            if cfg.dp_epsilon > 0:
                update, _ = privacy.clip_update(update, cfg.dp_clip)
            if strat.weighted:
                w = ws[i] * len(clients)
                update = jax.tree.map(lambda t: t * w, update)
            if cfg.secure_agg:
                update = privacy.mask_update(update, i, len(clients),
                                             cfg.seed * 7919 + r)
            comm.log(r, f"c{i}", "up", pytree_bytes(update), "update")
            updates.append(update)
        with timer:
            total = privacy.secure_sum(updates)
            mean = jax.tree.map(lambda t: t / len(clients), total)
            if cfg.dp_epsilon > 0:
                mean = privacy.add_dp_noise(mean, cfg.dp_epsilon,
                                            cfg.dp_delta,
                                            cfg.dp_clip * max(ws),
                                            cfg.seed * 31 + r)
            mean, sst = strat.server_update(sst, mean)
            gp = jax.tree.map(lambda g, u: g + u, gp, mean)
        if test is not None:
            xt = jnp.asarray(test[0])
            pred = np.asarray(spec["predict"](gp, xt))
            history.append(binary_metrics(
                pred, test[1], scores=np.asarray(spec["proba"](gp, xt))))
    return gp, comm, history


@pytest.mark.parametrize("kw", [
    dict(),
    dict(strategy="fedavg_weighted", sampling="ros"),
    dict(secure_agg=True, dp_epsilon=0.5, dp_clip=2.0),
    dict(strategy="fedadam"),
])
def test_parametric_runtime_matches_legacy_loop(kw):
    """The acceptance bar: under iid + full participation + plain
    transport the runtime path reproduces the pre-refactor losses,
    params, and ledger events bit-for-bit."""
    clients, test = _clients()
    cfg = P.FedParametricConfig(model="logreg", rounds=3, local_steps=6,
                                lr=0.05, **kw)
    p_new, c_new, h_new, _ = P.train_federated(clients, cfg, test=test)
    p_old, c_old, h_old = _legacy_train(clients, cfg, test=test)
    for a, b in zip(jax.tree.leaves(p_new), jax.tree.leaves(p_old)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert c_new.events == c_old.events
    assert h_new == h_old


def test_cfg_flags_equal_explicit_transport_stack():
    """secure_agg/dp_epsilon config flags and the 'secure_dp' transport
    preset must build the same wire pipeline (same masks, same noise)."""
    clients, test = _clients(n=350)
    a = P.FedParametricConfig(model="logreg", rounds=2, local_steps=4,
                              secure_agg=True, dp_epsilon=0.5,
                              dp_clip=2.0)
    b = P.FedParametricConfig(model="logreg", rounds=2, local_steps=4,
                              transport="secure_dp", dp_epsilon=0.5,
                              dp_clip=2.0)
    pa, ca, ha, _ = P.train_federated(clients, a, test=test)
    pb, cb, hb, _ = P.train_federated(clients, b, test=test)
    for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert ca.events == cb.events


# --- partial participation ----------------------------------------------------

def test_uniform_k_cuts_ledger_proportionally():
    clients, test = _clients(k=4)
    full = P.FedParametricConfig(model="logreg", rounds=3, local_steps=5)
    sub = P.FedParametricConfig(model="logreg", rounds=3, local_steps=5,
                                participation="uniform:2")
    _, cf, _, _ = P.train_federated(clients, full)
    _, cs, _, _ = P.train_federated(clients, sub)
    ups_f = [e for e in cf.events if e["direction"] == "up"]
    ups_s = [e for e in cs.events if e["direction"] == "up"]
    assert len(ups_f) == 4 * 3 and len(ups_s) == 2 * 3
    assert cs.total_bytes() == cf.total_bytes() // 2
    # schedule is deterministic in the runtime seed
    _, cs2, _, _ = P.train_federated(clients, sub)
    assert cs.events == cs2.events


def test_stratified_covers_strata():
    sched = get_participation("stratified:2")
    rng = np.random.default_rng(0)
    for r in range(20):
        plan = sched.plan(r, 8, rng)
        assert len(plan.arrive) == 2
        # one from each contiguous half
        assert sum(1 for i in plan.arrive if i < 4) == 1


def test_dropout_stragglers_deliver_stale():
    """With p_straggle=1 every dropped client computes and delivers next
    round: no update is lost, and stateful strategies stay finite."""
    clients, test = _clients(k=3)
    cfg = P.FedParametricConfig(model="logreg", rounds=4, local_steps=4,
                                strategy="fedavgm",
                                participation="dropout:0.5:1.0")
    params, comm, hist, _ = P.train_federated(clients, cfg, test=test)
    for leaf in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(leaf)).all()
    # every computed update was shipped (logged) exactly once
    ups = [e for e in comm.events if e["direction"] == "up"]
    assert len(ups) >= 4  # at least one client per round


def test_participation_registry_errors():
    with pytest.raises(KeyError):
        get_participation("sometimes")
    with pytest.raises(ValueError):
        get_participation("full:3")  # full takes no args


def test_stale_payloads_are_discounted():
    """A straggler's update must reach the aggregator scaled by
    stale_discount ** staleness, for any aggregator normalization."""
    from repro.core.runtime import (ClientMsg, ClientWork, FedRuntime,
                                    ServerAgg)
    from repro.core.participation import Participation, RoundPlan

    # deterministic schedule: round 0 everybody straggles except c0,
    # round 1 everybody arrives
    sched = Participation("test", lambda r, n, rng: (
        RoundPlan([0], [1]) if r == 0 else RoundPlan([0, 1], [])),
        may_straggle=True)

    seen = []

    class W(ClientWork, ServerAgg):
        def setup(self, rt):
            return {}

        def client_round(self, rt, state, rnd):
            return [ClientMsg(i, {"u": jnp.ones(2)}, 8)
                    for i in rnd.computing]

        def aggregate(self, rt, state, msgs, rnd):
            seen.append({m.client: float(m.payload["u"][0])
                         for m in msgs})
            return state

    rt = FedRuntime(n_clients=2, rounds=2, participation=sched,
                    stale_discount=0.5)
    rt.run(W())
    assert seen[0] == {0: 1.0}                 # straggler absent
    assert seen[1] == {0: 1.0, 1: 0.5}         # delivered stale, halved


def test_mask_transport_survives_straggling_schedule():
    """Secure-agg masks now compose with straggling schedules: the
    runtime reconstructs absent cohort members' pair seeds from the
    Shamir share book and subtracts their mask terms, so straggler-
    buffered rounds stay finite (tests/test_privacy.py proves the
    masked sums equal the plain sums)."""
    clients, _ = _clients(k=3)
    cfg = P.FedParametricConfig(model="logreg", rounds=3, local_steps=3,
                                secure_agg=True,
                                participation="dropout:0.3:0.5", seed=1)
    params, comm, _, _ = P.train_federated(clients, cfg)
    for leaf in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(leaf)).all()
    # lost-straggler dropout (p_straggle=0) still composes with masks
    cfg_ok = P.FedParametricConfig(model="logreg", rounds=2,
                                   local_steps=3, secure_agg=True,
                                   participation="dropout:0.3")
    params, _, _, _ = P.train_federated(clients, cfg_ok)
    for leaf in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_one_shot_survives_all_straggler_round():
    """allow_stale=False pipelines treat stragglers as drops but must
    keep the round alive: dropout:1:1 schedules everyone as a straggler,
    yet the one-shot RF still trains on a promoted client."""
    from repro.core import tree_subset as TS
    clients, test = _clients(k=3)
    cfg = TS.FedForestConfig(trees_per_client=3, subset=2, depth=3,
                             n_bins=16, participation="dropout:1.0:1.0",
                             seed=0)
    model, comm, _ = TS.train_federated_rf(clients, cfg)
    assert model is not None
    assert len([e for e in comm.events if e["what"] == "trees"]) == 1
    assert np.isfinite(TS.evaluate_rf(model, test[0], test[1])["f1"])


# --- tree pipelines on the runtime --------------------------------------------

def test_tree_subset_participation_and_framing():
    from repro.core import tree_subset as TS
    clients, test = _clients(n=450, k=4)
    base = dict(trees_per_client=3, subset=2, depth=3, n_bins=16, seed=0)
    m_full, c_full, _ = TS.train_federated_rf(
        clients, TS.FedForestConfig(**base))
    assert len([e for e in c_full.events
                if e["what"] == "trees"]) == 4
    m_sub, c_sub, _ = TS.train_federated_rf(
        clients, TS.FedForestConfig(participation="uniform:2", **base))
    assert len([e for e in c_sub.events if e["what"] == "trees"]) == 2
    assert int(m_sub.forest.feature.shape[0]) == 4  # 2 clients x s=2
    # framing adds exactly the header per logged message
    m_fr, c_fr, _ = TS.train_federated_rf(
        clients, TS.FedForestConfig(transport="framed", **base))
    assert c_fr.total_bytes() == c_full.total_bytes() \
        + 28 * len(c_full.events)
    # float codec layers don't apply to shipped trees
    with pytest.raises(ValueError):
        TS.train_federated_rf(clients, TS.FedForestConfig(
            transport="sparse", **base))


def test_fed_hist_partial_participation_ledger():
    from repro.core import fed_hist as FH
    clients, test = _clients(k=4)
    cfg = FH.FedHistConfig(num_rounds=4, depth=3, n_bins=16,
                           participation="uniform:2", seed=0)
    model, comm, _ = FH.train_federated_xgb_hist(clients, cfg)
    hist_events = [e for e in comm.events
                   if e["what"] == "grad-hess-histograms"]
    assert len(hist_events) == 2 * 4      # k=2 clients x 4 rounds
    # broadcast trees still reach all 4 clients
    tree_events = [e for e in comm.events if e["what"] == "tree"]
    assert len(tree_events) == 4 * 4
    m = FH.evaluate_fed_hist(model, test[0], test[1])
    assert np.isfinite(m["f1"])
    with pytest.raises(ValueError):  # codecs can't wrap in-jit hists
        FH.train_federated_xgb_hist(clients, FH.FedHistConfig(
            num_rounds=1, depth=2, transport="quant"))


# --- transport stack ----------------------------------------------------------

def test_transport_registry_and_validation():
    t = get_transport("full_stack", rho=0.25, dp_clip=1.0)
    assert [l.name for l in t.layers] == ["topk", "clip", "mask",
                                          "dpnoise", "frame"]
    assert t.frame_overhead == 28
    assert get_transport("plain").layers == []
    spec = get_transport("topk>frame", rho=0.1)
    assert [l.name for l in spec.layers] == ["topk", "frame"]
    with pytest.raises(KeyError):
        get_transport("carrier-pigeon")
    with pytest.raises(ValueError):
        get_transport("topk>int8")   # two codecs double-count bytes


def test_transport_encode_bytes_and_codec_state():
    t = get_transport("topk>frame", rho=0.25)
    delta = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(32,)), jnp.float32)}
    msg = t.encode(delta, ctx=CM.WireCtx(round=0, client=0, seed=0))
    k = int(np.ceil(0.25 * 32))
    assert msg.nbytes == k * 8 + 28      # topk values+indices + header
    assert msg.state is not None         # error-feedback residual
    plain = get_transport("plain").encode(delta)
    assert plain.nbytes == pytree_bytes(delta)


@pytest.mark.slow
def test_simulate_transport_and_participation():
    """LM engine: --transport/--participation end to end, and the
    compression knob composes with (but refuses to duplicate) codecs.
    (Tier 2: LM-scale; the ledger-exactness half is CI-gated by
    fed_engine_bench --smoke.)"""
    from repro.launch.fed_train import simulate
    smoke = dict(n_pods=4, rounds=2, local_steps=2, batch=2, seq=32,
                 verbose=False, seed=0)
    out = simulate("qwen3_4b", participation="uniform:2",
                   transport="framed", **smoke)
    ups = [e for e in out["comm"].events if e["direction"] == "up"]
    assert len(ups) == 2 * 2
    n_elems = sum(x.size for x in jax.tree.leaves(out["final_params"]))
    assert all(e["bytes"] == n_elems * 4 + 28 for e in ups)
    with pytest.raises(ValueError):
        simulate("qwen3_4b", compression="topk", transport="sparse",
                 **smoke)
