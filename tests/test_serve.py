"""Serving subsystem: forest-kernel parity, bundle round-trips, the
bucketed engine, Platt calibration, and the new threshold-free metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import cases, for_cases, ints

from repro.core.metrics import binary_metrics, brier_score, roc_auc
from repro.kernels.forest_infer.kernel import forest_infer_pallas
from repro.kernels.forest_infer.ops import forest_infer
from repro.kernels.forest_infer.ref import forest_infer_ref
from repro.serve import bundle as B
from repro.serve.engine import (ScoringEngine, apply_platt, fit_platt)
from repro.trees import forest as RF
from repro.trees import gbdt as GB
from repro.trees.growth import predict_forest

RNG = np.random.default_rng(5)


def _data(n=400, F=7):
    X = RNG.normal(size=(n, F)).astype(np.float32)
    y = (X[:, 0] + 0.5 * X[:, 1] + RNG.normal(size=n) * 0.5
         > 0).astype(np.float32)
    return X, y


# --- forest-inference kernel --------------------------------------------------

FOREST_CASES = cases(4, seed=9, depth=ints(1, 6), trees=ints(1, 12),
                     n=ints(33, 700))
# tier 1: two small cases; the full depth/size sweep is tier 2
FOREST_FAST = cases(1, seed=21, depth=ints(1, 4), trees=ints(1, 6),
                    n=ints(33, 260))


@for_cases(FOREST_FAST)
def test_forest_kernel_parity_fast(depth, trees, n):
    test_forest_kernel_parity.body(depth, trees, n)


@pytest.mark.slow
@for_cases(FOREST_CASES)
def test_forest_kernel_parity(depth, trees, n):
    """Pallas (interpret) == vmapped ref == the training-side
    predict_forest, bit for bit."""
    X, y = _data()
    rf = RF.fit(jnp.asarray(X), jnp.asarray(y), num_trees=trees,
                depth=depth, rng=jax.random.PRNGKey(depth))
    xq = jnp.asarray(RNG.normal(size=(n, X.shape[1])).astype(np.float32))
    base = np.asarray(predict_forest(rf.forest, xq))
    ref = np.asarray(forest_infer_ref(rf.forest.feature,
                                      rf.forest.threshold,
                                      rf.forest.leaf, xq))
    pal = np.asarray(forest_infer_pallas(rf.forest.feature,
                                         rf.forest.threshold,
                                         rf.forest.leaf, xq, block_n=64,
                                         interpret=True))
    np.testing.assert_array_equal(ref, base)
    np.testing.assert_array_equal(pal, base)


def test_forest_ops_routing():
    X, y = _data(120)
    rf = RF.fit(jnp.asarray(X), jnp.asarray(y), num_trees=2, depth=2,
                rng=jax.random.PRNGKey(0))
    xq = jnp.asarray(X[:50])
    base = np.asarray(predict_forest(rf.forest, xq))
    for impl in ("auto", "xla", "pallas", "pallas_interpret"):
        np.testing.assert_array_equal(
            np.asarray(forest_infer(rf.forest, xq, impl=impl)), base)
    with pytest.raises(ValueError):
        forest_infer(rf.forest, xq, impl="nope")


# --- bundles ------------------------------------------------------------------

def _tiny_artifacts():
    """One artifact per pipeline kind, trained fast on one shard set."""
    from repro.core import fed_hist as FH
    from repro.core import feature_extract as FE
    from repro.core import parametric as P
    from repro.core import tree_subset as TS
    from repro.data import framingham as F

    ds = F.synthesize(n=300, seed=0)
    tr, te = F.train_test_split(ds)
    clients = [(c.x, c.y) for c in F.partition_clients(tr, 2)]
    params, _, _, _ = P.train_federated(
        clients, P.FedParametricConfig(model="logreg", rounds=2,
                                       local_steps=4))
    rf, _, _ = TS.train_federated_rf(
        clients, TS.FedForestConfig(trees_per_client=3, subset=2, depth=2,
                                    n_bins=16))
    fe, _, _ = FE.train_federated_xgb_fe(
        clients, FE.FedXGBConfig(num_rounds=2, shallow_rounds=1, depth=2,
                                 shallow_depth=2, top_features=4,
                                 n_bins=16))
    gb, _, _ = FH.train_federated_xgb_hist(
        clients, FH.FedHistConfig(num_rounds=2, depth=2, n_bins=16))
    return {
        "parametric": B.pack("parametric", params, model="logreg"),
        "tree_subset": B.pack("tree_subset", rf),
        "feature_extract": B.pack("feature_extract", fe),
        "fed_hist": B.pack("fed_hist", gb),
    }, (te.x, te.y)


@pytest.fixture(scope="module")
def artifacts():
    return _tiny_artifacts()


def test_bundle_roundtrip_all_kinds(artifacts, tmp_path):
    bundles, (xt, _) = artifacts
    assert set(bundles) == set(B.BUNDLE_KINDS)
    for kind, bundle in bundles.items():
        path = str(tmp_path / kind)
        B.save_bundle(path, bundle)
        loaded = B.load_bundle(path)
        assert loaded.kind == kind
        assert loaded.version == B.BUNDLE_VERSION
        assert loaded.meta == bundle.meta
        assert set(loaded.arrays) == set(bundle.arrays)
        for k in bundle.arrays:
            np.testing.assert_array_equal(np.asarray(loaded.arrays[k]),
                                          np.asarray(bundle.arrays[k]))
        # the reloaded bundle scores identically
        a = ScoringEngine(bundle, bucket_sizes=(128,)).score(xt)
        b = ScoringEngine(loaded, bucket_sizes=(128,)).score(xt)
        np.testing.assert_array_equal(a, b)


def test_bundle_version_and_kind_validation(artifacts, tmp_path):
    import json
    import os
    bundles, _ = artifacts
    path = str(tmp_path / "v")
    B.save_bundle(path, bundles["fed_hist"])
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["version"] = 999
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError):
        B.load_bundle(path)
    manifest["version"] = B.BUNDLE_VERSION
    manifest["kind"] = "nope"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(KeyError):
        B.load_bundle(path)
    with pytest.raises(KeyError):
        B.pack("nope", None)


def test_bundle_unpack_matches_training_artifact(artifacts):
    """fed_hist round-trip reconstructs a GBDT that predicts like the
    original model object."""
    bundles, (xt, _) = artifacts
    gb = bundles["fed_hist"].model()
    assert isinstance(gb, GB.GBDT)
    probs = np.asarray(GB.predict_proba(gb, jnp.asarray(xt)))
    eng = ScoringEngine(bundles["fed_hist"], bucket_sizes=(len(xt),),
                        impl="xla")
    # tree leaf values are bit-exact (test_forest_kernel_parity); the
    # margin fold differs only by jit fusion (fma) of base + lr * sum
    np.testing.assert_allclose(eng.score(xt), probs, rtol=1e-6,
                               atol=1e-6)


def test_tree_subset_serving_matches_majority_vote(artifacts):
    """Thresholded serve-time predictions must reproduce the paper's
    majority-vote aggregation (the training-side predict_votes)."""
    bundles, (xt, _) = artifacts
    eng = ScoringEngine(bundles["tree_subset"], bucket_sizes=(128,),
                        impl="pallas_interpret")
    votes = np.asarray(RF.predict_votes(bundles["tree_subset"].model(),
                                        jnp.asarray(xt)))
    np.testing.assert_array_equal(eng.predict(xt), votes)


# --- engine -------------------------------------------------------------------

@pytest.mark.slow
def test_bucketed_equals_unbatched_every_kind(artifacts):
    """Tier 2: one XLA compile per (kind, bucket) pair; the same
    bucketed==unbatched invariant is CI-gated by serve_bench --smoke."""
    bundles, (xt, _) = artifacts
    for bundle in bundles.values():
        eng = ScoringEngine(bundle, bucket_sizes=(16, 64, 256),
                            impl="pallas_interpret")
        np.testing.assert_array_equal(eng.score(xt),
                                      eng.score_unbatched(xt))


@pytest.mark.slow
def test_engine_ensemble_composes_and_tracks_stats(artifacts):
    bundles, (xt, yt) = artifacts
    eng = ScoringEngine(list(bundles.values()), bucket_sizes=(64, 256))
    probs = eng.score(xt)
    assert probs.shape == (len(xt),)
    assert float(probs.min()) >= 0.0 and float(probs.max()) <= 1.0
    # ensemble = weighted mean of the per-bundle probabilities
    singles = np.stack([ScoringEngine(b, bucket_sizes=(64, 256)).score(xt)
                        for b in bundles.values()])
    np.testing.assert_allclose(probs, singles.mean(axis=0), atol=1e-6)
    st = eng.stats()
    assert st["calls"] == 1 and st["rows"] == len(xt)
    assert st["rows_per_s"] > 0 and st["p99_ms"] >= st["p50_ms"]


@pytest.mark.slow
def test_calibration_monotone_and_improves_brier(artifacts):
    bundles, (xt, yt) = artifacts
    eng = ScoringEngine(bundles["fed_hist"], bucket_sizes=(256,))
    raw = eng.score(xt).copy()
    a, b = eng.calibrate(xt, yt)
    assert a > 0  # higher score -> higher calibrated probability
    cal = eng.score(xt)
    # strictly monotone map preserves the score ordering (same AUC)
    order = np.argsort(raw)
    assert np.all(np.diff(cal[order]) >= 0)
    np.testing.assert_allclose(roc_auc(cal, yt), roc_auc(raw, yt),
                               atol=1e-9)
    assert brier_score(cal, yt) <= brier_score(raw, yt) + 1e-6


def _tiny_engine(n_features=5, bucket_sizes=(4, 16)):
    """A jit-cheap engine (zero-weight logreg) for stats-path tests —
    no training, no forest kernels, fast tier."""
    bundle = B.pack("parametric",
                    {"w": jnp.zeros((n_features,), jnp.float32),
                     "b": jnp.zeros((), jnp.float32)}, model="logreg")
    return ScoringEngine(bundle, bucket_sizes=bucket_sizes)


def test_stats_empty_window_is_all_zeros():
    st = _tiny_engine().stats()
    assert st == {"calls": 0, "rows": 0, "rows_per_s": 0.0,
                  "p50_ms": 0.0, "p99_ms": 0.0, "bucket_calls": {}}


def test_stats_single_call_percentiles_degenerate():
    eng = _tiny_engine()
    eng.score(np.zeros((3, 5), np.float32))
    st = eng.stats()
    assert st["calls"] == 1 and st["rows"] == 3
    # one sample: p50 == p99, throughput finite and positive
    assert st["p50_ms"] == st["p99_ms"]
    assert np.isfinite(st["rows_per_s"]) and st["rows_per_s"] >= 0.0
    assert st["bucket_calls"] == {4: 1}


def test_stats_zero_duration_guard():
    # a recorded zero-length window (coarse clock) must yield
    # rows_per_s == 0.0, never inf or ZeroDivisionError
    eng = _tiny_engine()
    eng.latencies_s = [0.0]
    eng.rows_scored = 7
    st = eng.stats()
    assert st["rows_per_s"] == 0.0 and np.isfinite(st["rows_per_s"])


def test_stats_zero_row_score_counts_a_call():
    eng = _tiny_engine()
    out = eng.score(np.zeros((0, 5), np.float32))
    assert out.shape == (0,)
    st = eng.stats()
    # the call is timed but scores nothing: no bucket is ever hit
    assert st["calls"] == 1 and st["rows"] == 0
    assert st["bucket_calls"] == {}
    assert np.isfinite(st["rows_per_s"])


def test_stats_bucket_calls_track_chunks_and_reset():
    eng = _tiny_engine(bucket_sizes=(4, 16))
    # 20 rows chunk by the largest bucket: one 16-chunk + one 4-chunk
    eng.score(np.zeros((20, 5), np.float32))
    assert eng.stats()["bucket_calls"] == {16: 1, 4: 1}
    eng.score(np.zeros((2, 5), np.float32))
    assert eng.stats()["bucket_calls"] == {16: 1, 4: 2}
    eng.reset_stats()
    st = eng.stats()
    assert st["calls"] == 0 and st["bucket_calls"] == {}


def test_platt_recovers_known_sigmoid():
    s = np.linspace(-4, 4, 2000)
    rng = np.random.default_rng(0)
    y = (rng.random(2000) < 1 / (1 + np.exp(-(2.0 * s - 1.0)))).astype(
        np.float32)
    a, b = fit_platt(s, y)
    assert abs(a - 2.0) < 0.3 and abs(b + 1.0) < 0.3
    p = apply_platt(np.asarray([0.0]), (a, b))
    assert 0 < p[0] < 1


# --- threshold-free metrics ---------------------------------------------------

def test_roc_auc_known_values():
    y = np.asarray([0, 0, 1, 1])
    assert roc_auc([0.1, 0.2, 0.8, 0.9], y) == 1.0
    assert roc_auc([0.9, 0.8, 0.2, 0.1], y) == 0.0
    assert roc_auc([0.5, 0.5, 0.5, 0.5], y) == 0.5       # all tied
    assert np.isnan(roc_auc([0.1, 0.2], [1, 1]))         # one class


def test_binary_metrics_scores_optional():
    y = np.asarray([0, 1, 0, 1, 1])
    s = np.asarray([0.2, 0.8, 0.4, 0.9, 0.6])
    m = binary_metrics(s > 0.5, y, scores=s)
    assert m["roc_auc"] == 1.0
    assert m["brier"] == pytest.approx(np.mean((s - y) ** 2))
    assert "roc_auc" not in binary_metrics(s > 0.5, y)
