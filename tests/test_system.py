"""End-to-end behaviour of the system (integration tests).

1. Federated Framingham pipeline improves over chance and the tree-subset
   protocol holds Theorem 1's bound at small scale.
2. Federated LM training (pods-as-clients) reduces loss; top-k update
   compression cuts uplink while staying within a loss tolerance.
3. Training/serving drivers run end to end on reduced configs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.core import feature_extract as FE
from repro.core import parametric as P
from repro.core import tree_subset as TS
from repro.data import framingham as F

# tier 2: full-size end-to-end runs.  Tier-1 keeps fast end-to-end
# coverage of the same pipelines via tests/test_golden.py and the
# bench parity gates (benchmarks/fed_engine_bench.py --smoke).
pytestmark = pytest.mark.slow


def _small_setup(seed=0):
    ds = F.synthesize(n=900, seed=seed)
    tr, te = F.train_test_split(ds)
    clients = [(c.x, c.y) for c in F.partition_clients(tr, 3, seed)]
    return tr, te, clients


def test_fed_parametric_end_to_end():
    tr, te, clients = _small_setup()
    cfg = P.FedParametricConfig(model="logreg", rounds=8, local_steps=30,
                                lr=0.05, sampling="ros")
    params, comm, hist, timer = P.train_federated(clients, cfg,
                                                  test=(te.x, te.y))
    assert hist[-1]["f1"] > 0.40                  # well above chance
    assert comm.total_bytes("up") > 0
    # DP + secure-agg variant still learns (noisier)
    cfg2 = P.FedParametricConfig(model="logreg", rounds=8, local_steps=30,
                                 lr=0.05, sampling="ros", secure_agg=True,
                                 dp_epsilon=8.0, dp_clip=5.0)
    _, _, hist2, _ = P.train_federated(clients, cfg2, test=(te.x, te.y))
    assert hist2[-1]["f1"] > 0.30


def test_fed_rf_tree_subset_theorem1_smallscale():
    tr, te, clients = _small_setup()
    full = TS.FedForestConfig(trees_per_client=25, subset=25, depth=6,
                              sampling="smote")
    sub = TS.FedForestConfig(trees_per_client=25, subset=5, depth=6,
                             sampling="smote")
    m_full, c_full, _ = TS.train_federated_rf(clients, full)
    m_sub, c_sub, _ = TS.train_federated_rf(clients, sub)
    f_full = TS.evaluate_rf(m_full, te.x, te.y)["f1"]
    f_sub = TS.evaluate_rf(m_sub, te.x, te.y)["f1"]
    # comm scales with subset size exactly
    np.testing.assert_allclose(c_sub.total_bytes("up")
                               / c_full.total_bytes("up"), 5 / 25,
                               rtol=1e-6)
    # bounded degradation (paper: |dF1| <= 0.03; small-scale slack 0.08)
    assert abs(f_full - f_sub) < 0.08


def test_fed_xgb_feature_extraction_comm_cut():
    tr, te, clients = _small_setup()
    cfg = FE.FedXGBConfig(num_rounds=15, depth=4, shallow_depth=3,
                          top_features=8, sampling="smote")
    dense, c_dense, _ = FE.train_federated_xgb(clients, cfg)
    fe, c_fe, _ = FE.train_federated_xgb_fe(clients, cfg)
    f_dense = FE.evaluate_fed_xgb(dense, te.x, te.y)["f1"]
    f_fe = FE.evaluate_fe(fe, te.x, te.y)["f1"]
    assert c_fe.total_bytes("up") < c_dense.total_bytes("up") / 3
    assert f_fe > 0.45 and f_dense > 0.45


def test_fed_lm_pods_and_compression():
    from repro.launch.fed_train import simulate
    dense = simulate("qwen3_4b", n_pods=2, rounds=3, local_steps=4,
                     batch=2, seq=64, verbose=False, seed=0)
    comp = simulate("qwen3_4b", n_pods=2, rounds=3, local_steps=4,
                    batch=2, seq=64, compression="topk", rho=0.05,
                    verbose=False, seed=0)
    assert dense["loss_history"][-1] < dense["loss_history"][0]
    assert comp["uplink_mb"] < dense["uplink_mb"] * 0.3
    # compressed run still trains
    assert comp["loss_history"][-1] < comp["loss_history"][0] + 0.1


def test_train_driver_loss_decreases():
    from repro.launch.train import train
    params, losses = train("phi3_mini", smoke=True, steps=30, batch=4,
                           seq=64, lr=2e-3, log_every=1000)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_serve_driver_runs():
    from repro.launch.serve import serve
    gen = serve("mamba2_13b", smoke=True, batch=2, prompt_len=8,
                gen_len=6)
    assert gen.shape == (2, 6)
    assert gen.dtype in (np.int32, np.int64)
