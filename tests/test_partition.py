"""Partitioner registry: every partitioner preserves each row exactly
once, skew knobs actually skew, Dirichlet draws are seed-deterministic,
and the LM mixture analogs are valid distributions."""
import numpy as np
import pytest

from repro.data import framingham as F
from repro.data import partition as P
from repro.data import sampling as S

RNG = np.random.default_rng(11)


def _xy(n=900, f=6, pos=0.2, seed=0):
    r = np.random.default_rng(seed)
    x = r.normal(size=(n, f)).astype(np.float32)
    y = (r.random(n) < pos).astype(np.float32)
    return x, y


# --- property: exact row preservation ----------------------------------------

@pytest.mark.parametrize("name", sorted(P.PARTITIONERS))
@pytest.mark.parametrize("n,n_clients,seed", [(900, 3, 0), (301, 7, 5),
                                              (64, 5, 2)])
def test_partitioner_preserves_rows_exactly_once(name, n, n_clients, seed):
    x, y = _xy(n=n, seed=seed)
    parts = P.partition_indices(name, x, y, n_clients, seed=seed)
    assert len(parts) == n_clients
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n
    assert all(len(p) >= 1 for p in parts)


def test_check_partition_rejects_losses_and_duplicates():
    with pytest.raises(ValueError):
        P.check_partition([np.array([0, 1]), np.array([1, 2])], 4)
    with pytest.raises(ValueError):
        P.check_partition([np.array([0, 1])], 3)
    with pytest.raises(KeyError):
        P.partition_indices("fancy", *_xy(), 3)


# --- skew semantics -----------------------------------------------------------

def test_dirichlet_is_seed_deterministic_and_skews():
    x, y = _xy(n=1200, pos=0.15, seed=3)
    a = P.partition_indices("dirichlet", x, y, 3, seed=7, alpha=0.2)
    b = P.partition_indices("dirichlet", x, y, 3, seed=7, alpha=0.2)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
    c = P.partition_indices("dirichlet", x, y, 3, seed=8, alpha=0.2)
    assert any(not np.array_equal(pa, pc) for pa, pc in zip(a, c))
    rates = [float(y[p].mean()) for p in a]
    assert max(rates) - min(rates) > 0.03   # visibly non-IID


def test_quantity_skews_sizes_iid_does_not():
    x, y = _xy(n=1500, seed=4)
    iid = P.partition_indices("iid", x, y, 4, seed=1)
    qty = P.partition_indices("quantity", x, y, 4, seed=1, alpha=0.3)
    iid_sizes = [len(p) for p in iid]
    qty_sizes = [len(p) for p in qty]
    assert max(iid_sizes) - min(iid_sizes) <= len(np.unique(y))
    assert max(qty_sizes) - min(qty_sizes) > 100
    # stratified within shards: base rates stay near global
    big = [p for p in qty if len(p) > 30]
    rates = [float(y[p].mean()) for p in big]
    assert max(rates) - min(rates) < 0.2


def test_site_shift_orders_the_covariate():
    ds = F.synthesize(n=800, seed=2)
    parts = P.partition_indices("site", ds.x, ds.y, 4, seed=0)
    # column 1 = age: per-site means must be strictly increasing
    means = [float(ds.x[p, 1].mean()) for p in parts]
    assert all(a < b for a, b in zip(means, means[1:]))


# --- LM mixture analogs -------------------------------------------------------

def test_pod_mixture_matrix_names():
    for name in ("iid", "dirichlet", "site"):
        rows = P.pod_mixture_matrix(name, 4, 3, alpha=0.4, seed=0)
        assert len(rows) == 4
        for m in rows:
            np.testing.assert_allclose(m.sum(), 1.0, rtol=1e-9)
            assert (m >= 0).all()
    np.testing.assert_allclose(P.pod_mixture_matrix("iid", 2, 4)[0], 0.25)
    site = P.pod_mixture_matrix("site", 3, 3)
    assert all(float(site[i][i % 3]) > 0.8 for i in range(3))
    with pytest.raises(ValueError):
        P.pod_mixture_matrix("quantity", 3, 4)
    with pytest.raises(KeyError):
        P.pod_mixture_matrix("fancy", 3, 4)


# --- fed-SMOTE statistics vs pooled-data SMOTE statistics ---------------------

def test_minority_stats_aggregation_matches_pooled():
    """Server-aggregated fed-SMOTE statistics vs the pooled-data minority
    statistics: exact for equal-count shards (mean of means == pooled
    mean), close under iid sharding."""
    ds = F.synthesize(n=1600, seed=5)
    # equal-count shards: slice the minority class evenly by hand
    mino = np.where(ds.y == 1)[0][:200]
    majo = np.where(ds.y == 0)[0][:1000]
    half = [np.concatenate([mino[:100], majo[:500]]),
            np.concatenate([mino[100:], majo[500:]])]
    stats = [S.minority_stats(ds.x[p], ds.y[p]) for p in half]
    mu_g, var_g = S.aggregate_stats(stats)
    pooled = np.concatenate(half)
    mu_p, var_p, m = S.minority_stats(ds.x[pooled], ds.y[pooled])
    assert m == 200
    np.testing.assert_allclose(mu_g, mu_p, atol=1e-6)
    # mean-of-variances omits the between-shard term; for a random even
    # split it is close to (and never above) the pooled variance
    assert np.all(var_g <= var_p + 1e-6)
    np.testing.assert_allclose(var_g, var_p, rtol=0.35)
    # iid registry shards: aggregated stats track the pooled ones
    parts = P.partition_indices("iid", ds.x, ds.y, 4, seed=3)
    stats4 = [S.minority_stats(ds.x[p], ds.y[p]) for p in parts]
    mu4, var4 = S.aggregate_stats(stats4)
    mu_all, var_all, _ = S.minority_stats(ds.x, ds.y)
    np.testing.assert_allclose(mu4, mu_all, atol=0.15)
    np.testing.assert_allclose(var4, var_all, rtol=0.5)
    # and the synthetic draws land on the aggregated statistics
    x2, y2 = S.fed_smote(ds.x[parts[0]], ds.y[parts[0]], mu4, var4,
                         seed=0)
    synth = x2[len(parts[0]):]
    np.testing.assert_allclose(synth.mean(0), mu4, atol=0.2)


def test_smote_chunked_matches_dense_reference():
    """The chunked kNN must reproduce the dense m×m implementation
    bit-for-bit (minority spans multiple chunks)."""
    r = np.random.default_rng(1)
    x = r.normal(size=(1100, 8)).astype(np.float32)
    y = (np.arange(1100) < 300).astype(np.float32)   # 300 minority
    xm = x[:300]
    d2 = ((xm[:, None, :] - xm[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    ref = np.argsort(d2, axis=1)[:, :5]
    np.testing.assert_array_equal(S._knn_indices(xm, 5, chunk=128), ref)
    xa, ya = S.smote(x, y, seed=3)
    assert abs(float(ya.mean()) - 0.5) < 0.01
