"""Tiny property-test driver (the ``hypothesis`` package is not installed
in this container — DESIGN.md): seeded random case generation + a
``for_cases`` decorator that runs a test body over every generated case and
reports the failing case's parameters."""
from __future__ import annotations

import functools
import itertools
from typing import Callable, Dict, Iterable, List

import numpy as np


def cases(num: int, seed: int, **space: Callable[[np.random.Generator], object]
          ) -> List[Dict]:
    rng = np.random.default_rng(seed)
    return [{k: gen(rng) for k, gen in space.items()}
            for _ in range(num)]


def grid(**space: Iterable) -> List[Dict]:
    keys = list(space)
    return [dict(zip(keys, vals))
            for vals in itertools.product(*space.values())]


def for_cases(case_list: List[Dict]):
    """Run the test body over every case. (Deliberately does NOT copy the
    wrapped signature — pytest would treat the parameters as fixtures.)"""
    def deco(fn):
        def wrapper():
            for i, case in enumerate(case_list):
                try:
                    fn(**case)
                except Exception as e:
                    raise AssertionError(
                        f"case {i} failed: {case}: {e}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.body = fn   # reusable: run one case (tiered subsets)
        return wrapper
    return deco


# common generators
def ints(lo, hi):
    return lambda rng: int(rng.integers(lo, hi + 1))


def choice(*opts):
    return lambda rng: opts[int(rng.integers(0, len(opts)))]


def floats(lo, hi):
    return lambda rng: float(rng.uniform(lo, hi))
