"""Privacy-layer unit + property tests (tests/proptest.py driver):

* pair-seed injectivity — regression for the legacy
  ``round_seed*1000003 + lo*1009 + hi`` formula whose collisions reuse
  one mask across distinct pairs at cohort scale (> 1009 clients);
* ``mask_update`` single-pass rewrite is bit-identical to the old
  per-peer pytree loop;
* Shamir t-of-n seed sharing: any t shares reconstruct, fewer don't,
  and :class:`SeedShareBook` enforces the threshold;
* dropout recovery: a delivery batch's masked sum equals its plain sum
  after :func:`strip_missing_masks`, for random shapes / drop patterns
  / thresholds — and end-to-end through the FedRuntime under
  ``dropout:p:p_straggle`` and ``async:K`` schedules;
* RDP accountant: closed form at q=1, monotone in steps, subsampling
  amplification, and the ``dp_budget`` stop criterion;
* layer construction validation (DPNoiseLayer / gaussian_sigma).
"""
import itertools

import jax
import numpy as np
import pytest

from proptest import cases, for_cases, ints

from repro.core import privacy
from repro.core.comm import DPNoiseLayer, MaskLayer
from repro.core.parametric import FedParametricConfig, train_federated
from repro.core.privacy import (MaskRecoveryError, RDPAccountant,
                                SeedShareBook, mask_round_seed,
                                mask_update, pair_seed, secure_sum,
                                shamir_reconstruct, shamir_share,
                                strip_missing_masks,
                                subsampled_gaussian_rdp)


def _legacy_pair_seed(round_seed, lo, hi):
    """The pre-fix formula, kept here as the regression target."""
    return round_seed * 1000003 + lo * 1009 + hi


def _leaves(t):
    return [np.asarray(x) for x in jax.tree.leaves(t)]


# --- pair-seed collision regression -------------------------------------------

def test_legacy_pair_seed_collides_beyond_1009_clients():
    """The documented counterexample: (0, 2018) and (1, 1009) hash to the
    same legacy seed (0*1009+2018 == 1*1009+1009), so two distinct pairs
    shared one mask — the new derivation separates them."""
    assert _legacy_pair_seed(7, 0, 2018) == _legacy_pair_seed(7, 1, 1009)
    assert pair_seed(7, 0, 2018) != pair_seed(7, 1, 1009)
    tree = {"w": np.zeros((3, 2), np.float32)}
    m1 = privacy._pair_mask(pair_seed(7, 0, 2018), tree)
    m2 = privacy._pair_mask(pair_seed(7, 1, 1009), tree)
    assert not np.allclose(np.asarray(m1["w"]), np.asarray(m2["w"]))


def test_pair_seed_distinct_on_adversarial_colliding_family():
    """Every pair family {(i, c - 1009*i)} is a legacy-collision class;
    the SeedSequence derivation must keep all of them (and a broad
    random sample at n > 1009) distinct."""
    seen = {}
    for c in (2018, 3031, 5000, 9000):
        fam = [(i, c - 1009 * i) for i in range(c // 1009 + 1)
               if i < c - 1009 * i]
        legacy = {_legacy_pair_seed(3, lo, hi) for lo, hi in fam}
        assert len(legacy) == 1, "family construction broken"
        for lo, hi in fam:
            seen[(lo, hi)] = pair_seed(3, lo, hi)
    rng = np.random.default_rng(0)
    n = 4096
    while len(seen) < 20_000:
        lo, hi = sorted(rng.integers(0, n, size=2))
        if lo != hi:
            seen[(int(lo), int(hi))] = pair_seed(3, int(lo), int(hi))
    assert len(set(seen.values())) == len(seen)


# --- mask_update single-pass parity -------------------------------------------

def _reference_mask_update(update, client_idx, n_clients, round_seed):
    """The old O(n_clients) full-pytree-per-peer loop, verbatim math."""
    masked = update
    for j in range(n_clients):
        if j == client_idx:
            continue
        lo, hi = min(client_idx, j), max(client_idx, j)
        mask = privacy._pair_mask(pair_seed(round_seed, lo, hi), update)
        sgn = 1.0 if client_idx < j else -1.0
        masked = jax.tree.map(lambda a, m: a + sgn * m, masked, mask)
    return masked


@for_cases(cases(6, seed=11, c=ints(2, 9), n=ints(1, 12), m=ints(1, 6),
                 seed2=ints(0, 10 ** 6)))
def test_mask_update_bit_identical_to_reference_loop(c, n, m, seed2):
    rng = np.random.default_rng(seed2)
    u = {"w": np.asarray(rng.normal(size=(n, m)), np.float32),
         "b": np.asarray(rng.normal(size=(m,)), np.float32)}
    for i in range(c):
        fast = mask_update(u, i, c, round_seed=seed2)
        ref = _reference_mask_update(u, i, c, round_seed=seed2)
        for a, b in zip(_leaves(fast), _leaves(ref)):
            np.testing.assert_array_equal(a, b)


# --- Shamir seed sharing ------------------------------------------------------

def test_shamir_any_threshold_subset_reconstructs():
    rng = np.random.default_rng(5)
    secret = int.from_bytes(rng.bytes(16), "little") % privacy.SHAMIR_PRIME
    shares = shamir_share(secret, n_shares=6, threshold=3, rng=rng)
    for sub in itertools.combinations(shares, 3):
        assert shamir_reconstruct(list(sub)) == secret
    # t-1 shares interpolate to something else (info-theoretically the
    # secret is unrecoverable; equality would be a 2^-127 fluke)
    assert shamir_reconstruct(shares[:2]) != secret


def test_shamir_validation():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="threshold"):
        shamir_share(1, n_shares=3, threshold=4, rng=rng)
    with pytest.raises(ValueError, match="threshold"):
        shamir_share(1, n_shares=3, threshold=0, rng=rng)
    with pytest.raises(ValueError, match="duplicate"):
        shamir_reconstruct([(1, 5), (1, 6)])
    with pytest.raises(ValueError, match="threshold"):
        SeedShareBook(round_seed=1, n_active=2, threshold=3)


def test_share_book_recovers_pair_seeds_and_enforces_threshold():
    book = SeedShareBook(round_seed=99, n_active=5, threshold=3)
    assert book.recover_seed(1, 4) == pair_seed(99, 1, 4)
    assert book.recover_seed(1, 4, respondents=(0, 2, 3)) == \
        pair_seed(99, 1, 4)
    assert book.shares_pulled == 6        # 2 recoveries * t=3
    with pytest.raises(MaskRecoveryError, match="threshold"):
        book.recover_seed(0, 2, respondents=(0, 1))


def test_mask_layer_threshold_resolution():
    assert MaskLayer(0.0).resolve_threshold(5) == 3      # n//2 + 1
    assert MaskLayer(0.6).resolve_threshold(5) == 3      # ceil(0.6*5)
    assert MaskLayer(2).resolve_threshold(5) == 2        # absolute
    assert MaskLayer(9).resolve_threshold(5) == 5        # clamped
    with pytest.raises(ValueError):
        MaskLayer(-1)


# --- dropout recovery (unit property) -----------------------------------------

@for_cases(cases(8, seed=17, c=ints(2, 7), n=ints(1, 10), m=ints(1, 5),
                 t=ints(1, 7), seed2=ints(0, 10 ** 6)))
def test_recovered_masked_sum_equals_plain_sum(c, n, m, t, seed2):
    """For any cohort size, leaf shapes, threshold <= cohort and
    non-empty delivery subset: sum of delivered masked payloads after
    ``strip_missing_masks`` == plain sum of the delivered updates."""
    t = min(t, c)
    rng = np.random.default_rng(seed2)
    updates = [{"w": np.asarray(rng.normal(size=(n, m)), np.float32),
                "b": np.asarray(rng.normal(size=(m,)), np.float32)}
               for _ in range(c)]
    rs = mask_round_seed(seed2, 0)
    masked = [mask_update(u, i, c, round_seed=rs)
              for i, u in enumerate(updates)]
    k = int(rng.integers(1, c + 1))
    present = set(int(s) for s in rng.choice(c, size=k, replace=False))
    book = SeedShareBook(rs, c, t)
    stripped = [strip_missing_masks(masked[s], book, s, present)[0]
                for s in sorted(present)]
    plain = secure_sum([updates[s] for s in sorted(present)])
    got = secure_sum(stripped)
    for a, b in zip(_leaves(got), _leaves(plain)):
        np.testing.assert_allclose(a, b, atol=2e-4 * c)


def test_strip_missing_masks_counts_and_full_batch_is_free():
    c, rs = 4, mask_round_seed(1, 2)
    u = {"w": np.ones((2, 2), np.float32)}
    masked = mask_update(u, 0, c, round_seed=rs)
    book = SeedShareBook(rs, c, 2)
    same, n_rec = strip_missing_masks(masked, book, 0, {0, 1, 2, 3})
    assert n_rec == 0 and book.shares_pulled == 0
    assert same is masked                 # untouched when nobody is missing
    _, n_rec = strip_missing_masks(masked, book, 0, {0, 2})
    assert n_rec == 2                     # peers 1 and 3 reconstructed
    assert book.shares_pulled == 2 * book.t


# --- end-to-end runtime recovery ----------------------------------------------

def _tiny_clients(n_clients=4, rows=24, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_clients):
        x = np.asarray(rng.normal(size=(rows, 5)), np.float32)
        y = np.asarray(rng.integers(0, 2, size=rows), np.float32)
        out.append((x, y))
    return out


def _run(transport, participation="full", schedule="sync", seed=3,
         rounds=4, dp_budget=None):
    cfg = FedParametricConfig(model="logreg", rounds=rounds,
                              local_steps=3, transport=transport,
                              participation=participation,
                              schedule=schedule, seed=seed,
                              dp_budget=dp_budget)
    return train_federated(_tiny_clients(), cfg)


@for_cases(cases(3, seed=23, seed2=ints(0, 10 ** 6)))
def test_masked_dropout_run_matches_plain(seed2):
    """Former hard rejection, now the recovery path: a mask transport
    under ``dropout:p:p_straggle`` must reproduce the plain transport's
    global params — stragglers' and droppers' mask terms are Shamir-
    recovered before each (possibly discounted) aggregation."""
    p_plain, *_ = _run("plain", "dropout:0.3:0.5", seed=seed2)
    p_mask, *_ = _run("secure", "dropout:0.3:0.5", seed=seed2)
    for a, b in zip(_leaves(p_plain), _leaves(p_mask)):
        np.testing.assert_allclose(a, b, atol=1e-3)


@for_cases(cases(2, seed=29, k=ints(1, 3), seed2=ints(0, 10 ** 6)))
def test_masked_async_run_matches_plain(k, seed2):
    """Async buffered aggregation mixes dispatch cohorts in one buffer;
    cross-cohort mask terms are recovered per delivery group, so the
    masked async run tracks the plain one."""
    p_plain, *_ = _run("plain", schedule=f"async:{k}", seed=seed2)
    p_mask, *_ = _run("secure", schedule=f"async:{k}", seed=seed2)
    for a, b in zip(_leaves(p_plain), _leaves(p_mask)):
        np.testing.assert_allclose(a, b, atol=1e-3)


def test_mask_share_traffic_ledgered_only_under_recovery():
    _, comm_full, *_ = _run("secure", "full")
    assert "mask-shares" not in comm_full.per_what_bytes()
    assert getattr(comm_full, "privacy", None) is None   # no dpnoise layer
    # heavy straggling forces split deliveries -> recovery traffic
    _, comm_drop, *_ = _run("secure", "dropout:0.2:0.9", seed=5)
    per_what = comm_drop.per_what_bytes()
    assert per_what.get("mask-shares", 0) > 0
    assert per_what["mask-shares"] % SeedShareBook.SHARE_NBYTES == 0


# --- RDP accountant -----------------------------------------------------------

def test_rdp_matches_gaussian_closed_form_at_full_participation():
    """q=1 reduces to the plain Gaussian mechanism: after T steps
    eps = min_a [ T*a/(2 z^2) + log(1/delta)/(a-1) ]."""
    z, delta, T = 1.7, 1e-5, 12
    acc = RDPAccountant(noise_multiplier=z, delta=delta)
    for _ in range(T):
        acc.step([0, 1, 2], q=1.0)
    expect = min(T * a / (2 * z * z) + np.log(1 / delta) / (a - 1)
                 for a in acc.orders)
    np.testing.assert_allclose(acc.epsilon(), expect, rtol=1e-12)
    assert subsampled_gaussian_rdp(1.0, z, 8) == 8 / (2 * z * z)


def test_rdp_monotone_in_steps_and_amplified_by_subsampling():
    full = RDPAccountant(noise_multiplier=2.0)
    sub = RDPAccountant(noise_multiplier=2.0)
    prev = 0.0
    for _ in range(8):
        full.step([0], q=1.0)
        sub.step([0], q=0.25)
        assert full.epsilon() > prev     # strictly accumulating
        prev = full.epsilon()
    assert sub.epsilon() < full.epsilon()   # amplification by subsampling
    assert sub.epsilon() > 0.0


def test_rdp_individual_accounting_per_client():
    acc = RDPAccountant(noise_multiplier=1.5)
    acc.step([0, 1], q=0.5)
    acc.step([0], q=0.5)
    s = acc.summary()
    assert s["per_client"][0] > s["per_client"][1] > 0
    assert s["epsilon"] == acc.epsilon(client=0)
    assert acc.epsilon(client=7) == 0.0     # never sampled
    assert s["steps"] == 2


def test_rdp_validation():
    with pytest.raises(ValueError, match="noise_multiplier"):
        RDPAccountant(noise_multiplier=0.0)
    with pytest.raises(ValueError, match="delta"):
        RDPAccountant(noise_multiplier=1.0, delta=1.0)
    acc = RDPAccountant(noise_multiplier=1.0)
    with pytest.raises(ValueError, match="q"):
        acc.step([0], q=0.0)
    with pytest.raises(ValueError, match="q"):
        acc.step([0], q=1.5)
    with pytest.raises(ValueError, match="order"):
        subsampled_gaussian_rdp(0.5, 1.0, 1)


def test_dp_budget_stops_training_early():
    rounds = 30
    _, comm, history, _ = _run("secure_dp", rounds=rounds, dp_budget=1.0)
    p = comm.privacy
    assert p is not None and p["epsilon"] >= 1.0
    assert p["budget"] == 1.0
    assert p["budget_stop_round"] < rounds - 1
    assert p["steps"] == p["budget_stop_round"] + 1


def test_dp_budget_requires_accountant():
    with pytest.raises(ValueError, match="dp_budget"):
        _run("plain", dp_budget=1.0)


# --- construction validation --------------------------------------------------

def test_dpnoise_layer_validates_epsilon_and_delta():
    DPNoiseLayer(0.5, 1e-5)                 # paper defaults construct
    for eps, delta in ((0.0, 1e-5), (-1.0, 1e-5), (0.5, 0.0),
                      (0.5, 1.0), (0.5, -0.1)):
        with pytest.raises(ValueError, match="dpnoise"):
            DPNoiseLayer(eps, delta)
    with pytest.raises(ValueError, match="epsilon"):
        privacy.gaussian_sigma(0.0, 1e-5)
    with pytest.raises(ValueError, match="delta"):
        privacy.gaussian_sigma(0.5, 2.0)
