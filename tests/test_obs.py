"""Observability layer (repro.obs): span invariants, metrics registry
validation, exporter round-trips, traced-vs-untraced bit-exact parity,
the unified timeline schema, and the sharded runtime's metadata-only
guarantee with tracing enabled."""
import hashlib
import json

import jax
import numpy as np
import pytest

from repro.core import parametric as P
from repro.core.runtime import ShardedFedRuntime
from repro.data import cohort as C
from repro.obs import (METRICS, NULL_TRACER, Tracer, annotate,
                       annotations_enabled, chrome_payload, current,
                       get_exporter, jsonl_bytes, set_annotations,
                       summarize, use)
from repro.obs.trace import _NULL_SPAN


# --- span invariants ---------------------------------------------------------

def test_null_tracer_is_falsy_and_inert():
    assert not NULL_TRACER and bool(Tracer()) is True
    # every recording call is a no-op returning the shared handle
    assert NULL_TRACER.begin("x") is NULL_TRACER.span("y")
    with NULL_TRACER.span("z"):
        pass
    NULL_TRACER.end(NULL_TRACER.begin("x"))
    NULL_TRACER.span_at("a", 0, 1)
    NULL_TRACER.instant("i")
    NULL_TRACER.count("c", 3)


def test_virtual_clock_requires_explicit_stamp():
    tr = Tracer(clock="virtual")
    with pytest.raises(ValueError, match="explicit t="):
        tr.instant("x")
    tr.instant("x", t=1.0)         # explicit stamp is fine
    wall = Tracer(clock="wall")
    wall.instant("x")              # wall clock self-stamps
    assert wall.events[0]["t"] > 0
    with pytest.raises(ValueError, match="unknown clock"):
        Tracer(clock="cpu")


def test_span_end_must_not_precede_begin():
    tr = Tracer()
    with pytest.raises(ValueError, match="end .* < begin"):
        tr.span_at("bad", 2.0, 1.0)
    h = tr.begin("s", t=5.0)
    with pytest.raises(ValueError, match="end .* < begin"):
        tr.end(h, t=4.0)


def test_spans_nest_per_track():
    tr = Tracer()
    outer = tr.begin("outer", track="a", t=0.0)
    inner = tr.begin("inner", track="a", t=1.0)
    other = tr.begin("other", track="b", t=0.5)   # tracks independent
    with pytest.raises(ValueError, match="must nest"):
        tr.end(outer, t=2.0)
    tr.end(inner, t=2.0)
    tr.end(outer, t=3.0)
    tr.end(other, t=1.0)
    assert not tr.open_spans()
    with pytest.raises(ValueError, match="must nest"):
        tr.end(inner, t=4.0)       # closing twice never works
    names = [e["name"] for e in tr.events]
    assert names == ["inner", "outer", "other"]   # close order


def test_span_context_manager_and_attrs():
    tr = Tracer(clock="wall")
    with tr.span("work", track="t", phase="x") as sp:
        assert tr.open_spans() == [sp]
    (ev,) = tr.events
    assert ev["ph"] == "span" and ev["args"] == {"phase": "x"}
    assert ev["t1"] >= ev["t0"]


# --- metrics registry --------------------------------------------------------

def test_metrics_registry_validates_names_and_kinds():
    tr = Tracer()
    with pytest.raises(KeyError):
        tr.metrics.inc("not_a_metric")
    with pytest.raises(ValueError, match="counter"):
        tr.metrics.observe("bytes_up", 1.0)    # counter, not histogram
    with pytest.raises(ValueError, match="gauge"):
        tr.metrics.inc("queue_depth")


def test_histogram_buckets_and_snapshot():
    tr = Tracer()
    spec = METRICS["round_s"]
    bounds = spec.bounds()
    assert len(bounds) == spec.n and bounds[0] == pytest.approx(spec.lo)
    tr.metrics.observe("round_s", 0.0)           # first bucket
    tr.metrics.observe("round_s", 1e9)           # overflow bucket
    snap = tr.metrics.snapshot()
    h = snap["round_s"]
    assert h["count"] == 2 and len(h["counts"]) == spec.n + 1
    assert h["counts"][0] == 1 and h["counts"][-1] == 1
    json.dumps(snap)                             # JSON-ready


# --- exporters ---------------------------------------------------------------

def _toy_tracer():
    tr = Tracer(meta={"run": "toy"})
    tr.span_at("round", 0.0, 1.0, track="server", round=0)
    tr.instant("drop", track="c1", t=0.5, client=1)
    tr.count("queue_depth", 3, track="q", t=0.25)
    tr.metrics.inc("bytes_up", 100)
    return tr


def test_jsonl_is_byte_stable_and_framed():
    tr = _toy_tracer()
    data = jsonl_bytes(tr)
    assert data == jsonl_bytes(_toy_tracer())    # same inputs, same bytes
    lines = [json.loads(l) for l in data.decode().splitlines()]
    assert lines[0]["ph"] == "meta" and lines[0]["meta"] == {"run": "toy"}
    assert lines[-1]["ph"] == "metrics"
    assert [l["ph"] for l in lines[1:-1]] == ["span", "inst", "count"]


def test_chrome_payload_shape():
    payload = chrome_payload(_toy_tracer())
    evs = payload["traceEvents"]
    assert {e["ph"] for e in evs} == {"X", "i", "C", "M"}
    (span,) = [e for e in evs if e["ph"] == "X"]
    assert span["ts"] == 0 and span["dur"] == pytest.approx(1e6)  # µs
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert names == {"server", "c1", "q"}


def test_summary_groups_by_track_and_name():
    s = summarize(_toy_tracer())
    (row,) = s["spans"]
    assert (row["track"], row["name"], row["count"]) == ("server",
                                                         "round", 1)
    assert row["total_s"] == pytest.approx(1.0)
    assert s["metrics"]["bytes_up"]["value"] == 100


def test_exporter_registry():
    with pytest.raises(ValueError, match="exporter"):
        get_exporter("protobuf:x")
    out = get_exporter("summary")(_toy_tracer())
    assert out["spans"][0]["track"] == "server"


def test_jsonl_file_roundtrip(tmp_path):
    path = tmp_path / "t.jsonl"
    tr = _toy_tracer()
    get_exporter(f"jsonl:{path}")(tr)
    lines = path.read_bytes()
    assert lines == jsonl_bytes(tr)


# --- traced == untraced parity ----------------------------------------------

FED_KW = dict(model="logreg", n_clients=3, rounds=2, local_steps=4,
              n_records=300, seed=0, verbose=False)


def _fed_digest(out):
    h = hashlib.sha256()
    h.update(json.dumps(out["metrics"], sort_keys=True).encode())
    h.update(json.dumps(out["history"], sort_keys=True,
                        default=float).encode())
    h.update(json.dumps(out["comm"].events, sort_keys=True).encode())
    for leaf in jax.tree.leaves(out["params"]):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


@pytest.mark.parametrize("extra", [
    {},                                               # sync
    dict(schedule="async:2", latency="lognormal:0.05:0.4"),
])
def test_traced_run_is_bit_exact(extra):
    from repro.launch.fed_train import simulate_parametric
    kw = dict(FED_KW, **extra)
    base = _fed_digest(simulate_parametric(**kw))
    tr = Tracer(clock="virtual")
    with use(tr):
        traced = _fed_digest(simulate_parametric(**kw))
    assert traced == base
    assert tr.events and not tr.open_spans()


@pytest.mark.parametrize("extra", [
    {},
    dict(schedule="async:2", latency="lognormal:0.05:0.4"),
])
def test_same_seed_trace_replay_is_byte_identical(extra):
    from repro.launch.fed_train import simulate_parametric
    kw = dict(FED_KW, **extra)

    def one_trace():
        tr = Tracer(clock="virtual", meta={"seed": kw["seed"]})
        with use(tr):
            simulate_parametric(**kw)
        return jsonl_bytes(tr)

    assert one_trace() == one_trace()


def test_serve_load_traced_parity():
    from repro.serve.load import LoadConfig, simulate_load
    cfg = LoadConfig(arrivals="poisson:2000", n_requests=300,
                     deadline=0.05, max_queue=64, seed=0)
    base = simulate_load(cfg)
    tr = Tracer(clock="virtual")
    res = simulate_load(cfg, tracer=tr)
    assert res.row == base.row
    assert res.records == base.records and res.batches == base.batches
    assert tr.events


def test_ambient_tracer_scoping():
    assert current() is NULL_TRACER
    tr = Tracer()
    with use(tr):
        assert current() is tr
    assert current() is NULL_TRACER


# --- timeline schema ---------------------------------------------------------

@pytest.mark.parametrize("extra", [
    {},
    dict(schedule="async:2", latency="lognormal:0.05:0.4"),
])
def test_timeline_schema_is_unified(extra):
    from repro.launch.fed_train import simulate_parametric
    out = simulate_parametric(**dict(FED_KW, **extra))
    tl = out["timeline"]
    assert len(tl) == FED_KW["rounds"]
    for rec in tl:
        assert set(rec) == {"round", "t", "n_clients", "n_msgs",
                            "staleness", "bytes"}
        assert rec["n_msgs"] == rec["n_clients"]    # legacy alias
        assert len(rec["staleness"]) == rec["n_clients"]
        assert rec["bytes"] > 0 and rec["t"] >= 0.0


# --- sharded runtime: tracing stays metadata-only ---------------------------

def test_sharded_tracing_never_gathers(monkeypatch):
    """Per-tier spans come from the ledger plan alone: a traced sharded
    run must still never call jax.device_get (the no-device_get
    regression from tests/test_shard_fed.py, with tracing ON)."""
    local_fn = P.build_local_delta("logreg", 2, 0.05)
    import repro.models.tabular as tabular
    params = tabular.MODELS["logreg"]["init"](jax.random.PRNGKey(0), 15)
    xs, ys = C.build_cohort("framingham_like:8:4", seed=0)

    def boom(*a, **k):
        raise AssertionError("device_get on the traced sharded path")
    monkeypatch.setattr(jax, "device_get", boom)
    tr = Tracer(clock="wall")
    rt = ShardedFedRuntime(n_clients=8, rounds=2, n_silos=4, tracer=tr)
    rt.run(local_fn, params, xs, ys)
    spans = [e for e in tr.events if e["ph"] == "span"]
    tiers = [e for e in tr.events if e["name"] == "fed.tier"]
    assert len(spans) == 2 and all(e["name"] == "fed.round"
                                   for e in spans)
    assert len(tiers) == 8                       # 4 tier events x 2 rounds
    assert {e["track"] for e in tiers} == {"tier:edge", "tier:wan"}


# --- kernel annotations ------------------------------------------------------

def test_annotate_is_noop_unless_enabled():
    assert not annotations_enabled()
    assert annotate("kernels.x") is _NULL_SPAN
    set_annotations(True)
    try:
        cm = annotate("kernels.x")
        assert cm is not _NULL_SPAN
        with cm:                                  # usable as a CM
            pass
    finally:
        set_annotations(False)
