"""Autotune cache contract: deterministic keys (across processes),
JSON store round-trips byte-stably, resolution precedence
(defaults < cached < explicit), and shape buckets that make nearby
shapes share one tuned entry."""
import json
import os
import subprocess
import sys
import time

import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.autotune import (ConfigStore, TUNABLES, autotune as
                                    autotune_sweep, cache_key,
                                    candidate_configs, resolve,
                                    shape_bucket)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_shape_bucket_rounds_up_to_pow2():
    assert shape_bucket((4097, 15)) == (8192, 16)
    assert shape_bucket((8000, 16)) == (8192, 16)
    assert shape_bucket((1, 1)) == (1, 1)
    assert shape_bucket((2, 3)) == (2, 4)
    assert shape_bucket((1024,)) == (1024,)


def test_cache_key_stable_within_bucket():
    # nearby shapes share the tuned entry; crossing a pow2 boundary
    # does not
    a = cache_key("hist", (4097, 15), jnp.float32, platform="tpu")
    b = cache_key("hist", (8000, 16), jnp.float32, platform="tpu")
    c = cache_key("hist", (8193, 16), jnp.float32, platform="tpu")
    assert a == b == "hist|8192x16|float32|tpu"
    assert c != a
    assert cache_key("hist", (4097, 15), jnp.bfloat16,
                     platform="tpu") != a


def test_cache_key_rejects_unknown_family():
    try:
        cache_key("nope", (1,), jnp.float32)
        assert False, "expected KeyError"
    except KeyError as e:
        assert "nope" in str(e)


def test_cache_key_deterministic_across_processes():
    """No hash-seed or dict-order dependence: a fresh interpreter
    produces byte-identical keys."""
    prog = ("import jax.numpy as jnp; "
            "from repro.kernels.autotune import cache_key; "
            "print(cache_key('forest_infer', (300, 15), jnp.float32, "
            "platform='tpu'))")
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
           "PYTHONHASHSEED": "1234"}
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, check=True)
    assert out.stdout.strip() == cache_key("forest_infer", (300, 15),
                                           jnp.float32, platform="tpu")


def test_store_round_trip_and_byte_stability(tmp_path):
    path = str(tmp_path / "store.json")
    st = ConfigStore(path)
    st.put(cache_key("ssd", (1, 256, 4, 32), jnp.float32,
                     platform="tpu"),
           {"chunk": 128}, us=12.5, device="test", jax="0.0")
    st.put(cache_key("hist", (2048, 8), jnp.float32, platform="tpu"),
           {"block_n": 512, "block_f": 4}, us=3.0)
    st.save()

    reloaded = ConfigStore(path)
    assert reloaded.entries == st.entries
    assert reloaded.get(cache_key("ssd", (1, 256, 4, 32), jnp.float32,
                                  platform="tpu")) == {"chunk": 128}
    assert reloaded.get("hist|missing|float32|tpu") is None

    with open(path, "rb") as f:
        first = f.read()
    reloaded.save()
    with open(path, "rb") as f:
        assert f.read() == first, "save() must be byte-stable"
    assert json.loads(first)["version"] == 1


def test_store_rejects_version_mismatch(tmp_path):
    path = str(tmp_path / "bad.json")
    with open(path, "w") as f:
        json.dump({"version": 999, "entries": {}}, f)
    try:
        ConfigStore(path)
        assert False, "expected ValueError"
    except ValueError as e:
        assert "999" in str(e)


def test_resolve_falls_back_to_defaults(tmp_path):
    st = ConfigStore(str(tmp_path / "empty.json"))   # nothing cached
    for family, spec in TUNABLES.items():
        cfg = resolve(family, (512, 8), jnp.float32, platform="tpu",
                      store=st)
        assert cfg == spec["defaults"], \
            f"{family}: empty cache must yield the shipped defaults"


def test_resolve_precedence(tmp_path):
    st = ConfigStore(str(tmp_path / "s.json"))
    key = cache_key("hist", (512, 8), jnp.float32, platform="tpu")
    st.put(key, {"block_n": 2048, "block_f": 2})
    # cached beats defaults
    assert resolve("hist", (512, 8), jnp.float32, platform="tpu",
                   store=st) == {"block_n": 2048, "block_f": 2}
    # explicit non-None override beats cached; None means "no opinion"
    assert resolve("hist", (512, 8), jnp.float32, platform="tpu",
                   store=st, block_n=128, block_f=None) \
        == {"block_n": 128, "block_f": 2}
    # a different shape bucket misses the cache entirely
    assert resolve("hist", (5000, 8), jnp.float32, platform="tpu",
                   store=st) == TUNABLES["hist"]["defaults"]


def test_resolve_ignores_unknown_cached_params(tmp_path):
    # a stale store entry with extra keys must not leak into configs
    st = ConfigStore(str(tmp_path / "s.json"))
    key = cache_key("ssd", (1, 64, 2, 16), jnp.float32, platform="tpu")
    st.put(key, {"chunk": 128, "retired_param": 7})
    assert resolve("ssd", (1, 64, 2, 16), jnp.float32, platform="tpu",
                   store=st) == {"chunk": 128}


def test_candidate_configs_cover_grid_deterministically():
    cfgs = candidate_configs("flash_attention")
    assert len(cfgs) == 9                      # 3 block_q x 3 block_kv
    assert cfgs == candidate_configs("flash_attention")
    assert TUNABLES["flash_attention"]["defaults"] in cfgs
    for family, spec in TUNABLES.items():
        assert spec["defaults"] in candidate_configs(family), \
            f"{family}: sweep grid must include the shipped defaults"


def test_autotune_harness_picks_fastest_and_caches(tmp_path):
    """No kernels involved: candidates are sleeps, the designated
    winner is instant, and the winning config lands in the store under
    the bucketed key."""
    st = ConfigStore(str(tmp_path / "tuned.json"))

    def build(cfg):
        if cfg["chunk"] == 64:
            return lambda: 0.0
        return lambda: time.sleep(0.02)

    best, us = autotune_sweep("ssd", build, (1, 300, 4, 32), jnp.float32,
                              store=st, iters=1, warmup=1, save=True)
    assert best == {"chunk": 64}
    assert us < 0.02 * 1e6
    key = cache_key("ssd", (1, 300, 4, 32), jnp.float32)
    assert st.get(key) == {"chunk": 64}
    # the bucket neighbour resolves to the tuned value on this platform
    assert resolve("ssd", (1, 500, 4, 32), jnp.float32,
                   store=st)["chunk"] == 64
    # and the saved file reloads with timing metadata attached
    entry = ConfigStore(st.path).entries[key]
    assert entry["config"] == {"chunk": 64} and "us" in entry


def test_autotune_skips_failing_candidates(tmp_path):
    st = ConfigStore(str(tmp_path / "t.json"))

    def build(cfg):
        if cfg["chunk"] != 128:
            raise ValueError("tile too large")    # invalid-config path
        return lambda: 0.0

    best, _ = autotune_sweep("ssd", build, (1, 64, 2, 16), jnp.float32,
                             store=st, iters=1, warmup=1, save=False)
    assert best == {"chunk": 128}

    def all_fail(cfg):
        raise ValueError("no")

    try:
        autotune_sweep("ssd", all_fail, (1, 64, 2, 16), jnp.float32,
                       store=st, iters=1, warmup=1, save=False)
        assert False, "expected RuntimeError"
    except RuntimeError as e:
        assert "every candidate failed" in str(e)


def test_env_var_redirects_default_store(tmp_path, monkeypatch):
    path = str(tmp_path / "redirected.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune.reset_default_store()
    try:
        assert autotune.default_store_path() == path
        st = autotune._store()
        assert st.path == path
        # ops-path resolution (no explicit store) now reads this file
        assert resolve("forest_infer", (100, 8), jnp.float32,
                       platform="tpu") \
            == TUNABLES["forest_infer"]["defaults"]
    finally:
        autotune.reset_default_store()
