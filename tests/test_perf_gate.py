"""Perf-gate logic on synthetic rows — no real timing anywhere.

Covers the acceptance contract: an injected 25% same-platform
regression fails the gate, a within-tolerance run passes and appends
exactly one trajectory entry, cross-platform rows are never compared,
and the trajectory file round-trips.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "tools"))
import perf_gate  # noqa: E402  (tools/ is not a package)

META = {"platform": "cpu", "device": "testbox", "jax": "0.0-test"}


def _row(name, us, **meta):
    return {"name": name, "us": float(us), "note": "", **META, **meta}


def _trajectory(*entries):
    return {"version": 1, "entries": list(entries)}


def _entry(rows, smoke=False):
    return {**META, "smoke": smoke, "note": "", "rows": rows}


BASE = [_row("hist_smoke", 10_000.0), _row("forest_fused_smoke", 8_000.0)]


def test_injected_regression_fails():
    traj = _trajectory(_entry(BASE))
    current = [_row("hist_smoke", 12_500.0),          # +25% — must fail
               _row("forest_fused_smoke", 8_100.0)]   # +1.25% — fine
    failures = perf_gate.compare(current, traj)
    assert [name for name, _ in failures] == ["hist_smoke"]
    assert "12500.0us" in failures[0][1]


def test_within_tolerance_passes():
    traj = _trajectory(_entry(BASE))
    current = [_row("hist_smoke", 11_500.0),          # +15% < 20%
               _row("forest_fused_smoke", 7_500.0)]   # faster
    assert perf_gate.compare(current, traj) == []


def test_gate_uses_best_baseline_not_latest():
    # a slow middle entry must not ratchet the limit upward
    traj = _trajectory(_entry([_row("k", 10_000.0)]),
                       _entry([_row("k", 30_000.0)]))
    assert perf_gate.compare([_row("k", 12_500.0)], traj) != []
    assert perf_gate.compare([_row("k", 11_900.0)], traj) == []


def test_cross_platform_rows_are_not_compared():
    traj = _trajectory(_entry(BASE))
    tpu = [_row("hist_smoke", 99_999.0, platform="tpu", device="v5e")]
    assert perf_gate.compare(tpu, traj) == []
    other_cpu = [_row("hist_smoke", 99_999.0, device="otherbox")]
    assert perf_gate.compare(other_cpu, traj) == []


def test_unknown_rows_pass_and_seed():
    traj = _trajectory(_entry(BASE))
    assert perf_gate.compare([_row("brand_new_kernel", 1e9)], traj) == []


def test_smoke_entries_filtered_from_full_comparison():
    traj = _trajectory(_entry([_row("k", 100.0)], smoke=True),
                       _entry([_row("k", 50_000.0)], smoke=False))
    row = [_row("k", 55_000.0)]
    # against full-shape history only: +10%, passes
    assert perf_gate.compare(row, traj, smoke=False) == []
    # unfiltered it would be compared to the 100us smoke row
    assert perf_gate.compare(row, traj, smoke=None) != []


def test_noise_floor_absorbs_microsecond_jitter():
    # 80% slower but only +40us absolute: scheduler noise, not a
    # regression.  The floor never loosens ms-scale rows.
    traj = _trajectory(_entry([_row("tiny", 50.0)]))
    assert perf_gate.compare([_row("tiny", 90.0)], traj) == []
    assert perf_gate.compare([_row("tiny", 400.0)], traj) != []
    assert perf_gate.compare([_row("tiny", 90.0)], traj,
                             noise_floor_us=0.0) != []


def test_append_entry_adds_exactly_one():
    traj = _trajectory(_entry(BASE))
    perf_gate.append_entry(traj, BASE, smoke=True, note="pr-6")
    assert len(traj["entries"]) == 2
    new = traj["entries"][-1]
    assert new["smoke"] is True and new["note"] == "pr-6"
    assert new["platform"] == "cpu" and new["jax"] == "0.0-test"
    assert new["rows"] == BASE and new["rows"] is not BASE


def test_trajectory_file_round_trip(tmp_path):
    path = str(tmp_path / "BENCH_kernels.json")
    assert perf_gate.load_trajectory(path) == {"version": 1,
                                               "entries": []}
    traj = _trajectory(_entry(BASE))
    perf_gate.save_trajectory(traj, path)
    assert perf_gate.load_trajectory(path) == traj
    with open(path, "w") as f:
        json.dump({"version": 42}, f)
    try:
        perf_gate.load_trajectory(path)
        assert False, "expected ValueError"
    except ValueError as e:
        assert "42" in str(e)


def test_run_check_end_to_end(tmp_path):
    """Full CLI body on synthetic files: a passing run appends one
    entry, an injected 25% regression exits non-zero and appends
    nothing."""
    current = str(tmp_path / "current.json")
    traj_path = str(tmp_path / "BENCH_kernels.json")

    def write_current(rows):
        with open(current, "w") as f:
            json.dump({"meta": {**META, "smoke": True}, "rows": rows}, f)

    write_current(BASE)
    assert perf_gate.run_check(current_path=current,
                               trajectory_path=traj_path) == 0
    assert len(perf_gate.load_trajectory(traj_path)["entries"]) == 1

    write_current([_row("hist_smoke", 10_100.0),
                   _row("forest_fused_smoke", 8_200.0)])
    assert perf_gate.run_check(current_path=current,
                               trajectory_path=traj_path) == 0
    assert len(perf_gate.load_trajectory(traj_path)["entries"]) == 2

    write_current([_row("hist_smoke", 12_500.0),       # +25%
                   _row("forest_fused_smoke", 8_000.0)])
    assert perf_gate.run_check(current_path=current,
                               trajectory_path=traj_path) == 1
    assert len(perf_gate.load_trajectory(traj_path)["entries"]) == 2, \
        "a failing run must not be recorded"
