"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles over
shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import cases, choice, for_cases, grid, ints

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hist.kernel import hist_pallas
from repro.kernels.hist.ref import hist_ref
from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_ref, ssd_sequential
from repro.models.attention import chunked_attention

RNG = jax.random.PRNGKey(42)


FLASH_CASES = grid(
    shape=[(1, 64, 64, 4, 2, 32), (2, 128, 128, 4, 4, 64),
           (1, 96, 96, 6, 1, 32),            # unaligned, MQA
           (1, 32, 160, 2, 2, 32)],          # cross shape
    causal=[True, False],
    dtype=[jnp.float32, jnp.bfloat16],
)
# tier 1 runs the aligned + unaligned fp32 causal cases; the full
# shape/dtype sweep is tier 2
FLASH_FAST = [c for c in FLASH_CASES
              if c["dtype"] == jnp.float32 and c["causal"]
              and c["shape"][1] == 64]


@pytest.mark.slow
@for_cases(FLASH_CASES)
def test_flash_attention_matches_oracle(shape, causal, dtype):
    B, T, S, H, K, dh = shape
    if causal and T != S:
        return
    q = jax.random.normal(jax.random.fold_in(RNG, 1), (B, T, H, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(RNG, 2), (B, S, K, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(RNG, 3), (B, S, K, dh), dtype)
    ref = attention_ref(q, k, v, causal=causal)
    pal = flash_attention(q, k, v, causal=causal, block_q=32, block_kv=32,
                          interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@for_cases(FLASH_FAST)
def test_flash_attention_matches_oracle_fast(shape, causal, dtype):
    test_flash_attention_matches_oracle.body(shape, causal, dtype)


def test_flash_attention_sliding_window():
    B, T, H, dh = 1, 128, 4, 32
    q = jax.random.normal(jax.random.fold_in(RNG, 1), (B, T, H, dh))
    k = jax.random.normal(jax.random.fold_in(RNG, 2), (B, T, H, dh))
    v = jax.random.normal(jax.random.fold_in(RNG, 3), (B, T, H, dh))
    for w in (16, 64):
        ref = attention_ref(q, k, v, causal=True, window=w)
        pal = flash_attention(q, k, v, causal=True, window=w, block_q=32,
                              block_kv=32, interpret=True)
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        xla = chunked_attention(q, k, v, causal=True, window=w,
                                kv_chunk=32)
        np.testing.assert_allclose(np.asarray(xla), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


SSD_CASES = grid(
    dims=[(1, 64, 4, 32, 1, 16, 16), (2, 64, 8, 32, 2, 32, 32),
          (1, 96, 4, 64, 4, 8, 32)],
)


@pytest.mark.slow
@for_cases(SSD_CASES)
def test_ssd_kernel_matches_sequential(dims):
    B, T, H, P, G, N, Q = dims
    ks = [jax.random.fold_in(RNG, i) for i in range(5)]
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.5
    b = jax.random.normal(ks[3], (B, T, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, T, G, N)) * 0.3
    y_seq, s_seq = ssd_sequential(x, dt, a_log, b, c)
    y_chk, s_chk = ssd_ref(x, dt, a_log, b, c, Q)
    y_pal, s_pal = ssd_pallas(x, dt, a_log, b, c, Q, interpret=True)
    scale = float(jnp.max(jnp.abs(y_seq))) + 1e-6
    assert float(jnp.max(jnp.abs(y_chk - y_seq))) / scale < 1e-4
    assert float(jnp.max(jnp.abs(y_pal - y_seq))) / scale < 1e-4
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_seq),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_seq),
                               atol=1e-3)


def test_ssd_kernel_matches_sequential_fast():
    test_ssd_kernel_matches_sequential.body((1, 64, 4, 32, 1, 16, 16))


HIST_CASES = cases(6, seed=7, n=ints(64, 3000), F=ints(1, 24),
                   nb=choice(16, 64, 128))
HIST_FAST = HIST_CASES[:2]


@for_cases(HIST_FAST)
def test_hist_kernel_matches_oracle_fast(n, F, nb):
    test_hist_kernel_matches_oracle.body(n, F, nb)


@pytest.mark.slow
@for_cases(HIST_CASES)
def test_hist_kernel_matches_oracle(n, F, nb):
    ks = [jax.random.fold_in(RNG, i) for i in range(3)]
    bins = jax.random.randint(ks[0], (n, F), 0, nb)
    g = jax.random.normal(ks[1], (n,))
    h = jax.random.uniform(ks[2], (n,))
    r = hist_ref(bins, g, h, nb)
    p = hist_pallas(bins, g, h, nb, block_n=256, block_f=4, interpret=True)
    np.testing.assert_allclose(np.asarray(p), np.asarray(r), atol=2e-4)


def test_hist_mass_conservation():
    """Property: total grad mass is preserved per feature."""
    n, F, nb = 512, 5, 32
    bins = jax.random.randint(jax.random.fold_in(RNG, 0), (n, F), 0, nb)
    g = jax.random.normal(jax.random.fold_in(RNG, 1), (n,))
    h = jnp.abs(g)
    out = hist_pallas(bins, g, h, nb, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.sum(out[:, :, 0], axis=1)),
                               float(jnp.sum(g)) * np.ones(F), rtol=1e-4,
                               atol=1e-3)
