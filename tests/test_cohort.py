"""Property tests for the synthetic population cohorts
(``repro.data.cohort``): marginal fidelity to the Framingham twin,
label prevalence, determinism + prefix stability, and partitioner
row-preservation over pooled synthetic rows."""
import numpy as np
import pytest

from repro.data import cohort as C
from repro.data import framingham as F
from repro.data import partition as P


def test_spec_parsing():
    s = C.get_cohort("framingham_like:1000:16")
    assert (s.name, s.n_clients, s.rows_per_client) == \
        ("framingham_like", 1000, 16)
    assert s.n_features == len(F.FEATURES)
    assert s.total_rows == 16000
    assert C.get_cohort(s) is s
    with pytest.raises(KeyError):
        C.get_cohort("nope:3:4")
    with pytest.raises(ValueError):
        C.get_cohort("framingham_like:3")
    with pytest.raises(ValueError):
        C.get_cohort("framingham_like:0:4")


def test_shapes_and_dtypes():
    x, y = C.build_cohort("framingham_like:5:7", seed=3)
    assert x.shape == (5, 7, len(F.FEATURES))
    assert y.shape == (5, 7)
    assert x.dtype == np.float32 and y.dtype == np.float32
    assert set(np.unique(y)) <= {0.0, 1.0}


def test_marginals_match_reference():
    """Pooled standardized columns sit near zero mean / unit std —
    within a fraction of the per-feature sd, the right scale for
    near-constant binary columns (prevalentStroke has mean ~0.006)."""
    x, _ = C.build_cohort("framingham_like:512:16", seed=0)
    pooled = x.reshape(-1, x.shape[-1])
    assert np.all(np.abs(pooled.mean(0)) < 0.1)
    assert np.all(np.abs(pooled.std(0) - 1.0) < 0.1)


def test_label_prevalence():
    """Pooled prevalence tracks the twin's 15.2% positive rate."""
    _, y = C.build_cohort("framingham_like:1024:16", seed=0)
    assert abs(float(y.mean()) - 0.152) < 0.015
    _, yt = C.cohort_testset(seed=0, n=8192)
    assert abs(float(yt.mean()) - 0.152) < 0.02


def test_determinism_and_seed_sensitivity():
    x1, y1 = C.build_cohort("framingham_like:64:8", seed=5)
    x2, y2 = C.build_cohort("framingham_like:64:8", seed=5)
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
    x3, _ = C.build_cohort("framingham_like:64:8", seed=6)
    assert not np.array_equal(x1, x3)


def test_prefix_stability():
    """Growing n_clients never changes earlier clients' data — across
    a chunk boundary (CHUNK=256) and within one."""
    small_x, small_y = C.build_cohort("framingham_like:100:8", seed=1)
    big_x, big_y = C.build_cohort(
        f"framingham_like:{C.CHUNK + 50}:8", seed=1)
    assert np.array_equal(big_x[:100], small_x)
    assert np.array_equal(big_y[:100], small_y)


def test_rows_per_client_changes_draws():
    """rows_per_client is part of the stream layout, not a truncation:
    different row counts are different cohorts by contract."""
    x8, _ = C.build_cohort("framingham_like:4:8", seed=0)
    x16, _ = C.build_cohort("framingham_like:4:16", seed=0)
    assert not np.array_equal(x8, x16[:, :8])


def test_testset_disjoint_stream():
    """The held-out test set never reuses a generation chunk."""
    x, _ = C.build_cohort("framingham_like:8:16", seed=0)
    xt, _ = C.cohort_testset(seed=0, n=128)
    pooled = x.reshape(-1, x.shape[-1])
    assert not any(np.array_equal(pooled[i], xt[0])
                   for i in range(len(pooled)))


def test_reference_stats_frozen():
    """Labeling constants come from the reference draw only — they do
    not move when cohorts of any size are built."""
    before = C.reference_stats(seed=0)
    C.build_cohort("framingham_like:300:4", seed=0)
    after = C.reference_stats(seed=0)
    assert np.array_equal(before[0], after[0])
    assert before[2] == after[2] and before[3] == after[3]


@pytest.mark.parametrize("name", sorted(P.PARTITIONERS))
def test_partitioners_preserve_synthetic_rows(name):
    """Every registered partitioner keeps each pooled synthetic row
    exactly once — the same invariant the twin's shards carry."""
    x, y = C.build_cohort("framingham_like:32:8", seed=2)
    px, py = x.reshape(-1, x.shape[-1]), y.reshape(-1)
    kw = {"alpha": 0.5} if name in ("dirichlet", "quantity") else {}
    parts = P.partition_indices(name, px, py, 4, seed=0, **kw)
    P.check_partition(parts, len(px))
