"""Data pipeline: Framingham twin card-matching, partitioning, LM corpus."""
import numpy as np
import pytest

from repro.data import framingham as F
from repro.data.pipeline import (CorpusConfig, SyntheticCorpus, lm_batches,
                                 pod_mixtures, sync_mixtures)


def test_framingham_matches_dataset_card():
    ds = F.synthesize()
    assert ds.x.shape == (4238, 15)
    assert abs(float(ds.y.mean()) - 0.152) < 0.005
    assert ds.feature_names == F.FEATURES
    # standardized features
    assert np.all(np.abs(ds.x.mean(0)) < 0.05)
    assert np.all(np.abs(ds.x.std(0) - 1.0) < 0.05)
    # raw marginals near the published ones
    raw = {f: ds.raw[:, i] for i, f in enumerate(F.FEATURES)}
    assert 45 < raw["age"].mean() < 54
    assert 120 < raw["sysBP"].mean() < 145
    assert 0.35 < raw["male"].mean() < 0.50
    # smokers only have cigsPerDay > 0
    assert np.all(raw["cigsPerDay"][raw["currentSmoker"] == 0] == 0)


@pytest.mark.slow
def test_teacher_importance_ordering():
    """The twin must induce the paper's Table-1 top features."""
    import jax.numpy as jnp
    from repro.trees import gbdt
    ds = F.synthesize(seed=3)
    m = gbdt.fit(jnp.asarray(ds.x), jnp.asarray(ds.y), num_rounds=20,
                 depth=4)
    imp = np.asarray(gbdt.feature_importance(m))
    top4 = {ds.feature_names[i] for i in np.argsort(-imp)[:4]}
    assert len(top4 & {"age", "sysBP", "glucose", "totChol"}) >= 3


def test_stratified_partition_is_even_and_balanced():
    ds = F.synthesize()
    tr, te = F.train_test_split(ds, 0.8)
    assert len(tr.y) + len(te.y) == 4238
    clients = F.partition_clients(tr, 3)
    sizes = [len(c.y) for c in clients]
    assert max(sizes) - min(sizes) <= 2
    rates = [float(c.y.mean()) for c in clients]
    assert max(rates) - min(rates) < 0.01
    # disjoint
    all_idx = np.concatenate([c.x[:, 0] for c in clients])
    assert len(all_idx) == len(tr.y)


def test_dirichlet_partition_skews():
    ds = F.synthesize()
    tr, _ = F.train_test_split(ds)
    clients = F.partition_clients(tr, 3, alpha=0.2, seed=1)
    rates = [float(c.y.mean()) for c in clients]
    assert max(rates) - min(rates) > 0.03  # visibly non-IID


def test_lm_corpus_and_mixture_sync():
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=128, n_domains=3))
    it = lm_batches(corpus, batch=2, seq=64, seed=0)
    b = next(it)
    assert b["tokens"].shape == (2, 64)
    assert b["targets"].shape == (2, 64)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
    assert b["tokens"].max() < 128
    mixes = pod_mixtures(4, 3, alpha=0.3, seed=0)
    for m in mixes:
        np.testing.assert_allclose(m.sum(), 1.0)
    sync = sync_mixtures(mixes)
    np.testing.assert_allclose(sync, np.mean(np.stack(mixes), 0))
