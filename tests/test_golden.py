"""Golden regression: every pipeline's seeded small-config test-set
metrics must match the committed snapshot (``results/golden/
metrics.json``) within tolerance.  Regenerate intentionally with
``PYTHONPATH=src python tools/refresh_golden.py``."""
import importlib.util
import json
import os

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _refresh_mod():
    spec = importlib.util.spec_from_file_location(
        "refresh_golden", os.path.join(ROOT, "tools", "refresh_golden.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod

# one module-level compute: the five runs share their jit caches
RG = _refresh_mod()
with open(RG.GOLDEN_PATH) as f:
    GOLDEN = json.load(f)


@pytest.fixture(scope="module")
def computed():
    return RG.compute_metrics()


def test_golden_covers_every_pipeline():
    assert set(GOLDEN["metrics"]) == set(RG.GOLDEN_RUNS)


@pytest.mark.parametrize("pipeline", sorted(RG.EXACT_RUNS))
def test_golden_pure_runs_exact(computed, pipeline):
    # the load engine and the trace export are pure functions of
    # (spec, seed) — no BLAS jitter, so the snapshot must match to the
    # rounding digit, not merely within TOLERANCE
    assert computed[pipeline] == GOLDEN["metrics"][pipeline]


@pytest.mark.parametrize("pipeline", sorted(RG.GOLDEN_RUNS))
def test_golden_metrics_within_tolerance(computed, pipeline):
    want = GOLDEN["metrics"][pipeline]
    got = computed[pipeline]
    assert set(got) == set(want), (
        f"{pipeline}: metric keys changed — rerun tools/refresh_golden.py")
    drift = {k: (got[k], want[k]) for k in want
             if abs(got[k] - want[k]) > RG.TOLERANCE}
    assert not drift, (
        f"{pipeline} drifted beyond ±{RG.TOLERANCE} (ours, golden): "
        f"{drift} — if intentional, regenerate with "
        f"`PYTHONPATH=src python tools/refresh_golden.py`")
