"""Model semantics: decode-vs-prefill equivalence, sliding windows, MoE
dispatch invariants, SSD chunk-size invariance, loss chunking, RoPE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.models import api, layers as L, moe as M
from repro.models.attention import chunked_attention, decode_attention
from repro.models.params import init_tree
from repro.models.ssm import ssd_chunked
from repro.sharding import ShardingCtx

RUN = RunConfig()
CTX = ShardingCtx.null()
RNG = jax.random.PRNGKey(0)


def _hi_cap(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


@pytest.mark.parametrize("arch", [
    "qwen3_4b",
    pytest.param("yi_34b", marks=pytest.mark.slow),
    pytest.param("mamba2_13b", marks=pytest.mark.slow),
    pytest.param("hymba_15b", marks=pytest.mark.slow),
    pytest.param("phi35_moe", marks=pytest.mark.slow),
    pytest.param("whisper_medium", marks=pytest.mark.slow),
    pytest.param("internvl2_2b", marks=pytest.mark.slow)])
def test_decode_matches_prefill(arch):
    """Autoregressive consistency: decoding token T on a prefix cache must
    reproduce the full-prefill logits at T (capacity drops disabled)."""
    cfg = _hi_cap(R.get_smoke(arch))
    params = init_tree(RNG, api.param_defs(cfg))
    B, T = 2, 12
    toks = jax.random.randint(RNG, (B, T + 1), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = jax.random.normal(
            RNG, (B, cfg.encoder.seq_len, cfg.d_model))
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(
            RNG, (B, cfg.encoder.num_image_tokens,
                  cfg.encoder.frontend_dim))
    lg_full, _ = api.prefill(params, {"tokens": toks, **extra}, cfg, RUN,
                             CTX)
    _, cache = api.prefill(params, {"tokens": toks[:, :T], **extra}, cfg,
                           RUN, CTX)
    cache = {k: (jnp.pad(v, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0)))
                 if k in ("k", "v") else v) for k, v in cache.items()}
    pos = T + (cfg.encoder.num_image_tokens if cfg.family == "vlm" else 0)
    lg_dec, _ = api.decode_step(params, {"token": toks[:, T],
                                         "pos": jnp.int32(pos)},
                                cache, cfg, RUN, CTX)
    scale = float(jnp.max(jnp.abs(lg_full))) + 1e-6
    assert float(jnp.max(jnp.abs(lg_dec - lg_full))) / scale < 2e-2, arch


@pytest.mark.slow
def test_chunk_size_invariance():
    """Attention and SSD results must not depend on chunk sizes."""
    B, T, H, dh = 2, 96, 4, 32
    q = jax.random.normal(jax.random.fold_in(RNG, 1), (B, T, H, dh))
    k = jax.random.normal(jax.random.fold_in(RNG, 2), (B, T, H, dh))
    v = jax.random.normal(jax.random.fold_in(RNG, 3), (B, T, H, dh))
    outs = [chunked_attention(q, k, v, causal=True, kv_chunk=c)
            for c in (16, 32, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=1e-5)
    # block-skip path == scan path
    bs = chunked_attention(q, k, v, causal=True, kv_chunk=16, q_chunk=32,
                           block_skip=True)
    np.testing.assert_allclose(np.asarray(bs), np.asarray(outs[0]),
                               atol=1e-5)

    x = jax.random.normal(jax.random.fold_in(RNG, 4), (B, T, H, dh))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(RNG, 5),
                                           (B, T, H)))
    a = jax.random.normal(jax.random.fold_in(RNG, 6), (H,)) * 0.5
    bb = jax.random.normal(jax.random.fold_in(RNG, 7), (B, T, 1, 16)) * 0.3
    cc = jax.random.normal(jax.random.fold_in(RNG, 8), (B, T, 1, 16)) * 0.3
    y1, s1 = ssd_chunked(x, dt, a, bb, cc, 16)
    y2, s2 = ssd_chunked(x, dt, a, bb, cc, 48)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


def test_decode_attention_window_masks_history():
    B, S, H, dh = 1, 64, 2, 16
    q = jax.random.normal(jax.random.fold_in(RNG, 1), (B, H, dh))
    ck = jax.random.normal(jax.random.fold_in(RNG, 2), (B, S, H, dh))
    cv = jax.random.normal(jax.random.fold_in(RNG, 3), (B, S, H, dh))
    pos = 40
    full = decode_attention(q, ck, cv, pos)
    w8 = decode_attention(q, ck, cv, pos, window=8)
    # windowed must equal attention over only the last 8 positions
    ck2 = ck[:, pos - 7:pos + 1]
    cv2 = cv[:, pos - 7:pos + 1]
    ref = decode_attention(q, ck2, cv2, 7)
    np.testing.assert_allclose(np.asarray(w8), np.asarray(ref), atol=1e-5)
    assert float(jnp.max(jnp.abs(w8 - full))) > 1e-4  # actually different


def test_moe_weights_sum_and_capacity():
    cfg = R.get_smoke("dbrx_132b")  # 4 experts top-2 reduced
    p = init_tree(RNG, M.moe_defs(cfg))
    x = jax.random.normal(RNG, (64, cfg.d_model))
    w, idx, aux = M._route({"router": p["router"]}, x, cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert int(jnp.max(idx)) < cfg.moe.num_experts
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, = 1 balanced
    slot, keep, token = M._dispatch_indices(idx, cfg.moe.num_experts, 8)
    # no slot collisions among kept assignments
    kept = np.asarray(slot)[np.asarray(keep)]
    assert len(set(kept.tolist())) == len(kept)


def test_moe_local_zero_capacity_drops():
    """With capacity 0ish tokens drop to zero output, not NaN."""
    cfg = dataclasses.replace(
        R.get_smoke("phi35_moe"),
        moe=dataclasses.replace(R.get_smoke("phi35_moe").moe,
                                capacity_factor=0.01))
    p = init_tree(RNG, M.moe_defs(cfg))
    x = jax.random.normal(RNG, (2, 16, cfg.d_model))
    y, aux = M.moe_local(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_cross_entropy_chunking_invariant():
    B, T, d, V = 2, 24, 16, 50
    h = jax.random.normal(jax.random.fold_in(RNG, 1), (B, T, d))
    w = jax.random.normal(jax.random.fold_in(RNG, 2), (d, V))
    labels = jax.random.randint(jax.random.fold_in(RNG, 3), (B, T), 0, V)
    mask = (jax.random.uniform(jax.random.fold_in(RNG, 4), (B, T))
            > 0.3).astype(jnp.float32)
    losses = [L.cross_entropy_chunked(h, w, labels, mask, c)[0]
              for c in (6, 16, 48, 1000)]
    for x in losses[1:]:
        np.testing.assert_allclose(float(x), float(losses[0]), rtol=1e-5)


def test_rope_preserves_norm_and_relativity():
    T, H, dh = 16, 2, 32
    x = jax.random.normal(RNG, (1, T, H, dh))
    sin, cos = L.rope_tables(jnp.arange(T), dh, 10000.0)
    y = L.apply_rope(x, sin, cos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-4)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.fold_in(RNG, 9), (1, 1, 1, dh))
    k = jax.random.normal(jax.random.fold_in(RNG, 10), (1, 1, 1, dh))
    def dot_at(i, j):
        si, ci = L.rope_tables(jnp.arange(i, i + 1), dh, 10000.0)
        sj, cj = L.rope_tables(jnp.arange(j, j + 1), dh, 10000.0)
        return float(jnp.sum(L.apply_rope(q, si, ci)
                             * L.apply_rope(k, sj, cj)))
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-3
