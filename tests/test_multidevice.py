"""Multi-device semantics, run in a subprocess with 8 virtual host devices
(the main pytest process must keep seeing 1 device — DESIGN.md)."""
import os
import subprocess
import sys

import pytest

# tier 2: each test spawns a fresh interpreter that recompiles under a
# forced 8-device host platform
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT_EP = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.models import moe as M
from repro.models.params import init_tree
from repro.sharding import ShardingCtx

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
ctx = ShardingCtx(mesh=mesh)
cfg = R.get_smoke("phi35_moe")
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=8.0))
p = init_tree(jax.random.PRNGKey(1), M.moe_defs(cfg))
x = jax.random.normal(jax.random.PRNGKey(2), (8, 16, cfg.d_model))
y_local, _ = M.moe_local(p, x, cfg)
with jax.sharding.set_mesh(mesh):
    y_ep, _ = jax.jit(lambda p, x: M.moe_ep(p, x, cfg, ctx))(p, x)
    y_ep16, _ = jax.jit(lambda p, x: M.moe_ep(
        p, x, cfg, ctx, RunConfig(moe_gather_bf16=True)))(p, x)
err = float(jnp.max(jnp.abs(y_ep - y_local)))
assert err < 1e-4, err
err16 = float(jnp.max(jnp.abs(y_ep16 - y_local)))
assert err16 < 0.1, err16   # bf16 gather tolerance
print("EP-OK")
"""

SCRIPT_TRAIN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import registry as R
from repro.configs.base import RunConfig, ShapeConfig
from repro.launch.steps import build_train_step, make_ctx, opt_defs
from repro.models import api
from repro.models.params import init_tree, spec_tree, abstract_tree
from repro.sharding import ShardingCtx

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = R.get_smoke("qwen3_4b")
run = RunConfig()
# sharded step == unsharded step (same math under SPMD)
rng = jax.random.PRNGKey(0)
params = init_tree(rng, api.param_defs(cfg))
odefs = opt_defs(api.param_defs(cfg))
opt0 = init_tree(rng, odefs)
B, T = 8, 32
batch = {"tokens": jax.random.randint(rng, (B, T), 0, cfg.vocab_size),
         "targets": jax.random.randint(rng, (B, T), 0, cfg.vocab_size),
         "mask": jnp.ones((B, T), jnp.float32)}
null_step = jax.jit(build_train_step(cfg, run, ShardingCtx.null()))
p1, o1, m1 = null_step(params, opt0, batch)
ctx = make_ctx(mesh, "train")
with jax.sharding.set_mesh(mesh):
    sh_step = jax.jit(build_train_step(cfg, run, ctx))
    p2, o2, m2 = sh_step(params, opt0, batch)
d = max(float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 2e-2, d
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2
print("TRAIN-OK")
"""


def _run(script: str, expect: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert expect in out.stdout


def test_moe_expert_parallel_matches_local():
    _run(SCRIPT_EP, "EP-OK")


def test_sharded_train_step_matches_single_device():
    _run(SCRIPT_TRAIN, "TRAIN-OK")
