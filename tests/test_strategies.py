"""Aggregation-strategy registry: combine math, server optimizers,
registry resolution, and integration with the parametric FL pipeline
(incl. secure-agg compatibility of weighted averaging)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import STRATEGIES, Strategy, get_strategy


def _deltas(seed=0, n=3):
    r = np.random.default_rng(seed)
    return [{"w": jnp.asarray(r.normal(size=(4, 2)), jnp.float32),
             "b": jnp.asarray(r.normal(size=(5,)), jnp.float32)}
            for _ in range(n)]


def test_registry_resolution_and_overrides():
    assert {"fedavg", "fedavg_weighted", "fedprox", "fedavgm",
            "fedadam"} <= set(STRATEGIES)
    s = get_strategy("fedadam", server_lr=0.5)
    assert s.server_lr == 0.5 and s.adam
    assert STRATEGIES["fedadam"].server_lr == 0.1  # original untouched
    try:
        get_strategy("nope")
        raise AssertionError("expected KeyError")
    except KeyError as e:
        assert "fedavg" in str(e)


def test_fedavg_is_uniform_mean():
    s = get_strategy("fedavg")
    ds = _deltas()
    upd, state = s.aggregate(s.init_state(ds[0]), ds, [10, 20, 30])
    manual = jax.tree.map(lambda *xs: sum(xs) / 3, *ds)
    assert state is None
    for a, b in zip(jax.tree.leaves(upd), jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_weighted_fedavg_weights_by_sample_count():
    s = get_strategy("fedavg_weighted")
    sizes = [10, 20, 70]
    assert np.allclose(s.norm_weights(sizes), [0.1, 0.2, 0.7])
    ds = _deltas()
    upd, _ = s.aggregate(None, ds, sizes)
    manual = jax.tree.map(
        lambda a, b, c: 0.1 * a + 0.2 * b + 0.7 * c, *ds)
    for a, b in zip(jax.tree.leaves(upd), jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_fedprox_is_clientside_only():
    s = get_strategy("fedprox")
    assert s.client_mu > 0
    ds = _deltas()
    upd, _ = s.aggregate(s.init_state(ds[0]), ds, [1, 1, 1])
    avg, _ = get_strategy("fedavg").aggregate(None, ds, [1, 1, 1])
    for a, b in zip(jax.tree.leaves(upd), jax.tree.leaves(avg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_fedavgm_momentum_accumulates():
    s = get_strategy("fedavgm", momentum=0.5, server_lr=1.0)
    g = {"w": jnp.ones((2,))}
    state = s.init_state(g)
    u1, state = s.server_update(state, g)
    u2, state = s.server_update(state, g)
    np.testing.assert_allclose(np.asarray(u1["w"]), 1.0)
    np.testing.assert_allclose(np.asarray(u2["w"]), 1.5)   # 0.5*1 + 1
    np.testing.assert_allclose(np.asarray(state["m"]["w"]), 1.5)


def test_fedadam_matches_manual_step():
    s = get_strategy("fedadam", beta1=0.9, beta2=0.99, eps=1e-3,
                     server_lr=0.1)
    g = {"w": jnp.asarray([0.2, -0.4])}
    state = s.init_state(g)
    upd, state = s.server_update(state, g)
    m = 0.1 * np.asarray([0.2, -0.4])
    v = 0.01 * np.asarray([0.2, -0.4]) ** 2
    np.testing.assert_allclose(np.asarray(upd["w"]),
                               0.1 * m / (np.sqrt(v) + 1e-3), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(state["v"]["w"]), v, rtol=1e-5)


def test_custom_strategy_registration():
    from repro.core.strategies import register
    register(Strategy("half_avg", server_lr=0.5))
    try:
        s = get_strategy("half_avg")
        upd, _ = s.aggregate(None, _deltas(), [1, 1, 1])
        avg, _ = get_strategy("fedavg").aggregate(None, _deltas(),
                                                  [1, 1, 1])
        for a, b in zip(jax.tree.leaves(upd), jax.tree.leaves(avg)):
            np.testing.assert_allclose(np.asarray(a), 0.5 * np.asarray(b),
                                       rtol=1e-6)
    finally:
        STRATEGIES.pop("half_avg", None)


def test_parametric_weighted_secure_agg_equivalence():
    """Pre-masking weighting must keep secure-agg mask cancellation:
    the run with masks on equals the run with masks off exactly."""
    from repro.core.parametric import FedParametricConfig, train_federated
    r = np.random.default_rng(3)
    clients = [(r.normal(size=(n, 4)).astype(np.float32),
                (r.uniform(size=n) > 0.5).astype(np.float32))
               for n in (60, 120, 240)]
    base = dict(model="logreg", rounds=2, local_steps=10, lr=0.05,
                sampling="none", strategy="fedavg_weighted", seed=0)
    p_plain, *_ = train_federated(clients,
                                  FedParametricConfig(**base))
    p_masked, *_ = train_federated(clients,
                                   FedParametricConfig(secure_agg=True,
                                                       **base))
    for a, b in zip(jax.tree.leaves(p_plain), jax.tree.leaves(p_masked)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_parametric_server_optimizers_run():
    from repro.core.parametric import FedParametricConfig, train_federated
    r = np.random.default_rng(4)
    x = r.normal(size=(150, 4)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.float32)
    clients = [(x[:75], y[:75]), (x[75:], y[75:])]
    for name in ("fedavgm", "fedadam", "fedprox"):
        cfg = FedParametricConfig(model="logreg", rounds=3, local_steps=15,
                                  lr=0.05, strategy=name, seed=0)
        params, comm, _, _ = train_federated(clients, cfg,
                                             test=(x, y))
        assert all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree.leaves(params)), name
        assert comm.total_bytes("up") > 0
