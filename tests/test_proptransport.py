"""Property tests (tests/proptest.py driver) for the wire layer:

* every ``TRANSPORTS`` preset round-trips a float update pytree through
  ``encode`` — structure/shape/dtype identity always, value identity for
  the lossless stacks, bounded/structured error for the codec stacks,
  and determinism (same payload + ctx → bit-identical wire msg);
* secure aggregation cancels: the server's sum of per-client masked
  updates equals the plain sum across random client counts and shapes,
  both through ``privacy.mask_update`` directly and through a
  mask-layer transport stack.
"""
import jax
import jax.numpy as jnp
import numpy as np

from proptest import cases, for_cases, ints

from repro.core import privacy
from repro.core.comm import TRANSPORTS, WireCtx, get_transport
from repro.core.privacy import mask_update, secure_sum

#: presets whose client-side encode is value-preserving when the payload
#: is inside the clip ball and the round has a single active client
#: (clip scales by 1, a 1-client mask has no peers, dpnoise/weight act
#: server-side / as *1.0): everything without a codec or HE layer.
LOSSLESS = ("plain", "framed", "secure", "dp", "secure_dp")
CODECS = ("sparse", "quant", "full_stack")
#: presets with the fixed-point HE cost-model layer: lossy at the
#: quantization step (gated in test_he_presets_* below)
HE = ("he", "he_dp")


def _payload(rng, scale=0.01):
    """Small-norm float32 pytree (inside every preset's clip ball)."""
    return {
        "w": jnp.asarray(rng.normal(size=(6, 5)) * scale, jnp.float32),
        "b": jnp.asarray(rng.normal(size=(5,)) * scale, jnp.float32),
    }


def _leaves(t):
    return [np.asarray(x) for x in jax.tree.leaves(t)]


def test_every_preset_roundtrips_structure_and_determinism():
    rng = np.random.default_rng(0)
    delta = _payload(rng)
    for name in sorted(TRANSPORTS):
        t = get_transport(name, rho=0.25, dp_clip=1.0)
        ctx = WireCtx(round=1, client=0, slot=0, n_active=1, seed=3)
        msg = t.encode(delta, ctx=ctx)
        # structure/shape/dtype identity: the wire msg stays decodable
        assert (jax.tree.structure(msg.payload)
                == jax.tree.structure(delta)), name
        for a, b in zip(_leaves(msg.payload), _leaves(delta)):
            assert a.shape == b.shape and a.dtype == b.dtype, name
        assert msg.nbytes > 0, name
        # determinism: bit-identical on re-encode
        msg2 = get_transport(name, rho=0.25, dp_clip=1.0).encode(
            delta, ctx=ctx)
        assert msg.nbytes == msg2.nbytes, name
        for a, b in zip(_leaves(msg.payload), _leaves(msg2.payload)):
            np.testing.assert_array_equal(a, b, err_msg=name)
        if name in LOSSLESS:
            for a, b in zip(_leaves(msg.payload), _leaves(delta)):
                np.testing.assert_array_equal(a, b, err_msg=name)


CODEC_CASES = cases(4, seed=13, n=ints(4, 40), m=ints(2, 12),
                    seed2=ints(0, 10 ** 6))


@for_cases(CODEC_CASES)
def test_codec_presets_error_is_structured(n, m, seed2):
    """sparse: kept entries are the original values, the rest zero;
    quant (int8_sr): elementwise error below one quantization step."""
    rng = np.random.default_rng(seed2)
    delta = {"w": jnp.asarray(rng.normal(size=(n, m)), jnp.float32)}
    ctx = WireCtx(round=0, client=1, slot=0, n_active=1, seed=seed2)
    sp = get_transport("sparse", rho=0.25).encode(delta, ctx=ctx)
    w, ww = np.asarray(delta["w"]), np.asarray(sp.payload["w"])
    assert np.all((ww == 0) | (ww == w))
    assert np.count_nonzero(ww) <= max(int(np.ceil(0.25 * w.size)), 1)
    assert sp.nbytes < w.size * 4 or w.size <= 4   # sparser on the wire
    q = get_transport("quant").encode(delta, ctx=ctx)
    step = np.abs(w).max() / 127.0
    assert np.abs(np.asarray(q.payload["w"]) - w).max() <= step + 1e-7


MASK_CASES = cases(8, seed=5, c=ints(2, 6), n=ints(1, 30),
                   m=ints(1, 8), seed2=ints(0, 10 ** 6))


@for_cases(MASK_CASES)
def test_secure_agg_masks_cancel_in_sum(c, n, m, seed2):
    """privacy invariant: sum_i mask(u_i) == sum_i u_i — the server only
    ever sees the aggregate, for any client count and leaf shapes."""
    rng = np.random.default_rng(seed2)
    updates = [{"w": jnp.asarray(rng.normal(size=(n, m)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(m,)), jnp.float32)}
               for _ in range(c)]
    masked = [mask_update(u, i, c, round_seed=seed2 * 7919 + 1)
              for i, u in enumerate(updates)]
    plain_sum = secure_sum(updates)
    masked_sum = secure_sum(masked)
    scale = max(1.0, max(float(np.abs(x).max())
                         for x in _leaves(masked)))
    for a, b in zip(_leaves(masked_sum), _leaves(plain_sum)):
        np.testing.assert_allclose(a, b, atol=2e-4 * scale * c)
    # a single masked update is NOT the plain update (masks exist)
    if c >= 2:
        assert any(not np.allclose(a, b, atol=1e-6)
                   for a, b in zip(_leaves(masked[0]),
                                   _leaves(updates[0])))


HE_CASES = cases(5, seed=7, n=ints(1, 64), m=ints(1, 9),
                 frac=ints(4, 20), seed2=ints(0, 10 ** 6))


@for_cases(HE_CASES)
def test_he_presets_quantize_within_one_step(n, m, frac, seed2):
    """The HE cost-model layer is fixed-point lossy: per-scalar error is
    bounded by half a quantization step (payloads inside the clip ball,
    so no magnitude clipping triggers)."""
    rng = np.random.default_rng(seed2)
    delta = {"w": jnp.asarray(rng.normal(size=(n, m)) * 0.01,
                              jnp.float32)}
    for name in HE:
        t = get_transport(name, he_frac_bits=frac)
        msg = t.encode(delta, ctx=WireCtx(round=0, client=0, slot=0,
                                          n_active=1, seed=seed2))
        err = np.abs(np.asarray(msg.payload["w"])
                     - np.asarray(delta["w"])).max()
        assert err <= 2.0 ** -frac, (name, err)


@for_cases(cases(4, seed=9, n=ints(1, 5000), c=ints(1, 40),
                 seed2=ints(0, 10 ** 6)))
def test_he_byte_accounting_matches_cost_model(n, c, seed2):
    """Wire bytes == ceil(n_scalars / slots_per_ct) * 2*key_bits/8 with
    slot width int+frac+sign+ceil(log2(n_active)) — the honest Paillier
    ciphertext-expansion accounting."""
    from repro.core.comm import HELayer
    lay = HELayer(key_bits=2048, frac_bits=16, int_bits=8)
    slot_bits = 8 + 16 + 1 + max(1, c).bit_length()
    slots = max(1, 2048 // slot_bits)
    expect = -(-n // slots) * (2 * 2048 // 8)
    assert lay.wire_bytes(n, c) == expect
    rng = np.random.default_rng(seed2)
    delta = {"w": jnp.asarray(rng.normal(size=(n,)) * 0.01, jnp.float32)}
    msg = get_transport("he").encode(
        delta, ctx=WireCtx(round=0, client=0, slot=0, n_active=c,
                           seed=seed2))
    assert msg.nbytes == expect


@for_cases(cases(4, seed=3, c=ints(2, 5), seed2=ints(0, 10 ** 6)))
def test_mask_transport_stack_cancels_like_privacy(c, seed2):
    """The 'secure' transport preset must realize exactly the
    privacy.mask_update math: summing every slot's encoded payload over
    the round's active set recovers the plain sum."""
    rng = np.random.default_rng(seed2)
    t = get_transport("secure")
    updates = [{"w": jnp.asarray(rng.normal(size=(5, 3)), jnp.float32)}
               for _ in range(c)]
    msgs = [t.encode(u, ctx=WireCtx(round=2, client=i, slot=i,
                                    n_active=c, seed=seed2))
            for i, u in enumerate(updates)]
    plain = secure_sum(updates)
    wire = secure_sum([m.payload for m in msgs])
    for a, b in zip(_leaves(wire), _leaves(plain)):
        np.testing.assert_allclose(a, b, atol=1e-3)
