"""Tiered test suite.

Tier-1 (the default, CI's fast gate):  ``pytest -x -q`` — tests marked
``slow`` are deselected, keeping the suite a few minutes on CPU
(currently 200 fast-tier tests; 49 deselected into tier 2).  The
fast tier keeps at least one test on every subsystem; the heavyweight
end-to-end sweeps (multi-arch smoke, LM system runs, multi-device
subprocesses, big kernel oracle sweeps) live in tier 2.

Tier-2 (nightly-style CI job):  ``pytest -q -m "slow or not slow"``
runs everything.  Any explicit ``-m`` expression disables the default
deselection, so ``-m slow`` runs only the slow tier.
"""
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: tier-2 test — deselected by default; run the full suite "
        "with -m 'slow or not slow'")


def pytest_collection_modifyitems(config, items):
    if config.getoption("-m"):
        return                     # explicit marker expression wins
    deselected = [i for i in items if "slow" in i.keywords]
    if deselected:
        config.hook.pytest_deselected(items=deselected)
        items[:] = [i for i in items if "slow" not in i.keywords]
