"""Federated-learning core: aggregation math, secure-agg mask cancellation,
DP calibration, compression + error feedback, tree-subset protocol, fed
SMOTE statistics, comm ledger."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C
from repro.core import privacy as PR
from repro.core.comm import CommLog, pytree_bytes
from repro.core.metrics import binary_metrics
from repro.data import framingham as F
from repro.data import sampling as S

RNG = np.random.default_rng(5)


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.normal(size=(8, 4)), jnp.float32),
            "b": {"c": jnp.asarray(r.normal(size=(16,)), jnp.float32)}}


def test_secure_agg_masks_cancel_exactly():
    updates = [_tree(i) for i in range(4)]
    plain_sum = jax.tree.map(lambda *xs: sum(xs), *updates)
    masked = [PR.mask_update(u, i, 4, round_seed=7)
              for i, u in enumerate(updates)]
    # individual masked updates differ from the true ones
    assert float(jnp.max(jnp.abs(masked[0]["a"] - updates[0]["a"]))) > 0.1
    masked_sum = PR.secure_sum(masked)
    for a, b in zip(jax.tree.leaves(plain_sum), jax.tree.leaves(masked_sum)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_dp_sigma_calibration_and_clip():
    s = PR.gaussian_sigma(0.5, 1e-5, 1.0)
    assert 9.0 < s < 10.0   # sqrt(2 ln(1.25e5))/0.5 ≈ 9.37
    t = _tree()
    clipped, nrm = PR.clip_update(t, 0.5)
    leaves = jax.tree.leaves(clipped)
    total = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in leaves)))
    assert total <= 0.5 + 1e-5
    noised = PR.add_dp_noise(t, 0.5, 1e-5, 0.01, seed=3)
    assert float(jnp.max(jnp.abs(noised["a"] - t["a"]))) > 1e-3


def test_topk_compression_error_feedback():
    """EF invariant: kept + residual == original (+ previous residual);
    over rounds the residual mass is bounded."""
    delta = _tree()
    kept, state, nbytes = C.topk_compress(delta, rho=0.25)
    recon = jax.tree.map(lambda k, r: k + r, kept, state.residual)
    for a, b in zip(jax.tree.leaves(recon), jax.tree.leaves(delta)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # sparsity: at most ceil(rho*n) nonzeros per leaf
    for k in jax.tree.leaves(kept):
        nz = int(jnp.sum(k != 0))
        assert nz <= int(np.ceil(0.25 * k.size))
    # wire bytes < dense bytes
    assert nbytes < C.dense_bytes(delta)
    # repeated compression of a CONSTANT delta: EF releases everything
    acc = None
    state = None
    target = delta
    shipped_total = jax.tree.map(jnp.zeros_like, delta)
    for r in range(30):
        kept, state, _ = C.topk_compress(target, 0.25, state)
        shipped_total = jax.tree.map(lambda s, k: s + k, shipped_total,
                                     kept)
    expect = jax.tree.map(lambda d: d * 30, delta)
    rel = max(float(jnp.max(jnp.abs(a - b))) / (float(jnp.max(jnp.abs(b)))
                                                + 1e-9)
              for a, b in zip(jax.tree.leaves(shipped_total),
                              jax.tree.leaves(expect)))
    assert rel < 0.1


def test_lowrank_and_int8():
    delta = _tree()
    lr, nb = C.lowrank_compress(delta, rank=2)
    assert nb < C.dense_bytes(delta)
    q, nbq = C.int8_compress(delta)
    err = float(jnp.max(jnp.abs(q["a"] - delta["a"])))
    assert err < 0.05  # int8 quant error bound for unit-scale data
    assert nbq < C.dense_bytes(delta) / 3


def test_fed_smote_statistics_and_balance():
    ds = F.synthesize(n=1200, seed=1)
    tr, _ = F.train_test_split(ds)
    clients = F.partition_clients(tr, 3, alpha=0.4)
    stats = [S.minority_stats(c.x, c.y) for c in clients]
    mu_g, var_g = S.aggregate_stats(stats)
    assert mu_g.shape == (15,) and var_g.shape == (15,)
    np.testing.assert_allclose(mu_g, np.mean([s[0] for s in stats], 0))
    x2, y2 = S.fed_smote(clients[0].x, clients[0].y, mu_g, var_g)
    assert abs(y2.mean() - 0.5) < 0.02          # balanced after synth
    assert len(y2) > len(clients[0].y)
    # no raw rows crossed: synthetic rows are not copies of real rows
    synth = x2[len(clients[0].y):]
    d = ((synth[:, None, :] - clients[0].x[None, :20, :]) ** 2).sum(-1)
    assert d.min() > 1e-6


def test_local_sampling_strategies_balance():
    ds = F.synthesize(n=1500, seed=2)
    for name in ["ros", "rus", "smote"]:
        x2, y2 = S.apply_strategy(name, ds.x, ds.y, seed=0)
        assert abs(float(np.mean(y2)) - 0.5) < 0.05, name
    x3, y3 = S.apply_strategy("none", ds.x, ds.y)
    assert len(y3) == len(ds.y)


def test_comm_ledger():
    log = CommLog()
    log.log(0, "c0", "up", 1000, "m")
    log.log(0, "c1", "up", 2000, "m")
    log.log(1, "c0", "down", 500, "m")
    assert log.total_bytes() == 3500
    assert log.total_bytes("up") == 3000
    assert abs(log.uplink_mb() - 0.003) < 1e-9
    assert log.per_round_mb()[0] == 0.003
    t = _tree()
    assert pytree_bytes(t) == 8 * 4 * 4 + 16 * 4


def test_metrics_known_values():
    pred = np.array([1, 1, 0, 0, 1])
    y = np.array([1, 0, 0, 1, 1])
    m = binary_metrics(pred, y)
    assert m["tp"] == 2 and m["fp"] == 1 and m["fn"] == 1
    np.testing.assert_allclose(m["precision"], 2 / 3)
    np.testing.assert_allclose(m["recall"], 2 / 3)
    np.testing.assert_allclose(m["f1"], 2 / 3)


def test_fedavg_is_mean_of_client_optima():
    """One-round FedAvg with full local convergence on quadratic losses
    lands at the mean of local optima (sanity of the aggregation math)."""
    from repro.core.parametric import FedParametricConfig, train_federated
    r = np.random.default_rng(0)
    # two clients with pure-bias logistic problems pulling opposite ways
    x0 = r.normal(size=(200, 3)).astype(np.float32)
    clients = [(x0, np.ones(200, np.float32)),
               (x0, np.zeros(200, np.float32))]
    cfg = FedParametricConfig(model="logreg", rounds=3, local_steps=60,
                              lr=0.1, sampling="none")
    params, comm, hist, _ = train_federated(clients, cfg)
    # opposing labels -> aggregated bias stays near 0
    assert abs(float(params["b"])) < 0.5
    assert comm.total_bytes("up") > 0 and comm.total_bytes("down") > 0
