"""Tree library: growth invariants, learning power, importance, binning."""
import jax
import jax.numpy as jnp
import numpy as np

from proptest import cases, for_cases, ints

from repro.trees import binning, forest, gbdt
from repro.trees.growth import grow_tree, nbytes, predict_tree

RNG = np.random.default_rng(3)


def _data(n=600, F=8, sep=2.0):
    X = RNG.normal(size=(n, F)).astype(np.float32)
    y = (X[:, 0] + 0.5 * X[:, 1] * X[:, 2] + RNG.normal(size=n) / sep
         > 0).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(y)


def test_binning_roundtrip_monotone():
    x, _ = _data()
    edges = binning.fit_bins(x, 32)
    b = binning.apply_bins(x, edges)
    assert int(jnp.min(b)) >= 0 and int(jnp.max(b)) < 32
    # monotone: larger value -> bin index >= smaller value's bin
    col = np.asarray(x[:, 0])
    order = np.argsort(col)
    bins_sorted = np.asarray(b[:, 0])[order]
    assert np.all(np.diff(bins_sorted) >= 0)


def test_tree_consistency_train_vs_raw_thresholds():
    """Tree routing via raw thresholds must reproduce the training-time
    bin routing (threshold = upper bin edge)."""
    x, y = _data()
    edges = binning.fit_bins(x, 32)
    bins = binning.apply_bins(x, edges)
    p = jnp.full_like(y, 0.5)
    tree = grow_tree(bins, edges, p - y, p * (1 - p), jnp.ones_like(y),
                     depth=3, n_bins=32)
    vals = predict_tree(tree, x)
    # every training sample's prediction equals its leaf's fitted value ->
    # predictions take at most 2^depth distinct values
    assert len(np.unique(np.asarray(vals).round(6))) <= 8


def test_gbdt_reduces_train_loss_monotonically_ish():
    x, y = _data(n=400)
    m = gbdt.fit(x, y, num_rounds=10, depth=3, learning_rate=0.4)
    margins = [m.base_margin * jnp.ones(len(y))]
    from repro.trees.growth import predict_forest
    vals = predict_forest(m.forest, x)
    losses = []
    acc = margins[0]
    for t in range(vals.shape[0]):
        acc = acc + m.learning_rate * vals[t]
        p = jax.nn.sigmoid(acc)
        eps = 1e-7
        losses.append(float(-jnp.mean(
            y * jnp.log(p + eps) + (1 - y) * jnp.log(1 - p + eps))))
    assert losses[-1] < losses[0] * 0.8
    assert losses[-1] == min(losses)


def test_gbdt_learns_and_importance_finds_signal():
    x, y = _data(n=500)
    m = gbdt.fit(x, y, num_rounds=12, depth=4)
    pred = gbdt.predict(m, x)
    acc = float(jnp.mean(pred == (y > 0.5)))
    assert acc > 0.9
    imp = np.asarray(gbdt.feature_importance(m))
    np.testing.assert_allclose(imp.sum(), 1.0, atol=1e-5)
    assert imp[0] == imp.max()          # x0 is the dominant feature


def test_rf_vote_and_bytes():
    x, y = _data(n=400)
    rf = forest.fit(x, y, num_trees=6, depth=3)
    votes = forest.predict_votes(rf, x)
    proba = forest.predict_proba(rf, x)
    assert votes.shape == (len(y),)
    assert float(jnp.min(proba)) >= 0 and float(jnp.max(proba)) <= 1
    # nbytes is linear in the number of trees
    from repro.trees.growth import take_trees
    b6 = nbytes(rf.forest)
    b3 = nbytes(take_trees(rf.forest, jnp.arange(3)))
    assert b6 == 2 * b3


PROP_CASES = cases(2, seed=11, depth=ints(2, 5), nb=ints(8, 64))


@for_cases(PROP_CASES)
def test_grow_tree_properties(depth, nb):
    x, y = _data(n=300, F=5)
    edges = binning.fit_bins(x, nb)
    bins = binning.apply_bins(x, edges)
    p = jnp.full_like(y, 0.5)
    w = jnp.ones_like(y)
    tree = grow_tree(bins, edges, p - y, p * (1 - p), w, depth=depth,
                     n_bins=nb)
    assert tree.feature.shape == (2 ** depth - 1,)
    assert tree.leaf.shape == (2 ** depth,)
    # features are valid indices or -1
    f = np.asarray(tree.feature)
    assert np.all((f >= -1) & (f < 5))
    # leaf values bounded by the newton step |G|/(H+lam) <= 0.5n/(0.25n)
    assert float(jnp.max(jnp.abs(tree.leaf))) <= 2.0 + 1e-6
    # gains non-negative
    assert float(jnp.min(tree.gain)) >= 0.0


def test_rf_excluded_samples_dont_matter():
    """Zero bootstrap weight = excluded: growing with w=0 for some rows
    equals growing on the subset."""
    x, y = _data(n=200, F=4)
    edges = binning.fit_bins(x, 16)
    bins = binning.apply_bins(x, edges)
    p = jnp.full_like(y, 0.5)
    g, h = p - y, p * (1 - p)
    w = jnp.asarray((RNG.random(200) > 0.4).astype(np.float32))
    t1 = grow_tree(bins, edges, g, h, w, depth=3, n_bins=16)
    keep = np.asarray(w) > 0
    t2 = grow_tree(bins[keep], edges, g[keep], h[keep],
                   jnp.ones(int(keep.sum())), depth=3, n_bins=16)
    np.testing.assert_array_equal(np.asarray(t1.feature),
                                  np.asarray(t2.feature))
    np.testing.assert_allclose(np.asarray(t1.leaf), np.asarray(t2.leaf),
                               atol=1e-5)
