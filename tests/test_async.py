"""Async virtual-time runtime invariants: the sync reduction (async:n +
zero latency == the synchronous loop bit-exactly), event-order and
metric determinism under a fixed seed, dropout ledger accounting, the
latency-model registry, and the one-shot tree pipelines under buffered
aggregation."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import parametric as P
from repro.core.latency import get_latency
from repro.core.runtime import (ClientMsg, ClientWork, FedRuntime,
                                ServerAgg, get_schedule)
from repro.data import framingham as F


def _clients(n=500, k=3, seed=1):
    ds = F.synthesize(n=n, seed=seed)
    tr, te = F.train_test_split(ds)
    return [(c.x, c.y) for c in F.partition_clients(tr, k)], (te.x, te.y)


def _strip(events):
    return [{k: v for k, v in e.items() if k != "t"} for e in events]


# --- registries ---------------------------------------------------------------

def test_schedule_registry():
    assert get_schedule("sync") == ("sync", 0)
    assert get_schedule("async") == ("async", 1)
    assert get_schedule("async:4") == ("async", 4)
    with pytest.raises(KeyError):
        get_schedule("eventually")
    with pytest.raises(ValueError):
        get_schedule("sync:2")        # sync takes no args
    with pytest.raises(ValueError):
        get_schedule("async:0")


def test_latency_registry_and_composition(tmp_path):
    assert get_latency(None) is None and get_latency("none") is None
    c = get_latency("constant:2.5")
    assert c.draw(0, 0).delay == 2.5 and not c.draw(0, 0).dropped
    ln = get_latency("lognormal:0:0.5", seed=3)
    d = ln.draw(1, 4)
    assert d.delay > 0
    assert ln.draw(1, 4).delay == d.delay       # seeded, order-free
    assert ln.draw(1, 5).delay != d.delay
    # composition: delays add, drops OR together
    comp = get_latency("constant:1+dropout:1.0", seed=0)
    out = comp.draw(0, 0)
    assert out.delay == 1.0 and out.dropped
    # trace files: list = per-client constants; dict = cycled sequences
    p = tmp_path / "lat.json"
    p.write_text(json.dumps([1.0, 4.0]))
    tr = get_latency(f"trace:{p}")
    assert tr.draw(0, 7).delay == 1.0 and tr.draw(1, 0).delay == 4.0
    assert tr.draw(2, 0).delay == 1.0           # modulo clients
    p.write_text(json.dumps({"0": [1.0, 2.0]}))
    tr = get_latency(f"trace:{p}")
    assert [tr.draw(0, k).delay for k in range(3)] == [1.0, 2.0, 1.0]
    with pytest.raises(KeyError):
        get_latency("warp-speed")


# --- the sync reduction -------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(),
    dict(strategy="fedadam", sampling="ros"),
    dict(strategy="fedavg_weighted"),  # cohort-independent weight fold
])
def test_async_n_zero_latency_equals_sync_parametric(kw):
    """The acceptance bar: with zero latency and K = n_clients the
    async event loop IS the synchronous round loop — same params, same
    metrics trace, same ledger events (modulo the virtual-time stamp)."""
    clients, test = _clients()
    base = dict(model="logreg", rounds=3, local_steps=6, lr=0.05, **kw)
    ps, cs, hs, _ = P.train_federated(
        clients, P.FedParametricConfig(**base), test=test)
    pa, ca, ha, _ = P.train_federated(
        clients, P.FedParametricConfig(schedule=f"async:{len(clients)}",
                                       **base), test=test)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(ps)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert _strip(ca.events) == cs.events
    assert [{k: v for k, v in h.items() if k not in ("t", "round")}
            for h in ha] == hs
    # async events all carry the virtual-time stamp
    assert all("t" in e for e in ca.events)


def test_async_n_zero_latency_equals_sync_fed_hist():
    from repro.core import fed_hist as FH
    clients, test = _clients(n=400, k=3)
    base = dict(num_rounds=3, depth=3, n_bins=16, seed=0)
    ms, cs, _ = FH.train_federated_xgb_hist(clients,
                                            FH.FedHistConfig(**base))
    ma, ca, _ = FH.train_federated_xgb_hist(
        clients, FH.FedHistConfig(schedule="async:3", **base))
    np.testing.assert_array_equal(np.asarray(ms.forest.feature),
                                  np.asarray(ma.forest.feature))
    np.testing.assert_array_equal(np.asarray(ms.forest.leaf),
                                  np.asarray(ma.forest.leaf))
    assert _strip(ca.events) == _strip(cs.events)
    assert ca.total_bytes() == cs.total_bytes()


def test_sync_latency_model_does_not_change_results():
    """In sync mode the latency model only drives the virtual clock (the
    barrier waits for the slowest client) — params and ledger bytes are
    untouched; the timeline is monotone with one record per round."""
    clients, test = _clients()
    base = dict(model="logreg", rounds=3, local_steps=5)
    p0, c0, h0, _ = P.train_federated(
        clients, P.FedParametricConfig(**base), test=test)
    work = P._ParametricWork(clients, P.FedParametricConfig(**base),
                             P.get_strategy("fedavg"), 0.0,
                             (P._prep("logreg", test[0]), test[1]))
    rt = FedRuntime(n_clients=len(clients), rounds=3,
                    latency="lognormal:0:1", seed=0)
    p1 = rt.run(work)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert _strip(rt.comm.events) == c0.events
    ts = [rec["t"] for rec in rt.timeline]
    assert len(ts) == 3 and ts == sorted(ts) and ts[0] > 0


# --- determinism --------------------------------------------------------------

def test_async_run_is_deterministic_under_fixed_seed():
    clients, test = _clients()
    cfg = P.FedParametricConfig(model="logreg", rounds=3, local_steps=4,
                                schedule="async:2",
                                latency="lognormal:0:1+dropout:0.2",
                                seed=7)
    out = [P.train_federated(clients, cfg, test=test) for _ in range(2)]
    (pa, ca, ha, _), (pb, cb, hb, _) = out
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ca.events == cb.events
    assert ha == hb


# --- ledger accounting under drops --------------------------------------------

class _CountingWork(ClientWork, ServerAgg):
    """Synthetic plugin: fixed 8-byte uplink per dispatch, sum server."""

    def __init__(self):
        self.aggregated = []

    def setup(self, rt):
        return {"sum": np.zeros(2)}

    def client_round(self, rt, state, rnd):
        msgs = []
        for i in rnd.computing:
            rt.log_up(rnd.index, i, 8, "update")
            msgs.append(ClientMsg(i, jnp.ones(2), 8))
        return msgs

    def aggregate(self, rt, state, msgs, rnd):
        self.aggregated.extend(m.client for m in msgs)
        state["sum"] = state["sum"] + sum(np.asarray(m.payload)
                                          for m in msgs)
        return state


def test_dropout_model_preserves_ledger_byte_accounting():
    """Every dispatch ships (and logs) its bytes whether or not the
    upload survives: up-bytes == 8 * dispatches, aggregated messages ==
    rounds * K, and lost uploads are exactly the difference."""
    work = _CountingWork()
    rt = FedRuntime(n_clients=3, rounds=4, schedule="async:2",
                    latency="constant:1+dropout:0.4", seed=11)
    rt.run(work)
    ups = [e for e in rt.comm.events if e["direction"] == "up"]
    dispatches = sum(rt._n_dispatch)
    assert len(ups) == dispatches
    assert rt.comm.total_bytes("up") == 8 * dispatches
    assert len(work.aggregated) == 4 * 2
    assert dispatches >= len(work.aggregated)   # drops only add retries


def test_async_all_drops_raises():
    with pytest.raises(RuntimeError, match="drops"):
        FedRuntime(n_clients=2, rounds=2, schedule="async:1",
                   latency="dropout:1.0").run(_CountingWork())


def test_async_rejects_partial_participation_but_allows_masks():
    with pytest.raises(ValueError, match="participation"):
        FedRuntime(n_clients=2, rounds=1, schedule="async:1",
                   participation="uniform:1")
    # mask transports are no longer rejected under async: buffered
    # aggregation recovers cross-cohort mask terms through the Shamir
    # share book (tests/test_privacy.py gates the sums)
    rt = FedRuntime(n_clients=2, rounds=1, schedule="async:1",
                    transport="secure")
    assert rt._mask_layer is not None


# --- staleness ----------------------------------------------------------------

def test_async_staleness_is_discounted_and_recorded():
    """With one very slow client under async:1, its update aggregates
    several versions after dispatch: the payload must arrive scaled by
    stale_discount ** staleness and the timeline must record it."""
    from repro.core.latency import Draw, LatencyModel
    work = _CountingWork()
    slow = LatencyModel("c0-slow", lambda c, k: Draw(2.5 if c == 0
                                                     else 1.0))
    rt = FedRuntime(n_clients=2, rounds=4, schedule="async:1",
                    latency=slow, stale_discount=0.5)
    state = rt.run(work)
    stale = [s for rec in rt.timeline for s in rec["staleness"] if s > 0]
    assert stale, "slow client never aggregated stale"
    # sum reflects the discounts: fresh contribute 1, stale 0.5**s
    expect = sum(0.5 ** s for rec in rt.timeline
                 for s in rec["staleness"])
    np.testing.assert_allclose(state["sum"], np.full(2, expect))


# --- one-shot tree pipelines under buffered aggregation -----------------------

def test_tree_pipelines_async_first_k_arrivals():
    """async:K on the one-shot protocols publishes after the first K
    uploads; the shipped per-client models must still be keyed to the
    right client (the feature_extract tops fix)."""
    from repro.core import feature_extract as FE
    from repro.core import tree_subset as TS
    clients, test = _clients(n=400, k=4)
    lat = "lognormal:0:1"
    rf_cfg = TS.FedForestConfig(trees_per_client=4, subset=2, depth=3,
                                n_bins=16, schedule="async:2",
                                latency=lat, seed=0)
    model, comm, _ = TS.train_federated_rf(clients, rf_cfg)
    assert int(model.forest.feature.shape[0]) == 4   # 2 clients x s=2
    assert len([e for e in comm.events if e["what"] == "trees"]) == 4
    assert np.isfinite(TS.evaluate_rf(model, *test)["f1"])

    fe_cfg = FE.FedXGBConfig(num_rounds=2, depth=3, shallow_depth=2,
                             shallow_rounds=1, top_features=4, n_bins=16,
                             schedule="async:2", latency=lat, seed=0)
    ens, _, _ = FE.train_federated_xgb_fe(clients, fe_cfg)
    assert len(ens.trees) == 2 and len(ens.top_features) == 2
    # sync run with the same cohort: each async (model, tops) pair must
    # match the sync pair of the SAME client — weights identify clients
    # (shard sizes are distinct under the dirichlet-free iid partition)
    sync_cfg = FE.FedXGBConfig(num_rounds=2, depth=3, shallow_depth=2,
                               shallow_rounds=1, top_features=4,
                               n_bins=16, seed=0)
    full, _, _ = FE.train_federated_xgb_fe(clients, sync_cfg)
    for tree, top in zip(ens.trees, ens.top_features):
        # find the sync client whose shallow trees bit-match this one
        hit = [i for i, t in enumerate(full.trees)
               if t.forest.feature.shape == tree.forest.feature.shape
               and np.array_equal(np.asarray(t.forest.feature),
                                  np.asarray(tree.forest.feature))
               and np.array_equal(np.asarray(t.forest.threshold),
                                  np.asarray(tree.forest.threshold))]
        assert hit, "async shipped a model no sync client produced"
        assert any(np.array_equal(full.top_features[i], top)
                   for i in hit), "tops mis-keyed to the wrong client"


def test_fed_hist_async_k_partial_buffers():
    """fed_hist under async:2/4 clients: every aggregation grows one
    tree from exactly 2 client histograms; trees still broadcast to all
    clients so margins stay in sync."""
    from repro.core import fed_hist as FH
    clients, test = _clients(n=400, k=4)
    cfg = FH.FedHistConfig(num_rounds=3, depth=3, n_bins=16,
                           schedule="async:2", latency="lognormal:0:1",
                           seed=0)
    model, comm, _ = FH.train_federated_xgb_hist(clients, cfg)
    assert int(model.forest.feature.shape[0]) == 3   # one tree per agg
    tree_events = [e for e in comm.events if e["what"] == "tree"]
    assert len(tree_events) == 3 * 4                 # broadcast to all
    m = FH.evaluate_fed_hist(model, *test)
    assert np.isfinite(m["f1"])
