"""Sharding rules engine: divisibility degradation, axis uniqueness,
null-ctx no-ops, production rule tables. (Pure logic — no 512-device init;
the real-mesh path is exercised by launch/dryrun.py.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from proptest import grid, for_cases

from repro.launch.steps import production_rules
from repro.sharding.rules import (DECODE_RULES, LONG_DECODE_RULES,
                                  TRAIN_RULES, ShardingCtx)


def _mesh22():
    n = jax.device_count()
    if n < 4:
        pytest.skip("needs >= 4 host devices")
    return jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_null_ctx_noops():
    ctx = ShardingCtx.null()
    assert not ctx.active
    x = jnp.ones((4, 4))
    assert ctx.constrain(x, "batch", "embed") is x
    assert ctx.spec(["batch", "embed"], (4, 4)) == P()


def test_divisible_sharding_assignment():
    mesh = _mesh22()
    ctx = ShardingCtx(mesh=mesh, rules=dict(TRAIN_RULES))
    # divisible -> sharded
    assert ctx.spec(["batch", "mlp"], (8, 8)) == P("data", "model")
    # not divisible -> replicated
    assert ctx.spec(["batch", "mlp"], (7, 8)) == P(None, "model")
    assert ctx.spec(["mlp"], (9,)) == P()
    # dim smaller than axis -> replicated
    assert ctx.spec(["batch"], (1,)) == P()


def test_axis_used_once_per_spec():
    mesh = _mesh22()
    ctx = ShardingCtx(mesh=mesh,
                      rules={"a": "model", "b": "model", "c": "data"})
    spec = ctx.spec(["a", "b", "c"], (4, 4, 4))
    flat = [s for s in spec if s is not None]
    assert len(flat) == len(set(flat)) == 2  # 'model' used once only


def test_tuple_target_degrades_to_divisible_prefix():
    mesh = _mesh22()
    ctx = ShardingCtx(mesh=mesh, rules={"seq": ("data", "model")})
    assert ctx.spec(["seq"], (8,)) == P(("data", "model"))
    # 6 % 4 != 0 but 6 % 2 == 0 -> degrade to ('data',)
    assert ctx.spec(["seq"], (6,)) == P("data")
    assert ctx.spec(["seq"], (5,)) == P()


def test_disabled_names():
    mesh = _mesh22()
    ctx = ShardingCtx(mesh=mesh, rules=dict(TRAIN_RULES),
                      disabled=("fsdp",))
    assert ctx.spec(["fsdp", "mlp"], (8, 8)) == P(None, "model")


RULES_CASES = grid(phase=["train", "prefill", "decode"],
                   shape=["train_4k", "prefill_32k", "decode_32k",
                          "long_500k"])


@for_cases(RULES_CASES)
def test_production_rules_tables(phase, shape):
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    rules = production_rules(FakeMesh(), phase, shape)
    if phase == "decode":
        if shape == "long_500k":
            assert rules["cache_seq"] == ("pod", "data", "model")
            assert rules["batch"] is None
        else:
            assert rules["cache_seq"] == "model"
            assert rules["batch"] == ("pod", "data")
    else:
        assert rules["batch"] == ("pod", "data")
        assert rules["experts"] == "data"


def test_constrain_under_mesh_runs():
    mesh = _mesh22()
    ctx = ShardingCtx(mesh=mesh, rules=dict(TRAIN_RULES))

    @jax.jit
    def f(x):
        return ctx.constrain(x * 2, "batch", None, "embed")

    with jax.sharding.set_mesh(mesh):
        y = f(jnp.ones((4, 3, 8)))
    np.testing.assert_allclose(np.asarray(y), 2.0)


def test_fed_rules_phase():
    """The 'fed' phase maps only the clients logical axis — LM phases
    are untouched by its existence."""
    from repro.sharding.rules import FED_RULES, rules_for_phase
    assert rules_for_phase("fed") is FED_RULES
    assert FED_RULES == {"clients": "clients"}
    assert rules_for_phase("train") is TRAIN_RULES
    assert rules_for_phase("decode") is DECODE_RULES
    assert rules_for_phase("decode", "long_500k") is LONG_DECODE_RULES
    assert "clients" not in TRAIN_RULES
    assert "clients" not in DECODE_RULES


def test_fed_rules_clients_axis_divisibility():
    """Client-axis placement shards when divisible, replicates when
    not — same degradation contract as the LM rules."""
    from repro.sharding.rules import FED_RULES
    n = jax.device_count()
    if n < 2:
        pytest.skip("needs >= 2 host devices")
    mesh = jax.make_mesh((n,), ("clients",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    ctx = ShardingCtx(mesh=mesh, rules=dict(FED_RULES))
    assert ctx.spec(["clients", None, None], (4 * n, 8, 15)) == \
        P("clients", None, None)
    assert ctx.spec(["clients", None], (4 * n + 1, 8)) == P(None, None)
    # unknown logical names replicate
    assert ctx.spec(["batch"], (4 * n,)) == P(None)
