"""Load-engine tests (``repro.serve.load``): queue invariants under
randomized arrival traces, byte-for-byte determinism of the virtual
clock, and analytic oracles (Poisson inter-arrival mean, M/D/1 queue
delay) — all virtual-only, no real engine, fast tier."""
import json
import os
import tempfile

import numpy as np
import pytest

from repro.serve.load import (ARRIVALS, SERVICE, LoadConfig,
                              get_arrivals, get_service, qps_sweep,
                              simulate_load, sweep_rates, table_service)

from proptest import cases, choice, floats, for_cases, ints


# --- invariants (shared by property + example tests) -------------------------

def check_invariants(cfg, result):
    """Every structural property the state machine promises, on one
    run's full records."""
    buckets = sorted(int(b) for b in cfg.bucket_sizes)
    recs, batches = result.records, result.batches
    admitted = [r for r in recs if not r["rejected"]]
    rejected = [r for r in recs if r["rejected"]]

    # work conservation: every admitted request is scored exactly once,
    # rejected requests never are
    assert all(r["t_done"] is not None for r in admitted)
    assert all(r["t_start"] is None and r["t_done"] is None
               for r in rejected)
    assert sum(b["n_requests"] for b in batches) == len(admitted)
    assert sum(b["rows"] for b in batches) \
        == sum(r["rows"] for r in admitted)

    # rejection only under admission control, and only at a full queue
    if cfg.max_queue is None:
        assert not rejected

    # FIFO: admitted requests start (and finish) in arrival order
    starts = [r["t_start"] for r in admitted]
    assert starts == sorted(starts)
    dones = [r["t_done"] for r in admitted]
    assert dones == sorted(dones)

    # causality + deadline accounting on each record
    for r in admitted:
        assert r["t_arrive"] <= r["t_start"] <= r["t_done"]
        assert r["latency"] == pytest.approx(r["t_done"] - r["t_arrive"])
        if cfg.deadline is None:
            assert not r["miss"]
        else:
            assert r["miss"] == (r["latency"] > cfg.deadline)

    # batches: rows fit the chosen bucket, occupancy in (0, 1],
    # batches never overlap on the single server
    for b in batches:
        assert b["bucket"] in buckets
        assert 0 < b["rows"] <= b["bucket"]
        assert b["occupancy"] == pytest.approx(b["rows"] / b["bucket"])
        assert 0.0 < b["occupancy"] <= 1.0
        assert b["t_start"] < b["t_done"]
    for prev, nxt in zip(batches, batches[1:]):
        assert prev["t_done"] <= nxt["t_start"]

    # summary consistency
    row = result.row
    assert row["n_requests"] == len(recs)
    assert row["rejection_rate"] == pytest.approx(
        len(rejected) / max(len(recs), 1))
    assert row["n_batches"] == len(batches)


# --- property tests over randomized specs ------------------------------------

@for_cases(cases(
    20, 7,
    arrivals=choice("poisson:400", "poisson:2000", "bursty:800:16:0.25",
                    "bursty:300:4:0.9"),
    n_requests=ints(50, 400),
    rows=choice(1, 3, "uniform:1:12"),
    max_wait=floats(0.0, 0.01),
    max_queue=choice(None, 4, 32),
    deadline=choice(None, 0.005, 0.05),
    run_seed=ints(0, 10_000),
))
def test_queue_invariants_hold(arrivals, n_requests, rows, max_wait,
                               max_queue, deadline, run_seed):
    cfg = LoadConfig(arrivals=arrivals, n_requests=n_requests,
                     rows=rows, bucket_sizes=(8, 32), max_wait=max_wait,
                     max_queue=max_queue, deadline=deadline,
                     service="affine:0.001:0.0001", seed=run_seed)
    check_invariants(cfg, simulate_load(cfg))


def test_admission_control_rejects_under_overload():
    # offered far above capacity with a tiny queue bound: rejections
    # must occur, and the queue depth seen by any admitted request is
    # bounded (its wait is bounded by max_queue * worst batch time)
    cfg = LoadConfig(arrivals="poisson:10000", n_requests=500, rows=1,
                     bucket_sizes=(4,), max_wait=0.0, max_queue=8,
                     deadline=0.05, service="constant:0.01", seed=1)
    res = simulate_load(cfg)
    check_invariants(cfg, res)
    assert res.row["rejection_rate"] > 0.0


def test_batch_closes_at_largest_bucket_under_backlog():
    # all requests arrive at once (trace of zero gaps): after the first
    # batch the backlog is deep, so every non-final batch must fill the
    # largest bucket exactly
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "gaps.json")
        with open(path, "w") as f:
            json.dump([0.0], f)
        cfg = LoadConfig(arrivals=f"trace:{path}", n_requests=100,
                         rows=1, bucket_sizes=(4, 16), max_wait=0.01,
                         service="constant:0.001", seed=0)
        res = simulate_load(cfg)
    check_invariants(cfg, res)
    assert all(b["rows"] == 16 for b in res.batches[:-1])
    assert res.row["mean_occupancy"] > 0.9


def test_max_wait_zero_dispatches_immediately():
    # with max_wait=0 an idle server never waits to grow a batch: under
    # light load every batch holds exactly one request
    cfg = LoadConfig(arrivals="poisson:10", n_requests=50, rows=1,
                     bucket_sizes=(8,), max_wait=0.0,
                     service="constant:0.001", seed=2)
    res = simulate_load(cfg)
    check_invariants(cfg, res)
    assert all(b["n_requests"] == 1 for b in res.batches)


# --- determinism --------------------------------------------------------------

def _dump(res):
    return json.dumps({"row": res.row, "records": res.records,
                       "batches": res.batches}, sort_keys=True)


def test_same_spec_and_seed_replays_byte_identical():
    cfg = LoadConfig(arrivals="bursty:1500:8:0.3", n_requests=300,
                     rows="uniform:1:6", bucket_sizes=(8, 32),
                     max_wait=0.002, max_queue=64, deadline=0.02,
                     service="affine:0.0005:0.0001", seed=11)
    assert _dump(simulate_load(cfg)) == _dump(simulate_load(cfg))


def test_different_seed_differs():
    cfg = LoadConfig(arrivals="poisson:900", n_requests=300,
                     service="constant:0.001", seed=0)
    other = LoadConfig(arrivals="poisson:900", n_requests=300,
                       service="constant:0.001", seed=1)
    assert _dump(simulate_load(cfg)) != _dump(simulate_load(other))


def test_arrival_draws_are_prefix_stable():
    # the first n gaps are a prefix of any longer run with the same
    # seed — request count doesn't reshuffle the trace
    a = get_arrivals("poisson:700", seed=5)
    np.testing.assert_array_equal(a.gaps(100), a.gaps(400)[:100])
    b = get_arrivals("bursty:700:16:0.5", seed=5)
    np.testing.assert_array_equal(b.gaps(100), b.gaps(400)[:100])


# --- analytic oracles ---------------------------------------------------------

def test_poisson_interarrival_mean_matches_rate():
    rate, n = 500.0, 20_000
    gaps = get_arrivals(f"poisson:{rate:g}", seed=9).gaps(n)
    se = (1.0 / rate) / np.sqrt(n)   # exponential: std == mean
    assert abs(gaps.mean() - 1.0 / rate) < 5 * se


def test_bursty_longrun_rate_matches_spec():
    rate, n = 800.0, 40_000
    gaps = get_arrivals(f"bursty:{rate:g}:32:0.2", seed=9).gaps(n)
    assert gaps.mean() * rate == pytest.approx(1.0, abs=0.05)


def test_md1_mean_wait_matches_pollaczek_khinchine():
    # M/D/1 at rho = lambda * s = 0.5: Wq = rho * s / (2 (1 - rho))
    # = 0.5 ms.  Single-row bucket + max_wait=0 makes every batch one
    # request, i.e. a textbook single server.
    lam, s = 500.0, 0.001
    rho = lam * s
    wq_ms = rho * s / (2 * (1 - rho)) * 1e3
    cfg = LoadConfig(arrivals=f"poisson:{lam:g}", n_requests=40_000,
                     rows=1, bucket_sizes=(1,), max_wait=0.0,
                     service=f"constant:{s:g}", seed=3)
    row = simulate_load(cfg).row
    assert row["mean_wait_ms"] == pytest.approx(wq_ms, rel=0.10)
    # and the latency percentiles sit above pure service time
    assert row["p50_ms"] >= s * 1e3


# --- registries, specs, sweep -------------------------------------------------

def test_registry_specs_resolve():
    assert set(ARRIVALS) == {"poisson", "bursty", "trace"}
    assert set(SERVICE) == {"constant", "affine", "measured"}
    svc = get_service("affine:0.001:0.0001")
    assert svc(3, 8, 0) == pytest.approx(0.001 + 0.0001 * 8)


@pytest.mark.parametrize("spec, err", [
    ("nope:1", KeyError), ("poisson", ValueError),
    ("poisson:-5", ValueError), ("bursty:100:0:0.5", ValueError),
    ("bursty:100:8:1.5", ValueError),
])
def test_bad_arrival_specs_raise(spec, err):
    with pytest.raises(err):
        get_arrivals(spec)


@pytest.mark.parametrize("spec, err", [
    ("nope", KeyError), ("constant:0", ValueError),
    ("affine:-1:0", ValueError), ("affine:0.1", ValueError),
])
def test_bad_service_specs_raise(spec, err):
    with pytest.raises(err):
        get_service(spec)


def test_measured_service_requires_engine():
    with pytest.raises(ValueError, match="ScoringEngine"):
        get_service("measured")


def test_table_service_falls_back_to_largest_bucket():
    svc = table_service({8: 0.001, 32: 0.003})
    assert svc(4, 8, 0) == 0.001
    assert svc(40, 64, 0) == 0.003   # unknown bucket -> largest entry
    assert svc.table == {8: 0.001, 32: 0.003}


def test_qps_sweep_finds_the_knee():
    # capacity = 1 / 0.001 = 1000 req/s; rates straddling it must be
    # split into sustainable below and unsustainable above
    cfg = LoadConfig(n_requests=4000, rows=1, bucket_sizes=(1,),
                     max_wait=0.0, max_queue=512, deadline=0.02,
                     service="constant:0.001", seed=0)
    rows, best = qps_sweep(cfg, [200.0, 600.0, 2000.0, 5000.0])
    assert [r["sustainable"] for r in rows] == [True, True, False, False]
    assert best == 600.0


def test_qps_sweep_requires_deadline():
    with pytest.raises(ValueError, match="deadline"):
        qps_sweep(LoadConfig(deadline=None), [100.0])


def test_sweep_rates_ladder():
    rates = sweep_rates(1000.0, n=5, lo=0.1, hi=1.0)
    assert len(rates) == 5
    assert rates[0] == pytest.approx(100.0)
    assert rates[-1] == pytest.approx(1000.0)
    assert rates == sorted(rates)
