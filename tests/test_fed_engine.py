"""Batched client-parallel federated engine: vmap/sequential parity,
wire-format registry, int8 stochastic rounding, ledger byte accounting,
and Pallas histogram routing for the federated tree pipelines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C

SMOKE = dict(n_pods=2, rounds=2, local_steps=3, batch=2, seq=64,
             verbose=False, seed=0)


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.normal(size=(8, 4)), jnp.float32),
            "b": {"c": jnp.asarray(r.normal(size=(16,)), jnp.float32)}}


# --- engine parity ------------------------------------------------------------

@pytest.mark.slow
def test_vmap_engine_matches_sequential():
    """The batched multi-client engine must reproduce the per-pod loop:
    same losses, same uplink bytes, same final params.  (Tier 2: the
    same parity is CI-gated by fed_engine_bench --smoke.)"""
    from repro.launch.fed_train import simulate
    v = simulate("qwen3_4b", engine="vmap", **SMOKE)
    s = simulate("qwen3_4b", engine="sequential", **SMOKE)
    np.testing.assert_allclose(v["loss_history"], s["loss_history"],
                               rtol=1e-5)
    assert v["comm"].total_bytes() == s["comm"].total_bytes()
    for a, b in zip(jax.tree.leaves(v["final_params"]),
                    jax.tree.leaves(s["final_params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_engine_rejects_unknown_names():
    from repro.launch.fed_train import simulate
    with pytest.raises(ValueError):
        simulate("qwen3_4b", engine="threads", **SMOKE)
    with pytest.raises(KeyError):
        simulate("qwen3_4b", strategy="fancy", **SMOKE)
    with pytest.raises(KeyError):
        simulate("qwen3_4b", compression="gzip", **SMOKE)


# --- wire formats -------------------------------------------------------------

def test_wire_format_registry_interface():
    delta = _tree()
    for name in ("none", "topk", "int8", "int8_sr", "lowrank"):
        approx, state, nb = C.compress_update(name, delta, rho=0.25,
                                              rank=2, seed=1)
        assert nb > 0, name
        assert (jax.tree.structure(approx)
                == jax.tree.structure(delta)), name
        if name != "none":
            assert nb < C.dense_bytes(delta), name
    # topk threads error-feedback state
    _, st, _ = C.compress_update("topk", delta, rho=0.25)
    assert st is not None
    _, st2, _ = C.compress_update("topk", delta, st, rho=0.25)
    assert st2 is not None


def test_int8_sr_roundtrip_error_and_bytes():
    delta = _tree()
    approx, nb = C.int8_sr_compress(delta, seed=0)
    # per-element error < one quantization step = amax/127
    for a, d in zip(jax.tree.leaves(approx), jax.tree.leaves(delta)):
        step = float(jnp.max(jnp.abs(d))) / 127.0
        assert float(jnp.max(jnp.abs(a - d))) <= step * (1 + 1e-5)
    # exact wire size: 1 byte/element + 4-byte scale per tensor
    expect = sum(x.size + 4 for x in jax.tree.leaves(delta))
    assert nb == expect


def test_int8_sr_is_unbiased():
    """Stochastic rounding: E[dequant] == input (round-to-nearest has a
    deterministic per-element bias; SR must average it out)."""
    x = {"w": jnp.linspace(-1.0, 1.0, 64).astype(jnp.float32)}
    acc = np.zeros(64)
    n = 300
    for s in range(n):
        a, _ = C.int8_sr_compress(x, seed=s)
        acc += np.asarray(a["w"])
    step = 1.0 / 127.0
    # mean within a few standard errors of one quantization step
    np.testing.assert_allclose(acc / n, np.asarray(x["w"]),
                               atol=4 * step / np.sqrt(n))


@pytest.mark.slow
def test_simulate_ledger_accounts_wire_bytes():
    """CommLog uplink bytes must equal the wire format's exact size —
    the bandwidth claims are measured, never asserted.  (Tier 2: the
    same invariant is CI-gated by fed_engine_bench --smoke.)"""
    from repro.launch.fed_train import simulate
    out = simulate("qwen3_4b", compression="int8_sr", **SMOKE)
    n_leaves = len(jax.tree.leaves(out["final_params"]))
    n_elems = sum(x.size for x in jax.tree.leaves(out["final_params"]))
    per_pod_round = n_elems + 4 * n_leaves
    ups = [e for e in out["comm"].events if e["direction"] == "up"]
    assert len(ups) == SMOKE["n_pods"] * SMOKE["rounds"]
    assert all(e["bytes"] == per_pod_round for e in ups)
    dense = simulate("qwen3_4b", compression="none", **SMOKE)
    assert out["uplink_mb"] < dense["uplink_mb"] / 3.5  # ~4x for fp32


@pytest.mark.slow
def test_strategies_selectable_in_simulate():
    """Tier 2: three full LM simulations; tier 1 keeps strategy-registry
    coverage in tests/test_strategies.py."""
    from repro.launch.fed_train import simulate
    losses = {}
    for name in ("fedavg", "fedavg_weighted", "fedavgm"):
        out = simulate("qwen3_4b", strategy=name, **SMOKE)
        assert out["strategy"] == name
        assert np.isfinite(out["loss_history"]).all()
        losses[name] = out["loss_history"]
    # equal pod sizes -> weighted == uniform exactly
    np.testing.assert_allclose(losses["fedavg"],
                               losses["fedavg_weighted"], rtol=1e-6)


# --- Pallas histogram routing -------------------------------------------------

def test_gradient_histogram_pallas_cpu_fallback():
    """impl='pallas' on CPU must transparently run interpret mode and
    match the XLA reference."""
    from repro.kernels.hist.ops import gradient_histogram
    r = np.random.default_rng(0)
    bins = jnp.asarray(r.integers(0, 16, size=(300, 5)), jnp.int32)
    g = jnp.asarray(r.normal(size=300), jnp.float32)
    h = jnp.asarray(r.uniform(0.1, 1, size=300), jnp.float32)
    ref = gradient_histogram(bins, g, h, 16, impl="xla")
    pal = gradient_histogram(bins, g, h, 16, impl="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=1e-4)


@pytest.mark.slow
def test_fed_rf_runs_on_pallas_histogram():
    """Federated RF local training routed through the Pallas kernel
    (interpret on CPU) agrees with the XLA route.  (Tier 2: kernel
    routing itself stays tier-1 via the gradient_histogram fallback
    test above.)"""
    from repro.core import tree_subset as TS
    from repro.data import framingham as F
    ds = F.synthesize(n=400, seed=0)
    tr, te = F.train_test_split(ds)
    clients = [(c.x, c.y) for c in F.partition_clients(tr, 2)]
    out = {}
    for impl in ("xla", "pallas_interpret"):
        cfg = TS.FedForestConfig(trees_per_client=4, subset=4, depth=3,
                                 n_bins=16, hist_impl=impl, seed=0)
        model, comm, _ = TS.train_federated_rf(clients, cfg)
        out[impl] = TS.evaluate_rf(model, te.x, te.y)["f1"]
        assert comm.total_bytes("up") > 0
    np.testing.assert_allclose(out["pallas_interpret"], out["xla"],
                               atol=1e-6)
