"""Per-architecture smoke tests (deliverable f): every assigned arch's
REDUCED config runs one forward/train step and one decode step on CPU,
asserting output shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.models import api
from repro.models.params import count_params, init_tree
from repro.sharding import ShardingCtx

RUN = RunConfig()
CTX = ShardingCtx.null()
# tier 1 keeps two cheap-to-compile representative archs (dense +
# SSM-free attention); the other compiles run in the slow tier
# (full suite: -m "slow or not slow")
SLOW_ARCHS = {"dbrx_132b", "whisper_medium", "hymba_15b", "internvl2_2b",
              "phi35_moe", "mamba2_13b", "yi_34b", "minitron_4b"}
ARCHS = [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS
         else a for a in R.LM_ARCH_IDS]


def _batch(cfg, B, T, rng):
    batch = {"tokens": jax.random.randint(rng, (B, T), 0, cfg.vocab_size),
             "targets": jax.random.randint(rng, (B, T), 0, cfg.vocab_size),
             "mask": jnp.ones((B, T), jnp.float32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encoder.seq_len, cfg.d_model))
    if cfg.family == "vlm":
        img = cfg.encoder.num_image_tokens
        batch["patches"] = jax.random.normal(
            rng, (B, img, cfg.encoder.frontend_dim))
        batch["tokens"] = batch["tokens"][:, :T - img]
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_train_step(arch):
    cfg = R.get_smoke(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    rng = jax.random.PRNGKey(0)
    params = init_tree(rng, api.param_defs(cfg))
    B, T = 2, 32
    batch = _batch(cfg, B, T, rng)
    loss, metrics = api.train_loss(params, batch, cfg, RUN, CTX)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: api.train_loss(p, batch, cfg, RUN, CTX)[0])(
        params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_prefill_decode(arch):
    cfg = R.get_smoke(arch)
    rng = jax.random.PRNGKey(1)
    params = init_tree(rng, api.param_defs(cfg))
    B, T = 2, 16
    batch = _batch(cfg, B, T, rng)
    batch.pop("targets")
    batch.pop("mask")
    logits, cache = api.prefill(params, batch, cfg, RUN, CTX)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # pad self-attn cache and take one decode step
    cache = {k: (jnp.pad(v, ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0)))
                 if k in ("k", "v") else v) for k, v in cache.items()}
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lg2, cache2 = api.decode_step(params, {"token": tok,
                                           "pos": jnp.int32(T)},
                                  cache, cfg, RUN, CTX)
    assert lg2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg2))), arch
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned shapes (exercised only via
    the dry-run; here we assert the numbers)."""
    spec = {
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "phi35_moe": (32, 4096, 32, 8, 6400, 32064),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
        "qwen3_4b": (36, 2560, 32, 8, 9728, 151936),
        "yi_34b": (60, 7168, 56, 8, 20480, 64000),
        "hymba_15b": (32, 1600, 25, 5, 5504, 32001),
        "mamba2_13b": (48, 2048, 0, 0, 0, 50280),
        "phi3_mini": (32, 3072, 32, 32, 8192, 32064),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
    }
    for arch, (L, d, H, K, ff, V) in spec.items():
        cfg = R.get(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads,
               cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, H, K, ff, V), (arch, got)
    assert R.get("dbrx_132b").moe.top_k == 4
    assert R.get("phi35_moe").moe.top_k == 2
    assert R.get("mamba2_13b").ssm.state_size == 128
    assert R.get("hymba_15b").ssm.state_size == 16
    assert R.get("qwen3_4b").qk_norm


def test_param_counts_near_model_names():
    """Analytic param counts should be in the ballpark of the model names."""
    expect = {"dbrx_132b": 132e9, "phi35_moe": 42e9, "yi_34b": 34e9,
              "qwen3_4b": 4e9, "phi3_mini": 3.8e9, "minitron_4b": 4e9,
              "mamba2_13b": 1.3e9, "hymba_15b": 1.5e9}
    for arch, target in expect.items():
        n = R.get(arch).num_params()
        assert 0.55 * target < n < 1.7 * target, (arch, n / 1e9)
    # MoE active < total
    assert (R.get("dbrx_132b").num_active_params()
            < 0.4 * R.get("dbrx_132b").num_params())
