"""Kernel perf gate: compare a bench run against the repo-committed
trajectory and fail CI on regressions.

``BENCH_kernels.json`` at the repo root is the **perf trajectory**: a
list of entries, one appended per PR (and per CI run of the
``kernel-perf-smoke`` job), each holding the dict rows produced by
``benchmarks/kernels_bench.py`` — every row carries ``platform`` /
``device`` / ``jax`` metadata, so the gate only ever compares rows
measured on the same platform+device and the same smoke/full shape set.
The gate is generic over trajectories: the ``serve-load-smoke`` job
points ``--current`` at ``results/serve_load/serve_load_gate.json``
(rows from ``repro.launch.serve_load --smoke`` /
``benchmarks.serve_bench --load``) and ``--bench`` at the repo-root
``BENCH_serve_load.json`` — same rule, same row shape.

Gate rule: for every current row whose ``name`` appears in
same-platform trajectory rows, the current time must not exceed
``max(best * (1 + threshold), best + noise_floor_us)`` where ``best``
is the minimum recorded time, ``--threshold`` defaults to 20% and
``--noise-floor-us`` to 250us.  The relative threshold is the actual
gate on production-shape rows (ms scale); the absolute floor exists so
micro-second smoke rows on shared CPU runners — where scheduler noise
alone is tens of microseconds — don't flake the job.  Comparing
against the best rather than the latest entry keeps one slow CI runner
from ratcheting the baseline upward.  Rows with no same-platform
history pass (and seed the trajectory for next time).
If roofline dry-run artifacts exist (``benchmarks/roofline.py`` over
``results/dryrun``), their bound times join the gated rows too.

On a passing ``--check`` the run is appended as one new trajectory
entry; on failure nothing is appended and the exit code is non-zero.

Run:
  PYTHONPATH=src python -m benchmarks.kernels_bench --smoke   # current run
  PYTHONPATH=src python tools/perf_gate.py --check --smoke    # gate+append
Library use (no timing): :func:`compare` / :func:`append_entry` over
synthetic rows — see tests/test_perf_gate.py.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_TRAJECTORY = os.path.join(ROOT, "BENCH_kernels.json")
DEFAULT_CURRENT = os.path.join(ROOT, "results", "kernels",
                               "kernels_bench.json")
DEFAULT_THRESHOLD = 0.20
DEFAULT_NOISE_FLOOR_US = 250.0
_VERSION = 1


def load_trajectory(path: str = DEFAULT_TRAJECTORY) -> Dict:
    """The trajectory file, or a fresh empty one if missing."""
    if not os.path.exists(path):
        return {"version": _VERSION, "entries": []}
    with open(path) as f:
        data = json.load(f)
    if data.get("version") != _VERSION:
        raise ValueError(f"{path}: trajectory version "
                         f"{data.get('version')!r} != {_VERSION}")
    return data


def save_trajectory(data: Dict, path: str = DEFAULT_TRAJECTORY) -> str:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)
    return path


def _same_platform(a: Dict, b: Dict) -> bool:
    return (a.get("platform") == b.get("platform")
            and a.get("device") == b.get("device"))


def baselines(trajectory: Dict, row: Dict,
              smoke: Optional[bool] = None) -> List[float]:
    """All recorded times for this row's name on the same
    platform+device (and, when given, the same smoke/full shape set)."""
    out = []
    for entry in trajectory.get("entries", []):
        if smoke is not None and bool(entry.get("smoke")) != smoke:
            continue
        for old in entry.get("rows", []):
            if old.get("name") == row.get("name") \
                    and _same_platform(old, row):
                out.append(float(old["us"]))
    return out


def compare(current_rows: List[Dict], trajectory: Dict, *,
            threshold: float = DEFAULT_THRESHOLD,
            noise_floor_us: float = DEFAULT_NOISE_FLOOR_US,
            smoke: Optional[bool] = None) -> List[Tuple[str, str]]:
    """Gate the current rows; returns [(row name, reason)] failures.

    A row fails when its time exceeds the best same-platform recorded
    time by more than ``threshold`` (0.20 = +20%) AND by more than
    ``noise_floor_us`` absolute (scheduler jitter on shared runners is
    tens of microseconds regardless of kernel size, so microsecond
    smoke rows are only gated on absolute drift).  Rows without
    same-platform history are skipped (they seed the trajectory)."""
    failures = []
    for row in current_rows:
        base = baselines(trajectory, row, smoke=smoke)
        if not base:
            continue
        best = min(base)
        limit = max(best * (1.0 + threshold), best + noise_floor_us)
        if float(row["us"]) > limit:
            failures.append((
                row["name"],
                f"{row['us']:.1f}us > {limit:.1f}us "
                f"(best {best:.1f}us +{threshold*100:.0f}% or "
                f"+{noise_floor_us:.0f}us, "
                f"{len(base)} same-platform baselines)"))
    return failures


def append_entry(trajectory: Dict, rows: List[Dict], *,
                 smoke: bool = False, note: str = "") -> Dict:
    """Append exactly one trajectory entry for this run (in place).

    Entry-level platform metadata is lifted from the rows (they all
    share it within one run)."""
    meta = {k: rows[0][k] for k in ("platform", "device", "jax")} \
        if rows else {}
    trajectory.setdefault("entries", []).append(
        {**meta, "smoke": bool(smoke), "note": note,
         "rows": [dict(r) for r in rows]})
    return trajectory


def _roofline_rows() -> List[Dict]:
    """Roofline bound times as gate rows (empty without dry-run
    artifacts)."""
    sys.path.insert(0, os.path.join(ROOT, "src"))
    sys.path.insert(0, ROOT)
    from benchmarks import kernels_bench, roofline
    recs = roofline.load(os.path.join(ROOT, "results", "dryrun"),
                         tag="baseline")
    meta = kernels_bench.bench_meta()
    return [{"name": name, "us": us, "note": note, **meta}
            for (name, us, note) in roofline.csv_rows(recs)]


def run_check(*, current_path: str = DEFAULT_CURRENT,
              trajectory_path: str = DEFAULT_TRAJECTORY,
              threshold: float = DEFAULT_THRESHOLD,
              noise_floor_us: float = DEFAULT_NOISE_FLOOR_US,
              smoke: bool = False,
              append: bool = True, rerun: bool = False) -> int:
    """The CLI body: load (or produce) the current rows, gate, append."""
    sys.path.insert(0, ROOT)
    if not os.path.exists(current_path) and not rerun \
            and os.path.abspath(current_path) \
            != os.path.abspath(DEFAULT_CURRENT):
        # a custom --current (e.g. the serve_load gate rows) that does
        # not exist must fail loudly — rerunning kernels_bench here
        # would gate kernel rows against the wrong trajectory
        raise FileNotFoundError(
            f"perf_gate: current-run file {current_path!r} not found; "
            f"produce it first (e.g. `python -m repro.launch.serve_load "
            f"--smoke` or `python -m benchmarks.serve_bench --load`)")
    if rerun or not os.path.exists(current_path):
        from benchmarks import kernels_bench
        rows = kernels_bench.run(smoke=smoke)
        kernels_bench.save_rows(rows, current_path, smoke=smoke)
    else:
        with open(current_path) as f:
            data = json.load(f)
        rows = data["rows"]
        smoke = bool(data.get("meta", {}).get("smoke", smoke))
    rows = rows + _roofline_rows()
    trajectory = load_trajectory(trajectory_path)
    failures = compare(rows, trajectory, threshold=threshold,
                       noise_floor_us=noise_floor_us, smoke=smoke)
    for name, reason in failures:
        print(f"REGRESSION  {name}: {reason}", file=sys.stderr)
    n_hist = len(trajectory.get("entries", []))
    if failures:
        print(f"perf_gate: FAIL — {len(failures)}/{len(rows)} rows "
              f"regressed >{threshold*100:.0f}% vs {n_hist} trajectory "
              f"entries (nothing appended)")
        return 1
    if append:
        append_entry(trajectory, rows, smoke=smoke)
        save_trajectory(trajectory, trajectory_path)
    print(f"perf_gate: ok — {len(rows)} rows within "
          f"{threshold*100:.0f}% of best same-platform baselines "
          f"({n_hist} prior entries"
          f"{'; appended entry ' + str(n_hist + 1) if append else ''})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="gate the current run against the trajectory")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke shape set (CI); used when rerunning and "
                    "to select comparable trajectory entries")
    ap.add_argument("--current", default=DEFAULT_CURRENT,
                    help="current-run JSON from kernels_bench (rerun "
                    "in-process if missing)")
    ap.add_argument("--bench", default=DEFAULT_TRAJECTORY,
                    help="trajectory file (default repo-root "
                    "BENCH_kernels.json)")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="allowed slowdown vs best baseline (0.2 = +20%%)")
    ap.add_argument("--noise-floor-us", type=float,
                    default=DEFAULT_NOISE_FLOOR_US,
                    help="absolute slack absorbing scheduler jitter on "
                    "microsecond-scale rows")
    ap.add_argument("--no-append", action="store_true",
                    help="gate only; do not record this run")
    ap.add_argument("--rerun", action="store_true",
                    help="re-time via kernels_bench even if --current "
                    "exists")
    args = ap.parse_args()
    if not args.check:
        ap.error("nothing to do: pass --check")
    return run_check(current_path=args.current,
                     trajectory_path=args.bench,
                     threshold=args.threshold,
                     noise_floor_us=args.noise_floor_us,
                     smoke=args.smoke,
                     append=not args.no_append, rerun=args.rerun)


if __name__ == "__main__":
    sys.exit(main())
