"""Docs reference check: every module, attribute, and file path referenced
in the docs must exist — and every repo file referenced from *source*
docstrings/comments must exist too (the ``EXPERIMENTS.md`` class of rot:
a module citing a doc that was never written).

Doc-side checks (README.md, DESIGN.md, docs/*.md):
  * dotted names (``repro.core.strategies.STRATEGIES``,
    ``benchmarks.run``) — the longest importable prefix is imported and
    any remaining parts are resolved with getattr;
  * ``python -m <module>`` commands — the module must import;
  * repo-relative file paths (``examples/quickstart.py``,
    ``docs/ARCHITECTURE.md``) — the file must exist.

Source-side checks (src/, examples/, benchmarks/, tests/, tools/):
  * repo-relative file paths, as above;
  * bare UPPERCASE doc names (``DESIGN.md``, ``EXPERIMENTS.md``) —
    resolved against the repo root, then ``docs/``.

Registry checks: every selectable name in the runtime registries —
strategies, wire formats, partitioners, participation schedules,
transport presets and layers (``REGISTRIES`` below) — must appear
somewhere in the docs corpus, so a registered-but-undocumented knob
fails CI.

Run:  PYTHONPATH=src python tools/check_docs.py
Exits non-zero listing every broken reference.
"""
from __future__ import annotations

import glob
import importlib
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_GLOBS = ["README.md", "DESIGN.md", "docs/*.md"]
SRC_GLOBS = ["src/**/*.py", "examples/*.py", "benchmarks/*.py",
             "tests/*.py", "tools/*.py"]
DOTTED = re.compile(r"\b((?:repro|benchmarks)(?:\.\w+)+)")
# only resolve repo-local modules: third-party tools invoked via -m
# (e.g. pytest) are not part of the docs import-smoke contract
PY_M = re.compile(r"python\s+-m\s+((?:repro|benchmarks)(?:\.\w+)*)")
PATH = re.compile(
    r"\b((?:src|examples|benchmarks|docs|tests|tools)/[\w/.-]+\.(?:py|md))")
# bare top-level doc names cited from docstrings ("DESIGN.md §Data-gate")
BARE_MD = re.compile(r"\b([A-Z][A-Z0-9_+-]+\.md)\b")
# every name registered in these dicts must appear in the docs corpus
REGISTRIES = [
    ("repro.core.strategies", "STRATEGIES"),
    ("repro.core.compression", "WIRE_FORMATS"),
    ("repro.data.partition", "PARTITIONERS"),
    ("repro.core.participation", "PARTICIPATION"),
    ("repro.core.comm", "TRANSPORTS"),
    ("repro.core.comm", "LAYERS"),
    ("repro.core.runtime", "SCHEDULES"),
    ("repro.core.latency", "LATENCY"),
    ("repro.serve.bundle", "BUNDLE_KINDS"),
    ("repro.serve.engine", "SCORERS"),
    ("repro.serve.load", "ARRIVALS"),
    ("repro.serve.load", "SERVICE"),
    ("repro.kernels.autotune", "TUNABLES"),
    ("repro.data.cohort", "COHORTS"),
    ("repro.launch.mesh", "MESHES"),
    ("repro.obs.export", "EXPORTERS"),
    ("repro.obs.metrics", "METRICS"),
]


def check_dotted(name: str) -> str:
    """Import the longest module prefix, getattr the rest. '' if ok."""
    parts = name.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return f"{name}: module {'.'.join(parts[:cut])} has no " \
                   f"attribute path {'.'.join(parts[cut:])}"
        return ""
    return f"{name}: no importable prefix"


def check_file_refs(text: str) -> list:
    """Broken repo-file references (paths + bare doc names) in text."""
    errors = []
    for path in sorted(set(PATH.findall(text))):
        if not os.path.exists(os.path.join(ROOT, path)):
            errors.append(f"missing file {path}")
    for name in sorted(set(BARE_MD.findall(text))):
        if not (os.path.exists(os.path.join(ROOT, name))
                or os.path.exists(os.path.join(ROOT, "docs", name))):
            errors.append(f"missing doc {name} (not at repo root or docs/)")
    return errors


def check_registries(docs_text: str) -> list:
    """Every registry name must be documented somewhere in the docs."""
    errors = []
    for mod_name, attr in REGISTRIES:
        try:
            registry = getattr(importlib.import_module(mod_name), attr)
        except (ImportError, AttributeError) as e:
            errors.append(f"registry {mod_name}.{attr} unimportable: {e}")
            continue
        for name in sorted(registry):
            if not re.search(rf"\b{re.escape(name)}\b", docs_text):
                errors.append(f"registry name {name!r} "
                              f"({mod_name}.{attr}) is undocumented")
    return errors


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    sys.path.insert(0, ROOT)  # for benchmarks.*
    docs = sorted(p for g in DOC_GLOBS
                  for p in glob.glob(os.path.join(ROOT, g)))
    if not docs:
        print("no docs found", file=sys.stderr)
        return 1
    errors = []
    docs_corpus = []
    for doc in docs:
        rel = os.path.relpath(doc, ROOT)
        text = open(doc).read()
        docs_corpus.append(text)
        refs = set(DOTTED.findall(text)) | set(PY_M.findall(text))
        for name in sorted(refs):
            err = check_dotted(name.rstrip("."))
            if err:
                errors.append(f"{rel}: {err}")
        errors.extend(f"{rel}: {e}" for e in check_file_refs(text))
    errors.extend(check_registries("\n".join(docs_corpus)))
    sources = sorted(p for g in SRC_GLOBS
                     for p in glob.glob(os.path.join(ROOT, g),
                                        recursive=True))
    for src in sources:
        rel = os.path.relpath(src, ROOT)
        errors.extend(f"{rel}: {e}"
                      for e in check_file_refs(open(src).read()))
    for e in errors:
        print(f"BROKEN  {e}", file=sys.stderr)
    print(f"checked {len(docs)} docs + {len(sources)} source files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken refs)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
