"""Golden end-to-end metrics snapshot: one small seeded run per
federated pipeline (plus an async-schedule variant), with test-set
F1/AUC committed under ``results/golden/metrics.json`` — and one
virtual load-engine run (``serve_load``) whose queue/batching summary
is snapshotted the same way, so a scheduling-policy regression in
``repro.serve.load`` shows up exactly like an F1 drift.

``tests/test_golden.py`` replays exactly these configs (it imports
:data:`GOLDEN_RUNS` from this file) and compares within
:data:`TOLERANCE` — a drive-by change to any pipeline's training math
shows up as a golden diff even when no invariant test names it.

Regenerate after an *intentional* behaviour change:

    PYTHONPATH=src python tools/refresh_golden.py

and commit the updated ``results/golden/metrics.json`` alongside the
change that explains it.
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN_PATH = os.path.join(ROOT, "results", "golden", "metrics.json")

#: |ours - golden| bound per metric — wide enough for BLAS/platform
#: jitter on these tiny models, tight enough to catch real regressions.
TOLERANCE = 0.03
#: metrics compared (binary_metrics keys that are rates in [0, 1]).
METRIC_KEYS = ("f1", "precision", "recall", "accuracy", "roc_auc",
               "brier")
SEED = 0


def _clients(n=500, k=3):
    from repro.data import framingham as F
    ds = F.synthesize(n=n, seed=1)
    tr, te = F.train_test_split(ds)
    return ([(c.x, c.y) for c in F.partition_clients(tr, k)],
            (te.x, te.y))


def _parametric(schedule="sync", latency=None):
    def run():
        from repro.core import parametric as P
        clients, test = _clients()
        cfg = P.FedParametricConfig(model="logreg", rounds=3,
                                    local_steps=8, lr=0.05,
                                    sampling="ros", schedule=schedule,
                                    latency=latency, seed=SEED)
        _, _, hist, _ = P.train_federated(clients, cfg, test=test)
        return hist[-1]
    return run


def _tree_subset():
    from repro.core import tree_subset as TS
    clients, test = _clients()
    cfg = TS.FedForestConfig(trees_per_client=4, subset=3, depth=3,
                             n_bins=16, seed=SEED)
    model, _, _ = TS.train_federated_rf(clients, cfg)
    return TS.evaluate_rf(model, *test)


def _feature_extract():
    from repro.core import feature_extract as FE
    clients, test = _clients()
    # ros sampling keeps the pinned model off the degenerate
    # all-negative point (F1=0 would mask quality regressions)
    cfg = FE.FedXGBConfig(num_rounds=3, depth=3, shallow_depth=2,
                          shallow_rounds=2, top_features=4, n_bins=16,
                          sampling="ros", seed=SEED)
    model, _, _ = FE.train_federated_xgb_fe(clients, cfg)
    return FE.evaluate_fe(model, *test)


def _fed_hist():
    from repro.core import fed_hist as FH
    clients, test = _clients()
    cfg = FH.FedHistConfig(num_rounds=3, depth=3, n_bins=16, seed=SEED)
    model, _, _ = FH.train_federated_xgb_hist(clients, cfg)
    return FH.evaluate_fed_hist(model, *test)


def _serve_load():
    """Virtual load-engine run (pure function of spec + seed): a small
    Poisson trace through the queue + continuous-batching state
    machine.  Snapshotted on its own keys (RAW_RUNS) — all O(1)-scale
    values, exactly reproducible, so any drift is a real behaviour
    change in the simulator's scheduling."""
    from repro.serve.load import LoadConfig, simulate_load
    cfg = LoadConfig(arrivals="poisson:400", n_requests=300,
                     rows="uniform:1:6", bucket_sizes=(8, 32),
                     max_wait=0.01, max_queue=64, deadline=0.08,
                     service="affine:0.004:0.0002", seed=SEED)
    row = simulate_load(cfg).row
    return {
        "achieved_over_offered": row["achieved_qps"]
        / row["offered_qps"],
        "p50_s": row["p50_ms"] / 1e3,
        "p99_s": row["p99_ms"] / 1e3,
        "mean_wait_s": row["mean_wait_ms"] / 1e3,
        "deadline_miss_rate": row["deadline_miss_rate"],
        "rejection_rate": row["rejection_rate"],
        "mean_occupancy": row["mean_occupancy"],
    }


def _obs_trace():
    """Golden trace snapshot: a tiny traced sync federated run on the
    virtual clock.  With no latency model every stamp is a small exact
    float and every event attribute is an integer, so the jsonl export
    is byte-stable across platforms — snapshotted as numeric
    fingerprints (event count, export size, sha256 prefix as an exact
    48-bit float).  Any change to event shapes, stamp placement, or
    export framing shows up as a digest diff."""
    import hashlib

    from repro.core import parametric as P
    from repro.obs import Tracer, jsonl_bytes, use
    clients, _ = _clients(n=200, k=3)
    cfg = P.FedParametricConfig(model="logreg", rounds=3, local_steps=4,
                                lr=0.05, seed=SEED)
    tr = Tracer(clock="virtual", meta={"golden": "obs_trace"})
    with use(tr):
        P.train_federated(clients, cfg)
    data = jsonl_bytes(tr)
    digest = int(hashlib.sha256(data).hexdigest()[:12], 16)
    return {"n_events": float(len(tr.events)),
            "n_bytes": float(len(data)), "digest": float(digest)}


#: pipeline name -> zero-arg callable returning its metrics dict.  The
#: async_parametric row pins the virtual-time event loop end to end
#: (fixed seed => deterministic dispatch/arrival order => stable F1).
GOLDEN_RUNS = {
    "parametric": _parametric(),
    "parametric_async": _parametric(schedule="async:2",
                                    latency="lognormal:0:1"),
    "tree_subset": _tree_subset,
    "feature_extract": _feature_extract,
    "fed_hist": _fed_hist,
    "serve_load": _serve_load,
    "obs_trace": _obs_trace,
}

#: runs whose returned dict is snapshotted on its own keys (already
#: O(1)-scale summary values) instead of the METRIC_KEYS filter.
RAW_RUNS = {"serve_load", "obs_trace"}

#: RAW_RUNS that are pure functions of (spec, seed) — no BLAS jitter —
#: so the snapshot must match exactly, not merely within TOLERANCE.
EXACT_RUNS = {"serve_load", "obs_trace"}


def compute_metrics() -> dict:
    out = {}
    for name, run in GOLDEN_RUNS.items():
        m = run()
        keys = sorted(m) if name in RAW_RUNS \
            else [k for k in METRIC_KEYS if k in m]
        out[name] = {k: round(float(m[k]), 6) for k in keys}
    return out


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    got = compute_metrics()
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump({"seed": SEED, "tolerance": TOLERANCE,
                   "metrics": got}, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.relpath(GOLDEN_PATH, ROOT)}")
    for name, m in got.items():
        print(f"  {name}: " + " ".join(f"{k}={v:.3f}"
                                       for k, v in m.items()))
    return 0


if __name__ == "__main__":
    sys.exit(main())
