"""Serving benchmark: bundle export/score throughput + latency per
pipeline kind, and the forest-inference kernel vs the training-side
per-level traversal loop.

Each row is ``(name, us_per_request, derived)`` in the harness CSV
shape.  ``serve/<kind>/b<batch>`` rows drive the bucketed
``repro.serve.engine`` over a request stream of that batch size and
carry ``rows_per_s`` / ``p50_ms`` / ``p99_ms``; ``forest_infer/*`` rows
time one large forest scored by (a) the per-level vmap traversal the
training code uses (``trees.growth.predict_forest``), (b) the jitted
XLA reference, and (c) the Pallas kernel path — the serving hot-path
before/after.

Full results land in ``results/serve/serve_bench.json`` for
``benchmarks.report serve``.

Run standalone:  PYTHONPATH=src python -m benchmarks.serve_bench
Parity gate:     PYTHONPATH=src python -m benchmarks.serve_bench --smoke
(the CI serve-smoke job; exits non-zero if the kernel, the bucketed
engine, or a bundle round-trip drifts from its reference).

QPS sweep:       PYTHONPATH=src python -m benchmarks.serve_bench --load
drives the trace-driven load engine (``repro.serve.load``) over the
Framingham 4-model ensemble: per-bucket service times are calibrated
by measuring ``engine.score``, then a Poisson offered-rate ladder is
simulated on the calibrated table and the **max-sustainable-QPS**
(highest offered rate with p99 under the deadline, zero rejections)
plus the p99 at the highest sustained point become perf-gate rows in
``results/serve_load/serve_load_gate.json`` — gated and appended to
the repo-root ``BENCH_serve_load.json`` trajectory by
``tools/perf_gate.py --check --current
results/serve_load/serve_load_gate.json --bench BENCH_serve_load.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import framingham as F
from repro.kernels.forest_infer.ops import forest_infer
from repro.launch.serve_fed import check_kernel_parity, train_smoke_bundles
from repro.serve import bundle as B
from repro.serve.engine import ScoringEngine
from repro.trees import forest as RF
from repro.trees.growth import predict_forest

BATCHES = (64, 256, 1024)
BUCKETS = (64, 256, 1024)
N_REQUESTS = 30


def _engine_rows():
    bundles, (xt, _) = train_smoke_bundles(seed=0, n_records=1200)
    stream = F.synthesize(n=max(BATCHES) * 4, seed=7).x
    rows, stats = [], {}
    for kind, bundle in bundles.items():
        engine = ScoringEngine(bundle, bucket_sizes=BUCKETS)
        engine.warmup(stream.shape[1])
        for batch in BATCHES:
            engine.reset_stats()
            for i in range(N_REQUESTS):
                lo = (i * batch) % (len(stream) - batch)
                engine.score(stream[lo:lo + batch])
            st = engine.stats()
            stats[f"{kind}/b{batch}"] = st
            rows.append((f"serve/{kind}/b{batch}",
                         st["p50_ms"] * 1e3,
                         f"rows_per_s={st['rows_per_s']:.0f};"
                         f"p50_ms={st['p50_ms']:.3f};"
                         f"p99_ms={st['p99_ms']:.3f}"))
    return rows, stats


def _kernel_rows():
    """One 128-tree depth-8 forest on a 4096-row batch: the per-level
    training traversal vs the jitted serving paths."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2000, 15)).astype(np.float32))
    y = jnp.asarray((rng.random(2000) < 0.3).astype(np.float32))
    rf = RF.fit(x, y, num_trees=128, depth=8,
                rng=jax.random.PRNGKey(0)).forest
    xq = jnp.asarray(rng.normal(size=(4096, 15)).astype(np.float32))

    variants = {
        "loop": lambda: predict_forest(rf, xq),
        "xla": jax.jit(lambda q: forest_infer(rf, q, impl="xla")),
    }
    if jax.default_backend() != "cpu":
        # compiled kernel only off-CPU; interpret mode is a correctness
        # tool, not a perf path
        variants["pallas"] = jax.jit(
            lambda q: forest_infer(rf, q, impl="pallas"))
    rows, stats = [], {}
    for name, fn in variants.items():
        call = (lambda: fn(xq)) if name != "loop" else fn
        jax.block_until_ready(call())            # warm / compile
        times = []
        for _ in range(10):
            t0 = time.perf_counter()
            jax.block_until_ready(call())
            times.append(time.perf_counter() - t0)
        us = float(np.median(times) * 1e6)
        thr = xq.shape[0] / (us / 1e6)
        stats[f"forest_infer/{name}"] = {"us": us, "rows_per_s": thr}
        rows.append((f"forest_infer/{name}", us,
                     f"trees=128;depth=8;rows=4096;"
                     f"rows_per_s={thr:.0f}"))
    return rows, stats


def load_sweep(*, n_requests: int = 30_000, deadline: float = None,
               out: str = "results/serve_load/load_bench.json",
               gate_out: str = "results/serve_load/serve_load_gate.json"):
    """QPS sweep on the 4-model ensemble (the paper's deployment
    shape): measure per-bucket service medians on the real engine,
    then ladder offered Poisson rates through the load engine on the
    calibrated table.  Returns (printable rows, gate rows)."""
    from benchmarks.kernels_bench import bench_meta
    from repro.serve.load import (LoadConfig, calibrate_service,
                                  qps_sweep, save_rows, sweep_rates)

    bundles, (xt, _) = train_smoke_bundles(seed=0, n_records=1200)
    engine = ScoringEngine(list(bundles.values()), bucket_sizes=BUCKETS)
    engine.warmup(xt.shape[1])
    svc = calibrate_service(engine, xt.shape[1])
    full_s = svc.table[BUCKETS[-1]]
    capacity = BUCKETS[-1] / full_s           # rows/s at full batches
    if deadline is None:
        # generous relative budget: ten full-batch service times (but
        # at least 50 ms) — saturation, not jitter, should break it
        deadline = max(10.0 * full_s, 0.05)
    cfg = LoadConfig(n_requests=n_requests, rows=1, bucket_sizes=BUCKETS,
                     max_wait=full_s, max_queue=8 * BUCKETS[-1],
                     deadline=deadline, service=svc, seed=0)
    sweep, max_qps = qps_sweep(cfg, sweep_rates(capacity, n=10))
    meta = bench_meta()
    save_rows(sweep, out, meta={**meta, "mode": "ensemble4_sweep",
                                "capacity_qps": capacity,
                                "deadline_s": deadline,
                                "service_table": svc.table,
                                "max_sustainable_qps": max_qps})
    gate = []
    if max_qps is not None:
        gate.append({"name": "serve_load/ensemble4/max_qps",
                     "us": 1e6 / max_qps,
                     "note": f"max_qps={max_qps:.0f};"
                             f"deadline_ms={deadline * 1e3:.0f};"
                             f"capacity_qps={capacity:.0f}", **meta})
        top = [r for r in sweep if r["sustainable"]][-1]
        gate.append({"name": "serve_load/ensemble4/p99_sustained",
                     "us": top["p99_ms"] * 1e3,
                     "note": f"offered_qps={top['offered_qps']:.0f};"
                             f"occupancy={top['mean_occupancy']:.2f}",
                     **meta})
    with open(gate_out, "w") as f:
        json.dump({"meta": {**meta, "smoke": False}, "rows": gate}, f,
                  indent=1)
        f.write("\n")
    rows = [(r2["name"], r2["us"], r2["note"]) for r2 in gate]
    rows += [(f"serve_load/ensemble4/offered{r['offered_qps']:.0f}",
              r["p99_ms"] * 1e3,
              f"achieved_qps={r['achieved_qps']:.0f};"
              f"miss={r['deadline_miss_rate']:.3f};"
              f"occ={r['mean_occupancy']:.2f};"
              f"sustainable={int(r['sustainable'])}") for r in sweep]
    return rows, gate


def run() -> list:
    from benchmarks.kernels_bench import bench_meta
    engine_rows, engine_stats = _engine_rows()
    kernel_rows, kernel_stats = _kernel_rows()
    os.makedirs("results/serve", exist_ok=True)
    with open("results/serve/serve_bench.json", "w") as f:
        # meta keys match kernels_bench rows so trajectory comparisons
        # stay same-platform only
        json.dump({"meta": bench_meta(), "engine": engine_stats,
                   "kernel": kernel_stats}, f, indent=1)
    return engine_rows + kernel_rows


def smoke() -> int:
    """CPU parity gate (the CI serve-smoke job).  Returns an exit code."""
    failures = []

    def check(name, fn):
        try:
            fn()
            print(f"  ok   {name}")
        except Exception as e:  # noqa: BLE001 — report, don't crash
            failures.append((name, e))
            print(f"  FAIL {name}: {e}")

    # seed differs from serve_fed --smoke so the two CI gates cover two
    # model draws instead of re-checking one
    bundles, (xt, yt) = train_smoke_bundles(seed=1)

    def kernel_parity():
        for bundle in bundles.values():
            check_kernel_parity(bundle, xt)

    def roundtrip_scores_stable():
        for kind, bundle in bundles.items():
            path = f"results/serve/bench_smoke/{kind}"
            B.save_bundle(path, bundle)
            a = ScoringEngine(bundle, bucket_sizes=(64,)).score(xt)
            b = ScoringEngine(B.load_bundle(path),
                              bucket_sizes=(64,)).score(xt)
            np.testing.assert_array_equal(a, b)

    def bucketed_matches_unbatched():
        for bundle in bundles.values():
            eng = ScoringEngine(bundle, bucket_sizes=(32, 128))
            np.testing.assert_array_equal(eng.score(xt),
                                          eng.score_unbatched(xt))

    def fused_matches_unfused():
        # documented tolerance (serve/engine.py): vote counts exact,
        # probabilities within 1e-6 (tree-sequential vs pairwise sums,
        # f32 vs float64 Platt)
        for kind in ("tree_subset", "fed_hist"):
            ref = ScoringEngine(bundles[kind], bucket_sizes=(64,))
            fus = ScoringEngine(bundles[kind], bucket_sizes=(64,),
                                fused=True, impl="pallas_interpret")
            np.testing.assert_allclose(fus.score(xt), ref.score(xt),
                                       atol=1e-6, rtol=0)
            ref.calibrate(xt, yt)
            fus.calibrate(xt, yt)
            np.testing.assert_allclose(fus.score(xt), ref.score(xt),
                                       atol=1e-6, rtol=0)

    def int8_within_bound():
        # analytic bound (serve/engine.py): leaves move < one quant
        # step each, routing unchanged.  fed_hist: |dmargin| <=
        # lr * rounds * step, probs within a quarter of that (sigmoid
        # is 1/4-Lipschitz).  tree_subset: votes flip only where
        # |leaf| < step, so the vote fraction moves <= flippable/T.
        from repro.kernels.forest_infer.ops import forest_infer as fi
        from repro.serve.engine import leaf_quant_step
        gb = bundles["fed_hist"]
        model = gb.model()
        step = leaf_quant_step(model.forest)
        bound = float(model.learning_rate) * model.forest.leaf.shape[0] \
            * step / 4.0
        ref = ScoringEngine(gb, bucket_sizes=(64,)).score(xt)
        q8 = ScoringEngine(gb, bucket_sizes=(64,),
                           quantize="int8_sr").score(xt)
        assert np.max(np.abs(q8 - ref)) <= bound + 1e-6, \
            f"int8 fed_hist drift {np.max(np.abs(q8 - ref)):.2e} > " \
            f"analytic bound {bound:.2e}"
        rf = bundles["tree_subset"]
        forest = rf.model().forest
        step = leaf_quant_step(forest)
        vals = np.asarray(fi(forest, jnp.asarray(xt, jnp.float32),
                             impl="xla"))                    # (T, n)
        flippable = np.mean(np.abs(vals) < step, axis=0)     # per row
        ref = ScoringEngine(rf, bucket_sizes=(64,)).score(xt)
        q8 = ScoringEngine(rf, bucket_sizes=(64,),
                           quantize="int8_sr").score(xt)
        assert np.all(np.abs(q8 - ref) <= flippable + 1e-6), \
            "int8 tree_subset vote drift exceeds flippable-leaf bound"

    print("serve_bench --smoke (parity gate)")
    check("forest kernel == predict_forest (all bundles)", kernel_parity)
    check("bundle round-trip scores stable", roundtrip_scores_stable)
    check("bucketed engine == unbatched", bucketed_matches_unbatched)
    check("fused scoring == unfused engine (1e-6)", fused_matches_unfused)
    check("int8_sr scoring within analytic bound", int8_within_bound)
    print(f"{len(failures)} parity regressions")
    return 1 if failures else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CPU parity gate for CI; exits non-zero "
                    "on regressions")
    ap.add_argument("--load", action="store_true",
                    help="QPS sweep on the 4-model ensemble via the "
                    "trace-driven load engine (writes perf-gate rows)")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke())
    if args.load:
        rows, _ = load_sweep()
        print("name,us,derived")
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
        sys.exit(0)
    print("name,us_per_request,derived")
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
