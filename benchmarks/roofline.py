"""Roofline report: reads results/dryrun/*.json (written by
``repro.launch.dryrun``) and renders the §Roofline table for docs/EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional


def load(save_dir: str = "results/dryrun", tag: Optional[str] = None,
         mesh: Optional[str] = None) -> List[Dict]:
    recs = []
    for fn in sorted(glob.glob(f"{save_dir}/*.json")):
        with open(fn) as f:
            r = json.load(f)
        if tag and r.get("tag") != tag:
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.2f}ms"


def table(recs: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | "
           "dominant | useful-FLOPs | note |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in recs:
        t = r["roofline"]
        note = _bottleneck_note(r)
        mem = t.get("memory_fused_s", t["memory_s"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_fmt_s(t['compute_s'])} | {_fmt_s(mem)} "
            f"| {_fmt_s(t['collective_s'])} "
            f"| {t['dominant'].replace('_s','').replace('memory_fused','memory')} "
            f"| {r['useful_flops_ratio']*100:.0f}% | {note} |")
    return "\n".join(lines)


def _bottleneck_note(r: Dict) -> str:
    """One sentence: what would move the dominant term down."""
    t = r["roofline"]
    dom = t["dominant"]
    phase = r["phase"]
    if dom == "compute_s":
        if r["useful_flops_ratio"] < 0.65:
            return ("cut non-useful FLOPs: remat policy / causal block-skip"
                    if phase == "train" else "cut redundant compute")
        return "compute-bound near peak; more chips or lower precision"
    if dom in ("memory_s", "memory_fused_s"):
        if phase == "decode":
            return "cache reads dominate; shard cache wider or quantize kv"
        return "activation traffic; fuse/reuse or shrink remat footprint"
    return "collective-bound; reshard to cut gathered bytes or overlap"


def csv_rows(recs: List[Dict]) -> List[tuple]:
    rows = []
    for r in recs:
        t = r["roofline"]
        rows.append((f"roofline_{r['mesh']}_{r['arch']}_{r['shape']}",
                     t["bound_s"] * 1e6,
                     f"dom={t['dominant']};useful="
                     f"{r['useful_flops_ratio']:.2f}"))
    return rows


def summarize(save_dir: str = "results/dryrun", tag: str = "baseline"):
    recs = load(save_dir, tag=tag)
    print(table(recs))
    return recs


if __name__ == "__main__":
    summarize()
