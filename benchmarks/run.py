"""Benchmark harness: one function per paper table/figure + kernel micro-
benchmarks + roofline readout. Prints ``name,us_per_call,derived`` CSV.

Modes:
  python -m benchmarks.run             # full: paper tables + kernels +
                                       # roofline + federated engine sweep
  python -m benchmarks.run --quick     # kernels + roofline only (no FL runs)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def paper_table_rows(results) -> list:
    rows = []
    for tname in ("table2", "table3"):
        for key, v in results[tname].items():
            rows.append((f"{tname}/{key}", v.get("agg_s", 0.0) * 1e6,
                         f"f1={v['f1']:.3f};comm_mb="
                         f"{v.get('uplink_mb', v.get('comm_mb', 0)):.3f}"))
    for key, v in results["table4"].items():
        rows.append((f"table4/{key}", v.get("agg_s", 0.0) * 1e6,
                     f"f1={v['f1']:.3f};comm_mb={v['uplink_mb']:.3f}"))
    for key, v in results["table5"].items():
        c = v.get("centralized_f1")
        rows.append((f"table5/{key}", 0.0,
                     f"centralized={c if c is None else round(c, 3)};"
                     f"federated={round(v['federated_f1'], 3)}"))
    for key, v in results["fig2"].items():
        rows.append((f"fig2/{key}", 0.0,
                     f"mb={v['uplink_mb']:.3f};f1={v['f1']:.3f}"))
    for key, v in results["fig3"].items():
        if key.endswith("recall_gain_pct"):
            rows.append((f"fig3/{key}", 0.0, f"gain_pct={v:.1f}"))
    for key, v in results["theorem1"].items():
        rows.append((f"theorem1/{key}", 0.0,
                     f"dF1={v['delta_f1']:.3f};ok={v['bound_ok']};"
                     f"comm_cut_pct={v['comm_reduction_pct']:.0f}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the FL paper-table runs")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print("name,us_per_call,derived")

    from benchmarks import kernels_bench
    for row in kernels_bench.run():
        _emit(row["name"], row["us"], row["note"])

    from benchmarks import roofline
    recs = roofline.load(tag="baseline")
    if recs:
        for row in roofline.csv_rows(recs):
            _emit(*row)
    else:
        _emit("roofline", 0.0,
              "no dry-run artifacts; run python -m repro.launch.dryrun")

    if not args.quick:
        from benchmarks import fed_engine_bench
        for row in fed_engine_bench.run():
            _emit(*row)

        cache = "results/paper/tables.json"
        if os.path.exists(cache):
            with open(cache) as f:
                results = json.load(f)
        else:
            from benchmarks import paper_tables
            results = paper_tables.run_all(seed=args.seed)
        for row in paper_table_rows(results):
            _emit(*row)


if __name__ == "__main__":
    main()
