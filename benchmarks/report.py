"""Render docs/EXPERIMENTS.md sections from results artifacts.

  python -m benchmarks.report dryrun    # §Dry-run summary table
  python -m benchmarks.report roofline  # §Roofline table
  python -m benchmarks.report paper     # §Repro tables vs paper claims
  python -m benchmarks.report perf      # §Perf before/after per tag
  python -m benchmarks.report serve     # §Serving throughput/latency
  python -m benchmarks.report async     # §Async — time-to-target-F1
"""
from __future__ import annotations

import json
import os
import sys
from collections import defaultdict

from benchmarks import roofline as RL

# the paper's published numbers (for side-by-side rendering)
PAPER = {
    "table2": {"logreg/none": 0.55, "logreg/ros": 0.65, "logreg/rus": 0.56,
               "logreg/smote": 0.64, "svm/none": 0.46, "svm/ros": 0.57,
               "svm/rus": 0.74, "svm/smote": 0.65, "mlp/none": 0.51,
               "mlp/ros": 0.59, "mlp/rus": 0.57, "mlp/smote": 0.64},
    "table3": {"rf_full/none": 0.80, "rf_full/ros": 0.80,
               "rf_full/rus": 0.68, "rf_full/smote": 0.79,
               "rf_sub30/smote": 0.81, "xgb_full/none": 0.80,
               "xgb_full/ros": 0.74, "xgb_full/rus": 0.67,
               "xgb_full/smote": 0.80, "xgb_fe/smote": 0.80},
    "table5": {"logreg": (0.65, 0.65), "svm": (0.72, 0.74),
               "mlp": (0.69, 0.64), "random_forest": (0.87, 0.81),
               "xgboost": (0.78, 0.80)},
}


def dryrun_section() -> str:
    lines = ["### §Dry-run — every (arch x shape x mesh) lowers + compiles",
             "",
             "| arch | shape | mesh | compile_s | args GiB/dev | "
             "temp GiB/dev | wire MB/dev | collectives |",
             "|---|---|---|---|---|---|---|---|"]
    for r in RL.load(tag="baseline"):
        mem = r.get("memory_analysis", {})
        args_g = mem.get("argument_size_in_bytes", 0) / 2 ** 30
        temp_g = mem.get("temp_size_in_bytes", 0) / 2 ** 30
        kinds = ",".join(f"{k.split('-')[1] if '-' in k else k}:"
                         f"{int(v)}"
                         for k, v in sorted(
                             r["collective_count_by_kind"].items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']:.0f} | {args_g:.2f} | {temp_g:.2f} "
            f"| {r['collective_wire_bytes']/1e6:,.0f} | {kinds} |")
    return "\n".join(lines)


def roofline_section() -> str:
    recs = RL.load(tag="baseline", mesh="16x16")
    return ("### §Roofline — single-pod (16x16 = 256 chips)\n\n"
            + RL.table(recs))


def paper_section() -> str:
    with open("results/paper/tables.json") as f:
        res = json.load(f)
    out = ["### §Repro — paper tables on the synthetic Framingham twin",
           ""]
    out.append("**Table 2 (parametric, federated)** — ours vs paper F1:")
    out.append("")
    out.append("| model/sampling | F1 (ours) | F1 (paper) | P | R | "
               "comm MB |")
    out.append("|---|---|---|---|---|---|")
    for k, v in res["table2"].items():
        pp = PAPER["table2"].get(k)
        out.append(f"| {k} | {v['f1']:.2f} | "
                   f"{pp if pp is not None else '—'} | "
                   f"{v['precision']:.2f} | {v['recall']:.2f} | "
                   f"{v['comm_mb']:.2f} |")
    out.append("")
    out.append("**Table 3 (non-parametric, federated)**:")
    out.append("")
    out.append("| model/sampling | F1 (ours) | F1 (paper) | uplink MB | "
               "agg s |")
    out.append("|---|---|---|---|---|")
    for k, v in res["table3"].items():
        pp = PAPER["table3"].get(k)
        out.append(f"| {k} | {v['f1']:.2f} | "
                   f"{pp if pp is not None else '—'} | "
                   f"{v['uplink_mb']:.2f} | {v['agg_s']:.2f} |")
    out.append("")
    out.append("**Table 4 (framework comparison)**:")
    for k, v in res["table4"].items():
        out.append(f"- {k}: F1={v['f1']:.2f}, uplink={v['uplink_mb']:.2f}MB,"
                   f" imbalance={v['imbalance']}, models={v['models']}")
    out.append("")
    out.append("**Table 5 (centralized vs federated F1)**:")
    out.append("")
    out.append("| model | centralized (ours/paper) | federated "
               "(ours/paper) |")
    out.append("|---|---|---|")
    for k, v in res["table5"].items():
        pp = PAPER["table5"].get(k, (None, None))
        c = "—" if v["centralized_f1"] is None else f"{v['centralized_f1']:.2f}"
        out.append(f"| {k} | {c} / {pp[0] if pp[0] else '—'} "
                   f"| {v['federated_f1']:.2f} / {pp[1] if pp[1] else '—'} |")
    out.append("")
    out.append("**Fig 2 (comm/F1 trade-off)**: "
               + "; ".join(f"{k}: {v['uplink_mb']:.1f}MB@F1={v['f1']:.2f}"
                           for k, v in res["fig2"].items()))
    out.append("")
    out.append("**Fig 3 (federated SMOTE recall gain, skewed non-IID)**: "
               + "; ".join(f"{k}: {v:+.1f}%"
                           for k, v in res["fig3"].items()
                           if k.endswith("recall_gain_pct"))
               + " (paper claims +22%)")
    out.append("")
    out.append("**Theorem 1**:")
    for k, v in res["theorem1"].items():
        out.append(f"- {k}: |dF1|={v['delta_f1']:.3f} "
                   f"(bound 0.03 -> {'OK' if v['bound_ok'] else 'MISS'}), "
                   f"comm cut {v['comm_reduction_pct']:.0f}%, "
                   f"F1 retention {v['f1_retention_pct']:.0f}%")
    return "\n".join(out)


def perf_section(pairs=None) -> str:
    """Compare all tags per (arch, shape) pair."""
    recs = RL.load()
    by_pair = defaultdict(list)
    for r in recs:
        if r["mesh"] != "16x16":
            continue
        by_pair[(r["arch"], r["shape"])].append(r)
    out = ["| arch x shape | tag | compute | memory(fused) | collective | "
           "dominant | useful |", "|---|---|---|---|---|---|---|"]
    for (arch, shape), rs in sorted(by_pair.items()):
        if len(rs) < 2 and pairs is None:
            continue
        if pairs is not None and (arch, shape) not in pairs:
            continue
        for r in sorted(rs, key=lambda x: x["tag"]):
            t = r["roofline"]
            mem = t.get("memory_fused_s", t["memory_s"])
            out.append(
                f"| {arch} x {shape} | {r['tag']} "
                f"| {t['compute_s']*1e3:.0f}ms | {mem*1e3:.0f}ms "
                f"| {t['collective_s']*1e3:.0f}ms "
                f"| {t['dominant'].replace('_s','')} "
                f"| {r['useful_flops_ratio']*100:.0f}% |")
    return "\n".join(out)


def serve_section() -> str:
    """Bucketed-engine throughput/latency per bundle kind + the forest
    kernel vs the training-side traversal (benchmarks.serve_bench)."""
    with open("results/serve/serve_bench.json") as f:
        res = json.load(f)
    out = ["### §Serving — bundle scoring throughput/latency", "",
           "| bundle kind / batch | rows/s | p50 ms | p99 ms |",
           "|---|---|---|---|"]
    for key, st in res["engine"].items():
        out.append(f"| {key} | {st['rows_per_s']:,.0f} "
                   f"| {st['p50_ms']:.3f} | {st['p99_ms']:.3f} |")
    out.append("")
    out.append("**Forest inference** (128 trees x depth 8 x 4096 rows):")
    for key, st in res["kernel"].items():
        out.append(f"- {key}: {st['us'] / 1e3:.1f}ms/call, "
                   f"{st['rows_per_s']:,.0f} rows/s")
    return "\n".join(out)


def async_section() -> str:
    """Sync vs buffered-async aggregation under heterogeneous latency:
    virtual time to the target F1 (benchmarks.fed_engine_bench writes
    results/async/async_bench.json from the runtime timeline)."""
    with open("results/async/async_bench.json") as f:
        res = json.load(f)
    out = [f"### §Async — time to F1 ≥ {res['target_f1']:.3f} "
           f"(latency `{res['latency']}`, virtual clock)", "",
           "| schedule | t→target (vs) | total (vs) | final F1 | "
           "uplink MB |", "|---|---|---|---|---|"]
    for sched, r in res["rows"].items():
        tt = ("never" if r["time_to_target_s"] is None
              else f"{r['time_to_target_s']:.2f}")
        out.append(f"| {sched} | {tt} | {r['vt_total_s']:.2f} "
                   f"| {r['final_f1']:.3f} | {r['uplink_mb']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    print({"dryrun": dryrun_section, "roofline": roofline_section,
           "paper": paper_section, "perf": perf_section,
           "serve": serve_section, "async": async_section}[which]())
