"""Population-scale federation benchmark: rounds-per-second of the
sharded client-axis engine (``repro.core.runtime.ShardedFedRuntime``)
as the cohort grows from 10³ to 10⁵ synthetic clients.

Each row times **one full federated round** — local Adam steps on every
client (vmapped over the mesh-sharded client axis), hierarchical
client→silo→server aggregation, and the server update — as min-over-
iterations wall time in µs, the same estimator and row shape as
``benchmarks/kernels_bench.py``.  Row names encode the swept config::

    fed_round/logreg/c{n_clients}/s{n_silos}/d{n_devices}

so the perf gate (``tools/perf_gate.py --bench BENCH_fed_scale.json``)
only compares like against like; the note carries the derived
rounds-per-second and clients-per-second throughput.  Device count
comes from ``jax.device_count()`` — the CI job forces 8 virtual CPU
devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--smoke`` additionally runs the **parity gate**: the mesh-sharded
round must match the single-device vmap round within
``ShardedFedRuntime.PARITY_ATOL`` (documented reduction-order
tolerance), and hierarchical silo aggregation must agree with the flat
mean under iid + full participation.  Exits non-zero on drift.

Run:       XLA_FLAGS=--xla_force_host_platform_device_count=8 \
             PYTHONPATH=src python -m benchmarks.fed_scale_bench
CI smoke:  ... python -m benchmarks.fed_scale_bench --smoke
Gate:      PYTHONPATH=src python tools/perf_gate.py --check --smoke \
             --current results/fed_scale/fed_scale_bench.json \
             --bench BENCH_fed_scale.json
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.kernels_bench import bench_meta, save_rows
from repro.core import parametric as P
from repro.core.runtime import ShardedFedRuntime
from repro.data.cohort import build_cohort

OUT = "results/fed_scale/fed_scale_bench.json"

#: (n_clients, n_silos) sweep per shape set.  Every n_clients divides
#: by 8 (the CI virtual-device count) and by its silo count, so mesh
#: placement never degrades to replication.
SWEEPS = {
    "smoke": [(256, 1), (256, 8), (1024, 8)],
    "full": [(1024, 8), (8192, 64), (100000, 100)],
}
ROWS_PER_CLIENT = 16
CFG = dict(model="logreg", rounds=1, local_steps=10, lr=0.05)


def _build(n_clients: int, n_silos: int, mesh):
    cfg = P.FedParametricConfig(**CFG)
    xs, ys = build_cohort(f"framingham_like:{n_clients}:{ROWS_PER_CLIENT}")
    rt = ShardedFedRuntime(n_clients=n_clients, rounds=1, n_silos=n_silos,
                           mesh=mesh, strategy=cfg.strategy, seed=cfg.seed)
    local_fn = P.build_local_delta(cfg.model, cfg.local_steps, cfg.lr)
    import repro.models.tabular as tabular
    params = tabular.MODELS[cfg.model]["init"](
        jax.random.PRNGKey(cfg.seed), xs.shape[-1])
    return rt, local_fn, params, rt.place(xs), rt.place(ys)


def _time_round(rt, local_fn, params, xs, ys, iters: int) -> float:
    """Min-over-iterations µs for one jitted federated round (compile
    excluded by a warmup call)."""
    round_fn = rt.build_round(local_fn)
    state = rt.strategy.init_state(params)
    jax.block_until_ready(round_fn(params, state, xs, ys))  # compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(round_fn(params, state, xs, ys))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(smoke: bool = False) -> List[Dict]:
    meta = bench_meta()
    sweep = SWEEPS["smoke" if smoke else "full"]
    iters = 3 if smoke else 5
    mesh = "host" if jax.device_count() > 1 else None
    rows = []
    for n_clients, n_silos in sweep:
        rt, local_fn, params, xs, ys = _build(n_clients, n_silos, mesh)
        us = _time_round(rt, local_fn, params, xs, ys, iters)
        rps = 1e6 / us
        name = (f"fed_round/{CFG['model']}/c{n_clients}/s{n_silos}"
                f"/d{rt.n_devices}")
        note = (f"{rps:.2f} rounds/s, "
                f"{n_clients * rps:,.0f} clients/s, "
                f"{CFG['local_steps']} local steps x "
                f"{ROWS_PER_CLIENT} rows/client")
        rows.append({"name": name, "us": us, "note": note, **meta})
        print(f"{name:40s} {us/1e3:10.2f} ms/round  ({note})")
    return rows


def parity_gate(atol: float = ShardedFedRuntime.PARITY_ATOL) -> int:
    """Sharded-mesh and hierarchical-silo rounds must match the
    single-device flat vmap round within the documented tolerance."""
    n_clients, failures = 64, []
    cfg = P.FedParametricConfig(model="logreg", rounds=3, local_steps=5,
                                lr=0.05)
    spec = f"framingham_like:{n_clients}:{ROWS_PER_CLIENT}"
    ref, *_ = P.train_federated_sharded(spec, cfg, mesh=None, silos=1)
    variants = [("silo-vs-flat", dict(mesh=None, silos=8))]
    if jax.device_count() > 1:
        variants += [("mesh-vs-flat", dict(mesh="host", silos=1)),
                     ("mesh+silo-vs-flat", dict(mesh="host", silos=8))]
    else:
        print("parity: single device — mesh variants skipped "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    for name, kw in variants:
        got, *_ = P.train_federated_sharded(spec, cfg, **kw)
        dev = max(float(np.max(np.abs(a - b))) for a, b in
                  zip(jax.tree.leaves(got), jax.tree.leaves(ref)))
        ok = dev <= atol
        print(f"parity {name:20s} max|Δ|={dev:.2e} "
              f"{'OK' if ok else f'FAIL (atol={atol:g})'}")
        if not ok:
            failures.append(name)
    return len(failures)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep + sharded==vmap parity gate (CI)")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()
    if args.smoke and parity_gate():
        print("fed_scale_bench: parity FAILED", file=sys.stderr)
        return 1
    rows = run(smoke=args.smoke)
    path = save_rows(rows, args.out, smoke=args.smoke)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
