"""Observability overhead bench: what tracing costs per federated round.

Three timings isolate the instrumentation layer from real model compute
(a near-zero-work :class:`~repro.core.runtime.ClientWork` plugin makes
round time ≈ runtime bookkeeping, the hot path the tracer guards sit
on):

* ``obs/round_baseline`` — a hand-inlined copy of the pre-observability
  sync round loop (participation plan, ledger logging, timeline record,
  aggregate), with no runtime object at all.  Context row: what the
  bookkeeping itself costs.
* ``obs/round_traced_off`` — the instrumented :class:`FedRuntime` with
  the disabled ``NULL_TRACER``.  The zero-overhead-when-off contract:
  every guard is one falsy-object truthiness check, no allocations
  (bit-exactness is gated separately in tests/test_obs.py and
  ``repro.launch.trace --smoke``; this row gates the *time* via the
  perf trajectory).
* ``obs/round_traced_on`` — the same run with a live virtual-clock
  :class:`Tracer`.  Documented bound: ≤ ``ON_OVERHEAD_X`` × the
  traced-off round plus an absolute floor (event dicts + per-track
  stacks are O(spans/round); the bound is generous because these
  rounds do no model work, so the *relative* cost here is the
  worst case — real training rounds amortize it to noise).

``obs/guard_1k`` times 1000 disabled-tracer guard checks directly —
the off-path cost the ≤1% claim rests on, gated at an absolute bound.

The ≤1% traced-off gate (``obs/off_overhead_pct``): instrumented-but-
off differs from pre-instrumentation code *only* in the guards, so the
per-round off overhead is (guards/round) × (per-check time from the
guard micro-bench).  That estimate, as a percentage of a **real**
measured training round (tiny logreg federation, jit-warmed), must stay
under ``OFF_OVERHEAD_PCT`` — the zero-allocation claim in time terms.

Rows land in ``results/obs/obs_bench.json`` and gate against the
repo-root ``BENCH_obs.json`` trajectory through the generic
``tools/perf_gate.py`` (the ``obs-smoke`` CI job)::

  PYTHONPATH=src python -m benchmarks.obs_bench --smoke
  PYTHONPATH=src python tools/perf_gate.py --check --smoke \\
      --current results/obs/obs_bench.json --bench BENCH_obs.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from benchmarks.kernels_bench import bench_meta  # noqa: E402
from repro.core.comm import CommLog  # noqa: E402
from repro.core.participation import get_participation  # noqa: E402
from repro.core.runtime import (ClientMsg, ClientWork, FedRuntime,  # noqa: E402
                                ServerAgg)
from repro.obs import NULL_TRACER, Tracer  # noqa: E402

OUT = "results/obs/obs_bench.json"
N_CLIENTS = 8
#: traced-on bound: per-round time ≤ ON_OVERHEAD_X × traced-off + floor
ON_OVERHEAD_X = 2.5
ON_FLOOR_US = 200.0
#: 1000 disabled-tracer guard checks must stay under this — the bound
#: includes the Python loop driving them (~30us of the budget by
#: itself), so it holds only while each check is a bare __bool__ call
#: with no allocation behind it
GUARD_1K_US = 200.0
#: traced-off overhead on a real training round (guards/round × guard
#: cost, vs the measured round time) must stay under this percentage
OFF_OVERHEAD_PCT = 1.0


class _TinyWork(ClientWork, ServerAgg):
    """Near-zero compute: tiny numpy payloads, counting aggregate."""

    def __init__(self):
        self.payload = np.zeros(8, np.float32)

    def setup(self, rt):
        return 0

    def client_round(self, rt, state, rnd):
        msgs = []
        nb = self.payload.nbytes
        for c in rnd.computing:
            rt.log_down(rnd.index, c, nb, "model")
            rt.log_up(rnd.index, c, nb, "update")
            msgs.append(ClientMsg(c, self.payload, nb))
        return msgs

    def aggregate(self, rt, state, msgs, rnd):
        return state + len(msgs)


def _baseline_rounds(rounds: int) -> float:
    """The pre-observability sync loop, hand-inlined: same plan /
    ledger / timeline / aggregate work, no runtime object, no guards."""
    comm = CommLog()
    part = get_participation("full")
    rng = np.random.default_rng([0, 0xFED])
    payload = np.zeros(8, np.float32)
    nb = payload.nbytes
    now, state = 0.0, 0
    for r in range(rounds):
        plan = part.plan(r, N_CLIENTS, rng)
        computing = sorted(plan.arrive)
        msgs = []
        for c in computing:
            comm.log(r, f"c{c}", "down", nb, "model")
            comm.log(r, f"c{c}", "up", nb, "update")
            msgs.append(ClientMsg(c, payload, nb))
        now += 1.0
        state += len(msgs)
        comm.timeline.append(
            {"round": r, "t": now, "n_clients": len(msgs),
             "n_msgs": len(msgs), "staleness": [0] * len(msgs),
             "bytes": nb * len(msgs)})
    return state


def _runtime_rounds(rounds: int, tracer) -> None:
    rt = FedRuntime(n_clients=N_CLIENTS, rounds=rounds, tracer=tracer)
    rt.run(_TinyWork())


def _time_us(fn, iters: int) -> float:
    """Min-over-iters wall time of one fn() call, in microseconds."""
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _guard_1k_us(iters: int) -> float:
    tr = NULL_TRACER

    def body():
        n = 0
        for _ in range(1000):
            if tr:           # the exact hot-path guard shape
                n += 1
        return n

    return _time_us(body, iters)


def _real_round_us(iters: int) -> float:
    """Per-round time of a real (tiny, jit-warmed) logreg federation —
    the denominator for the ≤1% off-overhead gate."""
    from repro.core import parametric as P
    from repro.data import framingham as F
    ds = F.synthesize(n=200, seed=1)
    train, _ = F.train_test_split(ds)
    clients = [(c.x, c.y) for c in F.partition_clients(train, 3)]
    cfg = P.FedParametricConfig(model="logreg", rounds=3, local_steps=4,
                                seed=0)
    P.train_federated(clients, cfg)       # warm the jit caches
    return _time_us(lambda: P.train_federated(clients, cfg),
                    iters) / cfg.rounds


def run(smoke: bool = False) -> List[Dict]:
    rounds = 100 if smoke else 400
    iters = 5 if smoke else 10
    meta = bench_meta()

    base = _time_us(lambda: _baseline_rounds(rounds), iters) / rounds
    off = _time_us(lambda: _runtime_rounds(rounds, NULL_TRACER),
                   iters) / rounds
    on = _time_us(
        lambda: _runtime_rounds(rounds, Tracer(clock="virtual")),
        iters) / rounds
    guard = _guard_1k_us(iters)
    real = _real_round_us(iters)
    # guards on one sync round of n clients: log_down/log_up/encode per
    # client plus the span/timeline/drop-branch checks
    n_guards = 3 * N_CLIENTS + 4
    off_us = n_guards * guard / 1000.0
    off_pct = 100.0 * off_us / real

    rows = [
        {"name": "obs/round_baseline", "us": base,
         "note": f"hand-inlined loop;n_clients={N_CLIENTS}", **meta},
        {"name": "obs/round_traced_off", "us": off,
         "note": f"FedRuntime+NULL_TRACER;n_clients={N_CLIENTS}",
         **meta},
        {"name": "obs/round_traced_on", "us": on,
         "note": f"FedRuntime+Tracer;bound={ON_OVERHEAD_X}x+"
         f"{ON_FLOOR_US:.0f}us", **meta},
        {"name": "obs/guard_1k", "us": guard,
         "note": f"1000 falsy guard checks;bound={GUARD_1K_US:.0f}us",
         **meta},
        {"name": "obs/off_overhead_pct", "us": off_us,
         "note": f"pct={off_pct:.4f};guards={n_guards};"
         f"real_round_us={real:.0f};bound={OFF_OVERHEAD_PCT}%",
         **meta},
    ]
    for r in rows:
        print(f"  {r['name']:<26} {r['us']:>10.1f}us  {r['note']}")
    return rows


def check_bounds(rows: List[Dict]) -> List[str]:
    """The in-bench overhead gates (trajectory drift is perf_gate's
    job; these are the absolute documented bounds)."""
    by = {r["name"]: r["us"] for r in rows}
    failures = []
    limit_on = by["obs/round_traced_off"] * ON_OVERHEAD_X + ON_FLOOR_US
    if by["obs/round_traced_on"] > limit_on:
        failures.append(
            f"traced-on round {by['obs/round_traced_on']:.1f}us > "
            f"{limit_on:.1f}us ({ON_OVERHEAD_X}x traced-off + "
            f"{ON_FLOOR_US:.0f}us)")
    if by["obs/guard_1k"] > GUARD_1K_US:
        failures.append(
            f"1000 disabled guards took {by['obs/guard_1k']:.1f}us > "
            f"{GUARD_1K_US:.0f}us — the off path is no longer a bare "
            f"truthiness check")
    (pct_row,) = [r for r in rows
                  if r["name"] == "obs/off_overhead_pct"]
    pct = float(pct_row["note"].split("pct=")[1].split(";")[0])
    if pct > OFF_OVERHEAD_PCT:
        failures.append(
            f"traced-off overhead {pct:.3f}% of a real round > "
            f"{OFF_OVERHEAD_PCT}% ({pct_row['note']})")
    return failures


def save_rows(rows: List[Dict], path: str = OUT,
              smoke: bool = False) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"meta": {**bench_meta(), "smoke": smoke},
                   "rows": rows}, f, indent=1)
        f.write("\n")
    return path


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape set (fewer rounds/iters)")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    save_rows(rows, args.out, smoke=args.smoke)
    print(f"wrote {args.out}")
    failures = check_bounds(rows)
    for f in failures:
        print(f"OVERHEAD  {f}", file=sys.stderr)
    print(f"obs_bench: {len(failures)} overhead-bound failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
