"""Paper-table reproductions (Tables 2-5, Figs 2-3, Theorem 1) on the
synthetic Framingham twin. One function per table; each returns a dict and
is invoked by ``benchmarks.run``. Results land in results/paper/."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.framingham import CONFIG as FCFG
from repro.core import parametric as P
from repro.core import tree_subset as TS
from repro.core import feature_extract as FE
from repro.core.metrics import binary_metrics
from repro.data import framingham as F
from repro.data import sampling as S

SAMPLINGS = ["none", "ros", "rus", "smote"]


def _setup(seed: int = 0, alpha: float = 0.0):
    ds = F.synthesize(n=FCFG.n_records, positive_rate=FCFG.positive_rate,
                      seed=seed)
    tr, te = F.train_test_split(ds, FCFG.train_frac, seed)
    clients = F.partition_clients(tr, FCFG.n_clients, seed, alpha=alpha)
    return tr, te, [(c.x, c.y) for c in clients]


def _fed_stats(clients):
    return S.aggregate_stats([S.minority_stats(x, y) for x, y in clients])


# --- Table 2: parametric federated models ------------------------------------

_PARAM_HP = {
    "logreg": dict(rounds=25, local_steps=40, lr=0.05),
    "svm": dict(rounds=25, local_steps=40, lr=0.02),
    "mlp": dict(rounds=25, local_steps=40, lr=0.01, fedprox_mu=FCFG.fedprox_mu),
}


def table2(seed: int = 0) -> Dict:
    tr, te, clients = _setup(seed)
    out = {}
    for model in ["logreg", "svm", "mlp"]:
        for samp in SAMPLINGS + ["fed_smote"]:
            cfg = P.FedParametricConfig(model=model, sampling=samp,
                                        seed=seed, **_PARAM_HP[model])
            _, comm, hist, timer = P.train_federated(clients, cfg,
                                                     test=(te.x, te.y))
            m = hist[-1]
            out[f"{model}/{samp}"] = {
                "f1": m["f1"], "precision": m["precision"],
                "recall": m["recall"],
                "roc_auc": m["roc_auc"], "brier": m["brier"],
                "comm_mb": comm.total_mb(),
                "uplink_mb": comm.uplink_mb(),
                "agg_s": timer.total_s,
            }
    return out


# --- Table 3: non-parametric federated models ---------------------------------

def table3(seed: int = 0) -> Dict:
    tr, te, clients = _setup(seed)
    fed_stats = _fed_stats(clients)
    out = {}
    k = FCFG.rf_trees
    for samp in SAMPLINGS:
        cfg = TS.FedForestConfig(trees_per_client=k, subset=k,
                                 sampling=samp, seed=seed)
        model, comm, timer = TS.train_federated_rf(clients, cfg)
        out[f"rf_full/{samp}"] = {
            **{kk: vv for kk, vv in TS.evaluate_rf(model, te.x, te.y).items()
               if kk in ("f1", "precision", "recall", "roc_auc", "brier")},
            "uplink_mb": comm.uplink_mb(), "agg_s": timer.total_s}
    # tree-subset variants (the paper's RF (30 Trees) row uses 30%):
    for s, name in [(30, "rf_sub30"), (FCFG.rf_subset_trees, "rf_sub10")]:
        cfg = TS.FedForestConfig(trees_per_client=k, subset=s,
                                 sampling="smote", seed=seed)
        model, comm, timer = TS.train_federated_rf(clients, cfg)
        out[f"{name}/smote"] = {
            **{kk: vv for kk, vv in TS.evaluate_rf(model, te.x, te.y).items()
               if kk in ("f1", "precision", "recall", "roc_auc", "brier")},
            "uplink_mb": comm.uplink_mb(), "agg_s": timer.total_s}
    xcfg0 = FE.FedXGBConfig(num_rounds=FCFG.xgb_trees,
                            depth=FCFG.xgb_max_depth,
                            shallow_depth=FCFG.xgb_shallow_depth,
                            top_features=FCFG.xgb_top_features,
                            learning_rate=FCFG.xgb_lr, seed=seed)
    for samp in SAMPLINGS:
        xcfg = FE.FedXGBConfig(**{**xcfg0.__dict__, "sampling": samp})
        ens, comm, timer = FE.train_federated_xgb(clients, xcfg)
        out[f"xgb_full/{samp}"] = {
            **{kk: vv for kk, vv in
               FE.evaluate_fed_xgb(ens, te.x, te.y).items()
               if kk in ("f1", "precision", "recall", "roc_auc", "brier")},
            "uplink_mb": comm.uplink_mb(), "agg_s": timer.total_s}
    xcfg = FE.FedXGBConfig(**{**xcfg0.__dict__, "sampling": "smote"})
    ens, comm, timer = FE.train_federated_xgb_fe(clients, xcfg)
    out["xgb_fe/smote"] = {
        **{kk: vv for kk, vv in FE.evaluate_fe(ens, te.x, te.y).items()
           if kk in ("f1", "precision", "recall", "roc_auc", "brier")},
        "uplink_mb": comm.uplink_mb(), "agg_s": timer.total_s}
    return out


# --- Table 4: framework comparison --------------------------------------------

def table4(t2: Dict, t3: Dict) -> Dict:
    """FedAvg baseline = best parametric FedAvg row; FedTree-style = dense
    federated GBDT; FedCVD++ = tree-subset RF (its headline)."""
    best_param = max((v for kk, v in t2.items() if "fed_smote" not in kk),
                     key=lambda v: v["f1"])
    return {
        "fedavg_parametric": {"f1": best_param["f1"],
                              "uplink_mb": best_param["uplink_mb"],
                              "imbalance": "no", "models": "parametric"},
        "fedtree_style_dense_gbdt": {
            "f1": t3["xgb_full/none"]["f1"],
            "uplink_mb": t3["xgb_full/none"]["uplink_mb"],
            "agg_s": t3["xgb_full/none"]["agg_s"],
            "imbalance": "no", "models": "GBDT only"},
        "fedcvd_pp": {
            "f1": t3["rf_sub30/smote"]["f1"],
            "uplink_mb": t3["rf_sub30/smote"]["uplink_mb"],
            "agg_s": t3["rf_sub30/smote"]["agg_s"],
            "imbalance": "yes", "models": "all 5"},
    }


# --- Table 5: centralized vs federated -----------------------------------------

def table5(t2: Dict, t3: Dict, seed: int = 0) -> Dict:
    tr, te, clients = _setup(seed)
    out = {}
    # parametric centralized (matched budget)
    best_samp = {m: max(SAMPLINGS,
                        key=lambda s: t2[f"{m}/{s}"]["f1"])
                 for m in ["logreg", "svm", "mlp"]}
    for model in ["logreg", "svm", "mlp"]:
        samp = best_samp[model]
        cfg = P.FedParametricConfig(model=model, sampling=samp, seed=seed,
                                    **_PARAM_HP[model])
        _, cm = P.train_centralized(tr.x, tr.y, cfg, test=(te.x, te.y))
        out[model] = {"centralized_f1": cm["f1"],
                      "federated_f1": t2[f"{model}/{samp}"]["f1"],
                      "centralized_auc": cm["roc_auc"],
                      "federated_auc": t2[f"{model}/{samp}"]["roc_auc"],
                      "centralized_brier": cm["brier"],
                      "federated_brier": t2[f"{model}/{samp}"]["brier"],
                      "sampling": samp}
    # trees centralized
    from repro.trees import forest as RF
    from repro.trees import gbdt as GB
    xs, ys = S.smote(tr.x, tr.y, seed=seed)
    xte = jnp.asarray(te.x)
    rf = RF.fit(jnp.asarray(xs), jnp.asarray(ys),
                num_trees=FCFG.rf_trees, depth=10, feature_frac=0.8,
                rng=jax.random.PRNGKey(seed))
    rf_m = binary_metrics(np.asarray(RF.predict(rf, xte)), te.y,
                          scores=np.asarray(RF.predict_proba(rf, xte)))
    gb = GB.fit(jnp.asarray(xs), jnp.asarray(ys), num_rounds=FCFG.xgb_trees,
                depth=FCFG.xgb_max_depth, learning_rate=FCFG.xgb_lr)
    gb_m = binary_metrics(np.asarray(GB.predict(gb, xte)), te.y,
                          scores=np.asarray(GB.predict_proba(gb, xte)))
    # best federated row by F1; its OWN auc (never pair metrics across
    # different sampling runs)
    best_rf = max((v for kk, v in t3.items() if kk.startswith("rf_full")),
                  key=lambda v: v["f1"])
    out["random_forest"] = {"centralized_f1": rf_m["f1"],
                            "federated_f1": best_rf["f1"],
                            "centralized_auc": rf_m["roc_auc"],
                            "federated_auc": best_rf["roc_auc"],
                            "centralized_brier": rf_m["brier"]}
    out["rf_optimized"] = {"centralized_f1": None,
                           "federated_f1": t3["rf_sub30/smote"]["f1"],
                           "federated_auc": t3["rf_sub30/smote"]["roc_auc"]}
    best_xgb = max((v for kk, v in t3.items()
                    if kk.startswith("xgb_full")),
                   key=lambda v: v["f1"])
    out["xgboost"] = {"centralized_f1": gb_m["f1"],
                      "federated_f1": best_xgb["f1"],
                      "centralized_auc": gb_m["roc_auc"],
                      "federated_auc": best_xgb["roc_auc"],
                      "centralized_brier": gb_m["brier"]}
    return out


# --- Fig 2: communication/performance trade-off --------------------------------

def fig2(t3: Dict) -> Dict:
    return {name: {"uplink_mb": v["uplink_mb"], "f1": v["f1"]}
            for name, v in t3.items()
            if name in ("xgb_full/smote", "rf_full/smote", "rf_sub30/smote",
                        "rf_sub10/smote", "xgb_fe/smote")}


# --- Fig 3: federated SMOTE vs local-only --------------------------------------

def fig3(seed: int = 0) -> Dict:
    """Minority recall under skewed (non-IID) minority partitions:
    local-only SMOTE vs federated SMOTE synchronization, swept over skew
    severity (alpha; smaller = some hospitals hold ~no CHD+ cases)."""
    out = {}
    for alpha in (1.0, 0.5, 0.25):
        tr, te, clients = _setup(seed, alpha=alpha)
        fed_stats = _fed_stats(clients)
        for samp, stats in [("smote", None), ("fed_smote", fed_stats)]:
            cfg = TS.FedForestConfig(trees_per_client=50, subset=50,
                                     sampling=samp, seed=seed)
            model, _, _ = TS.train_federated_rf(clients, cfg,
                                                fed_stats=stats)
            m = TS.evaluate_rf(model, te.x, te.y)
            out[f"rf/a{alpha}/{samp}"] = {"recall": m["recall"],
                                          "f1": m["f1"]}
        for samp in ["smote", "fed_smote"]:
            cfg = P.FedParametricConfig(model="logreg", sampling=samp,
                                        seed=seed, **_PARAM_HP["logreg"])
            _, _, hist, _ = P.train_federated(clients, cfg,
                                              test=(te.x, te.y))
            out[f"logreg/a{alpha}/{samp}"] = {"recall": hist[-1]["recall"],
                                              "f1": hist[-1]["f1"]}
        for head in ["rf", "logreg"]:
            lo = out[f"{head}/a{alpha}/smote"]["recall"]
            fs = out[f"{head}/a{alpha}/fed_smote"]["recall"]
            out[f"{head}/a{alpha}/recall_gain_pct"] = (
                100.0 * (fs - lo) / max(lo, 1e-9))
    return out


# --- Theorem 1 check ------------------------------------------------------------

def theorem1(t3: Dict) -> Dict:
    full = t3["rf_full/smote"]
    out = {}
    for name in ["rf_sub30/smote", "rf_sub10/smote"]:
        sub = t3[name]
        out[name] = {
            "delta_f1": abs(full["f1"] - sub["f1"]),
            "bound_ok": abs(full["f1"] - sub["f1"]) <= 0.03,
            "comm_reduction_pct":
                100 * (1 - sub["uplink_mb"] / full["uplink_mb"]),
            "f1_retention_pct": 100 * sub["f1"] / full["f1"],
        }
    return out


def run_all(seed: int = 0, save_dir: str = "results/paper") -> Dict:
    os.makedirs(save_dir, exist_ok=True)
    t0 = time.time()
    results = {}
    results["table2"] = table2(seed)
    print(f"table2 done ({time.time()-t0:.0f}s)", flush=True)
    results["table3"] = table3(seed)
    print(f"table3 done ({time.time()-t0:.0f}s)", flush=True)
    results["table4"] = table4(results["table2"], results["table3"])
    results["table5"] = table5(results["table2"], results["table3"], seed)
    print(f"table5 done ({time.time()-t0:.0f}s)", flush=True)
    results["fig2"] = fig2(results["table3"])
    results["fig3"] = fig3(seed)
    results["theorem1"] = theorem1(results["table3"])
    results["wall_s"] = time.time() - t0
    with open(f"{save_dir}/tables.json", "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


if __name__ == "__main__":
    r = run_all()
    print(json.dumps(r, indent=1, default=float))
