"""§Perf hillclimb driver: run tagged dry-run variants for the three chosen
pairs and print before/after roofline terms per iteration.

MUST run as its own process (owns the 512-device env):
  PYTHONPATH=src:. python -m benchmarks.hillclimb
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses  # noqa: E402
import json         # noqa: E402

from repro.configs.base import RunConfig  # noqa: E402
from repro.launch import mesh as M        # noqa: E402
from repro.launch.dryrun import dryrun_one  # noqa: E402

BASE = RunConfig()

# (arch, shape) -> list of (tag, RunConfig-overrides, cfg-overrides)
PLAN = {
    # 1. worst roofline fraction: 56 heads replicate over the 16-wide model
    #    axis -> attention compute + resharding storm
    ("yi_34b", "train_4k"): [
        ("it1_pad_heads", {}, {"pad_heads": True}),
        ("it2_pad_heads_blockskip", {"causal_block_skip": True},
         {"pad_heads": True}),
        ("it3_pad_heads_dots", {"remat": "dots"}, {"pad_heads": True}),
        ("it4_pad_heads_bkv", {"gqa_broadcast_kv": True},
         {"pad_heads": True}),
    ],
    # 2. most collective-bound: vocab 92553 unshardable -> replicated-head
    #    logits all-reduced per loss chunk
    ("internvl2_2b", "train_4k"): [
        ("it1_pad_vocab", {}, {"pad_vocab": True}),
        ("it2_pad_vocab_bkv", {"gqa_broadcast_kv": True},
         {"pad_vocab": True}),
        ("it3_pad_vocab_bkv_skip",
         {"gqa_broadcast_kv": True, "causal_block_skip": True},
         {"pad_vocab": True}),
    ],
    # 3. paper-representative: MoE expert-parallel federated workhorse
    ("dbrx_132b", "train_4k"): [
        ("it1_gather_bf16", {"moe_gather_bf16": True}, {}),
        ("it2_gather_bf16_dots", {"moe_gather_bf16": True,
                                  "remat": "dots"}, {}),
        ("it3_gather_bf16_bkv", {"moe_gather_bf16": True,
                                 "gqa_broadcast_kv": True}, {}),
    ],
}


def fmt(rec):
    t = rec["roofline"]
    mem = t.get("memory_fused_s", t["memory_s"])
    return (f"compute {t['compute_s']:7.3f}s  mem(fused) {mem:7.3f}s  "
            f"coll {t['collective_s']:7.3f}s  dom={t['dominant']:<14s} "
            f"useful={rec['useful_flops_ratio']*100:3.0f}%  "
            f"wire={rec['collective_wire_bytes']/1e9:8.1f}GB")


def main():
    mesh = M.make_production_mesh()
    for (arch, shape), iters in PLAN.items():
        print(f"\n=== {arch} x {shape} ===", flush=True)
        base = dryrun_one(arch, shape, run=BASE, mesh=mesh,
                          tag="baseline", verbose=False)
        print(f"  baseline               : {fmt(base)}", flush=True)
        for tag, run_over, cfg_over in iters:
            run = dataclasses.replace(BASE, **run_over)
            rec = dryrun_one(arch, shape, run=run, mesh=mesh, tag=tag,
                             verbose=False,
                             pad_vocab=cfg_over.get("pad_vocab", False),
                             pad_heads=cfg_over.get("pad_heads", False))
            print(f"  {tag:<23s}: {fmt(rec)}", flush=True)


if __name__ == "__main__":
    main()
