"""Federated-engine benchmark: sequential per-pod loop vs the batched
vmapped client-parallel round, a strategy / wire-format sweep, the tree
engines (client-batched RF rounds, ``fed_hist`` GBDT), the FedRuntime
axes — uniform-k vs full participation and transport-stack variants —
and the **virtual-time schedule rows**: sync vs ``async:K`` buffered
aggregation under heterogeneous client latency, reported as
time-to-target-F1 on the shared virtual clock (written to
``results/async/async_bench.json``; rendered by ``python -m
benchmarks.report async``).

Each row is ``(name, us_per_round, derived)`` in the harness CSV shape.
Engine rows time local training only (``round_s`` from ``simulate``,
first jitted round included), so the vmap speedup is end-to-end honest;
tree rows time local forest growth / server tree growth the same way and
carry bytes-per-round from the CommLog ledger.  Async rows report
*virtual* seconds from the runtime timeline, not host wall time.

Run standalone:  PYTHONPATH=src python -m benchmarks.fed_engine_bench
Parity gate:     PYTHONPATH=src python -m benchmarks.fed_engine_bench --smoke
(the CI smoke job; exits non-zero if the batched engines, the
runtime-routed pipelines, or the async→sync reduction drift from their
parity references).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.launch.fed_train import simulate, simulate_fed_hist

ARCH = "qwen3_4b"
COMMON = dict(n_pods=4, rounds=3, local_steps=4, batch=2, seq=64,
              verbose=False, seed=0)
TREE_COMMON = dict(n_clients=4, rounds=8, depth=4, n_bins=32,
                   n_records=1200, verbose=False, seed=0)
PARAM_COMMON = dict(rounds=6, local_steps=10, lr=0.05)


def _framingham_clients(n_clients=4, n=1200):
    from repro.data import framingham as F
    ds = F.synthesize(n=n, seed=0)
    tr, te = F.train_test_split(ds)
    clients = [(c.x, c.y) for c in F.partition_clients(tr, n_clients)]
    return clients, (te.x, te.y)


def _tree_engine_rows() -> list:
    """Batched vs sequential tree training, timed on the same shards."""
    import time

    from repro.core import tree_subset as TS

    clients, _ = _framingham_clients(TREE_COMMON["n_clients"],
                                     TREE_COMMON["n_records"])
    rows = []
    for engine in ("sequential", "batched"):
        cfg = TS.FedForestConfig(trees_per_client=16, subset=16, depth=4,
                                 n_bins=32, engine=engine, seed=0)
        t0 = time.perf_counter()
        _, comm, _ = TS.train_federated_rf(clients, cfg)
        dt = time.perf_counter() - t0
        rows.append((f"tree_engine/rf_{engine}", dt * 1e6,
                     f"uplink_mb={comm.uplink_mb():.3f};"
                     f"clients={TREE_COMMON['n_clients']}"))
    return rows


def _fed_hist_rows() -> list:
    rows = []
    for engine in ("sequential", "batched"):
        out = simulate_fed_hist(engine=engine, **TREE_COMMON)
        per_round = (out["comm"].total_bytes("up")
                     / TREE_COMMON["rounds"] / 1e6)
        rows.append((f"fed_hist/{engine}",
                     out["round_s"] / TREE_COMMON["rounds"] * 1e6,
                     f"f1={out['metrics']['f1']:.3f};"
                     f"up_mb_per_round={per_round:.3f}"))
    return rows


def _participation_rows() -> list:
    """Uniform-k vs full participation on the tabular parametric
    pipeline: ledger MB and the F1 cost of seeing fewer hospitals."""
    from repro.core import parametric as P

    clients, test = _framingham_clients()
    rows, f1_full = [], None
    for part in ("full", "uniform:2", "stratified:2", "dropout:0.3:0.5"):
        cfg = P.FedParametricConfig(model="logreg", sampling="ros",
                                    participation=part, **PARAM_COMMON)
        _, comm, hist, timer = P.train_federated(clients, cfg, test=test)
        f1 = hist[-1]["f1"] if hist else float("nan")
        f1_full = f1_full if f1_full is not None else f1
        rows.append((f"fed_participation/{part.replace(':', '_')}",
                     timer.total_s / PARAM_COMMON["rounds"] * 1e6,
                     f"ledger_mb={comm.total_mb():.3f};f1={f1:.3f};"
                     f"df1_vs_full={f1 - f1_full:+.3f}"))
    return rows


def _transport_rows() -> list:
    """Transport-stack variants on the parametric pipeline: what each
    layer stack costs in ledger MB and F1 vs the plain wire."""
    from repro.core import parametric as P

    clients, test = _framingham_clients()
    rows, f1_plain = [], None
    for tname in ("plain", "framed", "sparse", "quant", "secure_dp",
                  "full_stack"):
        cfg = P.FedParametricConfig(model="logreg", sampling="ros",
                                    transport=tname, dp_clip=2.0,
                                    **PARAM_COMMON)
        _, comm, hist, _ = P.train_federated(clients, cfg, test=test)
        f1 = hist[-1]["f1"] if hist else float("nan")
        f1_plain = f1_plain if f1_plain is not None else f1
        rows.append((f"fed_transport/{tname}", 0.0,
                     f"ledger_mb={comm.total_mb():.3f};"
                     f"up_mb={comm.uplink_mb():.3f};f1={f1:.3f};"
                     f"df1_vs_plain={f1 - f1_plain:+.3f}"))
    return rows


LATENCY_SPEC = "lognormal:0:1"       # heterogeneous hospitals: heavy tail
ASYNC_SCHEDULES = ("sync", "async:1", "async:2")


def _time_to_target(history, target: float):
    """First virtual time at which the metrics trace reaches the target
    F1 (None if it never does).  Entries carry ``t`` whenever the run
    models time (``repro.core.parametric`` stamps them)."""
    for h in history:
        if h["f1"] >= target:
            return h["t"]
    return None


def _async_rows() -> list:
    """Sync vs buffered-async aggregation under heterogeneous latency:
    the same parametric workload, the same latency model, `rounds`
    server aggregations each — who reaches the target F1 first on the
    virtual clock?  Writes results/async/async_bench.json."""
    from repro.core import parametric as P

    clients, test = _framingham_clients()
    runs = {}
    for sched in ASYNC_SCHEDULES:
        cfg = P.FedParametricConfig(model="logreg", sampling="ros",
                                    rounds=12, local_steps=10, lr=0.05,
                                    schedule=sched, latency=LATENCY_SPEC)
        _, comm, hist, _ = P.train_federated(clients, cfg, test=test)
        runs[sched] = {"history": hist,
                       "final_f1": hist[-1]["f1"],
                       "vt_total": hist[-1]["t"],
                       "uplink_mb": comm.total_mb("up")}
    # target: sync's own 90%-of-final F1 — reachable by construction
    target = 0.9 * runs["sync"]["final_f1"]
    rows = []
    out = {"latency": LATENCY_SPEC, "target_f1": target, "rows": {}}
    for sched, r in runs.items():
        tt = _time_to_target(r["history"], target)
        out["rows"][sched] = {
            "time_to_target_s": tt, "final_f1": r["final_f1"],
            "vt_total_s": r["vt_total"], "uplink_mb": r["uplink_mb"]}
        rows.append((f"fed_async/{sched.replace(':', '_')}", 0.0,
                     f"vt_to_target_s={tt if tt is not None else 'never'};"
                     f"vt_total_s={r['vt_total']:.2f};"
                     f"f1={r['final_f1']:.3f};"
                     f"up_mb={r['uplink_mb']:.3f}"))
    os.makedirs("results/async", exist_ok=True)
    with open("results/async/async_bench.json", "w") as f:
        json.dump(out, f, indent=1)
    return rows


def run(arch: str = ARCH) -> list:
    rows = []
    for engine in ("sequential", "vmap"):
        out = simulate(arch, engine=engine, **COMMON)
        rows.append((f"fed_engine/{engine}",
                     out["round_s"] / COMMON["rounds"] * 1e6,
                     f"loss={out['loss_history'][-1]:.3f};"
                     f"pods={COMMON['n_pods']}"))
    for strategy in ("fedavg", "fedavg_weighted", "fedavgm", "fedadam"):
        out = simulate(arch, strategy=strategy, **COMMON)
        rows.append((f"fed_strategy/{strategy}",
                     out["round_s"] / COMMON["rounds"] * 1e6,
                     f"loss={out['loss_history'][-1]:.3f}"))
    dense_mb = None
    for wf in ("none", "topk", "int8_sr"):
        out = simulate(arch, compression=wf, rho=0.05, **COMMON)
        dense_mb = dense_mb or out["uplink_mb"]
        rows.append((f"fed_wire/{wf}", 0.0,
                     f"uplink_mb={out['uplink_mb']:.3f};"
                     f"vs_dense={dense_mb/max(out['uplink_mb'],1e-9):.1f}x"))
    rows.extend(_tree_engine_rows())
    rows.extend(_fed_hist_rows())
    rows.extend(_participation_rows())
    rows.extend(_transport_rows())
    rows.extend(_async_rows())
    return rows


def smoke(arch: str = ARCH) -> int:
    """CPU parity gate (the CI job): batched engines must match their
    sequential references and the runtime-routed pipelines must keep
    their exact ledger accounting.  Returns a process exit code."""
    import jax
    import numpy as np

    failures = []

    def check(name, fn):
        try:
            fn()
            print(f"  ok   {name}")
        except Exception as e:  # noqa: BLE001 — report, don't crash
            failures.append((name, e))
            print(f"  FAIL {name}: {e}")

    lm = dict(n_pods=2, rounds=2, local_steps=3, batch=2, seq=64,
              verbose=False, seed=0)

    def lm_parity():
        v = simulate(arch, engine="vmap", **lm)
        s = simulate(arch, engine="sequential", **lm)
        np.testing.assert_allclose(v["loss_history"], s["loss_history"],
                                   rtol=1e-5)
        assert v["comm"].total_bytes() == s["comm"].total_bytes()
        for a, b in zip(jax.tree.leaves(v["final_params"]),
                        jax.tree.leaves(s["final_params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-5)

    def lm_ledger():
        out = simulate(arch, compression="int8_sr", **lm)
        n_leaves = len(jax.tree.leaves(out["final_params"]))
        n_elems = sum(x.size
                      for x in jax.tree.leaves(out["final_params"]))
        ups = [e for e in out["comm"].events if e["direction"] == "up"]
        assert all(e["bytes"] == n_elems + 4 * n_leaves for e in ups)

    def tree_parity():
        from repro.core import tree_subset as TS
        clients, _ = _framingham_clients(3, 600)
        out = {}
        for engine in ("sequential", "batched"):
            cfg = TS.FedForestConfig(trees_per_client=4, subset=3,
                                     depth=3, n_bins=16, engine=engine,
                                     seed=0)
            model, comm, _ = TS.train_federated_rf(clients, cfg)
            out[engine] = (model, comm.total_bytes())
        ms, mb = out["sequential"][0], out["batched"][0]
        np.testing.assert_array_equal(np.asarray(ms.forest.feature),
                                      np.asarray(mb.forest.feature))
        assert out["sequential"][1] == out["batched"][1]

    def hist_parity():
        tiny = dict(n_clients=3, rounds=3, depth=3, n_bins=16,
                    n_records=500, verbose=False, seed=0)
        outs = {e: simulate_fed_hist(engine=e, **tiny)
                for e in ("sequential", "batched")}
        assert outs["sequential"]["comm"].total_bytes() == \
            outs["batched"]["comm"].total_bytes()
        assert outs["sequential"]["metrics"]["f1"] == \
            outs["batched"]["metrics"]["f1"]

    def runtime_participation():
        from repro.core import parametric as P
        clients, _ = _framingham_clients(4, 600)
        full = P.FedParametricConfig(model="logreg", rounds=3,
                                     local_steps=4)
        sub = P.FedParametricConfig(model="logreg", rounds=3,
                                    local_steps=4,
                                    participation="uniform:2")
        _, cf, _, _ = P.train_federated(clients, full)
        _, cs, _, _ = P.train_federated(clients, sub)
        assert cs.total_bytes() * 2 == cf.total_bytes()

    def async_reduction():
        """async:K with zero latency and K=n_clients must reproduce the
        synchronous run bit-exactly (params, metrics trace, ledger)."""
        from repro.core import parametric as P
        clients, test = _framingham_clients(3, 600)
        base = dict(model="logreg", rounds=3, local_steps=4, lr=0.05)
        ps, cs, hs, _ = P.train_federated(
            clients, P.FedParametricConfig(**base), test=test)
        pa, ca, ha, _ = P.train_federated(
            clients, P.FedParametricConfig(schedule="async:3", **base),
            test=test)
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(ps)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        strip = lambda es: [{k: v for k, v in e.items() if k != "t"}
                            for e in es]
        assert strip(ca.events) == strip(cs.events)
        assert [{k: v for k, v in h.items()
                 if k not in ("t", "round")} for h in ha] == hs

    print("fed_engine_bench --smoke (parity gate)")
    check("lm vmap == sequential", lm_parity)
    check("lm int8_sr ledger exact", lm_ledger)
    check("rf batched == sequential", tree_parity)
    check("fed_hist batched == sequential", hist_parity)
    check("runtime uniform-k halves ledger", runtime_participation)
    check("async:n zero-latency == sync", async_reduction)
    print(f"{len(failures)} parity regressions")
    return 1 if failures else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CPU parity gate for CI; exits non-zero "
                    "on regressions")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke())
    print("name,us_per_round,derived")
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
