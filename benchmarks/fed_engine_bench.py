"""Federated-engine benchmark: sequential per-pod loop vs the batched
vmapped client-parallel round, plus a strategy / wire-format sweep.

Each row is ``(name, us_per_round, derived)`` in the harness CSV shape.
Engine rows time local training only (``round_s`` from ``simulate``,
first jitted round included), so the vmap speedup is end-to-end honest.

Run standalone:  PYTHONPATH=src python -m benchmarks.fed_engine_bench
"""
from __future__ import annotations

from repro.launch.fed_train import simulate

ARCH = "qwen3_4b"
COMMON = dict(n_pods=4, rounds=3, local_steps=4, batch=2, seq=64,
              verbose=False, seed=0)


def run(arch: str = ARCH) -> list:
    rows = []
    for engine in ("sequential", "vmap"):
        out = simulate(arch, engine=engine, **COMMON)
        rows.append((f"fed_engine/{engine}",
                     out["round_s"] / COMMON["rounds"] * 1e6,
                     f"loss={out['loss_history'][-1]:.3f};"
                     f"pods={COMMON['n_pods']}"))
    for strategy in ("fedavg", "fedavg_weighted", "fedavgm", "fedadam"):
        out = simulate(arch, strategy=strategy, **COMMON)
        rows.append((f"fed_strategy/{strategy}",
                     out["round_s"] / COMMON["rounds"] * 1e6,
                     f"loss={out['loss_history'][-1]:.3f}"))
    dense_mb = None
    for wf in ("none", "topk", "int8_sr"):
        out = simulate(arch, compression=wf, rho=0.05, **COMMON)
        dense_mb = dense_mb or out["uplink_mb"]
        rows.append((f"fed_wire/{wf}", 0.0,
                     f"uplink_mb={out['uplink_mb']:.3f};"
                     f"vs_dense={dense_mb/max(out['uplink_mb'],1e-9):.1f}x"))
    return rows


if __name__ == "__main__":
    print("name,us_per_round,derived")
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
