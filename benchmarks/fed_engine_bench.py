"""Federated-engine benchmark: sequential per-pod loop vs the batched
vmapped client-parallel round, plus a strategy / wire-format sweep and
the tree engines (client-batched RF rounds, ``fed_hist`` GBDT).

Each row is ``(name, us_per_round, derived)`` in the harness CSV shape.
Engine rows time local training only (``round_s`` from ``simulate``,
first jitted round included), so the vmap speedup is end-to-end honest;
tree rows time local forest growth / server tree growth the same way and
carry bytes-per-round from the CommLog ledger.

Run standalone:  PYTHONPATH=src python -m benchmarks.fed_engine_bench
"""
from __future__ import annotations

from repro.launch.fed_train import simulate, simulate_fed_hist

ARCH = "qwen3_4b"
COMMON = dict(n_pods=4, rounds=3, local_steps=4, batch=2, seq=64,
              verbose=False, seed=0)
TREE_COMMON = dict(n_clients=4, rounds=8, depth=4, n_bins=32,
                   n_records=1200, verbose=False, seed=0)


def _tree_engine_rows() -> list:
    """Batched vs sequential tree training, timed on the same shards."""
    import time

    from repro.core import tree_subset as TS
    from repro.data import framingham as F

    ds = F.synthesize(n=TREE_COMMON["n_records"], seed=0)
    tr, _ = F.train_test_split(ds)
    clients = [(c.x, c.y) for c in F.partition_clients(
        tr, TREE_COMMON["n_clients"])]
    rows = []
    for engine in ("sequential", "batched"):
        cfg = TS.FedForestConfig(trees_per_client=16, subset=16, depth=4,
                                 n_bins=32, engine=engine, seed=0)
        t0 = time.perf_counter()
        _, comm, _ = TS.train_federated_rf(clients, cfg)
        dt = time.perf_counter() - t0
        rows.append((f"tree_engine/rf_{engine}", dt * 1e6,
                     f"uplink_mb={comm.uplink_mb():.3f};"
                     f"clients={TREE_COMMON['n_clients']}"))
    return rows


def _fed_hist_rows() -> list:
    rows = []
    for engine in ("sequential", "batched"):
        out = simulate_fed_hist(engine=engine, **TREE_COMMON)
        per_round = (out["comm"].total_bytes("up")
                     / TREE_COMMON["rounds"] / 1e6)
        rows.append((f"fed_hist/{engine}",
                     out["round_s"] / TREE_COMMON["rounds"] * 1e6,
                     f"f1={out['metrics']['f1']:.3f};"
                     f"up_mb_per_round={per_round:.3f}"))
    return rows


def run(arch: str = ARCH) -> list:
    rows = []
    for engine in ("sequential", "vmap"):
        out = simulate(arch, engine=engine, **COMMON)
        rows.append((f"fed_engine/{engine}",
                     out["round_s"] / COMMON["rounds"] * 1e6,
                     f"loss={out['loss_history'][-1]:.3f};"
                     f"pods={COMMON['n_pods']}"))
    for strategy in ("fedavg", "fedavg_weighted", "fedavgm", "fedadam"):
        out = simulate(arch, strategy=strategy, **COMMON)
        rows.append((f"fed_strategy/{strategy}",
                     out["round_s"] / COMMON["rounds"] * 1e6,
                     f"loss={out['loss_history'][-1]:.3f}"))
    dense_mb = None
    for wf in ("none", "topk", "int8_sr"):
        out = simulate(arch, compression=wf, rho=0.05, **COMMON)
        dense_mb = dense_mb or out["uplink_mb"]
        rows.append((f"fed_wire/{wf}", 0.0,
                     f"uplink_mb={out['uplink_mb']:.3f};"
                     f"vs_dense={dense_mb/max(out['uplink_mb'],1e-9):.1f}x"))
    rows.extend(_tree_engine_rows())
    rows.extend(_fed_hist_rows())
    return rows


if __name__ == "__main__":
    print("name,us_per_round,derived")
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
