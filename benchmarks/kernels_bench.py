"""Kernel micro-benchmarks: wall-time of the XLA reference paths on CPU
(the Pallas kernels target TPU; interpret mode is correctness-only, so we
time the jit'd XLA implementations that the CPU paths actually use) plus
derived achieved-GFLOP/s."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.hist.ref import hist_ref
from repro.models.attention import chunked_attention
from repro.models.ssm import ssd_chunked


def _time(fn: Callable, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_attention() -> List[Tuple[str, float, str]]:
    rows = []
    f = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True,
                                                  kv_chunk=512))
    for (B, T, H, dh) in [(1, 512, 8, 64), (1, 2048, 8, 64)]:
        rng = jax.random.PRNGKey(0)
        q = jax.random.normal(rng, (B, T, H, dh), jnp.float32)
        us = _time(f, q, q, q) * 1e6
        flops = 4 * B * H * T * T * dh
        rows.append((f"attention_B{B}_T{T}_H{H}", us,
                     f"gflops={flops/us/1e3:.1f}"))
    return rows


def bench_ssd() -> List[Tuple[str, float, str]]:
    rows = []
    f = jax.jit(lambda x, dt, a, b, c: ssd_chunked(x, dt, a, b, c, 64)[0])
    for (B, T, H, P, N) in [(1, 1024, 8, 64, 64), (2, 2048, 8, 64, 128)]:
        ks = [jax.random.fold_in(jax.random.PRNGKey(1), i)
              for i in range(5)]
        x = jax.random.normal(ks[0], (B, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
        a = jax.random.normal(ks[2], (H,)) * 0.5
        b = jax.random.normal(ks[3], (B, T, 1, N)) * 0.3
        c = jax.random.normal(ks[4], (B, T, 1, N)) * 0.3
        us = _time(f, x, dt, a, b, c) * 1e6
        rows.append((f"ssd_B{B}_T{T}_H{H}_N{N}", us,
                     f"tok_per_s={B*T/us*1e6:.0f}"))
    return rows


def bench_hist() -> List[Tuple[str, float, str]]:
    rows = []
    f = jax.jit(lambda b, g, h: hist_ref(b, g, h, 64))
    for (n, F) in [(4238, 15), (65536, 32)]:
        rng = jax.random.PRNGKey(2)
        bins = jax.random.randint(rng, (n, F), 0, 64)
        g = jax.random.normal(rng, (n,))
        us = _time(f, bins, g, jnp.abs(g)) * 1e6
        rows.append((f"hist_n{n}_F{F}", us,
                     f"msamples_per_s={n*F/us:.1f}"))
    return rows


def bench_tree_training() -> List[Tuple[str, float, str]]:
    """The paper's §4.9 'local XGBoost cost' concern, measured."""
    import numpy as np
    from repro.trees import gbdt
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1130, 15)).astype(np.float32))
    y = jnp.asarray((rng.random(1130) < 0.3).astype(np.float32))
    t0 = time.perf_counter()
    gbdt.fit(x, y, num_rounds=10, depth=6)
    dt = (time.perf_counter() - t0) / 10
    return [("gbdt_tree_fit_n1130", dt * 1e6, "per-tree, paper-scale")]


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for fn in (bench_attention, bench_ssd, bench_hist,
               bench_tree_training):
        rows.extend(fn())
    return rows
