"""Kernel micro-benchmarks: wall-time of the XLA reference paths on CPU
(the Pallas kernels target TPU; interpret mode is correctness-only, so we
time the jit'd XLA implementations that the CPU paths actually use) plus
derived achieved-GFLOP/s.

Every row is a dict carrying the timing **and** the environment it was
measured in — ``platform`` (jax backend), ``device`` (device kind) and
``jax`` (version) — so the perf-gate trajectory (``tools/perf_gate.py``
against the repo-root ``BENCH_kernels.json``) only ever compares
same-platform rows.  One row per kernel family (hist, forest_infer,
flash_attention, ssd) plus the fused forest-scoring and int8-quantized
scoring paths.

Run:    PYTHONPATH=src python -m benchmarks.kernels_bench
Smoke:  PYTHONPATH=src python -m benchmarks.kernels_bench --smoke
        (tiny shapes, CI-sized; both modes write
        results/kernels/kernels_bench.json for the perf gate)
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.kernels.hist.ref import hist_ref
from repro.models.attention import chunked_attention
from repro.models.ssm import ssd_chunked


def _cpu_model() -> str:
    """A per-machine CPU identifier so the perf gate never compares
    timings across different hosts (``device_kind`` is just "cpu" on
    every CPU backend)."""
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    import platform as _platform
    return _platform.processor() or "cpu"


def bench_meta() -> Dict[str, str]:
    """The metadata every bench row carries (perf-gate matching key)."""
    device = jax.devices()[0].device_kind
    if jax.default_backend() == "cpu":
        device = _cpu_model()
    return {"platform": jax.default_backend(),
            "device": device,
            "jax": jax.__version__}


def _row(name: str, us: float, note: str) -> Dict:
    return {"name": name, "us": float(us), "note": note, **bench_meta()}


def _time(fn: Callable, *args, iters: int = 10) -> float:
    """Min over individually-timed iterations: the robust estimator for
    micro-kernels, where mean-of-batch picks up scheduler noise that
    dwarfs the 20% gate threshold on ~100us smoke shapes."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_attention(smoke: bool = False) -> List[Dict]:
    rows = []
    f = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True,
                                                  kv_chunk=512))
    shapes = [(1, 256, 4, 32)] if smoke \
        else [(1, 512, 8, 64), (1, 2048, 8, 64)]
    for (B, T, H, dh) in shapes:
        rng = jax.random.PRNGKey(0)
        q = jax.random.normal(rng, (B, T, H, dh), jnp.float32)
        us = _time(f, q, q, q) * 1e6
        flops = 4 * B * H * T * T * dh
        rows.append(_row(f"attention_B{B}_T{T}_H{H}", us,
                         f"gflops={flops/us/1e3:.1f}"))
    return rows


def bench_ssd(smoke: bool = False) -> List[Dict]:
    rows = []
    f = jax.jit(lambda x, dt, a, b, c: ssd_chunked(x, dt, a, b, c, 64)[0])
    shapes = [(1, 256, 4, 32, 32)] if smoke \
        else [(1, 1024, 8, 64, 64), (2, 2048, 8, 64, 128)]
    for (B, T, H, P, N) in shapes:
        ks = [jax.random.fold_in(jax.random.PRNGKey(1), i)
              for i in range(5)]
        x = jax.random.normal(ks[0], (B, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
        a = jax.random.normal(ks[2], (H,)) * 0.5
        b = jax.random.normal(ks[3], (B, T, 1, N)) * 0.3
        c = jax.random.normal(ks[4], (B, T, 1, N)) * 0.3
        us = _time(f, x, dt, a, b, c) * 1e6
        rows.append(_row(f"ssd_B{B}_T{T}_H{H}_N{N}", us,
                         f"tok_per_s={B*T/us*1e6:.0f}"))
    return rows


def bench_hist(smoke: bool = False) -> List[Dict]:
    rows = []
    f = jax.jit(lambda b, g, h: hist_ref(b, g, h, 64))
    shapes = [(2048, 8)] if smoke else [(4238, 15), (65536, 32)]
    for (n, F) in shapes:
        rng = jax.random.PRNGKey(2)
        bins = jax.random.randint(rng, (n, F), 0, 64)
        g = jax.random.normal(rng, (n,))
        us = _time(f, bins, g, jnp.abs(g)) * 1e6
        rows.append(_row(f"hist_n{n}_F{F}", us,
                         f"msamples_per_s={n*F/us:.1f}"))
    return rows


def _random_forest(T: int, depth: int, F: int, key: int = 3):
    """Dense-heap forest arrays with valid routing (pure kernel input;
    no training cost in the bench)."""
    from repro.trees.growth import Tree
    n_int = 2 ** depth - 1
    ks = [jax.random.fold_in(jax.random.PRNGKey(key), i) for i in range(3)]
    return Tree(
        feature=jax.random.randint(ks[0], (T, n_int), -1, F),
        threshold=jax.random.normal(ks[1], (T, n_int)),
        leaf=jax.random.normal(ks[2], (T, n_int + 1)) * 0.1,
        gain=jnp.zeros((T, F)))


def bench_forest_infer(smoke: bool = False) -> List[Dict]:
    """The serving traversal kernel (per-tree leaf matrix)."""
    from repro.kernels.forest_infer.ops import forest_infer
    T, depth, n, F = (16, 4, 512, 8) if smoke else (128, 8, 4096, 15)
    forest = _random_forest(T, depth, F)
    x = jax.random.normal(jax.random.PRNGKey(4), (n, F))
    rows = []
    impls = ["xla"] + (["pallas"] if jax.default_backend() != "cpu"
                       else [])
    for impl in impls:
        f = jax.jit(lambda q, impl=impl: forest_infer(forest, q,
                                                      impl=impl))
        us = _time(f, x) * 1e6
        rows.append(_row(f"forest_infer_{impl}_T{T}_d{depth}_n{n}", us,
                         f"rows_per_s={n/us*1e6:.0f}"))
    return rows


def bench_forest_fused(smoke: bool = False) -> List[Dict]:
    """Fused scoring (traversal+weighting+Platt in one call) vs the
    unfused compose-in-XLA path it replaces."""
    from repro.kernels.forest_infer.fused import forest_score
    from repro.kernels.forest_infer.ops import forest_infer
    T, depth, n, F = (16, 4, 512, 8) if smoke else (128, 8, 4096, 15)
    forest = _random_forest(T, depth, F)
    x = jax.random.normal(jax.random.PRNGKey(5), (n, F))
    platt = jnp.asarray([1.5, -0.3, 1.0], jnp.float32)
    impl = "xla" if jax.default_backend() == "cpu" else "pallas"

    def _composed(q, p):
        s = jax.nn.sigmoid(
            0.3 * jnp.sum(forest_infer(forest, q, impl=impl), axis=0))
        return jnp.where(p[2] > 0,
                         1.0 / (1.0 + jnp.exp(-(p[0] * s + p[1]))), s)

    composed = jax.jit(_composed)
    fused = jax.jit(lambda q, p: forest_score(forest, q, mode="margin",
                                              lr=0.3, platt=p, impl=impl))
    rows = []
    for name, f in (("composed", composed), ("fused", fused)):
        us = _time(f, x, platt) * 1e6
        rows.append(_row(f"forest_score_{name}_T{T}_d{depth}_n{n}", us,
                         f"impl={impl};rows_per_s={n/us*1e6:.0f}"))
    return rows


def bench_int8_scoring(smoke: bool = False) -> List[Dict]:
    """f32 vs int8_sr-resident leaf tables on the serving traversal
    (the memory-bound scoring path)."""
    from repro.core.compression import int8_sr_quantize
    from repro.kernels.forest_infer.ops import forest_infer
    T, depth, n, F = (16, 4, 512, 8) if smoke else (256, 8, 8192, 15)
    forest = _random_forest(T, depth, F)
    x = jax.random.normal(jax.random.PRNGKey(6), (n, F))
    impl = "xla" if jax.default_backend() == "cpu" else "pallas"
    q, scale = int8_sr_quantize(forest.leaf, jax.random.PRNGKey(0))
    variants = {
        "f32": jax.jit(lambda r: forest_infer(forest, r, impl=impl)),
        "int8_sr": jax.jit(lambda r: forest_infer(
            forest._replace(leaf=q.astype(jnp.float32) * scale), r,
            impl=impl)),
    }
    rows = []
    for name, f in variants.items():
        us = _time(f, x) * 1e6
        rows.append(_row(f"int8_scoring_{name}_T{T}_n{n}", us,
                         f"impl={impl};rows_per_s={n/us*1e6:.0f}"))
    return rows


def bench_tree_training(smoke: bool = False) -> List[Dict]:
    """The paper's §4.9 'local XGBoost cost' concern, measured."""
    import numpy as np
    from repro.trees import gbdt
    rng = np.random.default_rng(0)
    n, rounds = (300, 3) if smoke else (1130, 10)
    x = jnp.asarray(rng.normal(size=(n, 15)).astype(np.float32))
    y = jnp.asarray((rng.random(n) < 0.3).astype(np.float32))
    t0 = time.perf_counter()
    gbdt.fit(x, y, num_rounds=rounds, depth=6)
    dt = (time.perf_counter() - t0) / rounds
    return [_row(f"gbdt_tree_fit_n{n}", dt * 1e6,
                 "per-tree, paper-scale")]


def run(smoke: bool = False) -> List[Dict]:
    rows = []
    for fn in (bench_attention, bench_ssd, bench_hist,
               bench_forest_infer, bench_forest_fused,
               bench_int8_scoring, bench_tree_training):
        rows.extend(fn(smoke))
    return rows


def save_rows(rows: List[Dict],
              path: str = "results/kernels/kernels_bench.json",
              smoke: bool = False) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"meta": {**bench_meta(), "smoke": smoke},
                   "rows": rows}, f, indent=1)
        f.write("\n")
    return path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI kernel-perf-smoke job)")
    ap.add_argument("--out", default="results/kernels/kernels_bench.json")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us']:.1f},{r['note']}")
    print(f"wrote {save_rows(rows, args.out, smoke=args.smoke)} "
          f"({len(rows)} rows, platform={bench_meta()['platform']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
