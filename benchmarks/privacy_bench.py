"""Privacy-layer bench: what secure aggregation costs, and what DP buys.

Timed rows (perf-gated against the repo-root ``BENCH_privacy.json``
trajectory through the generic ``tools/perf_gate.py``, like the kernel
and observability benches):

* ``privacy/mask_encode`` — one client's pairwise-mask application
  (:func:`~repro.core.privacy.mask_update`, the single-pass rewrite)
  over an 8-member cohort on a logreg-sized pytree.
* ``privacy/mask_recover`` — server-side dropout recovery of one
  delivered payload missing 3 of its 8 cohort peers
  (:func:`~repro.core.privacy.strip_missing_masks` through a fresh
  :class:`~repro.core.privacy.SeedShareBook` — Shamir reconstruction
  included, the worst case; warm books only pay the PRG).
* ``privacy/rdp_step`` — one accountant step + epsilon conversion at a
  fresh subsampling rate (the uncached path; steps at a repeated q are
  a dict add).
* ``privacy/he_encode`` — the Paillier-shaped fixed-point encode of a
  16k-scalar update (:class:`~repro.core.comm.HELayer`).

In-bench correctness gates (absolute, not trajectory): recovered masked
sums must match plain sums, and the accountant must match the q=1
Gaussian closed form and show subsampling amplification.

The privacy/utility frontier sweeps ``dp_epsilon`` over the paper's
tabular pipeline for the DP transport stacks (``dp`` | ``secure_dp`` |
``he_dp``) and records (per-round epsilon, cumulative accountant
epsilon, F1, uplink MB) per point into
``results/privacy/frontier.json`` — the e-vs-utility curve
docs/EXPERIMENTS.md plots, with HE ciphertext expansion visible in the
uplink column::

  PYTHONPATH=src python -m benchmarks.privacy_bench --smoke
  PYTHONPATH=src python tools/perf_gate.py --check --smoke \\
      --current results/privacy/privacy_bench.json \\
      --bench BENCH_privacy.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from benchmarks.kernels_bench import bench_meta  # noqa: E402
from repro.core import privacy  # noqa: E402
from repro.core.comm import HELayer, WireCtx  # noqa: E402

OUT = "results/privacy/privacy_bench.json"
FRONTIER_OUT = "results/privacy/frontier.json"
COHORT = 8
#: recovery parity tolerance: float32 masks, sums over an 8-cohort
PARITY_ATOL = 1e-3
FRONTIER_STACKS = ("dp", "secure_dp", "he_dp")
EPS_GRID = (0.25, 0.5, 1.0, 2.0, 4.0)
EPS_GRID_SMOKE = (0.5, 2.0)


def _logreg_tree(rng):
    return {"w": np.asarray(rng.normal(size=(16, 1)), np.float32),
            "b": np.asarray(rng.normal(size=(1,)), np.float32)}


def _time_us(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _mask_encode_us(iters: int) -> float:
    u = _logreg_tree(np.random.default_rng(0))
    return _time_us(
        lambda: privacy.mask_update(u, 3, COHORT, round_seed=11), iters)


def _mask_recover_us(iters: int) -> float:
    rs = privacy.mask_round_seed(7, 0)
    u = privacy.mask_update(_logreg_tree(np.random.default_rng(1)),
                            0, COHORT, round_seed=rs)
    present = {0, 2, 4, 6, 7}          # slots 1, 3, 5 missing

    def body():
        book = privacy.SeedShareBook(rs, COHORT, COHORT // 2 + 1)
        privacy.strip_missing_masks(u, book, 0, present)

    return _time_us(body, iters)


def _rdp_step_us(iters: int) -> float:
    qs = iter(np.linspace(0.05, 0.95, iters * 2))

    def body():
        acc = privacy.RDPAccountant(noise_multiplier=1.1)
        acc.step(range(COHORT), q=float(next(qs)))
        acc.epsilon()

    return _time_us(body, iters)


def _he_encode_us(iters: int) -> float:
    rng = np.random.default_rng(2)
    delta = {"w": np.asarray(rng.normal(size=(16384,)) * 0.01,
                             np.float32)}
    lay = HELayer()
    ctx = WireCtx(round=0, client=0, slot=0, n_active=COHORT, seed=0)
    from repro.core.comm import WireMsg
    return _time_us(
        lambda: lay.encode(WireMsg(payload=delta, nbytes=0), ctx), iters)


def _recovery_parity_err() -> float:
    """Max |masked+recovered sum - plain sum| over a random drop split."""
    rng = np.random.default_rng(3)
    rs = privacy.mask_round_seed(3, 1)
    updates = [_logreg_tree(rng) for _ in range(COHORT)]
    masked = [privacy.mask_update(u, i, COHORT, round_seed=rs)
              for i, u in enumerate(updates)]
    present = {0, 1, 4, 5, 6}
    book = privacy.SeedShareBook(rs, COHORT, COHORT // 2 + 1)
    got = privacy.secure_sum(
        [privacy.strip_missing_masks(masked[s], book, s, present)[0]
         for s in sorted(present)])
    want = privacy.secure_sum([updates[s] for s in sorted(present)])
    import jax
    return max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
               for a, b in zip(jax.tree.leaves(got),
                               jax.tree.leaves(want)))


def _accountant_spot_err() -> float:
    """|accountant - closed form| at q=1 (subsampling gate is binary)."""
    z, delta, T = 1.3, 1e-5, 10
    acc = privacy.RDPAccountant(noise_multiplier=z, delta=delta)
    sub = privacy.RDPAccountant(noise_multiplier=z, delta=delta)
    for _ in range(T):
        acc.step([0], q=1.0)
        sub.step([0], q=0.2)
    closed = min(T * a / (2 * z * z) + np.log(1 / delta) / (a - 1)
                 for a in acc.orders)
    if not 0.0 < sub.epsilon() < acc.epsilon():
        return float("inf")
    return abs(acc.epsilon() - closed)


def frontier(smoke: bool = False) -> List[Dict]:
    """Sweep per-round dp_epsilon x DP transport stacks on the tabular
    parametric pipeline; one point = (stack, eps/round, cumulative
    accountant eps, F1, uplink MB)."""
    from repro.core import parametric as P
    from repro.data import framingham as F
    ds = F.synthesize(n=600 if smoke else 2000, seed=0)
    train, test = F.train_test_split(ds)
    clients = [(c.x, c.y) for c in F.partition_clients(train, 4)]
    points = []
    for stack in FRONTIER_STACKS:
        for eps in (EPS_GRID_SMOKE if smoke else EPS_GRID):
            cfg = P.FedParametricConfig(
                model="logreg", rounds=3 if smoke else 10,
                local_steps=3 if smoke else 10,
                transport=stack, dp_epsilon=eps, seed=0)
            _, comm, history, _ = P.train_federated(
                clients, cfg, test=(test.x, test.y))
            points.append({
                "pipeline": f"parametric/{stack}",
                "dp_epsilon_per_round": eps,
                "epsilon_cumulative": comm.privacy["epsilon"],
                "delta": comm.privacy["delta"],
                "f1": history[-1]["f1"],
                "uplink_mb": comm.total_mb("up"),
            })
            print(f"  frontier {points[-1]['pipeline']:<22} "
                  f"eps/round={eps:<5} "
                  f"eps_cum={points[-1]['epsilon_cumulative']:.2f} "
                  f"F1={points[-1]['f1']:.3f} "
                  f"uplink={points[-1]['uplink_mb']:.2f}MB")
    return points


def run(smoke: bool = False) -> List[Dict]:
    iters = 5 if smoke else 20
    meta = bench_meta()
    rows = [
        {"name": "privacy/mask_encode", "us": _mask_encode_us(iters),
         "note": f"mask_update;cohort={COHORT};logreg tree", **meta},
        {"name": "privacy/mask_recover", "us": _mask_recover_us(iters),
         "note": f"strip_missing_masks;3 of {COHORT} missing;"
         "cold share book", **meta},
        {"name": "privacy/rdp_step", "us": _rdp_step_us(iters),
         "note": "step+epsilon at fresh q (uncached)", **meta},
        {"name": "privacy/he_encode", "us": _he_encode_us(iters),
         "note": "HELayer fixed-point encode;16k scalars", **meta},
    ]
    for r in rows:
        print(f"  {r['name']:<22} {r['us']:>10.1f}us  {r['note']}")
    return rows


def check_correctness() -> List[str]:
    failures = []
    err = _recovery_parity_err()
    if not err <= PARITY_ATOL:
        failures.append(
            f"dropout-recovery parity: masked+recovered sum deviates "
            f"from plain sum by {err:.2e} > {PARITY_ATOL:.0e}")
    err = _accountant_spot_err()
    if not err <= 1e-9:
        failures.append(
            f"RDP accountant spot check failed: q=1 closed-form "
            f"deviation {err:.2e} (inf = amplification ordering broken)")
    return failures


def save_rows(rows: List[Dict], path: str = OUT,
              smoke: bool = False) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"meta": {**bench_meta(), "smoke": smoke},
                   "rows": rows}, f, indent=1)
        f.write("\n")
    return path


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape set (fewer iters, 2-point frontier)")
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--frontier-out", default=FRONTIER_OUT)
    ap.add_argument("--skip-frontier", action="store_true",
                    help="timed rows + correctness gates only")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    save_rows(rows, args.out, smoke=args.smoke)
    print(f"wrote {args.out}")
    if not args.skip_frontier:
        points = frontier(smoke=args.smoke)
        os.makedirs(os.path.dirname(args.frontier_out) or ".",
                    exist_ok=True)
        with open(args.frontier_out, "w") as f:
            json.dump({"meta": {**bench_meta(), "smoke": args.smoke},
                       "points": points}, f, indent=1)
            f.write("\n")
        print(f"wrote {args.frontier_out}")
    failures = check_correctness()
    for f in failures:
        print(f"PRIVACY  {f}", file=sys.stderr)
    print(f"privacy_bench: {len(failures)} correctness failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
