"""Assigned-architecture registry: ``get(arch_id)`` / ``get_smoke(arch_id)``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig

ARCH_IDS: List[str] = [
    "dbrx_132b",
    "phi35_moe",
    "whisper_medium",
    "internvl2_2b",
    "qwen3_4b",
    "yi_34b",
    "hymba_15b",
    "mamba2_13b",
    "phi3_mini",
    "minitron_4b",
    "framingham",   # the paper's own (tabular) "architecture"
]

_ALIASES = {
    "dbrx-132b": "dbrx_132b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "whisper-medium": "whisper_medium",
    "internvl2-2b": "internvl2_2b",
    "qwen3-4b": "qwen3_4b",
    "yi-34b": "yi_34b",
    "hymba-1.5b": "hymba_15b",
    "mamba2-1.3b": "mamba2_13b",
    "phi3-mini-3.8b": "phi3_mini",
    "minitron-4b": "minitron_4b",
}

LM_ARCH_IDS = [a for a in ARCH_IDS if a != "framingham"]


def _module(arch_id: str):
    arch_id = _ALIASES.get(arch_id, arch_id).replace("-", "_")
    return importlib.import_module(f"repro.configs.{arch_id}")


def get(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE_CONFIG


def shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]
