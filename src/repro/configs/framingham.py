"""The paper's own workload: Framingham CHD tabular prediction
(n=4238, 15 features, 15.2% positive; Kaggle dileep070 card)."""
from dataclasses import dataclass


@dataclass(frozen=True)
class FraminghamConfig:
    n_records: int = 4238
    n_features: int = 15
    positive_rate: float = 0.152
    n_clients: int = 3
    train_frac: float = 0.8
    # paper hyper-params
    rf_trees: int = 100
    rf_subset_trees: int = 10          # floor(sqrt(100))
    rf_max_depth: int = 8
    xgb_trees: int = 50
    xgb_max_depth: int = 6
    xgb_shallow_depth: int = 4         # feature-extraction tree depth
    xgb_top_features: int = 8          # top-p ranked features
    xgb_lr: float = 0.3
    lr_l2: float = 0.01
    svm_c: float = 1.0
    nn_hidden: int = 16
    fedprox_mu: float = 0.01
    dp_epsilon: float = 0.5
    dp_delta: float = 1e-5
    n_bins: int = 64


CONFIG = FraminghamConfig()
SMOKE_CONFIG = FraminghamConfig(n_records=400, rf_trees=10,
                                rf_subset_trees=3, xgb_trees=5)
