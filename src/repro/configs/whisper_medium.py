"""Whisper-medium backbone: 24L enc + 24L dec, d=1024, 16H, d_ff=4096,
vocab=51865. Conv/mel frontend stubbed (DESIGN.md); encoder frames padded
1500 -> 1536 for clean mesh divisibility. [arXiv:2212.04356]"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    encoder=EncoderConfig(num_layers=24, seq_len=1536, frontend_dim=1024),
    source="arXiv:2212.04356",
)
SMOKE_CONFIG = CONFIG.reduced()
