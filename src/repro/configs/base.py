"""Config system: model architecture, input shapes, runtime options.

Every assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
(the exact published shape, used only via the dry-run) and ``SMOKE_CONFIG``
(a reduced same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

Family = str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'encdec' | 'vlm'


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    top_k: int = 2
    capacity_factor: float = 1.25
    # d_ff of each expert (the arch table's d_ff is per-expert for MoE archs)
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    num_groups: int = 1  # B/C projection groups

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class EncoderConfig:
    """Stub-frontend encoder (audio frames / vision patches are supplied
    pre-embedded by ``input_specs`` — see DESIGN.md carve-out)."""
    num_layers: int = 0
    seq_len: int = 0            # e.g. 1536 audio frames (padded from 1500)
    frontend_dim: int = 0       # dim of the supplied embeddings
    # vlm: number of image tokens prepended to the text sequence
    num_image_tokens: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int          # q heads; 0 for attn-free (ssm)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0       # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # Sliding-window size used for the long_500k decode variant on archs whose
    # native attention is full/causal (DESIGN.md long_500k policy). None for
    # SSM (not needed).
    long_context_window: Optional[int] = 8192
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # §Perf lever: pad the embedding/head vocab dim up to a multiple of 512
    # so odd vocab sizes shard over 'model' instead of replicating.
    pad_vocab: bool = False
    # §Perf lever: pad q heads up to the next multiple of 16 (when the
    # padded count stays divisible by num_kv_heads) so attention shards
    # over 'model' instead of replicating — yi-34b's 56 heads otherwise
    # replicate 16x. Adds initially-dead heads (model surgery; documented
    # in docs/EXPERIMENTS.md §Perf).
    pad_heads: bool = False
    dtype: str = "bfloat16"
    # citation for the shape (hf model card or arXiv id)
    source: str = ""

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def padded_vocab_size(self) -> int:
        if not self.pad_vocab:
            return self.vocab_size
        return (self.vocab_size + 511) // 512 * 512

    @property
    def padded_num_heads(self) -> int:
        if not self.pad_heads or not self.num_heads:
            return self.num_heads
        h = (self.num_heads + 15) // 16 * 16
        if self.num_kv_heads and h % self.num_kv_heads:
            return self.num_heads  # padding would break GQA grouping
        return h

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def num_params(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        n += d  # final norm
        per_layer = 0
        if self.family != "ssm":
            H, K, dh = self.num_heads, self.num_kv_heads, self.head_dim_
            per_layer += d * H * dh + 2 * d * K * dh + H * dh * d  # qkvo
            per_layer += 2 * d  # ln1/ln2 (rms)
            if self.qk_norm:
                per_layer += 2 * dh
        if self.family in ("dense", "encdec", "vlm"):
            per_layer += 3 * d * self.d_ff
        if self.family == "hybrid":
            per_layer += 3 * d * self.d_ff
        if self.moe is not None:
            e = self.moe.num_experts
            per_layer += e * 3 * d * self.d_ff + d * e  # experts + router
        if self.ssm is not None:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.num_heads(d)
            gn = s.num_groups * s.state_size
            in_dim = 2 * di + 2 * gn + nh
            per_layer += d * in_dim + di * d  # in/out proj
            per_layer += (di + 2 * gn) * s.conv_width  # conv
            per_layer += 3 * nh + di  # A, dt_bias, D, gate-norm
            if self.family == "ssm":
                per_layer += d  # single pre-norm
        n += per_layer * L
        if self.encoder is not None and self.encoder.num_layers:
            # whisper-style encoder: bidirectional attn + mlp, same dims
            H, K, dh = self.num_heads, self.num_kv_heads, self.head_dim_
            enc_layer = d * H * dh + 2 * d * K * dh + H * dh * d + 2 * d
            enc_layer += 3 * d * self.d_ff
            n += enc_layer * self.encoder.num_layers + d
        if self.encoder is not None and self.encoder.num_image_tokens:
            # vlm projector: frontend_dim -> d (2-layer mlp)
            f = self.encoder.frontend_dim
            n += f * d + d * d + 2 * d
        return n

    def num_active_params(self) -> int:
        """Active params per token (MoE uses top_k of num_experts)."""
        if self.moe is None:
            return self.num_params()
        d, L = self.d_model, self.num_layers
        e, k = self.moe.num_experts, self.moe.top_k
        dead = (e - k) * 3 * d * self.d_ff * L
        return self.num_params() - dead

    def reduced(self, **over) -> "ModelConfig":
        """Smoke-test variant: same family/features, tiny dims."""
        base = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            head_dim=32 if self.num_heads else 0,
            name=self.name + "-smoke",
        )
        if self.moe is not None:
            base["moe"] = dataclasses.replace(self.moe, num_experts=4,
                                              top_k=min(self.moe.top_k, 2))
        if self.ssm is not None:
            base["ssm"] = dataclasses.replace(
                self.ssm, state_size=min(self.ssm.state_size, 16),
                head_dim=32, chunk_size=32)
        if self.encoder is not None:
            base["encoder"] = dataclasses.replace(
                self.encoder,
                num_layers=min(self.encoder.num_layers, 2),
                seq_len=min(self.encoder.seq_len, 64) or 0,
                frontend_dim=min(self.encoder.frontend_dim, 64)
                if self.encoder.frontend_dim else 0,
                num_image_tokens=min(self.encoder.num_image_tokens, 8)
                if self.encoder.num_image_tokens else 0,
            )
        base.update(over)
        return dataclasses.replace(self, **base)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    phase: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    """Runtime/perf knobs — the levers the §Perf hillclimb turns."""
    use_pallas: bool = False          # pallas kernels (interpret on CPU)
    remat: str = "full"               # 'none' | 'full' | 'dots'
    causal_block_skip: bool = False   # skip fully-masked kv blocks (prefill)
    seq_shard_activations: bool = True  # Megatron-SP style boundary constraint
    loss_chunk: int = 8192            # CE computed in token chunks
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    moe_impl: str = "auto"            # 'auto' | 'local' | 'ep'  (expert parallel)
    decode_window_slice: bool = False  # §Perf: slice window instead of masking
    fsdp_params: bool = True          # shard weights over 'data' too (train)
    # Analysis mode: unroll every scan so compiled cost_analysis/HLO
    # reflects true per-step op counts (XLA costs a scan body ONCE,
    # ignoring trip count). Used by the dry-run; execution paths keep
    # rolled scans.
    scan_unroll: bool = False
    # --- §Perf levers (beyond-paper optimizations; baseline = all off) ---
    # pad embed/head vocab dim to a multiple of 512 so odd vocabs
    # (whisper/internvl/hymba/mamba2) shard over 'model' instead of
    # replicating; CE slices the logits back to the true vocab.
    pad_vocab: bool = False
    # broadcast kv heads to q heads before the attention einsum so the
    # (B,T,H,dh)->(B,T,K,G,dh) reshape never splits the model-sharded H
    # dim (avoids per-layer q resharding collectives).
    gqa_broadcast_kv: bool = False
    # cast expert weights to the activation dtype BEFORE the shard_map
    # all-gather in the EP MoE layer (halves FSDP gather traffic).
    moe_gather_bf16: bool = False
