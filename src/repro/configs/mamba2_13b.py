"""Mamba2-1.3B: attention-free SSD. 48L, d=2048, d_inner=4096 (64 heads x
head_dim 64), ssm_state=128, vocab=50280. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, long_context_window=None,
    ssm=SSMConfig(state_size=128, head_dim=64, expand=2),
    source="arXiv:2405.21060",
)
SMOKE_CONFIG = CONFIG.reduced()
