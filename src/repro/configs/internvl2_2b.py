"""InternVL2-2B backbone: InternLM2-1.8B LM (24L, d=2048, 16H GQA kv=8,
d_ff=8192, vocab=92553) + stub InternViT frontend supplying 256 patch
embeddings (dim 1024) through a real 2-layer MLP projector.
[arXiv:2404.16821]"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, rope_theta=1000000.0,
    encoder=EncoderConfig(num_image_tokens=256, frontend_dim=1024),
    source="arXiv:2404.16821",
)
SMOKE_CONFIG = CONFIG.reduced()
