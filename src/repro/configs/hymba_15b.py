"""Hymba-1.5B: hybrid heads — parallel attention (25H, GQA kv=5) + Mamba
heads in the same block. 32L, d=1600, d_ff=5504, vocab=32001, ssm_state=16.
[arXiv:2411.13676]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001,
    ssm=SSMConfig(state_size=16, head_dim=64, expand=2),
    source="arXiv:2411.13676",
)
SMOKE_CONFIG = CONFIG.reduced(num_heads=4, num_kv_heads=2, head_dim=32)
