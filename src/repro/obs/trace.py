"""Span tracer: virtual-clock and wall-clock spans with a zero-cost off
path.

Two tracer types share one call surface:

* :data:`NULL_TRACER` — the disabled tracer.  It is *falsy*
  (``bool(NULL_TRACER) is False``), so every hot path in the repo guards
  instrumentation with ``if tr:`` and pays one truthiness check — no
  allocations, no kwargs dicts, no event objects.  Instrumented-but-off
  runs are bit-exact with untraced runs (parity-gated in
  tests/test_obs.py and ``repro.launch.trace --smoke``).
* :class:`Tracer` — the enabled tracer.  Events are appended to a flat
  in-memory list in deterministic order and exported through
  ``repro.obs.export`` (jsonl / chrome / summary).

Clock sources
-------------
``Tracer(clock='virtual')`` has **no clock of its own**: every record
call must carry an explicit ``t=`` stamp taken from the caller's virtual
clock (``FedRuntime.now``, the serve-load simulator's event time).  A
missing stamp raises, so virtual traces can never be polluted by wall
time.  ``Tracer(clock='wall')`` defaults stamps to
``time.perf_counter()`` for benches and the scoring engine; explicit
``t=`` stamps are still honoured.

Span lifecycle
--------------
Three recording styles cover every call site:

* ``span_at(name, t0, t1, ...)`` — retrospective complete span, used
  when both endpoints are already known (sync rounds, batch service).
* ``begin(...)`` / ``end(handle)`` — explicit open/close for the async
  event loop, where a client's compute span closes many events later.
  Handles form a per-track stack; closing out of order raises, which is
  what the "spans nest" property test leans on.
* ``span(name, ...)`` — context manager for wall-clock sections.

Tracks map to Perfetto threads: ``server``, ``c<i>`` per client,
``queue``, ``comm``, ``tier:<name>``.

The ambient tracer (``current()`` / ``use()`` / ``install()``) lets CLI
entry points enable tracing without threading a parameter through every
``simulate_*`` signature; runtimes resolve ``tracer=None`` to it.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

from .metrics import MetricsRegistry


class _NullSpan:
    """Shared no-op context manager / span handle."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: falsy, allocation-free, accepts every call."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def span(self, name, track="main", t=None, **attrs):
        return _NULL_SPAN

    def span_at(self, name, t0, t1, track="main", **attrs):
        pass

    def begin(self, name, track="main", t=None, **attrs):
        return _NULL_SPAN

    def end(self, handle, t=None, **attrs):
        pass

    def instant(self, name, track="main", t=None, **attrs):
        pass

    def count(self, name, value, track="main", t=None):
        pass


NULL_TRACER = NullTracer()


class _Span:
    """Handle returned by ``Tracer.begin`` / used by the ``span`` CM."""

    __slots__ = ("tracer", "name", "track", "t0", "attrs", "open")

    def __init__(self, tracer, name, track, t0, attrs):
        self.tracer = tracer
        self.name = name
        self.track = track
        self.t0 = t0
        self.attrs = attrs
        self.open = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.tracer.end(self)
        return False


class Tracer:
    """Enabled tracer collecting span/instant/counter events in memory.

    Parameters
    ----------
    clock:
        ``'virtual'`` (default) — every record call must pass ``t=``;
        ``'wall'`` — ``t`` defaults to ``time.perf_counter()``.
    meta:
        Free-form run metadata carried into exporter headers.
    """

    enabled = True

    def __init__(self, clock: str = "virtual",
                 meta: Optional[dict] = None) -> None:
        if clock not in ("virtual", "wall"):
            raise ValueError(f"unknown clock {clock!r}: virtual|wall")
        self.clock = clock
        self.meta = dict(meta or {})
        self.events: List[Dict[str, Any]] = []
        self.metrics = MetricsRegistry()
        self._stacks: Dict[str, List[_Span]] = {}

    def __bool__(self) -> bool:
        return True

    def _now(self, t) -> float:
        if t is not None:
            return float(t)
        if self.clock == "wall":
            return time.perf_counter()
        raise ValueError(
            "virtual-clock tracer needs an explicit t= stamp; "
            "pass the runtime's virtual time or use Tracer(clock='wall')")

    # -- recording ----------------------------------------------------
    def span_at(self, name, t0, t1, track="main", **attrs) -> None:
        """Record a complete span with both endpoints known."""
        t0, t1 = float(t0), float(t1)
        if t1 < t0:
            raise ValueError(f"span {name!r}: end {t1} < begin {t0}")
        ev = {"ph": "span", "name": name, "track": track,
              "t0": t0, "t1": t1}
        if attrs:
            ev["args"] = attrs
        self.events.append(ev)

    def begin(self, name, track="main", t=None, **attrs) -> _Span:
        """Open a span; close it with ``end(handle)``.  Handles stack
        per track, so spans on one track must nest."""
        sp = _Span(self, name, track, self._now(t), attrs)
        self._stacks.setdefault(track, []).append(sp)
        return sp

    def end(self, handle: _Span, t=None, **attrs) -> None:
        stack = self._stacks.get(handle.track, [])
        if not stack or stack[-1] is not handle:
            raise ValueError(
                f"span {handle.name!r} on track {handle.track!r} is not "
                "the innermost open span (spans must nest per track)")
        if not handle.open:
            raise ValueError(f"span {handle.name!r} already closed")
        stack.pop()
        handle.open = False
        if attrs:
            handle.attrs.update(attrs)
        self.span_at(handle.name, handle.t0, self._now(t),
                     track=handle.track, **handle.attrs)

    def span(self, name, track="main", t=None, **attrs) -> _Span:
        """Context-manager form of begin/end (wall clock, or explicit
        ``t`` on enter — exit stamps with the clock's now)."""
        return self.begin(name, track=track, t=t, **attrs)

    def instant(self, name, track="main", t=None, **attrs) -> None:
        ev = {"ph": "inst", "name": name, "track": track,
              "t": self._now(t)}
        if attrs:
            ev["args"] = attrs
        self.events.append(ev)

    def count(self, name, value, track="main", t=None) -> None:
        self.events.append({"ph": "count", "name": name, "track": track,
                            "t": self._now(t), "value": float(value)})

    # -- inspection ---------------------------------------------------
    def open_spans(self) -> List[_Span]:
        return [sp for stack in self._stacks.values() for sp in stack]


# -- ambient tracer ---------------------------------------------------
_CURRENT: Any = NULL_TRACER


def current() -> Any:
    """The ambient tracer (NULL_TRACER unless one was installed)."""
    return _CURRENT


def install(tracer: Any) -> Any:
    """Install ``tracer`` as the ambient tracer; returns the previous."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tracer if tracer is not None else NULL_TRACER
    return prev


class use:
    """``with use(tracer): ...`` — scoped ambient-tracer install."""

    def __init__(self, tracer: Any) -> None:
        self.tracer = tracer
        self._prev: Any = None

    def __enter__(self):
        self._prev = install(self.tracer)
        return self.tracer

    def __exit__(self, *exc):
        install(self._prev)
        return False


# -- jax.profiler annotations ----------------------------------------
_ANNOTATE = os.environ.get("REPRO_OBS_ANNOTATE", "") not in ("", "0")


def set_annotations(on: bool) -> None:
    """Toggle jax.profiler annotations around kernel entry points."""
    global _ANNOTATE
    _ANNOTATE = bool(on)


def annotations_enabled() -> bool:
    return _ANNOTATE


def annotate(name: str):
    """``jax.profiler.TraceAnnotation`` context for kernel dispatch.

    Off by default (returns a shared no-op CM) so instrumented kernel
    entry points stay bit-exact and allocation-free; enable with
    ``REPRO_OBS_ANNOTATE=1`` or :func:`set_annotations` when capturing a
    device profile.
    """
    if not _ANNOTATE:
        return _NULL_SPAN
    import jax.profiler
    return jax.profiler.TraceAnnotation(name)
