"""Unified tracing + metrics subsystem (see docs/ARCHITECTURE.md
§Observability).

Spans stamp from the same virtual clock the FedRuntime and the serve
load engine share (wall-clock mode for benches), metrics follow the
repo registry idiom, and exporters emit byte-stable JSONL, Chrome
trace-event / Perfetto files, or an aggregated summary table.  The
disabled tracer (``NULL_TRACER``) is falsy and allocation-free, so
instrumented-but-off runs stay bit-exact with untraced runs.
"""
from .trace import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    Tracer,
    annotate,
    annotations_enabled,
    current,
    install,
    set_annotations,
    use,
)
from .metrics import METRICS, MetricSpec, MetricsRegistry  # noqa: F401
from .export import (  # noqa: F401
    EXPORTERS,
    chrome_payload,
    format_summary,
    get_exporter,
    jsonl_bytes,
    summarize,
)
