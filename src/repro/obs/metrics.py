"""Metrics registry: named counters, gauges, and exponential-bucket
histograms shared by every instrumented runtime.

Follows the repo's registry idiom: ``METRICS`` maps a metric name to a
:class:`MetricSpec` (kind + docstring + bucket geometry), and
``tools/check_docs.py`` fails CI if a registered name is missing from the
docs corpus.  A :class:`MetricsRegistry` instance holds the *values* for
one tracer; recording against a name that is not in ``METRICS`` raises,
so ad-hoc metric names cannot silently leak into traces.

Histograms use exponential buckets: upper bounds ``lo * growth**i`` for
``i in range(n)`` plus a +inf overflow bucket.  Snapshots are plain dicts
with sorted keys, so exported metrics are byte-stable under a fixed seed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List


@dataclass(frozen=True)
class MetricSpec:
    """Declaration of one metric: kind, doc line, histogram geometry."""

    kind: str            # 'counter' | 'gauge' | 'hist'
    desc: str
    lo: float = 1e-4     # hist: upper bound of the first bucket
    growth: float = 4.0  # hist: geometric growth factor between buckets
    n: int = 12          # hist: number of finite buckets

    def bounds(self) -> List[float]:
        if self.kind != "hist":
            raise ValueError(f"metric kind {self.kind!r} has no buckets")
        return [self.lo * self.growth ** i for i in range(self.n)]


METRICS: Dict[str, MetricSpec] = {
    # federated training
    "bytes_up": MetricSpec("counter", "client->server payload bytes"),
    "bytes_down": MetricSpec("counter", "server->client payload bytes"),
    "msgs_delivered": MetricSpec(
        "counter", "client messages delivered to the aggregator"),
    "msgs_dropped": MetricSpec(
        "counter", "client uploads lost to dropout/straggling"),
    "round_s": MetricSpec(
        "hist", "per-round duration on the tracer's clock (s)",
        lo=1e-3, growth=4.0, n=12),
    "staleness_rounds": MetricSpec(
        "hist", "staleness (in rounds) of delivered messages",
        lo=1.0, growth=2.0, n=8),
    # serving
    "queue_wait_s": MetricSpec(
        "hist", "request wait between arrival and batch start (s)",
        lo=1e-4, growth=4.0, n=12),
    "batch_rows": MetricSpec(
        "hist", "rows per formed batch", lo=1.0, growth=2.0, n=12),
    "queue_depth": MetricSpec("gauge", "requests queued at last event"),
    "deadline_misses": MetricSpec(
        "counter", "requests completed after their deadline"),
    "rejections": MetricSpec(
        "counter", "requests rejected by admission control"),
    "score_s": MetricSpec(
        "hist", "wall-clock ScoringEngine.score latency (s)",
        lo=1e-5, growth=4.0, n=14),
}


class MetricsRegistry:
    """Value store for the metrics declared in ``METRICS``.

    One instance per tracer.  All mutation paths validate the metric name
    and kind against the spec registry; ``snapshot()`` returns a plain
    sorted-key dict suitable for byte-stable JSON export.
    """

    def __init__(self) -> None:
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, dict] = {}

    @staticmethod
    def _spec(name: str, kind: str) -> MetricSpec:
        spec = METRICS.get(name)
        if spec is None:
            known = ", ".join(sorted(METRICS))
            raise KeyError(f"unknown metric {name!r}; known: {known}")
        if spec.kind != kind:
            raise ValueError(
                f"metric {name!r} is a {spec.kind}, not a {kind}")
        return spec

    def inc(self, name: str, value: float = 1.0) -> None:
        self._spec(name, "counter")
        self._counters[name] = self._counters.get(name, 0.0) + value

    def set(self, name: str, value: float) -> None:
        self._spec(name, "gauge")
        self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        spec = self._spec(name, "hist")
        h = self._hists.get(name)
        if h is None:
            h = {"counts": [0] * (spec.n + 1), "sum": 0.0, "count": 0}
            self._hists[name] = h
        i = 0
        bound = spec.lo
        while i < spec.n and value > bound:
            bound *= spec.growth
            i += 1
        h["counts"][i] += 1
        h["sum"] += float(value)
        h["count"] += 1

    def snapshot(self) -> dict:
        """Sorted, JSON-ready view of every metric touched so far."""
        out: dict = {}
        for name in sorted(self._counters):
            out[name] = {"kind": "counter", "value": self._counters[name]}
        for name in sorted(self._gauges):
            out[name] = {"kind": "gauge", "value": self._gauges[name]}
        for name in sorted(self._hists):
            spec = METRICS[name]
            h = self._hists[name]
            out[name] = {
                "kind": "hist",
                "count": h["count"],
                "sum": h["sum"],
                "bounds": spec.bounds(),
                "counts": list(h["counts"]),
            }
        return out
