"""Trace exporters: ``jsonl`` (byte-stable event log), ``chrome``
(Chrome trace-event / Perfetto format), ``summary`` (aggregated table).

``EXPORTERS`` follows the repo registry idiom — selectable by name,
``check_docs``-enforced — and :func:`get_exporter` resolves colon specs
(``jsonl:results/trace.jsonl``, ``chrome:trace.json``, ``summary``)
to a ``fn(tracer) -> payload`` closure that also writes the file when a
path is given.

Byte stability: ``jsonl_bytes`` serializes every event with
``json.dumps(sort_keys=True)`` one per line, header line first and a
final metrics line last.  Both the federated virtual-time runtimes and
the serve-load simulator are deterministic given a seed, so a traced
re-run produces an identical file — the golden trace snapshot and the
same-seed replay property test both hinge on this.
"""
from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, List


def _dumps(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def jsonl_bytes(tracer) -> bytes:
    """One event per line: meta header, events in record order, metrics."""
    lines = [_dumps({"ph": "meta", "clock": tracer.clock,
                     "meta": tracer.meta})]
    lines.extend(_dumps(ev) for ev in tracer.events)
    lines.append(_dumps({"ph": "metrics",
                         "metrics": tracer.metrics.snapshot()}))
    return ("\n".join(lines) + "\n").encode()


def chrome_payload(tracer) -> dict:
    """Chrome trace-event JSON (the format Perfetto/chrome://tracing
    loads).  Tracks map to threads of one process; timestamps are
    microseconds on the tracer's clock."""
    tids: Dict[str, int] = {}
    trace_events: List[dict] = []

    def tid(track: str) -> int:
        if track not in tids:
            tids[track] = len(tids)
            trace_events.append(
                {"ph": "M", "name": "thread_name", "pid": 0,
                 "tid": tids[track], "args": {"name": track}})
        return tids[track]

    for ev in tracer.events:
        ph, name, track = ev["ph"], ev["name"], ev["track"]
        args = ev.get("args", {})
        if ph == "span":
            trace_events.append(
                {"ph": "X", "name": name, "cat": "obs", "pid": 0,
                 "tid": tid(track), "ts": ev["t0"] * 1e6,
                 "dur": (ev["t1"] - ev["t0"]) * 1e6, "args": args})
        elif ph == "inst":
            trace_events.append(
                {"ph": "i", "name": name, "cat": "obs", "s": "t",
                 "pid": 0, "tid": tid(track), "ts": ev["t"] * 1e6,
                 "args": args})
        elif ph == "count":
            trace_events.append(
                {"ph": "C", "name": name, "pid": 0, "tid": tid(track),
                 "ts": ev["t"] * 1e6, "args": {name: ev["value"]}})
    return {"traceEvents": trace_events, "displayTimeUnit": "ms",
            "otherData": {"clock": tracer.clock, **tracer.meta}}


def summarize(tracer) -> dict:
    """Aggregate spans per (track, name) plus instant counts + metrics.

    The span rows are what ``repro.launch.trace`` prints as the
    per-round/per-tier summary and what ``report.py`` style tables
    consume: count, total duration, mean/min/max on the trace clock.
    """
    spans: Dict[tuple, dict] = {}
    instants: Dict[tuple, int] = {}
    for ev in tracer.events:
        key = (ev["track"], ev["name"])
        if ev["ph"] == "span":
            dur = ev["t1"] - ev["t0"]
            row = spans.setdefault(
                key, {"count": 0, "total": 0.0,
                      "min": dur, "max": dur})
            row["count"] += 1
            row["total"] += dur
            row["min"] = min(row["min"], dur)
            row["max"] = max(row["max"], dur)
        elif ev["ph"] == "inst":
            instants[key] = instants.get(key, 0) + 1
    span_rows = [
        {"track": tr, "name": nm, "count": row["count"],
         "total_s": row["total"], "mean_s": row["total"] / row["count"],
         "min_s": row["min"], "max_s": row["max"]}
        for (tr, nm), row in sorted(spans.items())]
    inst_rows = [{"track": tr, "name": nm, "count": n}
                 for (tr, nm), n in sorted(instants.items())]
    return {"spans": span_rows, "instants": inst_rows,
            "metrics": tracer.metrics.snapshot()}


def format_summary(summary: dict) -> str:
    """Render :func:`summarize` output as an aligned text table."""
    lines = []
    if summary["spans"]:
        lines.append(f"{'track':<14} {'span':<22} {'count':>6} "
                     f"{'total_s':>10} {'mean_s':>10} {'max_s':>10}")
        for r in summary["spans"]:
            lines.append(
                f"{r['track']:<14} {r['name']:<22} {r['count']:>6} "
                f"{r['total_s']:>10.4f} {r['mean_s']:>10.5f} "
                f"{r['max_s']:>10.5f}")
    if summary["instants"]:
        lines.append("")
        lines.append(f"{'track':<14} {'event':<22} {'count':>6}")
        for r in summary["instants"]:
            lines.append(f"{r['track']:<14} {r['name']:<22} "
                         f"{r['count']:>6}")
    counters = {k: v for k, v in summary["metrics"].items()
                if v["kind"] in ("counter", "gauge")}
    if counters:
        lines.append("")
        for k, v in sorted(counters.items()):
            lines.append(f"{k:<36} {v['value']:>14.1f}")
    return "\n".join(lines)


def _write(path: str, data: bytes) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)


def _export_jsonl(tracer, path: str = "") -> bytes:
    data = jsonl_bytes(tracer)
    if path:
        _write(path, data)
    return data


def _export_chrome(tracer, path: str = "") -> dict:
    payload = chrome_payload(tracer)
    if path:
        _write(path, (json.dumps(payload, sort_keys=True) + "\n").encode())
    return payload


def _export_summary(tracer, path: str = "") -> dict:
    summary = summarize(tracer)
    if path:
        _write(path, (_dumps(summary) + "\n").encode())
    return summary


EXPORTERS: Dict[str, Callable] = {
    "jsonl": _export_jsonl,
    "chrome": _export_chrome,
    "summary": _export_summary,
}


def get_exporter(spec: str) -> Callable[[Any], Any]:
    """Resolve ``'name[:path]'`` to a ``fn(tracer)`` closure."""
    name, _, path = spec.partition(":")
    if name not in EXPORTERS:
        known = ", ".join(sorted(EXPORTERS))
        raise ValueError(f"unknown exporter {name!r}; known: {known}")
    fn = EXPORTERS[name]
    return lambda tracer: fn(tracer, path)
