"""Trace-driven serving load engine: request queue, continuous
batching, deadline accounting — on a deterministic virtual clock.

``repro.serve.engine.ScoringEngine`` measures single-batch latency;
this module measures the engine *under load* (ROADMAP open item 2 —
the "millions of users" story is saturation throughput, not one
batch's p50).  It reuses the repo's discrete-event conventions: the
virtual-clock event loop is the same deterministic pattern as
``FedRuntime._run_async`` (``repro.core.runtime``), and arrival
processes live in an :data:`ARRIVALS` registry shaped exactly like
``repro.core.latency.LATENCY`` — spec strings with colon-separated
parameters, resolved by :func:`get_arrivals`.

The simulation is a **pure function of (config, seed)**: arrivals and
request sizes are drawn from seeded generators, service times come from
a deterministic model (or from real ``engine.score`` wall-clock when
you want measured numbers), and every event is processed in a total
order — so a fixed spec + seed replays the identical per-request
records and summary row byte for byte.  That is what makes the CI
determinism gate (``launch/serve_load.py --smoke``) and the golden
load snapshot (``tools/refresh_golden.py``) possible.

**Arrival processes** (:data:`ARRIVALS`, spec ``name[:arg...]``)::

    poisson:500            memoryless arrivals at 500 req/s
    bursty:500:32:0.2      mean 500 req/s in bursts of 32 requests;
                           within a burst the instantaneous rate is
                           rate/duty (here 2500/s), bursts are spaced
                           so the long-run mean stays `rate`
    trace:gaps.json        replay recorded inter-arrival gaps (JSON
                           list of seconds, cycled; or {"gaps": [...]})

**Service-time models** (:data:`SERVICE`, spec ``name[:arg...]``)::

    constant:0.002         every batch takes 2 ms
    affine:0.001:0.00001   base + per_row * padded-bucket-rows (the
                           engine pads to a bucket, so cost scales
                           with the bucket, not the raw batch)
    measured               time a real engine.score() call per batch
                           (requires engine= and features=)

plus :func:`calibrate_service` — measure per-bucket ``score()``
medians on a real engine once, then run the sweep virtually on the
calibrated table (reproducible *and* grounded in real timings).

**Continuous batch formation** (the queue's state machine, documented
in docs/ARCHITECTURE.md §Serving): admitted requests enter a FIFO
queue; whenever the single server is free, the head-of-queue batch
closes as soon as any of these holds —

* the batch reaches the largest padding bucket (``max(bucket_sizes)``),
* the next queued request no longer fits (the batch cannot grow),
* the head request has waited ``max_wait`` virtual seconds,
* no future arrivals exist (drain).

Otherwise the server idles until the earlier of (next arrival, head
timeout).  While the server is busy, arrivals keep queueing; on batch
completion the conditions are re-evaluated immediately — that is the
"continuous" in continuous batching.

**Admission control**: with ``max_queue`` set, an arrival that finds
``max_queue`` requests already waiting is rejected (recorded, never
scored) instead of growing the queue without bound.

**Deadline accounting**: per request, ``latency = t_done - t_arrive``
(enqueue to batch completion); ``miss`` ⇔ ``latency > deadline``.
Rejected requests are counted separately (``rejection_rate``), not as
misses.

Outputs: :class:`LoadResult` — per-request records, per-batch records,
and one summary ``row`` (offered/achieved QPS, p50/p99 latency,
deadline-miss rate, rejection rate, mean batch occupancy) written to
``results/serve_load/load_bench.json`` by the CLI
(``repro.launch.serve_load``).  :func:`qps_sweep` ladders offered
rates and reports max-sustainable-QPS (highest offered rate whose p99
stays under the deadline with zero rejections) — the row
``benchmarks/serve_bench.py --load`` feeds the ``BENCH_serve_load.json``
perf-gate trajectory (``tools/perf_gate.py``).
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import current as _ambient_tracer

#: SeedSequence tag isolating load-engine draws from every other
#: seeded stream in the repo (latency models use 0x1A7, the runtime
#: 0xFED).
_TAG = 0x10AD


def _rng(seed: int, comp: int) -> np.random.Generator:
    return np.random.default_rng([int(seed), _TAG, comp])


# --- arrival processes --------------------------------------------------------

@dataclass(frozen=True)
class ArrivalProcess:
    """A named arrival process: ``times(n)`` returns the n absolute
    (virtual-second) arrival times, deterministic in the construction
    seed.  The first n draws are a prefix of any longer run, so the
    same seed yields consistent traces across request counts."""
    name: str
    gaps_fn: Callable[[int], np.ndarray]

    def gaps(self, n: int) -> np.ndarray:
        g = np.asarray(self.gaps_fn(int(n)), np.float64)
        if g.shape != (n,):
            raise ValueError(f"arrival model {self.name!r} returned "
                             f"shape {g.shape}, wanted ({n},)")
        return g

    def times(self, n: int) -> np.ndarray:
        return np.cumsum(self.gaps(n))


def _poisson(rate):
    rate = float(rate)
    if rate <= 0:
        raise ValueError(f"poisson rate must be > 0, got {rate}")

    def make(seed: int) -> Callable[[int], np.ndarray]:
        return lambda n: _rng(seed, 1).exponential(1.0 / rate, size=n)
    return make


def _bursty(rate, burst, duty):
    """ON/OFF arrivals: requests come in bursts of ``burst``; within a
    burst the instantaneous rate is ``rate / duty`` and each burst's
    leading gap absorbs the OFF period, so the long-run mean rate is
    exactly ``rate``."""
    rate, burst, duty = float(rate), int(float(burst)), float(duty)
    if rate <= 0 or burst < 1 or not 0.0 < duty <= 1.0:
        raise ValueError(f"bursty needs rate>0, burst>=1, 0<duty<=1 "
                         f"(got rate={rate}, burst={burst}, duty={duty})")
    within = duty / rate                      # mean gap inside a burst
    lead = within + (1.0 - duty) * burst / rate   # burst-leading gap

    def make(seed: int) -> Callable[[int], np.ndarray]:
        def gaps(n):
            means = np.where(np.arange(n) % burst == 0, lead, within)
            return _rng(seed, 2).exponential(1.0, size=n) * means
        return gaps
    return make


def _arrival_trace(path: str):
    """Replay recorded inter-arrival gaps: a JSON list of seconds (or
    ``{"gaps": [...]}``), cycled when the run is longer than the
    trace."""
    with open(path) as f:
        data = json.load(f)
    raw = data.get("gaps") if isinstance(data, dict) else data
    if not raw:
        raise ValueError(f"arrival trace {path!r} is empty")
    gaps = np.asarray([float(g) for g in raw], np.float64)
    if np.any(gaps < 0):
        raise ValueError(f"arrival trace {path!r} has negative gaps")

    def make(seed: int) -> Callable[[int], np.ndarray]:
        return lambda n: np.resize(gaps, n)
    return make


#: arrival model name -> factory(*args) -> (seed) -> gaps(n).
#: Resolved via :func:`get_arrivals` spec strings
#: ("poisson:500", "bursty:500:32:0.2", "trace:gaps.json").
ARRIVALS: Dict[str, Callable] = {
    "poisson": _poisson,
    "bursty": _bursty,
    "trace": _arrival_trace,
}


def get_arrivals(spec, seed: int = 0) -> ArrivalProcess:
    """Resolve an arrival process from a spec string (or pass one
    through)."""
    if isinstance(spec, ArrivalProcess):
        return spec
    tokens = str(spec).strip().split(":")
    name, args = tokens[0], tokens[1:]
    if name not in ARRIVALS:
        raise KeyError(f"unknown arrival process {spec!r}; available: "
                       f"{sorted(ARRIVALS)} (spec: name[:arg...])")
    coerced = args if name == "trace" else [float(a) for a in args]
    try:
        return ArrivalProcess(str(spec), ARRIVALS[name](*coerced)(seed))
    except TypeError as e:
        raise ValueError(f"bad arrival spec {spec!r}: {e}") from e


# --- service-time models ------------------------------------------------------

def _svc_constant(t=0.001):
    t = float(t)
    if t <= 0:
        raise ValueError(f"constant service time must be > 0, got {t}")

    def make(seed, engine, features):
        return lambda rows, bucket, b_idx: t
    return make


def _svc_affine(base, per_row):
    """``base + per_row * bucket`` seconds per batch: the engine pads
    every batch to its bucket, so compute scales with the *padded*
    rows."""
    base, per_row = float(base), float(per_row)
    if base < 0 or per_row < 0 or base + per_row <= 0:
        raise ValueError(f"affine service needs non-negative base/"
                         f"per_row with a positive sum (got {base}, "
                         f"{per_row})")

    def make(seed, engine, features):
        return lambda rows, bucket, b_idx: base + per_row * bucket
    return make


def _svc_measured():
    """Real wall-clock of ``engine.score`` on ``rows`` feature rows —
    the batch is actually scored, so measured runs exercise the full
    jitted path (and are *not* replayable byte-for-byte; use
    :func:`calibrate_service` for reproducible grounded sweeps)."""
    def make(seed, engine, features):
        if engine is None or features is None:
            raise ValueError("service 'measured' needs a ScoringEngine "
                             "and a feature matrix (engine=, features=)")
        feats = np.asarray(features, np.float32)

        def service(rows, bucket, b_idx):
            lo = (b_idx * bucket) % max(len(feats) - rows, 1)
            t0 = time.perf_counter()
            engine.score(feats[lo:lo + rows])
            return time.perf_counter() - t0
        return service
    return make


#: service model name -> factory(*args) -> (seed, engine, features)
#: -> service(batch_rows, bucket, batch_idx) -> seconds.
SERVICE: Dict[str, Callable] = {
    "constant": _svc_constant,
    "affine": _svc_affine,
    "measured": _svc_measured,
}


def get_service(spec, seed: int = 0, engine=None, features=None
                ) -> Callable[[int, int, int], float]:
    """Resolve a service-time model from a spec string; callables pass
    through (the :func:`calibrate_service` / :func:`table_service`
    path)."""
    if callable(spec):
        return spec
    tokens = str(spec).strip().split(":")
    name, args = tokens[0], tokens[1:]
    if name not in SERVICE:
        raise KeyError(f"unknown service model {spec!r}; available: "
                       f"{sorted(SERVICE)} (spec: name[:arg...])")
    try:
        return SERVICE[name](*[float(a) for a in args])(seed, engine,
                                                        features)
    except TypeError as e:
        raise ValueError(f"bad service spec {spec!r}: {e}") from e


def table_service(table: Dict[int, float]
                  ) -> Callable[[int, int, int], float]:
    """Deterministic per-bucket service times from a measured table
    (``{bucket: seconds}``); unknown buckets use the largest entry."""
    tab = {int(b): float(s) for b, s in table.items()}
    if not tab or any(s <= 0 for s in tab.values()):
        raise ValueError(f"bad service table {table!r}")
    top = tab[max(tab)]

    def service(rows, bucket, b_idx):
        return tab.get(bucket, top)
    service.table = tab  # introspectable (bench rows report it)
    return service


def calibrate_service(engine, n_features: int, reps: int = 5
                      ) -> Callable[[int, int, int], float]:
    """Measure per-bucket ``engine.score`` wall-clock medians once and
    return a :func:`table_service` over them: sweeps run virtually
    (replayable) on real measured costs."""
    table = {}
    for b in engine.buckets:
        x = np.zeros((b, n_features), np.float32)
        engine.score(x)                        # compile / warm
        ts = []
        for _ in range(int(reps)):
            t0 = time.perf_counter()
            engine.score(x)
            ts.append(time.perf_counter() - t0)
        table[b] = float(np.median(ts))
    return table_service(table)


# --- request sizes ------------------------------------------------------------

def _request_rows(spec, seed: int, n: int, bucket_max: int) -> np.ndarray:
    """Per-request row counts: an int (every request carries that many
    rows) or ``uniform:lo:hi`` (seeded per-run draw).  Clamped to the
    largest bucket so every request fits in some batch."""
    try:
        k = int(spec)
    except (TypeError, ValueError):
        tokens = str(spec).split(":")
        if tokens[0] != "uniform" or len(tokens) != 3:
            raise ValueError(f"bad request-rows spec {spec!r} "
                             f"(int or uniform:lo:hi)")
        lo, hi = int(tokens[1]), int(tokens[2])
        if not 1 <= lo <= hi:
            raise ValueError(f"bad uniform rows bounds {spec!r}")
        draw = _rng(seed, 3).integers(lo, hi + 1, size=n)
        return np.minimum(draw, bucket_max).astype(np.int64)
    if k < 1:
        raise ValueError(f"request rows must be >= 1, got {k}")
    return np.full(n, min(k, bucket_max), np.int64)


# --- the load engine ----------------------------------------------------------

@dataclass
class LoadConfig:
    """One load run.  ``arrivals`` / ``service`` take registry spec
    strings (:data:`ARRIVALS` / :data:`SERVICE`) or prebuilt objects;
    ``rows`` is the per-request row-count spec (int or
    ``uniform:lo:hi``).  ``max_wait`` is the continuous-batching
    timeout on the head request's queue age; ``max_queue`` bounds the
    waiting queue (None = no admission control); ``deadline`` is the
    per-request enqueue→completion budget (None = no deadline
    accounting)."""
    arrivals: Any = "poisson:500"
    n_requests: int = 1000
    rows: Any = 1
    bucket_sizes: Sequence[int] = (64, 256, 1024)
    max_wait: float = 0.002
    max_queue: Optional[int] = None
    deadline: Optional[float] = None
    service: Any = "constant:0.001"
    seed: int = 0


@dataclass
class LoadResult:
    """One run's full output: the summary ``row`` (what lands in
    ``results/serve_load/load_bench.json``), per-request ``records``
    (arrival/start/done stamps, latency, miss/rejected flags), and
    per-batch ``batches`` (rows, bucket, occupancy)."""
    row: Dict
    records: List[Dict]
    batches: List[Dict]


def simulate_load(cfg: LoadConfig, engine=None, features=None,
                  tracer=None) -> LoadResult:
    """Run one trace through the queue + continuous-batching state
    machine on the virtual clock (module docstring).  With a virtual
    ``service`` model no engine is needed and the result is a pure
    function of (cfg, seed); with ``service='measured'`` the batches
    are really scored through ``engine``.

    ``tracer=None`` resolves to the ambient ``repro.obs`` tracer
    (NULL_TRACER unless a run installed one); batch service spans,
    queue-wait observations, and deadline-miss / rejection events are
    recorded on the virtual clock, on tracks suffixed with the arrival
    spec so sweep rungs stay distinguishable in one trace.  Traced-off
    runs are byte-identical to untraced ones (tests/test_obs.py)."""
    buckets = tuple(sorted(int(b) for b in cfg.bucket_sizes))
    if not buckets or buckets[0] < 1:
        raise ValueError(f"bad bucket_sizes {cfg.bucket_sizes!r}")
    if cfg.max_wait < 0:
        raise ValueError(f"max_wait must be >= 0, got {cfg.max_wait}")
    if cfg.max_queue is not None and cfg.max_queue < 1:
        raise ValueError(f"max_queue must be >= 1, got {cfg.max_queue}")
    bmax = buckets[-1]
    n = int(cfg.n_requests)
    arrivals = get_arrivals(cfg.arrivals, cfg.seed)
    times = arrivals.times(n)
    req_rows = _request_rows(cfg.rows, cfg.seed, n, bmax)
    service = get_service(cfg.service, cfg.seed, engine=engine,
                          features=features)
    tr = _ambient_tracer() if tracer is None else tracer
    srv_track = f"serve[{arrivals.name}]"
    q_track = f"queue[{arrivals.name}]"

    INF = float("inf")
    queue: deque = deque()         # admitted requests awaiting a batch
    records: List[Dict] = []
    batches: List[Dict] = []
    in_flight: Optional[Tuple[List[Dict], Dict]] = None
    done_t = INF
    t = 0.0
    i = 0                          # next arrival index

    def bucket_for(rows: int) -> int:
        for b in buckets:
            if b >= rows:
                return b
        return bmax

    def admit(idx: int) -> None:
        rec = {"id": idx, "t_arrive": float(times[idx]),
               "rows": int(req_rows[idx]), "rejected": False,
               "t_start": None, "t_done": None, "latency": None,
               "miss": False}
        if cfg.max_queue is not None and len(queue) >= cfg.max_queue:
            rec["rejected"] = True         # admission control: bounce
            if tr:
                tr.instant("load.reject", track=q_track,
                           t=rec["t_arrive"], id=idx)
                tr.metrics.inc("rejections")
        else:
            queue.append(rec)
            if tr:
                tr.count("queue_depth", len(queue), track=q_track,
                         t=rec["t_arrive"])
                tr.metrics.set("queue_depth", len(queue))
        records.append(rec)

    def batch_prefix() -> Tuple[int, int]:
        """Longest FIFO prefix of the queue fitting the largest
        bucket: (n_requests, total_rows)."""
        total = k = 0
        for rec in queue:
            if total + rec["rows"] > bmax:
                break
            total += rec["rows"]
            k += 1
        return k, total

    def start_batch(now: float) -> None:
        nonlocal in_flight, done_t
        k, total = batch_prefix()
        batch = [queue.popleft() for _ in range(k)]
        bucket = bucket_for(total)
        for rec in batch:
            rec["t_start"] = now
        brec = {"t_start": now, "rows": total, "bucket": bucket,
                "n_requests": k, "occupancy": total / bucket}
        done_t = now + float(service(total, bucket, len(batches)))
        in_flight = (batch, brec)
        if tr:  # batch formation: queue waits drain into this batch
            for rec in batch:
                tr.metrics.observe("queue_wait_s",
                                   now - rec["t_arrive"])
            tr.metrics.observe("batch_rows", total)
            tr.count("queue_depth", len(queue), track=q_track, t=now)
            tr.metrics.set("queue_depth", len(queue))

    while i < n or queue or in_flight is not None:
        t_arr = float(times[i]) if i < n else INF
        if in_flight is not None:
            # completion vs arrival; ties complete first (the server
            # frees before the coincident arrival is considered)
            if done_t <= t_arr:
                t = done_t
                batch, brec = in_flight
                brec["t_done"] = t
                batches.append(brec)
                for rec in batch:
                    rec["t_done"] = t
                    rec["latency"] = t - rec["t_arrive"]
                    rec["miss"] = (cfg.deadline is not None
                                   and rec["latency"] > cfg.deadline)
                    if tr and rec["miss"]:
                        tr.instant("load.deadline_miss", track=srv_track,
                                   t=t, id=rec["id"],
                                   latency=rec["latency"])
                        tr.metrics.inc("deadline_misses")
                if tr:
                    tr.span_at("load.batch", brec["t_start"], t,
                               track=srv_track, rows=brec["rows"],
                               bucket=brec["bucket"],
                               n_requests=brec["n_requests"],
                               occupancy=brec["occupancy"])
                in_flight, done_t = None, INF
            else:
                t = t_arr
                admit(i)
                i += 1
            continue
        if queue:
            k, total = batch_prefix()
            t_close = queue[0]["t_arrive"] + cfg.max_wait
            if (total >= bmax          # largest padding bucket reached
                    or k < len(queue)  # next request no longer fits
                    or i >= n          # drain: nothing more will come
                    or t >= t_close):  # head waited max_wait
                start_batch(t)
            elif t_arr <= t_close:
                t = t_arr
                admit(i)
                i += 1
            else:
                t = t_close
                start_batch(t)
            continue
        # idle server, empty queue: jump to the next arrival
        t = t_arr
        admit(i)
        i += 1

    return LoadResult(_summary(cfg, arrivals.name, records, batches,
                               times),
                      records, batches)


def _summary(cfg: LoadConfig, arrivals_name: str, records: List[Dict],
             batches: List[Dict], times: np.ndarray) -> Dict:
    done = [r for r in records if r["t_done"] is not None]
    rejected = sum(r["rejected"] for r in records)
    lat = np.asarray([r["latency"] for r in done], np.float64)
    wait = np.asarray([r["t_start"] - r["t_arrive"] for r in done],
                      np.float64)
    span = float(times[-1]) if len(times) else 0.0
    makespan = max((b["t_done"] for b in batches), default=0.0)
    row = {
        "arrivals": arrivals_name,
        "service": (str(cfg.service) if not callable(cfg.service)
                    else "table:" + json.dumps(
                        getattr(cfg.service, "table", {}), sort_keys=True)
                    if getattr(cfg.service, "table", None)
                    else "callable"),
        "n_requests": len(records),
        "bucket_sizes": list(int(b) for b in sorted(cfg.bucket_sizes)),
        "max_wait": float(cfg.max_wait),
        "max_queue": cfg.max_queue,
        "deadline": cfg.deadline,
        "seed": int(cfg.seed),
        "offered_qps": len(records) / span if span > 0 else 0.0,
        "achieved_qps": len(done) / makespan if makespan > 0 else 0.0,
        "p50_ms": float(np.percentile(lat, 50) * 1e3) if lat.size
        else 0.0,
        "p99_ms": float(np.percentile(lat, 99) * 1e3) if lat.size
        else 0.0,
        "mean_wait_ms": float(wait.mean() * 1e3) if wait.size else 0.0,
        "deadline_miss_rate": (float(np.mean([r["miss"] for r in done]))
                               if done and cfg.deadline is not None
                               else 0.0),
        "rejection_rate": rejected / max(len(records), 1),
        "mean_occupancy": (float(np.mean([b["occupancy"]
                                          for b in batches]))
                           if batches else 0.0),
        "mean_batch_rows": (float(np.mean([b["rows"] for b in batches]))
                            if batches else 0.0),
        "n_batches": len(batches),
    }
    return row


# --- QPS sweep ----------------------------------------------------------------

def qps_sweep(cfg: LoadConfig, rates: Sequence[float], engine=None,
              features=None, min_goodput: float = 0.95
              ) -> Tuple[List[Dict], Optional[float]]:
    """Ladder offered Poisson rates over one config; returns (rows,
    max_sustainable_qps).  A rate is *sustainable* when its p99 stays
    under the deadline, nothing is rejected, AND achieved ≥
    ``min_goodput`` × offered — on a finite trace an over-capacity
    rate shows up as a growing backlog (achieved < offered) well
    before the backlog is deep enough to push p99 past the deadline,
    so the throughput criterion is what catches early saturation.
    Max-sustainable is the highest offered rate that passes (None if
    none do)."""
    if cfg.deadline is None:
        raise ValueError("qps_sweep needs cfg.deadline to judge "
                         "sustainability")
    rows, best = [], None
    for rate in rates:
        c = replace(cfg, arrivals=f"poisson:{float(rate):g}")
        row = simulate_load(c, engine=engine, features=features).row
        ok = (row["p99_ms"] <= cfg.deadline * 1e3
              and row["rejection_rate"] == 0.0
              and row["achieved_qps"]
              >= min_goodput * row["offered_qps"])
        row["sustainable"] = bool(ok)
        rows.append(row)
        if ok:
            best = max(best, float(rate)) if best is not None \
                else float(rate)
    return rows, best


def sweep_rates(capacity_qps: float, n: int = 10, lo: float = 0.05,
                hi: float = 1.25) -> List[float]:
    """A geometric offered-rate ladder spanning [lo, hi] × capacity —
    capacity being ``bucket_max / service(bucket_max)`` for the model
    under test."""
    if capacity_qps <= 0 or n < 2:
        raise ValueError(f"bad sweep ladder ({capacity_qps}, {n})")
    return [float(capacity_qps * lo * (hi / lo) ** (k / (n - 1)))
            for k in range(n)]


def save_rows(rows: List[Dict], path: str, meta: Optional[Dict] = None
              ) -> str:
    """Write summary rows (atomic, trailing newline — byte-stable for
    the determinism gate)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"meta": meta or {}, "rows": rows}, f, indent=1,
                  sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path
