"""Exportable model bundles: the train -> serve handoff.

Every federated pipeline in the repo ends in a different artifact — a
parametric pytree (``core/parametric.py``), a ``RandomForest`` of
shipped tree subsets (``core/tree_subset.py``), a per-client
``FeatureExtractEnsemble`` cascade (``core/feature_extract.py``), or a
single global ``GBDT`` (``core/fed_hist.py``).  A :class:`ModelBundle`
packages any of them into one on-disk format the scoring engine
(``repro.serve.engine``) can load without knowing which pipeline
produced it:

* ``arrays`` — a flat ``{name: array}`` pytree, saved with
  ``repro.checkpoint.save_pytree`` (zstd/zlib framing, same bytes
  guarantees as training checkpoints);
* ``meta`` — JSON-safe scalars the arrays can't carry (model kind,
  learning rate, parametric model name, schema version);
* a **self-describing manifest** (``manifest.json``) recording every
  array's dtype and shape, so ``load_bundle`` reconstructs the
  ``load_pytree`` template itself — no caller-supplied template, the
  bundle file is the contract.

Bundle kinds are registry-addressable (``BUNDLE_KINDS``): each kind owns
``pack`` (typed artifact -> bundle) and ``unpack`` (bundle -> typed
artifact), and the engine keys its score functions off the same names.
The four registered kinds mirror the paper's four pipelines:
``parametric``, ``tree_subset``, ``feature_extract``, ``fed_hist``.

On disk a bundle is a directory::

    <path>/manifest.json   # version, kind, meta, array specs
    <path>/arrays.ckpt     # checkpoint.save_pytree of the arrays dict
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Callable, Dict

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.core.feature_extract import FeatureExtractEnsemble
from repro.trees import forest as RF
from repro.trees import gbdt as GB
from repro.trees.growth import Tree

BUNDLE_VERSION = 1
_MANIFEST = "manifest.json"
_ARRAYS = "arrays.ckpt"


@dataclass
class ModelBundle:
    """One exported model: kind + JSON-safe meta + flat array dict."""
    kind: str
    meta: Dict
    arrays: Dict[str, jnp.ndarray]
    version: int = BUNDLE_VERSION

    def model(self):
        """Reconstruct the typed training-side artifact."""
        return get_kind(self.kind).unpack(self)


@dataclass(frozen=True)
class BundleKind:
    name: str
    pack: Callable          # (model, **meta) -> ModelBundle
    unpack: Callable        # (bundle) -> model


def _tree_arrays(prefix: str, tree: Tree) -> Dict[str, jnp.ndarray]:
    return {f"{prefix}.feature": tree.feature,
            f"{prefix}.threshold": tree.threshold,
            f"{prefix}.leaf": tree.leaf,
            f"{prefix}.gain": tree.gain}


def _tree_from(arrays: Dict, prefix: str) -> Tree:
    return Tree(arrays[f"{prefix}.feature"], arrays[f"{prefix}.threshold"],
                arrays[f"{prefix}.leaf"], arrays[f"{prefix}.gain"])


# --- parametric (LR / poly-SVM / MLP pytrees) --------------------------------

def _pack_parametric(params, *, model: str) -> ModelBundle:
    arrays = {f"params.{k}": jnp.asarray(v) for k, v in params.items()}
    return ModelBundle("parametric", {"model": model}, arrays)


def _unpack_parametric(b: ModelBundle):
    return {k.split(".", 1)[1]: v for k, v in b.arrays.items()
            if k.startswith("params.")}


# --- tree_subset (union Random Forest, majority vote) ------------------------

def _pack_tree_subset(model: RF.RandomForest, *, edges=None) -> ModelBundle:
    arrays = _tree_arrays("forest", model.forest)
    if edges is not None:
        arrays["edges"] = jnp.asarray(edges)
    return ModelBundle("tree_subset", {}, arrays)


def _unpack_tree_subset(b: ModelBundle) -> RF.RandomForest:
    return RF.RandomForest(_tree_from(b.arrays, "forest"))


# --- fed_hist (one global GBDT: margins + base + learning rate) --------------

def _pack_fed_hist(model: GB.GBDT, *, edges=None) -> ModelBundle:
    arrays = _tree_arrays("forest", model.forest)
    if edges is not None:
        arrays["edges"] = jnp.asarray(edges)
    meta = {"learning_rate": float(model.learning_rate),
            "base_margin": float(model.base_margin)}
    return ModelBundle("fed_hist", meta, arrays)


def _unpack_fed_hist(b: ModelBundle) -> GB.GBDT:
    return GB.GBDT(_tree_from(b.arrays, "forest"),
                   b.meta["learning_rate"], b.meta["base_margin"])


# --- feature_extract (per-client shallow GBDT cascade, weighted vote) --------

def _pack_feature_extract(ens: FeatureExtractEnsemble) -> ModelBundle:
    # every client ships the same (rounds, depth) shallow ensemble, so
    # the C forests stack onto a leading client axis
    stacked = Tree(*(jnp.stack([getattr(m.forest, f) for m in ens.trees])
                     for f in Tree._fields))
    arrays = _tree_arrays("forests", stacked)
    arrays["weights"] = jnp.asarray(ens.weights, jnp.float32)
    arrays["base_margins"] = jnp.asarray(ens.base_margins, jnp.float32)
    arrays["top_features"] = jnp.asarray(
        np.stack([np.asarray(t, np.int32) for t in ens.top_features]))
    meta = {"learning_rate": float(ens.trees[0].learning_rate),
            "n_clients": len(ens.trees)}
    return ModelBundle("feature_extract", meta, arrays)


def _unpack_feature_extract(b: ModelBundle) -> FeatureExtractEnsemble:
    stacked = _tree_from(b.arrays, "forests")
    lr = b.meta["learning_rate"]
    margins = np.asarray(b.arrays["base_margins"])
    trees = [GB.GBDT(Tree(*(a[c] for a in stacked)), lr, float(margins[c]))
             for c in range(b.meta["n_clients"])]
    return FeatureExtractEnsemble(
        trees, [float(w) for w in np.asarray(b.arrays["weights"])],
        [float(m) for m in margins],
        [np.asarray(t) for t in np.asarray(b.arrays["top_features"])])


BUNDLE_KINDS: Dict[str, BundleKind] = {
    "parametric": BundleKind("parametric", _pack_parametric,
                             _unpack_parametric),
    "tree_subset": BundleKind("tree_subset", _pack_tree_subset,
                              _unpack_tree_subset),
    "feature_extract": BundleKind("feature_extract", _pack_feature_extract,
                                  _unpack_feature_extract),
    "fed_hist": BundleKind("fed_hist", _pack_fed_hist, _unpack_fed_hist),
}


def get_kind(name: str) -> BundleKind:
    if name not in BUNDLE_KINDS:
        raise KeyError(f"unknown bundle kind {name!r}; "
                       f"registered: {sorted(BUNDLE_KINDS)}")
    return BUNDLE_KINDS[name]


def pack(kind: str, artifact, **meta) -> ModelBundle:
    """Package a trained artifact under a registered kind."""
    return get_kind(kind).pack(artifact, **meta)


def save_bundle(path: str, bundle: ModelBundle) -> int:
    """Write ``<path>/manifest.json`` + ``<path>/arrays.ckpt``.

    Returns the compressed checkpoint size in bytes."""
    os.makedirs(path, exist_ok=True)
    arrays = {k: jnp.asarray(v) for k, v in bundle.arrays.items()}
    manifest = {
        "version": bundle.version,
        "kind": bundle.kind,
        "meta": bundle.meta,
        "arrays": {k: {"dtype": str(np.asarray(v).dtype),
                       "shape": list(np.asarray(v).shape)}
                   for k, v in arrays.items()},
    }
    with open(os.path.join(path, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return save_pytree(os.path.join(path, _ARRAYS), arrays)


def load_bundle(path: str) -> ModelBundle:
    """Load a bundle with no caller-supplied template: the manifest's
    dtype/shape specs build the ``load_pytree`` template, and the
    checkpoint layer still validates structure + shapes against it."""
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    if manifest["version"] != BUNDLE_VERSION:
        raise ValueError(
            f"{path}: bundle version {manifest['version']} != "
            f"supported {BUNDLE_VERSION}")
    if manifest["kind"] not in BUNDLE_KINDS:
        raise KeyError(f"{path}: unknown bundle kind "
                       f"{manifest['kind']!r}")
    template = {k: np.zeros(s["shape"], dtype=s["dtype"])
                for k, s in manifest["arrays"].items()}
    arrays = load_pytree(os.path.join(path, _ARRAYS), template)
    return ModelBundle(manifest["kind"], manifest["meta"], arrays,
                       manifest["version"])
