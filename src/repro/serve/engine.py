"""Bucketed batch scoring engine over exported model bundles.

The serving half of the repro (ROADMAP north star: serve heavy traffic
as fast as the hardware allows).  Three pieces:

* **Score functions** — one per bundle kind, composing the paper's model
  zoo: parametric LR / poly-SVM / MLP probabilities, Random Forest vote
  averaging (``tree_subset``; thresholding the vote fraction reproduces
  the paper's majority vote), global-GBDT margins (``fed_hist``), and
  the feature-extract cascade (per-client XGBoost frontends -> weighted
  sigmoid vote).  All tree kinds run through the Pallas forest-inference
  kernel (``repro.kernels.forest_infer``) instead of the per-level
  training-side traversal loop.
* **Padding-bucket microbatching** — request batches are padded up to
  the smallest configured bucket size, so XLA compiles exactly one
  program per bucket shape and every later call of that shape replays
  it.  Traversal and scoring are row-independent, so pad rows are
  sliced off unseen.
* **Platt-scaling calibration** — a 2-parameter sigmoid fit on held-out
  data (Newton iterations on the log-loss) mapping raw ensemble scores
  to calibrated probabilities; strictly monotone for a > 0, so ranking
  metrics (ROC-AUC) are invariant under it.

Two optional fast paths (ROADMAP item 3's fusion targets):

* ``fused=True`` routes the single-forest kinds (``tree_subset``,
  ``fed_hist``) through the fused Pallas scorer
  (``repro.kernels.forest_infer.fused.forest_score``): traversal,
  ensemble weighting, and Platt calibration in one kernel call — the
  (T, n) per-tree leaf matrix is never materialized and calibration runs
  in-graph (f32) instead of as a numpy post-pass.  Parity with the
  unfused composition: vote counts are exact; probabilities agree within
  **1e-6** (tree-sequential vs pairwise summation, f32 vs float64
  Platt) — gated in ``benchmarks/serve_bench.py --smoke``.
* ``quantize="int8_sr"`` stores every forest's leaf table as int8 +
  scale via the unbiased stochastic-rounding codec
  (``repro.core.compression.int8_sr_quantize``) and dequantizes inside
  the jitted scorer — memory-bound batches read 1 byte/leaf instead
  of 4.  Thresholds stay f32, so tree *routing* is unchanged and the
  output error is analytically bounded: per tree, one leaf step
  (``amax/127``); e.g. fed_hist margins shift by at most
  ``lr * rounds * step`` (probabilities by a quarter of that — sigmoid
  is 1/4-Lipschitz), and votes flip only where ``|leaf| < step``.  The
  serve_bench smoke gate asserts these bounds.  Parametric bundles are
  unaffected (no leaf table).

An engine scores one bundle or an ensemble of bundles (weighted mean of
per-bundle probabilities) and keeps per-call latency stats for the
serving benchmarks (``launch/serve_fed.py``, ``benchmarks/serve_bench``).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import int8_sr_quantize
from repro.kernels.forest_infer.fused import forest_score
from repro.kernels.forest_infer.ops import forest_infer
from repro.models import tabular
from repro.obs import current as _ambient_tracer
from repro.serve.bundle import ModelBundle
from repro.trees.growth import Tree

QUANTIZE_MODES = (None, "int8_sr")


def _forest_maker(forest: Tree, quantize: Optional[str]):
    """Nullary forest constructor for use inside a jitted scorer.

    With ``quantize="int8_sr"`` the leaf table is held as int8 + f32
    scale (the wire codec's arithmetic, seed 0) and dequantized in-graph;
    features/thresholds stay untouched so routing is bit-identical."""
    if quantize is None:
        return lambda: forest
    if quantize not in QUANTIZE_MODES:
        raise ValueError(f"unknown quantize mode {quantize!r}; "
                         f"available: {QUANTIZE_MODES}")
    q, scale = int8_sr_quantize(jnp.asarray(forest.leaf, jnp.float32),
                                jax.random.PRNGKey(0))
    return lambda: forest._replace(leaf=q.astype(jnp.float32) * scale)


def leaf_quant_step(forest: Tree) -> float:
    """The int8 quantization step of a forest's leaf table
    (``amax/127``) — the per-tree output error bound of the int8_sr
    scoring path."""
    return float(jnp.maximum(jnp.max(jnp.abs(
        jnp.asarray(forest.leaf, jnp.float32))), 1e-12) / 127.0)


# --- per-kind score functions (x (n, F) raw -> probs (n,)) -------------------

def _parametric_scorer(bundle: ModelBundle, impl: str, quantize=None):
    params = bundle.model()
    spec = tabular.MODELS[bundle.meta["model"]]

    def score(x):
        if spec["needs_poly"]:
            pairs, triples = tabular.poly3_indices(x.shape[1])
            x = tabular.poly3_features(x, pairs, triples)
        return spec["proba"](params, x)
    return score


def _tree_subset_scorer(bundle: ModelBundle, impl: str, quantize=None):
    make = _forest_maker(bundle.model().forest, quantize)

    def score(x):
        vals = forest_infer(make(), x, impl=impl) + 0.5  # (k, n) p(y=1)
        # vote averaging: fraction of trees voting positive, so that
        # thresholding at 0.5 reproduces the paper's majority-vote
        # aggregation (forest.predict_votes) exactly
        return jnp.mean((vals > 0.5).astype(jnp.float32), axis=0)
    return score


def _fed_hist_scorer(bundle: ModelBundle, impl: str, quantize=None):
    model = bundle.model()
    make = _forest_maker(model.forest, quantize)

    def score(x):
        vals = forest_infer(make(), x, impl=impl)  # (rounds, n)
        margin = model.base_margin \
            + model.learning_rate * jnp.sum(vals, axis=0)
        return jax.nn.sigmoid(margin)
    return score


def _feature_extract_scorer(bundle: ModelBundle, impl: str, quantize=None):
    stacked = Tree(*(bundle.arrays[f"forests.{f}"] for f in Tree._fields))
    C, R = stacked.feature.shape[:2]
    flat = Tree(*(a.reshape((C * R,) + a.shape[2:]) for a in stacked))
    make = _forest_maker(flat, quantize)
    w = jnp.asarray(bundle.arrays["weights"], jnp.float32)
    base = jnp.asarray(bundle.arrays["base_margins"], jnp.float32)
    lr = bundle.meta["learning_rate"]

    def score(x):
        vals = forest_infer(make(), x, impl=impl)      # (C*R, n)
        margins = base[:, None] \
            + lr * jnp.sum(vals.reshape(C, R, -1), axis=1)
        return jnp.sum(w[:, None] * jax.nn.sigmoid(margins), axis=0)
    return score


def _fused_prob_fn(bundle: ModelBundle, impl: str, quantize=None):
    """Fused (x, platt) -> probs fn for single-forest kinds, else None.

    ``platt`` is the (3,) [a, b, enabled] triple threaded as a traced
    argument so calibrating never recompiles."""
    if bundle.kind == "tree_subset":
        make = _forest_maker(bundle.model().forest, quantize)
        return lambda x, platt: forest_score(make(), x, mode="vote",
                                             platt=platt, impl=impl)
    if bundle.kind == "fed_hist":
        model = bundle.model()
        make = _forest_maker(model.forest, quantize)
        lr = float(model.learning_rate)
        base = float(model.base_margin)
        return lambda x, platt: forest_score(make(), x, mode="margin",
                                             lr=lr, base=base,
                                             platt=platt, impl=impl)
    return None


SCORERS = {
    "parametric": _parametric_scorer,
    "tree_subset": _tree_subset_scorer,
    "fed_hist": _fed_hist_scorer,
    "feature_extract": _feature_extract_scorer,
}


# --- Platt scaling ------------------------------------------------------------

def fit_platt(scores, y, *, iters: int = 50,
              ridge: float = 1e-6) -> Tuple[float, float]:
    """Fit p = sigmoid(a*s + b) on held-out (score, label) pairs.

    Newton iterations on the binary log-loss; the 2x2 Hessian is solved
    in closed form.  Returns (a, b); a > 0 whenever higher scores mean
    higher positive rate, which makes the calibration map strictly
    monotone (rank metrics unchanged)."""
    s = np.asarray(scores, np.float64)
    yv = np.asarray(y, np.float64)
    a, b = 1.0, 0.0
    for _ in range(iters):
        p = 1.0 / (1.0 + np.exp(-(a * s + b)))
        g = p - yv
        ga, gb = float(np.sum(g * s)), float(np.sum(g))
        w = np.maximum(p * (1.0 - p), 1e-12)
        haa = float(np.sum(w * s * s)) + ridge
        hab = float(np.sum(w * s))
        hbb = float(np.sum(w)) + ridge
        det = haa * hbb - hab * hab
        da = (hbb * ga - hab * gb) / det
        db = (haa * gb - hab * ga) / det
        a, b = a - da, b - db
        if abs(da) + abs(db) < 1e-10:
            break
    return float(a), float(b)


def apply_platt(scores, ab: Tuple[float, float]):
    a, b = ab
    return 1.0 / (1.0 + np.exp(-(a * np.asarray(scores, np.float64) + b)))


# --- the engine ---------------------------------------------------------------

class ScoringEngine:
    """Ensemble scorer with padding-bucket microbatching.

    Args:
      bundles: one ``ModelBundle`` or a sequence (ensemble: weighted
        mean of per-bundle probabilities).
      weights: per-bundle ensemble weights (default uniform); normalized.
      bucket_sizes: ascending padding buckets.  A request batch of n
        rows is cut into chunks of at most ``max(bucket_sizes)`` rows
        and each chunk is zero-padded up to the smallest bucket that
        fits, so only ``len(bucket_sizes)`` distinct shapes ever reach
        the jitted scorer (one XLA compile per bucket).
      impl: forest-inference kernel routing (``auto`` | ``pallas`` |
        ``pallas_interpret`` | ``xla`` — see
        ``repro.kernels.forest_infer.ops``).
      fused: route single-forest kinds through the fused Pallas scorer
        (one kernel call: traversal + weighting + Platt; see module
        docstring for the 1e-6 parity contract).  Kinds without a fused
        kernel (parametric, feature_extract) fall back to their
        composed scorer inside the same jit.
      quantize: None | ``int8_sr`` — hold forest leaf tables as int8 +
        scale (stochastic-rounding codec), dequantized in-graph
        (documented error bound in the module docstring).
    """

    def __init__(self, bundles, weights: Optional[Sequence[float]] = None,
                 bucket_sizes: Sequence[int] = (64, 256, 1024),
                 impl: str = "auto", fused: bool = False,
                 quantize: Optional[str] = None, tracer=None):
        if isinstance(bundles, ModelBundle):
            bundles = [bundles]
        if not bundles:
            raise ValueError("ScoringEngine needs at least one bundle")
        if quantize not in QUANTIZE_MODES:
            raise ValueError(f"unknown quantize mode {quantize!r}; "
                             f"available: {QUANTIZE_MODES}")
        self.bundles: List[ModelBundle] = list(bundles)
        w = np.asarray(weights if weights is not None
                       else np.ones(len(self.bundles)), np.float32)
        self.weights = w / w.sum()
        self.buckets = tuple(sorted(int(b) for b in bucket_sizes))
        if not self.buckets or min(self.buckets) < 1:
            raise ValueError(f"bad bucket_sizes {bucket_sizes!r}")
        self.calibration: Optional[Tuple[float, float]] = None
        self.latencies_s: List[float] = []
        self.rows_scored = 0
        self.bucket_calls: Dict[int, int] = {}
        self.fused = bool(fused)
        self.quantize = quantize
        # None resolves to the ambient repro.obs tracer (falsy
        # NULL_TRACER unless a run installed one); score() records
        # wall-clock spans only when it is truthy
        self.tracer = _ambient_tracer() if tracer is None else tracer
        wj = jnp.asarray(self.weights)

        if self.fused:
            fns = []
            for b in self.bundles:
                f = _fused_prob_fn(b, impl, quantize)
                if f is None:           # no fused kernel for this kind
                    s = SCORERS[b.kind](b, impl, quantize)
                    f = None, s
                fns.append(f)
            if len(fns) == 1 and not isinstance(fns[0], tuple):
                # single fused bundle: Platt folds into the kernel call
                ensemble = fns[0]
            else:
                def ensemble(x, platt):
                    probs = jnp.stack(
                        [f[1](x) if isinstance(f, tuple) else f(x, None)
                         for f in fns])
                    s = jnp.sum(wj[:, None] * probs, axis=0)
                    cal = 1.0 / (1.0 + jnp.exp(-(platt[0] * s
                                                 + platt[1])))
                    return jnp.where(platt[2] > 0, cal, s)
            self._jit_score = jax.jit(ensemble)
        else:
            scorers = [SCORERS[b.kind](b, impl, quantize)
                       for b in self.bundles]

            def ensemble(x):
                probs = jnp.stack([s(x) for s in scorers])  # (models, n)
                return jnp.sum(wj[:, None] * probs, axis=0)

            self._jit_score = jax.jit(ensemble)

    def _platt_vec(self) -> jnp.ndarray:
        """(3,) [a, b, enabled] f32 — the fused path's traced Platt arg."""
        a, b = self.calibration if self.calibration is not None \
            else (0.0, 0.0)
        return jnp.asarray(
            [a, b, 1.0 if self.calibration is not None else 0.0],
            jnp.float32)

    # -- bucketing ------------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _score_chunk(self, chunk) -> np.ndarray:
        """One jit call; the fused path threads the Platt triple (its
        calibration runs in-graph, the composed path applies it in
        numpy afterwards)."""
        if self.fused:
            return np.asarray(self._jit_score(jnp.asarray(chunk),
                                              self._platt_vec()))
        return np.asarray(self._jit_score(jnp.asarray(chunk)))

    def score_unbatched(self, x) -> np.ndarray:
        """Raw ensemble probabilities with no bucketing/padding — the
        parity reference for the bucketed path (and the calibration
        input)."""
        probs = self._score_chunk(jnp.asarray(x, jnp.float32))
        if self.fused:
            return probs
        return (apply_platt(probs, self.calibration).astype(np.float32)
                if self.calibration is not None else probs)

    def score(self, x) -> np.ndarray:
        """Bucketed scoring: chunk, pad to bucket, jit-replay, unpad.

        Row-independent models make padding invisible; the timed span
        (one entry in ``latencies_s`` per call) covers the full
        request — chunking, device work, and calibration."""
        x = np.asarray(x, np.float32)
        n = len(x)
        out = np.empty((n,), np.float32)
        t0 = time.perf_counter()
        step = self.buckets[-1]
        for i in range(0, n, step):
            chunk = x[i:i + step]
            bucket = self._bucket_for(len(chunk))
            pad = bucket - len(chunk)
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            self.bucket_calls[bucket] = self.bucket_calls.get(bucket,
                                                              0) + 1
            probs = self._score_chunk(chunk)
            out[i:i + bucket - pad] = probs[:bucket - pad]
        if self.calibration is not None and not self.fused:
            out = apply_platt(out, self.calibration).astype(np.float32)
        t1 = time.perf_counter()
        self.latencies_s.append(t1 - t0)
        self.rows_scored += n
        tr = self.tracer
        if tr:
            tr.span_at("engine.score", t0, t1, track="engine", rows=n)
            tr.metrics.observe("score_s", t1 - t0)
        return out

    def predict(self, x, threshold: float = 0.5) -> np.ndarray:
        return self.score(x) > threshold

    # -- calibration ----------------------------------------------------------

    def calibrate(self, x_held, y_held) -> Tuple[float, float]:
        """Fit Platt scaling on held-out data; subsequent ``score``
        calls return calibrated probabilities."""
        raw = self.score_unbatched(np.asarray(x_held, np.float32))
        self.calibration = fit_platt(raw, y_held)
        return self.calibration

    # -- serving stats --------------------------------------------------------

    def warmup(self, n_features: int) -> None:
        """Compile every bucket shape up front (not counted in stats)."""
        for b in self.buckets:
            self._score_chunk(jnp.zeros((b, n_features), jnp.float32))

    def stats(self) -> Dict:
        """Throughput + latency percentiles over recorded score()
        calls, plus per-bucket call counts (which padding buckets the
        load actually hits).  Guarded for the empty window and for a
        zero recorded duration (coarse clocks / zero-row calls):
        ``rows_per_s`` is 0.0, never a division error or inf."""
        lat = np.asarray(self.latencies_s, np.float64)
        if lat.size == 0:
            return {"calls": 0, "rows": 0, "rows_per_s": 0.0,
                    "p50_ms": 0.0, "p99_ms": 0.0, "bucket_calls": {}}
        total = float(lat.sum())
        return {
            "calls": int(lat.size),
            "rows": int(self.rows_scored),
            "rows_per_s": (self.rows_scored / total if total > 0.0
                           else 0.0),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "bucket_calls": dict(self.bucket_calls),
        }

    def reset_stats(self) -> None:
        self.latencies_s = []
        self.rows_scored = 0
        self.bucket_calls = {}
