from repro.serve.bundle import (BUNDLE_KINDS, ModelBundle, load_bundle,  # noqa: F401
                                pack, save_bundle)
from repro.serve.engine import ScoringEngine, fit_platt  # noqa: F401
