from repro.serve.bundle import (BUNDLE_KINDS, ModelBundle, load_bundle,  # noqa: F401
                                pack, save_bundle)
from repro.serve.engine import ScoringEngine, fit_platt  # noqa: F401
from repro.serve.load import (ARRIVALS, SERVICE, LoadConfig,  # noqa: F401
                              calibrate_service, get_arrivals,
                              get_service, qps_sweep, simulate_load)
