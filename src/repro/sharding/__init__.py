from repro.sharding.rules import (  # noqa: F401
    ShardingCtx,
    Rules,
    TRAIN_RULES,
    DECODE_RULES,
    LONG_DECODE_RULES,
    FED_RULES,
)
