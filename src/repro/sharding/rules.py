"""Logical-axis sharding rules with divisibility-aware assignment.

Parameters and activations are annotated with *logical* axis names
('batch', 'heads', 'mlp', ...).  A ``Rules`` table maps logical names to
mesh axes; assignment degrades gracefully:

  1. exact divisibility -> use the mapped mesh axis (or axis tuple),
  2. dim >= mesh-axis size -> still shard (GSPMD pads uneven shards),
  3. dim <  mesh-axis size -> replicate (sharding would idle devices).

``ShardingCtx`` threads the mesh + rules through model code; the null
context (CPU smoke tests, single device) turns every annotation into a
no-op so the same model code runs everywhere.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisTarget = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, AxisTarget]

# --- rule tables -----------------------------------------------------------
# train/prefill: batch data-parallel, TP over heads/mlp/vocab, expert
# parallel over 'data', Megatron-SP style sequence sharding of boundary
# activations over 'model', FSDP weight sharding over 'data'.
TRAIN_RULES: Rules = {
    "batch": "data",
    "act_seq": "model",        # residual-stream seq at layer boundaries
    "embed": None,             # d_model dim of activations
    "heads": "model",
    "kv_heads": None,          # GQA kv heads replicated (see DESIGN.md)
    "head_dim": None,
    "mlp": "model",
    "experts": "data",         # expert parallelism
    "vocab": "model",
    "fsdp": "data",            # extra weight-shard dim for train
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "enc_seq": None,
    "cache_seq": None,         # no kv cache in train
    "frontend": None,
}

# decode_32k: batch over data, kv-cache sequence over model.
DECODE_RULES: Rules = dict(
    TRAIN_RULES,
    batch="data",
    act_seq=None,              # decode seq is length 1
    cache_seq="model",
    fsdp="data",               # weights stay 2-D sharded for serving memory
)

# long_500k: global_batch=1 -> cache sequence sharded over the full mesh.
LONG_DECODE_RULES: Rules = dict(
    DECODE_RULES,
    batch=None,
    cache_seq=("data", "model"),
)

# fed: the federated simulation's only sharded dimension is the leading
# (n_clients, ...) client axis of stacked per-client pytrees — a *data*
# axis (clients are independent rows of the simulation), mapped onto the
# 1-D 'clients' mesh from repro.launch.mesh.  Deliberately NOT derived
# from TRAIN_RULES: the LM table's fsdp/model/heads mappings are
# nonsensical for stacked tabular client shards (a 'clients'-sized mesh
# has no 'model' axis, and fsdp-sharding 16-float logreg params would
# only replicate anyway, but a larger mesh with reused axis names would
# silently shard the wrong dims).  Every logical name other than
# 'clients' replicates.
FED_RULES: Rules = {
    "clients": "clients",
}


def rules_for_phase(phase: str, shape_name: str = "") -> Rules:
    if phase == "decode":
        return LONG_DECODE_RULES if shape_name == "long_500k" else DECODE_RULES
    if phase == "fed":
        return FED_RULES
    return TRAIN_RULES


def _axis_size(mesh: Mesh, target: AxisTarget) -> int:
    if target is None:
        return 1
    if isinstance(target, str):
        return mesh.shape[target]
    n = 1
    for t in target:
        n *= mesh.shape[t]
    return n


@dataclass
class ShardingCtx:
    """Mesh + rules carrier for model code. ``null()`` disables everything."""
    mesh: Optional[Mesh] = None
    rules: Rules = field(default_factory=lambda: dict(TRAIN_RULES))
    # logical names disabled at runtime (e.g. fsdp off for some perf configs)
    disabled: Tuple[str, ...] = ()

    @staticmethod
    def null() -> "ShardingCtx":
        return ShardingCtx(mesh=None)

    @property
    def active(self) -> bool:
        return self.mesh is not None and self.mesh.size > 1

    def axis_size(self, mesh_axis: str) -> int:
        if not self.active or mesh_axis not in self.mesh.shape:
            return 1
        return self.mesh.shape[mesh_axis]

    def _resolve_dim(self, name: Optional[str], dim: int) -> AxisTarget:
        if name is None or name in self.disabled:
            return None
        target = self.rules.get(name)
        if target is None:
            return None
        size = _axis_size(self.mesh, target)
        if size <= 1:
            return None
        # jit argument shardings must divide evenly (GSPMD padding is not
        # allowed for inputs) -> degrade to divisible sub-targets, else
        # replicate. (Vocab/head padding to a shardable multiple is a §Perf
        # lever, not the baseline.)
        if dim % size == 0:
            return target
        if isinstance(target, tuple):
            for k in range(len(target) - 1, 0, -1):
                sub = target[:k]
                s = _axis_size(self.mesh, sub)
                if s > 1 and dim % s == 0:
                    return sub if len(sub) > 1 else sub[0]
        return None

    def spec(self, names: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
        """PartitionSpec for logical axis names given concrete dims."""
        if not self.active:
            return P()
        assert len(names) == len(shape), (names, shape)
        used = set()
        parts = []
        for name, dim in zip(names, shape):
            tgt = self._resolve_dim(name, dim)
            # a mesh axis may appear only once in a spec
            flat = (tgt,) if isinstance(tgt, str) else (tgt or ())
            if tgt is not None and any(t in used for t in flat):
                tgt = None
            else:
                used.update(flat)
            parts.append(tgt)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding(self, names: Sequence[Optional[str]],
                 shape: Sequence[int]) -> Optional[NamedSharding]:
        if not self.active:
            return None
        return NamedSharding(self.mesh, self.spec(names, shape))

    def constrain(self, x, *names: Optional[str]):
        """with_sharding_constraint by logical names; no-op for null ctx."""
        if not self.active:
            return x
        spec = self.spec(list(names), x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))
