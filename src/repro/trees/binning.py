"""Quantile binning for histogram-based tree training.

Two binning regimes coexist:

* **Local bins** (``fit_bins``): edges computed per client on local data;
  learned split thresholds are stored as *raw feature values* so trees
  transfer across clients/servers without sharing the bin edges (required
  by the paper's tree-shipping protocols C2/C3).
* **Federated bins** (``quantile_sketch`` / ``merge_sketches`` /
  ``fed_fit_bins``): clients ship fixed-size per-feature quantile
  sketches, the server merges them (count-weighted) into one shared
  ``edges`` array and broadcasts it back.  Identical bins on every client
  are the prerequisite for exact histogram aggregation (``fed_hist``):
  with shared edges, the sum of per-client grad/hess histograms equals
  the histogram of the union of shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fit_bins(x, n_bins: int):
    """x (n, F) -> edges (F, n_bins-1), ascending per feature."""
    qs = jnp.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    edges = jnp.quantile(x, qs, axis=0).T  # (F, n_bins-1)
    return edges


def apply_bins(x, edges):
    """x (n, F), edges (F, n_bins-1) -> bins (n, F) int32 in [0, n_bins)."""
    def per_feature(col, e):
        return jnp.searchsorted(e, col, side="left").astype(jnp.int32)
    return jax.vmap(per_feature, in_axes=(1, 0), out_axes=1)(x, edges)


def edge_value(edges, feature, bin_idx):
    """Raw threshold for 'bin <= bin_idx': the upper edge of bin_idx.

    edges (F, n_bins-1); returns edges[feature, bin_idx] (clamped)."""
    nb1 = edges.shape[1]
    idx = jnp.clip(bin_idx, 0, nb1 - 1)
    return edges[feature, idx]


# --- federated binning (shared edges via merged quantile sketches) -----------

def quantile_sketch(x, sketch_size: int = 128):
    """Client-side: per-feature quantile summary.

    x (n, F) -> (values (F, m), n) with m = ``sketch_size`` evenly spaced
    local quantiles per feature.  The sketch (not raw rows) is the only
    thing shipped to the server; its wire size is ``sketch_bytes``.
    """
    qs = jnp.linspace(0.0, 1.0, sketch_size)
    vals = jnp.quantile(x, qs, axis=0).T  # (F, m)
    return vals, int(x.shape[0])


def sketch_bytes(sketch) -> int:
    """Bytes-on-wire for one client sketch (values + the sample count)."""
    vals, _ = sketch
    return int(vals.size * vals.dtype.itemsize) + 4


def merge_sketches(sketches, n_bins: int):
    """Server-side: merge client sketches into shared edges (F, n_bins-1).

    Each client's m sketch points are treated as weighted samples with
    weight n_i/m, so larger shards pull the merged quantiles harder; the
    merged edges converge to the centralized quantiles of the union as
    sketch_size grows (tested against ``fit_bins`` on the union).
    """
    vals = jnp.stack([s[0] for s in sketches])                 # (C, F, m)
    counts = jnp.asarray([float(s[1]) for s in sketches])
    C, F, m = vals.shape
    w = jnp.repeat(counts / m, m)                              # (C*m,)
    v = vals.transpose(1, 0, 2).reshape(F, C * m)
    order = jnp.argsort(v, axis=1)
    sv = jnp.take_along_axis(v, order, axis=1)
    sw = w[order]                                              # (F, C*m)
    cw = jnp.cumsum(sw, axis=1)
    # midpoint rule: point k sits at cumulative-weight fraction
    # (cw_k - w_k/2) / total; interpolate edge levels between points
    frac = (cw - sw / 2) / cw[:, -1:]
    qs = jnp.linspace(0.0, 1.0, n_bins + 1)[1:-1]              # (n_bins-1,)
    return jax.vmap(lambda fr, svf: jnp.interp(qs, fr, svf))(frac, sv)


def fed_fit_bins(client_xs, n_bins: int, *, sketch_size: int = 128,
                 comm=None, round_idx: int = 0):
    """One federated-binning round: sketches up, shared edges down.

    client_xs: sequence of (n_i, F) arrays.  When ``comm`` (a
    ``repro.core.comm.CommLog``) is given, the exact sketch bytes (up)
    and edge bytes (down) are logged per client — shared binning is a
    communication round and is accounted like one.

    Returns edges (F, n_bins-1) shared by every client.
    """
    sketches = [quantile_sketch(jnp.asarray(x), sketch_size)
                for x in client_xs]
    edges = merge_sketches(sketches, n_bins)
    if comm is not None:
        down = int(edges.size * edges.dtype.itemsize)
        for i, s in enumerate(sketches):
            comm.log(round_idx, f"c{i}", "up", sketch_bytes(s),
                     "quantile-sketch")
            comm.log(round_idx, f"c{i}", "down", down, "shared-edges")
    return edges
