"""Quantile binning for histogram-based tree training.

Bin edges are computed per client on local data; learned split thresholds
are stored as *raw feature values* so trees transfer across clients/servers
without sharing the bin edges (required by the paper's tree-shipping
protocols C2/C3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fit_bins(x, n_bins: int):
    """x (n, F) -> edges (F, n_bins-1), ascending per feature."""
    qs = jnp.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    edges = jnp.quantile(x, qs, axis=0).T  # (F, n_bins-1)
    return edges


def apply_bins(x, edges):
    """x (n, F), edges (F, n_bins-1) -> bins (n, F) int32 in [0, n_bins)."""
    def per_feature(col, e):
        return jnp.searchsorted(e, col, side="left").astype(jnp.int32)
    return jax.vmap(per_feature, in_axes=(1, 0), out_axes=1)(x, edges)


def edge_value(edges, feature, bin_idx):
    """Raw threshold for 'bin <= bin_idx': the upper edge of bin_idx.

    edges (F, n_bins-1); returns edges[feature, bin_idx] (clamped)."""
    nb1 = edges.shape[1]
    idx = jnp.clip(bin_idx, 0, nb1 - 1)
    return edges[feature, idx]
