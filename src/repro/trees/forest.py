"""Random Forest (bagged histogram trees, vmapped growth) in pure JAX.

Trees are regression trees on y - 0.5 (variance-reduction splits, leaf =
class-probability offset); per-tree feature subsampling of ~sqrt(F)
features. Majority vote across trees matches the paper's
f_global(x) = mode(union of trees).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.trees import binning
from repro.trees.growth import Tree, grow_tree, predict_forest


class RandomForest(NamedTuple):
    forest: Tree  # stacked (k, ...)


def bootstrap_masks(rng, num_trees: int, n: int, F: int,
                    feature_frac: float = 0.0):
    """Per-tree bootstrap weights and feature masks.

    Returns (sample_w (num_trees, n), feat_mask (num_trees, F)).  Split
    out of ``fit`` so the client-batched engine (``fit_batched``) can
    draw the *identical* randomness per client before padding — the
    sequential/batched parity contract depends on it.
    """
    k_boot, k_feat = jax.random.split(rng)
    # bootstrap multiplicities ~ Binomial(n, 1/n) ≈ multinomial counts
    idx = jax.random.randint(k_boot, (num_trees, n), 0, n)
    sample_w = jax.vmap(
        lambda ii: jnp.bincount(ii, length=n).astype(jnp.float32))(idx)
    n_feat = max(int(feature_frac * F) if feature_frac else int(F ** 0.5), 1)
    scores = jax.random.uniform(k_feat, (num_trees, F))
    thresh = jnp.sort(scores, axis=1)[:, n_feat - 1:n_feat]
    feat_mask = (scores <= thresh).astype(jnp.float32)
    return sample_w, feat_mask


def fit(x, y, *, num_trees: int = 100, depth: int = 8, n_bins: int = 64,
        lam: float = 1.0, rng=None, feature_frac: float = 0.0,
        hist_impl: str = "auto") -> RandomForest:
    """x (n,F) fp32, y (n,) {0,1}. feature_frac=0 -> sqrt(F)/F."""
    n, F = x.shape
    if rng is None:
        rng = jax.random.PRNGKey(0)
    edges = binning.fit_bins(x, n_bins)
    bins = binning.apply_bins(x, edges)
    grad = 0.5 - y.astype(jnp.float32)   # leaf value = mean(y) - 0.5
    hess = jnp.ones((n,), jnp.float32)
    sample_w, feat_mask = bootstrap_masks(rng, num_trees, n, F,
                                          feature_frac)
    grown = jax.vmap(
        lambda w, fm: grow_tree(bins, edges, grad, hess, w, depth=depth,
                                n_bins=n_bins, lam=lam, feature_mask=fm,
                                hist_impl=hist_impl))(sample_w, feat_mask)
    return RandomForest(grown)


def fit_batched(bins, edges, y, sample_w, feat_mask, *, depth: int = 8,
                n_bins: int = 64, lam: float = 1.0,
                hist_impl: str = "auto"):
    """Client-batched bagging: C clients' forests grown in one call.

    bins (C, n, F) pre-binned shards padded to a common n; edges
    (C, F, n_bins-1) per-client; y (C, n); sample_w (C, T, n) bootstrap
    weights with 0 on pad rows; feat_mask (C, T, F).  Tree growth is
    ``vmap(clients) ∘ vmap(trees)`` over ``grow_tree`` — replacing the
    per-client Python loop — and the histogram build inside runs through
    the kernel's client-batched axis.

    Returns a list of C ``RandomForest`` (unstacked, for the existing
    per-client selection/shipping code).
    """
    C = bins.shape[0]
    grad = 0.5 - y.astype(jnp.float32)
    hess = jnp.ones(y.shape, jnp.float32)

    def one_client(b, e, g, h, ws, fms):
        return jax.vmap(
            lambda w, fm: grow_tree(b, e, g, h, w, depth=depth,
                                    n_bins=n_bins, lam=lam,
                                    feature_mask=fm,
                                    hist_impl=hist_impl))(ws, fms)

    grown = jax.vmap(one_client)(bins, edges, grad, hess, sample_w,
                                 feat_mask)
    return [RandomForest(jax.tree.map(lambda a: a[c], grown))
            for c in range(C)]


def predict_proba(model: RandomForest, x) -> jnp.ndarray:
    vals = predict_forest(model.forest, x) + 0.5   # (k, n) per-tree p(y=1)
    return jnp.mean(vals, axis=0)


def predict_votes(model: RandomForest, x) -> jnp.ndarray:
    """Majority vote (the paper's mode aggregation)."""
    vals = predict_forest(model.forest, x) + 0.5
    return jnp.mean((vals > 0.5).astype(jnp.float32), axis=0) > 0.5


predict = predict_votes


def feature_importance(model: RandomForest) -> jnp.ndarray:
    g = jnp.sum(model.forest.gain, axis=0)
    return g / jnp.maximum(jnp.sum(g), 1e-12)
