"""Level-wise histogram tree growth (shared by GBDT and Random Forest).

Dense heap-layout trees: internal node i has children 2i+1 / 2i+2; a tree of
depth D has 2^D - 1 internal slots and 2^D leaves.  Growth is second-order
(XGBoost-style): per level, per node, a gradient/hessian histogram
(``repro.kernels.hist``) and the split gain

    gain = 1/2 [ G_L^2/(H_L+lam) + G_R^2/(H_R+lam) - G^2/(H+lam) ]

Nodes with no positive-gain split store feature = -1 (all samples routed
right, children inherit the node's value).  Thresholds are stored as raw
feature values (see ``binning``).

Two growers share the split-finding math (``_find_splits``):

* ``grow_tree`` — one data shard (a client's local training, or
  centralized training); histograms never leave the process.
* ``grow_tree_fed`` — the histogram-aggregation federated grower: inputs
  carry a leading client axis ``(C, n, ...)``, each level's per-client
  histograms are built in one client-batched ``gradient_histogram`` call
  (these (C, F, nodes*bins, 2) arrays are exactly what crosses the wire
  in ``repro.core.fed_hist``), aggregated — plain sum, or a pluggable
  ``hist_agg`` adding secure-agg masking / DP noise — and the server
  picks splits from the aggregate.  With shared bins the summed
  histogram equals the union-shard histogram, so the grown tree matches
  centralized ``grow_tree`` on the concatenated shards.

Shape conventions (client-batched paths): a leading ``C`` axis is always
the client/shard axis — bins ``(C, n, F)``, grad/hess/sample_w ``(C, n)``,
per-client histograms ``(C, F, n_nodes*n_bins, 2)``.  Padding rows carry
``sample_w = 0`` and are invisible to growth (zero grad/hess mass).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.hist.ops import gradient_histogram
from repro.trees import binning


class Tree(NamedTuple):
    """Dense heap tree; all arrays may carry leading 'forest' dims."""
    feature: jnp.ndarray     # (2^D - 1,) int32, -1 = no split
    threshold: jnp.ndarray   # (2^D - 1,) f32 raw value, go left if x <= t
    leaf: jnp.ndarray        # (2^D,) f32 leaf values
    gain: jnp.ndarray        # (F,) total split gain per feature (importance)

    @property
    def depth(self) -> int:
        return int(jnp.log2(self.leaf.shape[-1]))


def nbytes(tree: Tree) -> int:
    """Bytes-on-wire for transmitting this tree/forest (comm accounting)."""
    return int(sum(x.size * x.dtype.itemsize
                   for x in [tree.feature, tree.threshold, tree.leaf]))


class _Splits(NamedTuple):
    """Per-node split decisions for one level, from an aggregated hist."""
    best_f: jnp.ndarray     # (n_nodes,) int32, -1 = no split
    best_b: jnp.ndarray     # (n_nodes,) int32 split bin
    do_split: jnp.ndarray   # (n_nodes,) bool
    best_gain: jnp.ndarray  # (n_nodes,) f32
    gl: jnp.ndarray         # (n_nodes,) grad sum of the left child
    hl: jnp.ndarray
    gt: jnp.ndarray         # (n_nodes,) node-total grad/hess
    ht: jnp.ndarray


def _find_splits(hist, n_nodes: int, n_bins: int, lam: float, gamma: float,
                 min_child_weight: float,
                 feature_mask: Optional[jnp.ndarray]) -> _Splits:
    """hist (F, n_nodes*n_bins, 2) -> best split per node of the level."""
    F = hist.shape[0]
    hist = hist.reshape(F, n_nodes, n_bins, 2).transpose(1, 0, 2, 3)
    g, h = hist[..., 0], hist[..., 1]
    gl = jnp.cumsum(g, axis=-1)
    hl = jnp.cumsum(h, axis=-1)
    gt = gl[..., -1:]
    ht = hl[..., -1:]
    gr, hr = gt - gl, ht - hl
    gain = 0.5 * (gl ** 2 / (hl + lam) + gr ** 2 / (hr + lam)
                  - gt ** 2 / (ht + lam)) - gamma
    valid = (hl >= min_child_weight) & (hr >= min_child_weight)
    # never split on the last bin (empty right child by construction)
    valid = valid & (jnp.arange(n_bins) < n_bins - 1)
    if feature_mask is not None:
        valid = valid & feature_mask.astype(bool)[None, :, None]
    gain = jnp.where(valid, gain, -jnp.inf)
    flat = gain.reshape(n_nodes, -1)
    best = jnp.argmax(flat, axis=-1)
    best_gain = jnp.take_along_axis(flat, best[:, None], 1)[:, 0]
    best_f = (best // n_bins).astype(jnp.int32)
    best_b = (best % n_bins).astype(jnp.int32)
    do_split = best_gain > 0.0
    pick = lambda a: jnp.take_along_axis(
        a.reshape(n_nodes, -1), best[:, None], 1)[:, 0]
    return _Splits(jnp.where(do_split, best_f, -1), best_b, do_split,
                   best_gain, pick(gl), pick(hl), gt[..., 0, 0],
                   ht[..., 0, 0])


@functools.partial(jax.jit,
                   static_argnames=("depth", "n_bins", "hist_impl"))
def grow_tree(bins, edges, grad, hess, sample_w, *, depth: int,
              n_bins: int, lam: float = 1.0, gamma: float = 0.0,
              min_child_weight: float = 1e-3,
              feature_mask: Optional[jnp.ndarray] = None,
              hist_impl: str = "auto") -> Tree:
    """Grow one tree.

    bins (n, F) int32 pre-binned features; edges (F, n_bins-1);
    grad/hess (n,) fp32; sample_w (n,) fp32 (bootstrap multiplicities — 0
    excludes a sample); feature_mask (F,) 1/0 per-tree feature subsample.
    """
    n, F = bins.shape
    n_internal = 2 ** depth - 1
    n_leaves = 2 ** depth

    grad = grad * sample_w
    hess = hess * sample_w
    feats = jnp.full((n_internal,), -1, jnp.int32)
    thrs = jnp.zeros((n_internal,), jnp.float32)
    fgain = jnp.zeros((F,), jnp.float32)
    assign = jnp.zeros((n,), jnp.int32)  # node id within current level

    for level in range(depth):
        n_nodes = 2 ** level
        base = n_nodes - 1  # first node index of this level in heap order
        # one histogram call over the combined (node, bin) index space:
        # O(n*F) per level regardless of node count, and the same Pallas
        # kernel serves it (its bin axis is just n_nodes*n_bins wide).
        combined = assign[:, None] * n_bins + bins     # (n, F)
        hist = gradient_histogram(combined, grad, hess, n_nodes * n_bins,
                                  impl=hist_impl)      # (F, nodes*bins, 2)
        s = _find_splits(hist, n_nodes, n_bins, lam, gamma,
                         min_child_weight, feature_mask)
        thr = binning.edge_value(edges, jnp.maximum(s.best_f, 0), s.best_b)
        feats = feats.at[base + jnp.arange(n_nodes)].set(s.best_f)
        thrs = thrs.at[base + jnp.arange(n_nodes)].set(
            jnp.where(s.do_split, thr, 0.0))
        fgain = fgain.at[jnp.maximum(s.best_f, 0)].add(
            jnp.where(s.do_split, jnp.maximum(s.best_gain, 0.0), 0.0))
        # route samples
        nf = s.best_f[assign]                          # (n,)
        nb = s.best_b[assign]
        sample_bin = jnp.take_along_axis(
            bins, jnp.maximum(nf, 0)[:, None], axis=1)[:, 0]
        go_left = (nf >= 0) & (sample_bin <= nb)
        assign = assign * 2 + jnp.where(go_left, 0, 1)

    # leaf values: newton step -G/(H+lam)
    gsum = jax.ops.segment_sum(grad, assign, n_leaves)
    hsum = jax.ops.segment_sum(hess, assign, n_leaves)
    leaf = -gsum / (hsum + lam)
    return Tree(feats, thrs, leaf, fgain)


@functools.partial(jax.jit,
                   static_argnames=("depth", "n_bins", "hist_impl",
                                    "batch_clients"))
def grow_tree_fed(bins, edges, grad, hess, sample_w, *, depth: int,
                  n_bins: int, lam: float = 1.0, gamma: float = 0.0,
                  min_child_weight: float = 1e-3,
                  feature_mask: Optional[jnp.ndarray] = None,
                  hist_impl: str = "auto", hist_agg=None, agg_key=None,
                  batch_clients: bool = True) -> Tree:
    """Grow one tree on the server from aggregated client histograms.

    bins (C, n, F) int32 client-stacked pre-binned features — **all
    clients binned with the same shared edges** (see
    ``binning.fed_fit_bins``); edges (F, n_bins-1); grad/hess/sample_w
    (C, n) fp32 (pad rows carry sample_w = 0).

    Per level, per-client histograms over the combined (node, bin) space
    are built client-batched (``batch_clients=True``, one kernel call
    with a leading client grid axis) or via a sequential per-client loop
    (the parity reference), then aggregated:

    * ``hist_agg=None`` — plain ``sum`` over the client axis.  With
      shared bins this equals the union-shard histogram, so the result
      matches centralized ``grow_tree`` on the concatenated shards.
    * ``hist_agg(hists, key) -> hist`` — e.g. secure-agg masked sum or
      DP-noised sum (``repro.core.fed_hist``); ``agg_key`` is folded
      per level.  Pass a ``jax.tree_util.Partial`` so jit can trace it.

    Leaf values are computed from the last level's *shipped* histograms
    (left child = -G_L/(H_L+lam) at the chosen split, right child the
    complement), so fed training communicates histograms only — no
    per-leaf statistics round.
    """
    C, n, F = bins.shape
    n_internal = 2 ** depth - 1
    n_leaves = 2 ** depth

    grad = grad * sample_w
    hess = hess * sample_w
    feats = jnp.full((n_internal,), -1, jnp.int32)
    thrs = jnp.zeros((n_internal,), jnp.float32)
    fgain = jnp.zeros((F,), jnp.float32)
    leaf = jnp.zeros((n_leaves,), jnp.float32)
    assign = jnp.zeros((C, n), jnp.int32)

    for level in range(depth):
        n_nodes = 2 ** level
        base = n_nodes - 1
        width = n_nodes * n_bins
        combined = assign[:, :, None] * n_bins + bins  # (C, n, F)
        if batch_clients:
            hists = gradient_histogram(combined, grad, hess, width,
                                       impl=hist_impl)  # (C, F, width, 2)
        else:
            hists = jnp.stack([
                gradient_histogram(combined[c], grad[c], hess[c], width,
                                   impl=hist_impl) for c in range(C)])
        if hist_agg is None:
            hist = jnp.sum(hists, axis=0)
        else:
            key = (jax.random.fold_in(agg_key, level)
                   if agg_key is not None else None)
            hist = hist_agg(hists, key)
        s = _find_splits(hist, n_nodes, n_bins, lam, gamma,
                         min_child_weight, feature_mask)
        thr = binning.edge_value(edges, jnp.maximum(s.best_f, 0), s.best_b)
        feats = feats.at[base + jnp.arange(n_nodes)].set(s.best_f)
        thrs = thrs.at[base + jnp.arange(n_nodes)].set(
            jnp.where(s.do_split, thr, 0.0))
        fgain = fgain.at[jnp.maximum(s.best_f, 0)].add(
            jnp.where(s.do_split, jnp.maximum(s.best_gain, 0.0), 0.0))
        if level == depth - 1:
            # leaves from the already-aggregated histograms: split nodes
            # put -G_L/(H_L+lam) left and the complement right; no-split
            # nodes route everything right with the node's newton value
            gr, hr = s.gt - s.gl, s.ht - s.hl
            left = jnp.where(s.do_split, -s.gl / (s.hl + lam), 0.0)
            right = jnp.where(s.do_split, -gr / (hr + lam),
                              -s.gt / (s.ht + lam))
            leaf = jnp.stack([left, right], axis=1).reshape(-1)
        # each client routes its own samples with the broadcast split
        nf = s.best_f[assign]                          # (C, n)
        nb = s.best_b[assign]
        sample_bin = jnp.take_along_axis(
            bins, jnp.maximum(nf, 0)[:, :, None], axis=2)[:, :, 0]
        go_left = (nf >= 0) & (sample_bin <= nb)
        assign = assign * 2 + jnp.where(go_left, 0, 1)

    return Tree(feats, thrs, leaf, fgain)


def fed_hist_bytes(n_features: int, n_bins: int, depth: int) -> int:
    """Uplink bytes per client per tree under histogram aggregation:
    one (F, 2^level * n_bins, 2) fp32 histogram per level."""
    return sum(n_features * (2 ** level) * n_bins * 2 * 4
               for level in range(depth))


def predict_tree(tree: Tree, x) -> jnp.ndarray:
    """x (n, F) raw features -> leaf values (n,)."""
    n = x.shape[0]
    depth = tree.depth
    node = jnp.zeros((n,), jnp.int32)
    for _ in range(depth):
        f = tree.feature[node]
        t = tree.threshold[node]
        xv = jnp.take_along_axis(x, jnp.maximum(f, 0)[:, None], 1)[:, 0]
        go_left = (f >= 0) & (xv <= t)
        node = 2 * node + jnp.where(go_left, 1, 2)
    leaf_idx = node - (2 ** depth - 1)
    return tree.leaf[leaf_idx]


def predict_forest(forest: Tree, x) -> jnp.ndarray:
    """forest: Tree with leading k dim -> (k, n) per-tree values."""
    return jax.vmap(lambda t: predict_tree(t, x))(forest)


def stack_trees(trees) -> Tree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def concat_forests(forests) -> Tree:
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *forests)


def take_trees(forest: Tree, idx) -> Tree:
    return jax.tree.map(lambda a: a[idx], forest)
