"""Level-wise histogram tree growth (shared by GBDT and Random Forest).

Dense heap-layout trees: internal node i has children 2i+1 / 2i+2; a tree of
depth D has 2^D - 1 internal slots and 2^D leaves.  Growth is second-order
(XGBoost-style): per level, per node, a gradient/hessian histogram
(``repro.kernels.hist``) and the split gain

    gain = 1/2 [ G_L^2/(H_L+lam) + G_R^2/(H_R+lam) - G^2/(H+lam) ]

Nodes with no positive-gain split store feature = -1 (all samples routed
right, children inherit the node's value).  Thresholds are stored as raw
feature values (see ``binning``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.hist.ops import gradient_histogram
from repro.trees import binning


class Tree(NamedTuple):
    """Dense heap tree; all arrays may carry leading 'forest' dims."""
    feature: jnp.ndarray     # (2^D - 1,) int32, -1 = no split
    threshold: jnp.ndarray   # (2^D - 1,) f32 raw value, go left if x <= t
    leaf: jnp.ndarray        # (2^D,) f32 leaf values
    gain: jnp.ndarray        # (F,) total split gain per feature (importance)

    @property
    def depth(self) -> int:
        return int(jnp.log2(self.leaf.shape[-1]))


def nbytes(tree: Tree) -> int:
    """Bytes-on-wire for transmitting this tree/forest (comm accounting)."""
    return int(sum(x.size * x.dtype.itemsize
                   for x in [tree.feature, tree.threshold, tree.leaf]))


@functools.partial(jax.jit,
                   static_argnames=("depth", "n_bins", "hist_impl"))
def grow_tree(bins, edges, grad, hess, sample_w, *, depth: int,
              n_bins: int, lam: float = 1.0, gamma: float = 0.0,
              min_child_weight: float = 1e-3,
              feature_mask: Optional[jnp.ndarray] = None,
              hist_impl: str = "auto") -> Tree:
    """Grow one tree.

    bins (n, F) int32 pre-binned features; edges (F, n_bins-1);
    grad/hess (n,) fp32; sample_w (n,) fp32 (bootstrap multiplicities — 0
    excludes a sample); feature_mask (F,) 1/0 per-tree feature subsample.
    """
    n, F = bins.shape
    n_internal = 2 ** depth - 1
    n_leaves = 2 ** depth

    grad = grad * sample_w
    hess = hess * sample_w
    feats = jnp.full((n_internal,), -1, jnp.int32)
    thrs = jnp.zeros((n_internal,), jnp.float32)
    fgain = jnp.zeros((F,), jnp.float32)
    assign = jnp.zeros((n,), jnp.int32)  # node id within current level

    for level in range(depth):
        n_nodes = 2 ** level
        base = n_nodes - 1  # first node index of this level in heap order
        # one histogram call over the combined (node, bin) index space:
        # O(n*F) per level regardless of node count, and the same Pallas
        # kernel serves it (its bin axis is just n_nodes*n_bins wide).
        combined = assign[:, None] * n_bins + bins     # (n, F)
        hist = gradient_histogram(combined, grad, hess, n_nodes * n_bins,
                                  impl=hist_impl)      # (F, nodes*bins, 2)
        hist = hist.reshape(F, n_nodes, n_bins, 2).transpose(1, 0, 2, 3)
        g, h = hist[..., 0], hist[..., 1]
        gl = jnp.cumsum(g, axis=-1)
        hl = jnp.cumsum(h, axis=-1)
        gt = gl[..., -1:]
        ht = hl[..., -1:]
        gr, hr = gt - gl, ht - hl
        gain = 0.5 * (gl ** 2 / (hl + lam) + gr ** 2 / (hr + lam)
                      - gt ** 2 / (ht + lam)) - gamma
        valid = (hl >= min_child_weight) & (hr >= min_child_weight)
        # never split on the last bin (empty right child by construction)
        valid = valid & (jnp.arange(n_bins) < n_bins - 1)
        if feature_mask is not None:
            valid = valid & feature_mask.astype(bool)[None, :, None]
        gain = jnp.where(valid, gain, -jnp.inf)
        flat = gain.reshape(n_nodes, -1)
        best = jnp.argmax(flat, axis=-1)
        best_gain = jnp.take_along_axis(flat, best[:, None], 1)[:, 0]
        best_f = (best // n_bins).astype(jnp.int32)
        best_b = (best % n_bins).astype(jnp.int32)
        do_split = best_gain > 0.0
        best_f = jnp.where(do_split, best_f, -1)
        thr = binning.edge_value(edges, jnp.maximum(best_f, 0), best_b)
        feats = feats.at[base + jnp.arange(n_nodes)].set(best_f)
        thrs = thrs.at[base + jnp.arange(n_nodes)].set(
            jnp.where(do_split, thr, 0.0))
        fgain = fgain.at[jnp.maximum(best_f, 0)].add(
            jnp.where(do_split, jnp.maximum(best_gain, 0.0), 0.0))
        # route samples
        nf = best_f[assign]                            # (n,)
        nb = best_b[assign]
        sample_bin = jnp.take_along_axis(
            bins, jnp.maximum(nf, 0)[:, None], axis=1)[:, 0]
        go_left = (nf >= 0) & (sample_bin <= nb)
        assign = assign * 2 + jnp.where(go_left, 0, 1)

    # leaf values: newton step -G/(H+lam)
    gsum = jax.ops.segment_sum(grad, assign, n_leaves)
    hsum = jax.ops.segment_sum(hess, assign, n_leaves)
    leaf = -gsum / (hsum + lam)
    return Tree(feats, thrs, leaf, fgain)


def predict_tree(tree: Tree, x) -> jnp.ndarray:
    """x (n, F) raw features -> leaf values (n,)."""
    n = x.shape[0]
    depth = tree.depth
    node = jnp.zeros((n,), jnp.int32)
    for _ in range(depth):
        f = tree.feature[node]
        t = tree.threshold[node]
        xv = jnp.take_along_axis(x, jnp.maximum(f, 0)[:, None], 1)[:, 0]
        go_left = (f >= 0) & (xv <= t)
        node = 2 * node + jnp.where(go_left, 1, 2)
    leaf_idx = node - (2 ** depth - 1)
    return tree.leaf[leaf_idx]


def predict_forest(forest: Tree, x) -> jnp.ndarray:
    """forest: Tree with leading k dim -> (k, n) per-tree values."""
    return jax.vmap(lambda t: predict_tree(t, x))(forest)


def stack_trees(trees) -> Tree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def concat_forests(forests) -> Tree:
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *forests)


def take_trees(forest: Tree, idx) -> Tree:
    return jax.tree.map(lambda a: a[idx], forest)
