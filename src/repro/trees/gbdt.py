"""XGBoost-style GBDT (logistic loss, second-order) in pure JAX."""
from __future__ import annotations

from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.trees import binning
from repro.trees.growth import (Tree, grow_tree, predict_forest,
                                predict_tree, stack_trees)


class GBDT(NamedTuple):
    forest: Tree          # stacked (rounds, ...)
    learning_rate: float
    base_margin: float


def _base_margin(y, sample_w):
    """log-odds of the weighted positive rate (pads carry w = 0)."""
    pos = jnp.clip(jnp.sum(y * sample_w, axis=-1)
                   / jnp.maximum(jnp.sum(sample_w, axis=-1), 1e-9),
                   1e-4, 1 - 1e-4)
    return jnp.log(pos / (1 - pos))


def fit_binned(x, y, bins, edges, sample_w, *, num_rounds: int = 50,
               depth: int = 6, n_bins: int = 64,
               learning_rate: float = 0.3, lam: float = 1.0,
               feature_mask: Optional[jnp.ndarray] = None,
               hist_impl: str = "auto") -> GBDT:
    """Boost on pre-binned features (the shared-bins entry point).

    x (n, F) raw fp32 (for margin updates via raw thresholds); bins
    (n, F) int32 = ``binning.apply_bins(x, edges)``; sample_w (n,) fp32
    with 0 excluding a sample (padding or subsampling).
    """
    base = _base_margin(y, sample_w)
    margin = jnp.full(y.shape, base, jnp.float32)
    trees = []
    for _ in range(num_rounds):
        p = jax.nn.sigmoid(margin)
        grad = p - y
        hess = p * (1 - p)
        tree = grow_tree(bins, edges, grad, hess, sample_w, depth=depth,
                         n_bins=n_bins, lam=lam, feature_mask=feature_mask,
                         hist_impl=hist_impl)
        trees.append(tree)
        margin = margin + learning_rate * predict_tree(tree, x)
    return GBDT(stack_trees(trees), learning_rate, float(base))


def fit(x, y, *, num_rounds: int = 50, depth: int = 6, n_bins: int = 64,
        learning_rate: float = 0.3, lam: float = 1.0,
        sample_w: Optional[jnp.ndarray] = None,
        feature_mask: Optional[jnp.ndarray] = None,
        hist_impl: str = "auto") -> GBDT:
    """x (n,F) fp32, y (n,) {0,1}.  Bins locally, then boosts."""
    n, F = x.shape
    edges = binning.fit_bins(x, n_bins)
    bins = binning.apply_bins(x, edges)
    if sample_w is None:
        sample_w = jnp.ones((n,), jnp.float32)
    return fit_binned(x, y, bins, edges, sample_w, num_rounds=num_rounds,
                      depth=depth, n_bins=n_bins,
                      learning_rate=learning_rate, lam=lam,
                      feature_mask=feature_mask, hist_impl=hist_impl)


def fit_batched(x, y, bins, edges, sample_w, *, num_rounds: int = 50,
                depth: int = 6, n_bins: int = 64,
                learning_rate: float = 0.3, lam: float = 1.0,
                feature_mask: Optional[jnp.ndarray] = None,
                hist_impl: str = "auto") -> List[GBDT]:
    """Client-batched local boosting: C independent GBDTs in lockstep.

    All inputs carry a leading client axis — x/bins (C, n, F), y/sample_w
    (C, n) (shards padded to a common n with sample_w = 0), edges
    (C, F, n_bins-1) per-client, feature_mask (C, F) or None.  Each round
    grows all C trees in one vmapped ``grow_tree`` (the histogram build
    runs client-batched through the kernel's client grid axis) instead of
    a per-client Python loop; arithmetic per client is identical to
    ``fit_binned``, which is the sequential parity path.

    Returns one ``GBDT`` per client (unstacked).
    """
    C = x.shape[0]
    base = _base_margin(y, sample_w)                   # (C,)
    margin = jnp.broadcast_to(base[:, None], y.shape).astype(jnp.float32)
    grow_v = jax.vmap(
        lambda b, e, g, h, w, fm: grow_tree(
            b, e, g, h, w, depth=depth, n_bins=n_bins, lam=lam,
            feature_mask=fm, hist_impl=hist_impl),
        in_axes=(0, 0, 0, 0, 0, None if feature_mask is None else 0))
    trees = []
    for _ in range(num_rounds):
        p = jax.nn.sigmoid(margin)
        grad = p - y
        hess = p * (1 - p)
        tree = grow_v(bins, edges, grad, hess, sample_w, feature_mask)
        trees.append(tree)
        margin = margin + learning_rate * jax.vmap(predict_tree)(tree, x)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *trees)
    return [GBDT(jax.tree.map(lambda a: a[c], stacked), learning_rate,
                 float(base[c])) for c in range(C)]


def predict_margin(model: GBDT, x) -> jnp.ndarray:
    vals = predict_forest(model.forest, x)          # (rounds, n)
    return model.base_margin + model.learning_rate * jnp.sum(vals, axis=0)


def predict_proba(model: GBDT, x) -> jnp.ndarray:
    return jax.nn.sigmoid(predict_margin(model, x))


def predict(model: GBDT, x) -> jnp.ndarray:
    return predict_margin(model, x) > 0


def feature_importance(model: GBDT) -> jnp.ndarray:
    """Total gain per feature, normalized (the paper's phi for C3)."""
    g = jnp.sum(model.forest.gain, axis=0)
    return g / jnp.maximum(jnp.sum(g), 1e-12)
