"""XGBoost-style GBDT (logistic loss, second-order) in pure JAX."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.trees import binning
from repro.trees.growth import (Tree, grow_tree, predict_forest,
                                stack_trees)


class GBDT(NamedTuple):
    forest: Tree          # stacked (rounds, ...)
    learning_rate: float
    base_margin: float


def fit(x, y, *, num_rounds: int = 50, depth: int = 6, n_bins: int = 64,
        learning_rate: float = 0.3, lam: float = 1.0,
        sample_w: Optional[jnp.ndarray] = None,
        feature_mask: Optional[jnp.ndarray] = None,
        hist_impl: str = "auto") -> GBDT:
    """x (n,F) fp32, y (n,) {0,1}."""
    n, F = x.shape
    edges = binning.fit_bins(x, n_bins)
    bins = binning.apply_bins(x, edges)
    if sample_w is None:
        sample_w = jnp.ones((n,), jnp.float32)
    pos = jnp.clip(jnp.mean(y), 1e-4, 1 - 1e-4)
    base = jnp.log(pos / (1 - pos))
    margin = jnp.full((n,), base, jnp.float32)
    trees = []
    for _ in range(num_rounds):
        p = jax.nn.sigmoid(margin)
        grad = p - y
        hess = p * (1 - p)
        tree = grow_tree(bins, edges, grad, hess, sample_w, depth=depth,
                         n_bins=n_bins, lam=lam, feature_mask=feature_mask,
                         hist_impl=hist_impl)
        trees.append(tree)
        margin = margin + learning_rate * predict_forest(
            jax.tree.map(lambda a: a[None], tree), x)[0]
    return GBDT(stack_trees(trees), learning_rate, float(base))


def predict_margin(model: GBDT, x) -> jnp.ndarray:
    vals = predict_forest(model.forest, x)          # (rounds, n)
    return model.base_margin + model.learning_rate * jnp.sum(vals, axis=0)


def predict_proba(model: GBDT, x) -> jnp.ndarray:
    return jax.nn.sigmoid(predict_margin(model, x))


def predict(model: GBDT, x) -> jnp.ndarray:
    return predict_margin(model, x) > 0


def feature_importance(model: GBDT) -> jnp.ndarray:
    """Total gain per feature, normalized (the paper's phi for C3)."""
    g = jnp.sum(model.forest.gain, axis=0)
    return g / jnp.maximum(jnp.sum(g), 1e-12)
