"""Pure-jnp oracle for the forest-inference kernel."""
import jax
import jax.numpy as jnp


def forest_infer_ref(feature, threshold, leaf, x):
    """feature/threshold (T, 2^D - 1), leaf (T, 2^D), x (n, F) ->
    (T, n) f32 per-tree leaf values.

    Gather-based heap traversal, vmapped over the tree axis — the same
    arithmetic as ``trees.growth.predict_tree`` (go left iff the node
    splits and x[feature] <= threshold; no-split nodes route right)."""
    depth = int(feature.shape[1]).bit_length()

    def one_tree(feat, thr, lf):
        node = jnp.zeros((x.shape[0],), jnp.int32)
        for _ in range(depth):
            f = feat[node]
            t = thr[node]
            xv = jnp.take_along_axis(x, jnp.maximum(f, 0)[:, None],
                                     axis=1)[:, 0]
            go_left = (f >= 0) & (xv <= t)
            node = 2 * node + jnp.where(go_left, 1, 2)
        return lf[node - feat.shape[0]]

    return jax.vmap(one_tree)(feature, threshold.astype(jnp.float32),
                              leaf.astype(jnp.float32))
