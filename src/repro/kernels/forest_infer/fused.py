"""Fused forest scoring: traversal + ensemble weighting + Platt in one
``pallas_call`` (ROADMAP item 3's concrete fusion target).

The unfused serving path (``repro.serve.engine``) composes three stages
per request: the ``forest_infer`` kernel returns the full (T, n)
per-tree leaf matrix to XLA, a reduction collapses it to a per-row
score (vote fraction or boosted margin), and Platt calibration maps the
score to a probability.  The (T, n) intermediate is pure memory
traffic — every element is read exactly once by the reduction.

The fused kernel never materializes it.  The grid is (row-tiles, trees)
with the tree axis innermost, so each row tile's output block stays
VMEM-resident while every tree accumulates into it (the same
revisit-accumulate pattern as the ``hist`` kernel's sample axis); the
last tree step applies the finalization — vote normalization or
``sigmoid(base + lr * acc)`` — and the Platt sigmoid, so one kernel
call goes straight from raw features to calibrated probabilities.

Two modes cover the repo's single-forest bundle kinds:

* ``"vote"`` (``tree_subset``): per-tree contribution is the vote
  indicator ``leaf > 0`` (identical to the engine's
  ``leaf + 0.5 > 0.5``); the finalized score is the vote fraction.
  Votes are exact 0/1 counts in f32, so this mode is **bit-exact**
  with the unfused composition.
* ``"margin"`` (``fed_hist``): contributions are raw leaf values; the
  finalized score is ``sigmoid(base + lr * sum)``.  The kernel sums
  tree-sequentially while XLA reduces pairwise, so parity is within
  float tolerance (~1e-6 on probabilities), documented and gated in
  ``benchmarks/serve_bench.py --smoke``.

Platt parameters ride in as a tiny (1, 3) array ``[a, b, enabled]`` —
a *traced* input, so calibrating an engine never recompiles — and the
fused path evaluates the calibration sigmoid in f32 (the unfused engine
uses float64 numpy; the difference is inside the same documented
tolerance).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune
from repro.kernels.forest_infer.ref import forest_infer_ref
from repro.obs import annotate

MODES = ("vote", "margin")


def _check_mode(mode: str) -> None:
    if mode not in MODES:
        raise ValueError(f"unknown fused scoring mode {mode!r}; "
                         f"available: {MODES}")


def _finalize(acc, platt, *, mode: str, n_trees: int, lr: float,
              base: float):
    """Accumulated per-tree contributions -> calibrated probability."""
    if mode == "vote":
        s = acc / n_trees
    else:
        s = jax.nn.sigmoid(base + lr * acc)
    calibrated = 1.0 / (1.0 + jnp.exp(-(platt[0] * s + platt[1])))
    return jnp.where(platt[2] > 0, calibrated, s)


def _fused_kernel(feat_ref, thr_ref, leaf_ref, x_ref, platt_ref, o_ref, *,
                  depth: int, block_n: int, n_feat: int, n_trees: int,
                  mode: str, lr: float, base: float):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # identical traversal to kernel._infer_kernel: one tree, one row tile
    feat = feat_ref[0]                       # (2^D - 1,) int32
    thr = thr_ref[0]                         # (2^D - 1,) f32
    leaf = leaf_ref[0]                       # (2^D,) f32
    x = x_ref[...]                           # (block_n, F) f32
    n_internal = feat.shape[0]
    n_leaves = leaf.shape[0]

    f_iota = jax.lax.broadcasted_iota(jnp.int32, (n_internal, n_feat), 1)
    feat_oh = (feat[:, None] == f_iota).astype(jnp.float32)
    no_split = (feat < 0).astype(jnp.float32)

    node = jnp.zeros((block_n,), jnp.int32)
    for _ in range(depth):
        n_iota = jax.lax.broadcasted_iota(jnp.int32,
                                          (block_n, n_internal), 1)
        node_oh = (node[:, None] == n_iota).astype(jnp.float32)
        t = node_oh @ thr
        dead = node_oh @ no_split
        sel = node_oh @ feat_oh
        xv = jnp.sum(x * sel, axis=1)
        go_left = (dead < 0.5) & (xv <= t)
        node = 2 * node + jnp.where(go_left, 1, 2)

    leaf_idx = node - n_internal
    l_iota = jax.lax.broadcasted_iota(jnp.int32, (block_n, n_leaves), 1)
    leaf_oh = (leaf_idx[:, None] == l_iota).astype(jnp.float32)
    val = leaf_oh @ leaf                                   # (block_n,)

    # the fusion: reduce into the resident output block instead of
    # shipping the (T, n) leaf matrix back to XLA
    contrib = (val > 0).astype(jnp.float32) if mode == "vote" else val
    o_ref[...] += contrib[None, :]

    @pl.when(ti == n_trees - 1)
    def _fin():
        o_ref[...] = _finalize(o_ref[0, :], platt_ref[0], mode=mode,
                               n_trees=n_trees, lr=lr, base=base)[None, :]


def _platt_array(platt) -> jnp.ndarray:
    """(a, b) | None | ready-made (3,) array -> (3,) f32 [a, b, flag]."""
    if platt is None:
        return jnp.zeros((3,), jnp.float32)
    platt = jnp.asarray(platt, jnp.float32)
    if platt.shape == (2,):
        platt = jnp.concatenate([platt, jnp.ones((1,), jnp.float32)])
    if platt.shape != (3,):
        raise ValueError(f"platt must be (a, b) or [a, b, flag]; "
                         f"got shape {platt.shape}")
    return platt


def fused_forest_score_ref(feature, threshold, leaf, x, *, mode: str,
                           lr: float = 1.0, base: float = 0.0,
                           platt=None):
    """Pure-jnp oracle: unfused composition of the same arithmetic."""
    _check_mode(mode)
    vals = forest_infer_ref(feature, threshold, leaf, x)   # (T, n)
    contrib = (vals > 0).astype(jnp.float32) if mode == "vote" else vals
    return _finalize(jnp.sum(contrib, axis=0), _platt_array(platt),
                     mode=mode, n_trees=feature.shape[0], lr=lr,
                     base=base)


def fused_forest_score_pallas(feature, threshold, leaf, x, *, mode: str,
                              lr: float = 1.0, base: float = 0.0,
                              platt=None, block_n: int = 256,
                              interpret: bool = False):
    """One-call forest scoring (see module docstring for the contract).

    Args mirror ``kernel.forest_infer_pallas`` (dense-heap forest +
    (n, F) raw rows) plus ``mode``/``lr``/``base`` statics and the
    traced ``platt`` calibration triple.  Returns (n,) f32 calibrated
    probabilities."""
    _check_mode(mode)
    T, n_internal = feature.shape
    n, F = x.shape
    n_leaves = leaf.shape[1]
    depth = n_internal.bit_length()
    assert n_leaves == n_internal + 1, "leaf axis must be 2^depth"
    block_n = min(block_n, max(n, 1))
    pad_n = (-n) % block_n
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
    np_ = x.shape[0]
    grid = (np_ // block_n, T)        # trees innermost: resident output
    out = pl.pallas_call(
        functools.partial(_fused_kernel, depth=depth, block_n=block_n,
                          n_feat=F, n_trees=T, mode=mode, lr=float(lr),
                          base=float(base)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_internal), lambda s, t: (t, 0)),
            pl.BlockSpec((1, n_internal), lambda s, t: (t, 0)),
            pl.BlockSpec((1, n_leaves), lambda s, t: (t, 0)),
            pl.BlockSpec((block_n, F), lambda s, t: (s, 0)),
            pl.BlockSpec((1, 3), lambda s, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda s, t: (0, s)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        interpret=interpret,
    )(feature, threshold.astype(jnp.float32), leaf.astype(jnp.float32),
      x.astype(jnp.float32), _platt_array(platt)[None, :])
    return out[0, :n]


def forest_score(forest, x, *, mode: str, lr: float = 1.0,
                 base: float = 0.0, platt=None, impl: str = "auto",
                 block_n=None):
    """Routing wrapper for the fused scorer, mirroring ``ops.forest_infer``
    (``auto`` | ``pallas`` | ``pallas_interpret`` | ``xla``; auto picks
    the kernel off-CPU and the jnp composition on CPU).  ``block_n``
    defaults to the ``forest_score_fused`` autotune entry."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() != "cpu" else "xla"
    if impl in ("pallas", "pallas_interpret"):
        cfg = autotune.resolve("forest_score_fused", x.shape, x.dtype,
                               block_n=block_n)
        interpret = (impl == "pallas_interpret"
                     or jax.default_backend() == "cpu")
        with annotate("kernels.forest_score.pallas"):
            return fused_forest_score_pallas(
                forest.feature, forest.threshold, forest.leaf, x,
                mode=mode, lr=lr, base=base, platt=platt,
                block_n=cfg["block_n"], interpret=interpret)
    if impl != "xla":
        raise ValueError(f"unknown forest_score impl {impl!r}")
    with annotate("kernels.forest_score.xla"):
        return fused_forest_score_ref(forest.feature, forest.threshold,
                                      forest.leaf, x, mode=mode, lr=lr,
                                      base=base, platt=platt)
