"""Pallas TPU kernel: batched dense-heap forest inference (serving).

The serving hot path scores every tree of a trained forest on every
request row (``repro.serve.engine``).  The training-side traversal
(``trees/growth.predict_tree``) is a per-level Python loop of dynamic
gathers; TPUs have no efficient per-row gather, so the TPU-native
formulation turns every gather of the traversal into a small one-hot
contraction (the same trick the ``hist`` kernel uses for scatters):

    node one-hot (rows, 2^D-1) @ threshold     -> per-row threshold
    node one-hot @ feature one-hot (2^D-1, F)  -> per-row feature mask
    sum(x * feature mask, axis=1)              -> per-row feature value
    leaf one-hot (rows, 2^D) @ leaf            -> per-row leaf value

Each grid cell traverses ONE tree over ONE row tile; the grid is
(trees, row-tiles), so the whole forest scores in a single
``pallas_call``.  Every contraction selects exactly one element
(1.0 * v + 0.0 + ...), so the kernel is bit-exact with the gather-based
reference — the parity tests assert equality, not closeness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _infer_kernel(feat_ref, thr_ref, leaf_ref, x_ref, o_ref, *,
                  depth: int, block_n: int, n_feat: int):
    feat = feat_ref[0]                       # (2^D - 1,) int32
    thr = thr_ref[0]                         # (2^D - 1,) f32
    leaf = leaf_ref[0]                       # (2^D,) f32
    x = x_ref[...]                           # (block_n, F) f32
    n_internal = feat.shape[0]
    n_leaves = leaf.shape[0]

    # feature one-hot per internal node; no-split nodes (feature = -1)
    # match nothing -> all-zero row -> xv = 0 (routing ignores it anyway)
    f_iota = jax.lax.broadcasted_iota(jnp.int32, (n_internal, n_feat), 1)
    feat_oh = (feat[:, None] == f_iota).astype(jnp.float32)
    no_split = (feat < 0).astype(jnp.float32)

    node = jnp.zeros((block_n,), jnp.int32)
    for _ in range(depth):
        n_iota = jax.lax.broadcasted_iota(jnp.int32,
                                          (block_n, n_internal), 1)
        node_oh = (node[:, None] == n_iota).astype(jnp.float32)
        t = node_oh @ thr                                   # (block_n,)
        dead = node_oh @ no_split                           # 1.0 = no split
        sel = node_oh @ feat_oh                             # (block_n, F)
        xv = jnp.sum(x * sel, axis=1)
        go_left = (dead < 0.5) & (xv <= t)
        node = 2 * node + jnp.where(go_left, 1, 2)

    leaf_idx = node - n_internal
    l_iota = jax.lax.broadcasted_iota(jnp.int32, (block_n, n_leaves), 1)
    leaf_oh = (leaf_idx[:, None] == l_iota).astype(jnp.float32)
    o_ref[...] = (leaf_oh @ leaf)[None, :]


def forest_infer_pallas(feature, threshold, leaf, x, *,
                        block_n: int = 256, interpret: bool = False):
    """Score a stacked forest on a batch of rows.

    Usage contract:
      * feature (T, 2^D - 1) int32 (-1 = no split), threshold
        (T, 2^D - 1) f32 raw values, leaf (T, 2^D) f32 — the dense-heap
        layout of ``repro.trees.growth.Tree`` with a leading tree axis.
      * x (n, F) f32 raw features (thresholds are raw values, so no
        binning at serve time).
      * Rows are zero-padded up to a ``block_n`` multiple; traversal is
        row-independent, so pad rows are sliced off the output unseen.
      * VMEM per cell is O(block_n * (2^D + F)); shrink ``block_n`` for
        very deep trees.
      * interpret=True runs the same program in the Pallas interpreter —
        the CPU fallback (see ``repro.kernels.forest_infer.ops``).

    Returns (T, n) f32 per-tree leaf values — identical to
    ``trees.growth.predict_forest`` bit for bit.
    """
    T, n_internal = feature.shape
    n, F = x.shape
    n_leaves = leaf.shape[1]
    depth = n_internal.bit_length()  # 2^D - 1 internal -> D levels
    assert n_leaves == n_internal + 1, "leaf axis must be 2^depth"
    block_n = min(block_n, max(n, 1))
    pad_n = (-n) % block_n
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
    np_ = x.shape[0]
    grid = (T, np_ // block_n)
    out = pl.pallas_call(
        functools.partial(_infer_kernel, depth=depth, block_n=block_n,
                          n_feat=F),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_internal), lambda t, s: (t, 0)),
            pl.BlockSpec((1, n_internal), lambda t, s: (t, 0)),
            pl.BlockSpec((1, n_leaves), lambda t, s: (t, 0)),
            pl.BlockSpec((block_n, F), lambda t, s: (s, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda t, s: (t, s)),
        out_shape=jax.ShapeDtypeStruct((T, np_), jnp.float32),
        interpret=interpret,
    )(feature, threshold.astype(jnp.float32), leaf.astype(jnp.float32),
      x.astype(jnp.float32))
    return out[:, :n]
