"""Public jit'd wrapper for the forest-inference kernel (serving path)."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import autotune
from repro.kernels.forest_infer.kernel import forest_infer_pallas
from repro.kernels.forest_infer.ref import forest_infer_ref
from repro.obs import annotate


def forest_infer(forest, x, *, impl: str = "auto",
                 block_n: Optional[int] = None):
    """Per-tree leaf values for a stacked forest (the serving hot path).

    Args:
      forest: any object with dense-heap ``feature`` (T, 2^D - 1) int32,
        ``threshold`` (T, 2^D - 1) f32, ``leaf`` (T, 2^D) f32 arrays —
        ``repro.trees.growth.Tree`` with a leading tree axis, as produced
        by every tree pipeline in the repo.
      x: (n, F) f32 raw features (thresholds are raw values; no binning
        needed at serve time).
      impl: routing table, mirroring ``repro.kernels.hist.ops`` —

        ==================  ==================================================
        ``"auto"``          Pallas kernel on TPU/GPU, XLA reference on CPU.
        ``"pallas"``        force the kernel; on CPU degrades to
                            ``interpret=True`` (same kernel program, no
                            Mosaic compile) instead of failing.
        ``"pallas_interpret"``  force interpreter mode on any backend.
        ``"xla"``           force the vmapped gather reference.
        ==================  ==================================================

    ``block_n`` (row-tile size) defaults to the autotune cache entry for
    this shape bucket (``repro.kernels.autotune``) and falls back to the
    hand-picked 256; an explicit value always wins.

    Returns (T, n) f32 — bit-exact with
    ``trees.growth.predict_forest(forest, x)`` on every impl (the kernel's
    one-hot contractions each select exactly one element).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() != "cpu" else "xla"
    if impl in ("pallas", "pallas_interpret"):
        cfg = autotune.resolve("forest_infer", x.shape, x.dtype,
                               block_n=block_n)
        interpret = (impl == "pallas_interpret"
                     or jax.default_backend() == "cpu")
        with annotate("kernels.forest_infer.pallas"):
            return forest_infer_pallas(forest.feature, forest.threshold,
                                       forest.leaf, x,
                                       block_n=cfg["block_n"],
                                       interpret=interpret)
    if impl != "xla":
        raise ValueError(f"unknown forest_infer impl {impl!r}")
    with annotate("kernels.forest_infer.xla"):
        return forest_infer_ref(forest.feature, forest.threshold,
                                forest.leaf, x)
