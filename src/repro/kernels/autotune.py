"""Kernel autotuning: tunable registry, best-config cache, sweep harness.

Every Pallas kernel family in the repo carries hand-picked tile/block
shapes (hist's ``block_n``/``block_f``, forest_infer's row tile, flash
attention's q/kv blocks, the SSD chunk).  This module makes those shapes
*tunable* instead of hard-coded:

* :data:`TUNABLES` — one entry per kernel family: the hand-picked
  defaults (exactly the values the kernels shipped with, so behaviour
  with an empty cache is unchanged) and the candidate sweep grid.
* **Shape buckets** — configs are cached per ``(kernel, shape-bucket,
  dtype, platform)``: each dimension of the timed shape is rounded up to
  the next power of two, so one tuned entry serves every nearby shape
  (a 4.1k-row batch and a 7.9k-row batch hit the same ``8192`` bucket).
* :class:`ConfigStore` — a JSON file of best configs
  (``results/autotune/best_configs.json`` by default, override with
  ``REPRO_AUTOTUNE_CACHE``).  Keys are plain strings, entries carry the
  winning config plus the measured time and device metadata; the file is
  written sorted so the store is byte-stable across runs.
* :func:`autotune` — the sweep harness: build a candidate callable per
  config, time it with warm-up iterations and ``jax.block_until_ready``
  (median of ``iters`` timed calls), keep the fastest, and cache it.
* :func:`resolve` — what the kernel ``ops.py`` entry points call: start
  from the family defaults, overlay a cached best config if one matches
  the current shape bucket, and let explicit caller arguments win over
  both.

``python -m repro.kernels.autotune --smoke`` sweeps every family on
canonical shapes and writes the store (docs/EXPERIMENTS.md §Perf gate).
``tools/check_docs.py`` validates that every TUNABLES family name is
documented.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

#: kernel family -> {"defaults": hand-picked params (the pre-autotune
#: values — the fallback when no cache entry matches), "candidates":
#: per-param sweep values}.  Families: ``hist`` (gradient histograms),
#: ``forest_infer`` (per-tree serving traversal), ``forest_score_fused``
#: (fused traversal + ensemble + Platt), ``flash_attention``, ``ssd``.
TUNABLES: Dict[str, Dict[str, Dict[str, Any]]] = {
    "hist": {
        "defaults": {"block_n": 1024, "block_f": 8},
        "candidates": {"block_n": (256, 512, 1024, 2048),
                       "block_f": (2, 4, 8, 16)},
    },
    "forest_infer": {
        "defaults": {"block_n": 256},
        "candidates": {"block_n": (64, 128, 256, 512, 1024)},
    },
    "forest_score_fused": {
        "defaults": {"block_n": 256},
        "candidates": {"block_n": (64, 128, 256, 512, 1024)},
    },
    "flash_attention": {
        "defaults": {"block_q": 512, "block_kv": 512},
        "candidates": {"block_q": (128, 256, 512),
                       "block_kv": (128, 256, 512)},
    },
    "ssd": {
        "defaults": {"chunk": 64},
        "candidates": {"chunk": (32, 64, 128)},
    },
}


# --- cache keys ---------------------------------------------------------------

def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def shape_bucket(shape: Iterable[int]) -> Tuple[int, ...]:
    """Round every dimension up to the next power of two.

    Nearby shapes share a bucket (4097 rows and 8000 rows both key as
    8192), so a tuned config is reused instead of re-swept per exact
    shape."""
    return tuple(_next_pow2(int(d)) for d in shape)


def cache_key(kernel: str, shape: Iterable[int], dtype,
              platform: Optional[str] = None) -> str:
    """Stable string key ``kernel|bucket|dtype|platform``.

    Deterministic across processes: no hashing, just the bucketed dims
    joined with ``x`` and the canonical numpy dtype name."""
    if kernel not in TUNABLES:
        raise KeyError(f"unknown kernel family {kernel!r}; "
                       f"available: {sorted(TUNABLES)}")
    bucket = "x".join(str(d) for d in shape_bucket(shape))
    dname = jnp.dtype(dtype).name
    return f"{kernel}|{bucket}|{dname}|{platform or jax.default_backend()}"


# --- the on-disk store --------------------------------------------------------

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
_STORE_VERSION = 1


def default_store_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(_REPO_ROOT, "results", "autotune",
                     "best_configs.json"))


class ConfigStore:
    """JSON-backed best-config cache.

    ``entries`` maps :func:`cache_key` strings to
    ``{"config": {...}, "us": float, "device": str, "jax": str}``.
    ``save`` writes keys sorted (byte-stable file) via a temp-file
    rename, so concurrent readers never see a torn write."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_store_path()
        self.entries: Dict[str, Dict] = {}
        if os.path.exists(self.path):
            with open(self.path) as f:
                data = json.load(f)
            if data.get("version") != _STORE_VERSION:
                raise ValueError(
                    f"autotune store {self.path} has version "
                    f"{data.get('version')!r}, expected {_STORE_VERSION}")
            self.entries = data.get("entries", {})

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        """The cached best config for ``key``, or None."""
        entry = self.entries.get(key)
        return dict(entry["config"]) if entry else None

    def put(self, key: str, config: Dict[str, Any], **meta) -> None:
        self.entries[key] = {"config": dict(config), **meta}

    def save(self) -> str:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": _STORE_VERSION,
                       "entries": dict(sorted(self.entries.items()))},
                      f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)
        return self.path


_default_store: Optional[ConfigStore] = None


def _store() -> ConfigStore:
    global _default_store
    if _default_store is None or \
            _default_store.path != default_store_path():
        _default_store = ConfigStore()
    return _default_store


def reset_default_store() -> None:
    """Drop the cached module-level store (tests; env-var changes)."""
    global _default_store
    _default_store = None


# --- resolution (what ops.py calls) -------------------------------------------

def resolve(kernel: str, shape: Iterable[int], dtype=jnp.float32, *,
            platform: Optional[str] = None,
            store: Optional[ConfigStore] = None,
            **overrides) -> Dict[str, Any]:
    """Tuned parameters for one kernel call.

    Precedence (lowest to highest): hand-picked defaults from
    :data:`TUNABLES` < cached best config matching the shape bucket <
    explicit caller ``overrides`` (any override that is not None wins).
    With an empty cache and no overrides this returns exactly the
    defaults, so untuned behaviour is unchanged."""
    cfg = dict(TUNABLES[kernel]["defaults"])
    st = store if store is not None else _store()
    cached = st.get(cache_key(kernel, shape, dtype, platform))
    if cached:
        cfg.update({k: v for k, v in cached.items() if k in cfg})
    cfg.update({k: v for k, v in overrides.items() if v is not None})
    return cfg


# --- sweep harness ------------------------------------------------------------

def candidate_configs(kernel: str) -> List[Dict[str, Any]]:
    """Cartesian product of the family's candidate values, deterministic
    order (sorted param names, listed candidate order)."""
    cands = TUNABLES[kernel]["candidates"]
    names = sorted(cands)
    return [dict(zip(names, vals))
            for vals in itertools.product(*(cands[n] for n in names))]


def time_fn(fn: Callable[[], Any], *, iters: int = 10,
            warmup: int = 2) -> float:
    """Median wall-time of ``fn()`` in microseconds.

    ``warmup`` untimed calls absorb compilation; every call is fenced
    with ``jax.block_until_ready`` so async dispatch cannot hide device
    time."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn())
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def autotune(kernel: str, build: Callable[[Dict[str, Any]],
                                          Callable[[], Any]],
             shape: Iterable[int], dtype=jnp.float32, *,
             store: Optional[ConfigStore] = None, iters: int = 10,
             warmup: int = 2, save: bool = True,
             verbose: bool = False) -> Tuple[Dict[str, Any], float]:
    """Sweep every candidate config for ``kernel`` and cache the winner.

    ``build(config)`` returns a nullary callable running the kernel
    under that config (typically a jitted closure); it may raise to
    mark a config invalid for the shape (e.g. a tile larger than VMEM
    allows) — failed candidates are skipped, not fatal.  Returns
    ``(best_config, best_us)`` and writes the store entry under
    :func:`cache_key` unless ``save=False``."""
    st = store if store is not None else _store()
    key = cache_key(kernel, shape, dtype)
    best_cfg, best_us = None, float("inf")
    for config in candidate_configs(kernel):
        try:
            fn = build(config)
            us = time_fn(fn, iters=iters, warmup=warmup)
        except Exception as e:  # noqa: BLE001 — a bad tile is a skip
            if verbose:
                print(f"  {kernel} {config}: skipped ({e})")
            continue
        if verbose:
            print(f"  {kernel} {config}: {us:.1f}us")
        if us < best_us:
            best_cfg, best_us = config, us
    if best_cfg is None:
        raise RuntimeError(f"autotune({kernel!r}): every candidate failed")
    st.put(key, best_cfg, us=round(best_us, 3),
           device=jax.devices()[0].device_kind, jax=jax.__version__)
    if save:
        st.save()
    return best_cfg, best_us


# --- canonical sweeps (the CLI) -----------------------------------------------

def _sweep_hist(shape, dtype, **kw):
    from repro.kernels.hist.kernel import hist_pallas
    from repro.kernels.hist.ref import hist_ref
    n, F, n_bins = shape
    rng = jax.random.PRNGKey(0)
    bins = jax.random.randint(rng, (n, F), 0, n_bins)
    g = jax.random.normal(rng, (n,), dtype)
    on_cpu = jax.default_backend() == "cpu"

    def build(cfg):
        if on_cpu:
            # CPU has no compiled kernel; tune the XLA path's shape
            # bucket so the entry exists (config is a no-op there)
            return jax.jit(lambda: hist_ref(bins, g, jnp.abs(g), n_bins))
        return jax.jit(lambda: hist_pallas(bins, g, jnp.abs(g), n_bins,
                                           **cfg))
    return autotune("hist", build, (n, F), dtype, **kw)


def _sweep_forest(kernel, shape, dtype, **kw):
    from repro.kernels.forest_infer.kernel import forest_infer_pallas
    from repro.kernels.forest_infer.ref import forest_infer_ref
    T, depth, n, F = shape
    n_int = 2 ** depth - 1
    ks = [jax.random.fold_in(jax.random.PRNGKey(1), i) for i in range(4)]
    feat = jax.random.randint(ks[0], (T, n_int), 0, F)
    thr = jax.random.normal(ks[1], (T, n_int))
    leaf = jax.random.normal(ks[2], (T, n_int + 1))
    x = jax.random.normal(ks[3], (n, F), dtype)
    on_cpu = jax.default_backend() == "cpu"

    def build(cfg):
        if kernel == "forest_score_fused":
            from repro.kernels.forest_infer.fused import (
                fused_forest_score_pallas, fused_forest_score_ref)
            if on_cpu:
                return jax.jit(lambda: fused_forest_score_ref(
                    feat, thr, leaf, x, mode="margin"))
            return jax.jit(lambda: fused_forest_score_pallas(
                feat, thr, leaf, x, mode="margin", **cfg))
        if on_cpu:
            return jax.jit(lambda: forest_infer_ref(feat, thr, leaf, x))
        return jax.jit(lambda: forest_infer_pallas(feat, thr, leaf, x,
                                                   **cfg))
    return autotune(kernel, build, (n, F), dtype, **kw)


def _sweep_attention(shape, dtype, **kw):
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.models.attention import chunked_attention
    B, T, H, dh = shape
    rng = jax.random.PRNGKey(2)
    q = jax.random.normal(rng, (B, T, H, dh), dtype)
    on_cpu = jax.default_backend() == "cpu"

    def build(cfg):
        if on_cpu:
            return jax.jit(lambda: chunked_attention(q, q, q, causal=True,
                                                     kv_chunk=512))
        return jax.jit(lambda: flash_attention(q, q, q, causal=True,
                                               **cfg))
    return autotune("flash_attention", build, shape, dtype, **kw)


def _sweep_ssd(shape, dtype, **kw):
    from repro.kernels.ssd.kernel import ssd_pallas
    from repro.models.ssm import ssd_chunked
    B, T, H, P, N = shape
    ks = [jax.random.fold_in(jax.random.PRNGKey(3), i) for i in range(5)]
    x = jax.random.normal(ks[0], (B, T, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a = jax.random.normal(ks[2], (H,)) * 0.5
    b = jax.random.normal(ks[3], (B, T, 1, N)) * 0.3
    c = jax.random.normal(ks[4], (B, T, 1, N)) * 0.3
    on_cpu = jax.default_backend() == "cpu"

    def build(cfg):
        if on_cpu:
            return jax.jit(lambda: ssd_chunked(x, dt, a, b, c,
                                               cfg["chunk"])[0])
        return jax.jit(lambda: ssd_pallas(x, dt, a, b, c, cfg["chunk"]))
    return autotune("ssd", build, shape, dtype, **kw)


def sweep_all(*, smoke: bool = False, store: Optional[ConfigStore] = None,
              verbose: bool = True) -> Dict[str, Dict[str, Any]]:
    """Tune every family on a canonical shape; returns name -> config."""
    kw = dict(store=store, verbose=verbose,
              iters=3 if smoke else 10, warmup=1 if smoke else 2)
    shapes = {
        "hist": (512, 8, 16) if smoke else (65536, 32, 64),
        "forest_infer": (16, 4, 512, 8) if smoke else (128, 8, 4096, 15),
        "forest_score_fused": ((16, 4, 512, 8) if smoke
                               else (128, 8, 4096, 15)),
        "flash_attention": ((1, 128, 2, 32) if smoke
                            else (1, 2048, 8, 64)),
        "ssd": (1, 128, 2, 16, 16) if smoke else (1, 1024, 8, 64, 64),
    }
    out = {}
    out["hist"], _ = _sweep_hist(shapes["hist"], jnp.float32, **kw)
    out["forest_infer"], _ = _sweep_forest(
        "forest_infer", shapes["forest_infer"], jnp.float32, **kw)
    out["forest_score_fused"], _ = _sweep_forest(
        "forest_score_fused", shapes["forest_score_fused"], jnp.float32,
        **kw)
    out["flash_attention"], _ = _sweep_attention(
        shapes["flash_attention"], jnp.float32, **kw)
    out["ssd"], _ = _sweep_ssd(shapes["ssd"], jnp.float32, **kw)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + few iterations (CI-sized)")
    ap.add_argument("--out", default=None,
                    help="store path (default: REPRO_AUTOTUNE_CACHE or "
                    "results/autotune/best_configs.json)")
    args = ap.parse_args()
    store = ConfigStore(args.out) if args.out else _store()
    configs = sweep_all(smoke=args.smoke, store=store)
    for name, cfg in sorted(configs.items()):
        print(f"{name}: {cfg}")
    print(f"store: {store.save()} ({len(store.entries)} entries)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
