"""Pallas TPU flash attention (blockwise online softmax).

TPU adaptation notes (DESIGN.md §Hardware-adaptation): the GPU flash
algorithm's warp-level softmax is re-blocked for VMEM/MXU — q blocks of
``block_q`` rows stay resident in VMEM while the kv-block grid dimension
iterates sequentially (TPU grids are sequential on the last axis), carrying
(m, l, acc) in VMEM scratch. Matmul dims are 128-aligned for the MXU.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks); kv block index maps
GQA q-heads onto their kv head via integer division.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: Optional[int],
                 block_q: int, block_kv: int, seq_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # (block_q, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (block_kv, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 0)
    kv_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 1)
    mask = kv_pos < seq_kv
    if causal:
        mask = mask & (kv_pos <= q_pos)
    if window is not None:
        mask = mask & (kv_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * corr
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, :, 0, :] = (acc_scr[...]
                             / jnp.maximum(l_scr[...], 1e-30)
                             ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False):
    """q (B,T,H,dh); k,v (B,S,K,dh) with H = G*K. Returns (B,T,H,dh)."""
    B, T, H, dh = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    block_q = min(block_q, T)
    block_kv = min(block_kv, S)
    pad_t = (-T) % block_q
    pad_s = (-S) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, 0))) if pad_t else q
    kp = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0))) if pad_s else k
    vp = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0))) if pad_s else v
    Tp, Sp = T + pad_t, S + pad_s
    nq, nk = Tp // block_q, Sp // block_kv

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=dh ** -0.5, causal=causal, window=window,
            block_q=block_q, block_kv=block_kv, seq_kv=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dh),
                         lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_kv, 1, dh),
                         lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_kv, 1, dh),
                         lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dh),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tp, H, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :T]
