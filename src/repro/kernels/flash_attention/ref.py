"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None):
    """Naive full-matrix attention. q (B,T,H,dh); k,v (B,S,K,dh)."""
    B, T, H, dh = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, dh).astype(jnp.float32) * dh ** -0.5
    s = jnp.einsum("btkgd,bskd->btkgs", qg, k.astype(jnp.float32))
    q_pos = jnp.arange(T)[:, None]
    kv_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask = mask & (kv_pos <= q_pos)
    if window is not None:
        mask = mask & (kv_pos > q_pos - window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("btkgs,bskd->btkgd", w, v.astype(jnp.float32))
    return out.reshape(B, T, H, dh).astype(q.dtype)
