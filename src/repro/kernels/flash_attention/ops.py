"""Public jit'd wrapper: picks the Pallas kernel (TPU, or interpret mode on
CPU for validation) or the chunked-XLA path used by the dry-run."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import autotune
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import chunked_attention
from repro.obs import annotate


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              impl: str = "auto", block_q: Optional[int] = None,
              block_kv: Optional[int] = None):
    """impl: 'pallas' | 'pallas_interpret' | 'xla' | 'ref' | 'auto'.

    block_q/block_kv default to the autotune cache entry for q's shape
    bucket (``repro.kernels.autotune``), falling back to the hand-picked
    512/512; explicit values always win."""
    if impl == "auto":
        impl = "pallas" if not _on_cpu() else "xla"
    if impl in ("pallas", "pallas_interpret"):
        cfg = autotune.resolve("flash_attention", q.shape, q.dtype,
                               block_q=block_q, block_kv=block_kv)
        with annotate("kernels.flash_attention.pallas"):
            return flash_attention(q, k, v, causal=causal, window=window,
                                   block_q=cfg["block_q"],
                                   block_kv=cfg["block_kv"],
                                   interpret=(impl == "pallas_interpret"))
    if impl == "xla":
        with annotate("kernels.flash_attention.xla"):
            return chunked_attention(q, k, v, causal=causal,
                                     window=window)
    return attention_ref(q, k, v, causal=causal, window=window)
