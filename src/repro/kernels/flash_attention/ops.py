"""Public jit'd wrapper: picks the Pallas kernel (TPU, or interpret mode on
CPU for validation) or the chunked-XLA path used by the dry-run."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import chunked_attention


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              impl: str = "auto", block_q: int = 512, block_kv: int = 512):
    """impl: 'pallas' | 'pallas_interpret' | 'xla' | 'ref' | 'auto'."""
    if impl == "auto":
        impl = "pallas" if not _on_cpu() else "xla"
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv)
    if impl == "pallas_interpret":
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=True)
    if impl == "xla":
        return chunked_attention(q, k, v, causal=causal, window=window)
    return attention_ref(q, k, v, causal=causal, window=window)
