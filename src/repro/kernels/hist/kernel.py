"""Pallas TPU kernel: gradient/hessian histogram build for GBDT training.

This is the compute hot-spot of the paper's local XGBoost training
(§4.9 notes local XGBoost cost as a limitation) and the layer FedTree-style
systems optimize.  GPU implementations scatter with atomics; TPUs have no
atomics, so the TPU-native formulation (DESIGN.md §Hardware-adaptation)
turns the scatter into an MXU contraction per (sample-block, feature-block):

    one_hot(bins)ᵀ @ [grad, hess]  --  (F_b·B_bins, N_b) x (N_b, 2)

The sample-block grid axis is sequential; the (F_b, B_bins, 2) output block
stays resident in VMEM and accumulates across sample blocks.

Client-batched builds (the federated tree engine) add a leading *client*
grid axis: bins ``(C, n, F)`` runs as grid ``(C, F_blocks, N_blocks)`` with
one VMEM-resident output block per (client, feature-block) — every client
shard is histogrammed by the same kernel program in one ``pallas_call``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(bins_ref, gh_ref, o_ref, *, n_bins: int, block_f: int,
                 block_n: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bins = bins_ref[0]                         # (block_n, block_f) int32
    gh = gh_ref[0].astype(jnp.float32)         # (block_n, 2)
    iota = jax.lax.broadcasted_iota(jnp.int32,
                                    (block_n, block_f, n_bins), 2)
    onehot = (bins[:, :, None] == iota).astype(jnp.float32)
    oh2 = onehot.reshape(block_n, block_f * n_bins)
    upd = jax.lax.dot_general(oh2, gh, (((0,), (0,)), ((), ())))
    o_ref[...] += upd.reshape(1, block_f, n_bins, 2)


def hist_pallas(bins, grad, hess, n_bins: int, *, block_n: int = 1024,
                block_f: int = 8, interpret: bool = False):
    """Pallas gradient/hessian histogram.

    Usage contract:
      * bins (n, F) int32 with values in [0, n_bins); out-of-range bins
        contribute nothing (the one-hot comparison never matches).  A
        leading client axis is accepted: bins (C, n, F) with grad/hess
        (C, n) returns (C, F, n_bins, 2) — one histogram per client
        shard, built by the same kernel over a (C, F_blk, N_blk) grid.
      * grad / hess (n,) or (C, n) float; cast to f32 inside the kernel.
      * Inputs are zero-padded up to block multiples: padded samples
        carry grad = hess = 0 (bin 0 receives zero mass — no effect) and
        padded feature columns are sliced off the output, so padding is
        invisible to callers.
      * The (block_n, block_f, n_bins) one-hot lives in VMEM: keep
        block_n * block_f * n_bins * 4B within the VMEM budget (shrink
        block_f for wide level-combined histograms).
      * interpret=True runs the same kernel in the Pallas interpreter —
        the CPU fallback used when no TPU/GPU is present (see
        ``repro.kernels.hist.ops.gradient_histogram``).

    Returns (F, n_bins, 2) — or (C, F, n_bins, 2) for client-stacked
    input — float32: grad sums in [..., 0], hess sums in [..., 1].
    """
    squeeze = bins.ndim == 2
    if squeeze:
        bins, grad, hess = bins[None], grad[None], hess[None]
    C, n, F = bins.shape
    block_n = min(block_n, max(n, 1))
    block_f = min(block_f, F)
    pad_n = (-n) % block_n
    pad_f = (-F) % block_f
    gh = jnp.stack([grad, hess], axis=-1).astype(jnp.float32)  # (C, n, 2)
    if pad_n:
        bins = jnp.pad(bins, ((0, 0), (0, pad_n), (0, 0)))
        gh = jnp.pad(gh, ((0, 0), (0, pad_n), (0, 0)))  # zero grad -> noop
    if pad_f:
        bins = jnp.pad(bins, ((0, 0), (0, 0), (0, pad_f)))
    _, np_, Fp = bins.shape
    grid = (C, Fp // block_f, np_ // block_n)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, n_bins=n_bins, block_f=block_f,
                          block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, block_f), lambda c, f, s: (c, s, f)),
            pl.BlockSpec((1, block_n, 2), lambda c, f, s: (c, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_f, n_bins, 2),
                               lambda c, f, s: (c, f, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((C, Fp, n_bins, 2), jnp.float32),
        interpret=interpret,
    )(bins, gh)
    out = out[:, :F]
    return out[0] if squeeze else out
