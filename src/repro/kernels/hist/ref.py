"""Pure-jnp oracle for the histogram kernel."""
import jax
import jax.numpy as jnp


def hist_ref(bins, grad, hess, n_bins: int):
    """bins (n,F) int32; grad/hess (n,) -> (F, n_bins, 2) fp32.

    A leading client axis is accepted: (C,n,F)/(C,n) -> (C,F,n_bins,2)
    via vmap (one independent histogram per client shard)."""
    if bins.ndim == 3:
        return jax.vmap(lambda b, g, h: hist_ref(b, g, h, n_bins))(
            bins, grad, hess)

    def per_feature(col):
        g = jax.ops.segment_sum(grad.astype(jnp.float32), col, n_bins)
        h = jax.ops.segment_sum(hess.astype(jnp.float32), col, n_bins)
        return jnp.stack([g, h], axis=-1)
    return jax.vmap(per_feature, in_axes=1)(bins)
