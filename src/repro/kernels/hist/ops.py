"""Public jit'd wrapper for the GBDT gradient histogram."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import autotune
from repro.kernels.hist.kernel import hist_pallas
from repro.kernels.hist.ref import hist_ref
from repro.obs import annotate


def gradient_histogram(bins, grad, hess, n_bins: int, *, impl: str = "auto",
                       block_n: Optional[int] = None,
                       block_f: Optional[int] = None):
    """Per-feature gradient/hessian histogram (the tree-growth hot path).

    Args:
      bins: (n, F) int32, values in [0, n_bins); out-of-range bins are
        silently dropped (the one-hot match never fires).  Client-batched
        builds pass a leading client axis — bins (C, n, F) with grad/hess
        (C, n) — and get back (C, F, n_bins, 2): one histogram per client
        shard in a single call (the Pallas kernel runs it as an extra
        grid dimension, the XLA reference as a vmap).
      grad/hess: (n,) or (C, n) float, per-sample first/second-order
        gradients.
      n_bins: histogram width (tree growth passes n_nodes * n_bins to
        histogram a whole level in one call).
      block_n/block_f: Pallas tile sizes.  Default None consults the
        autotune cache (``repro.kernels.autotune``, keyed on the bins
        shape bucket/dtype/platform) and falls back to the hand-picked
        1024/8; explicit values always win.
      impl: routing table —

        ==================  ==================================================
        ``"auto"``          Pallas kernel on TPU/GPU, XLA reference on CPU.
        ``"pallas"``        force the kernel; on CPU degrades to
                            ``interpret=True`` (same kernel program, no
                            Mosaic compile) instead of failing, so the
                            federated tree pipelines run the identical
                            code path everywhere.
        ``"pallas_interpret"``  force interpreter mode on any backend.
        ``"xla"``           force the segment-sum reference.
        ==================  ==================================================

    Returns (F, n_bins, 2) float32 — or (C, F, n_bins, 2) for
    client-stacked input: [..., 0] = sum of grad, [..., 1] = sum of hess
    per (feature, bin).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() != "cpu" else "xla"
    if impl in ("pallas", "pallas_interpret"):
        cfg = autotune.resolve("hist", bins.shape[-2:], grad.dtype,
                               block_n=block_n, block_f=block_f)
        interpret = (impl == "pallas_interpret"
                     or jax.default_backend() == "cpu")
        with annotate("kernels.hist.pallas"):
            return hist_pallas(bins, grad, hess, n_bins,
                               interpret=interpret, **cfg)
    with annotate("kernels.hist.xla"):
        return hist_ref(bins, grad, hess, n_bins)
