"""Public jit'd wrapper for the GBDT gradient histogram."""
from __future__ import annotations

import jax

from repro.kernels.hist.kernel import hist_pallas
from repro.kernels.hist.ref import hist_ref


def gradient_histogram(bins, grad, hess, n_bins: int, *, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() != "cpu" else "xla"
    if impl in ("pallas", "pallas_interpret"):
        return hist_pallas(bins, grad, hess, n_bins,
                           interpret=(impl == "pallas_interpret"))
    return hist_ref(bins, grad, hess, n_bins)
