"""Public jit'd wrapper for the SSD scan."""
from __future__ import annotations

import jax

from repro.kernels.ssd.kernel import ssd_pallas
from repro.models.ssm import ssd_chunked


def ssd(x, dt, a_log, b, c, chunk: int, *, impl: str = "auto",
        init_state=None):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() != "cpu" else "xla"
    if impl in ("pallas", "pallas_interpret"):
        assert init_state is None, "pallas SSD path starts from zero state"
        return ssd_pallas(x, dt, a_log, b, c, chunk,
                          interpret=(impl == "pallas_interpret"))
    return ssd_chunked(x, dt, a_log, b, c, chunk, init_state=init_state)
