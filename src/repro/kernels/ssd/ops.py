"""Public jit'd wrapper for the SSD scan."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import autotune
from repro.kernels.ssd.kernel import ssd_pallas
from repro.models.ssm import ssd_chunked
from repro.obs import annotate


def ssd(x, dt, a_log, b, c, chunk: Optional[int] = None, *,
        impl: str = "auto", init_state=None):
    """chunk=None consults the autotune cache for x's shape bucket
    (``repro.kernels.autotune``; hand-picked fallback 64).  Callers with
    a model-config chunk pass it explicitly and are unaffected."""
    if chunk is None:
        chunk = autotune.resolve("ssd", x.shape, x.dtype)["chunk"]
    if impl == "auto":
        impl = "pallas" if jax.default_backend() != "cpu" else "xla"
    if impl in ("pallas", "pallas_interpret"):
        assert init_state is None, "pallas SSD path starts from zero state"
        with annotate("kernels.ssd.pallas"):
            return ssd_pallas(x, dt, a_log, b, c, chunk,
                              interpret=(impl == "pallas_interpret"))
    with annotate("kernels.ssd.xla"):
        return ssd_chunked(x, dt, a_log, b, c, chunk,
                           init_state=init_state)
