"""Pure-jnp oracle: re-exports the model's chunked SSD (itself validated
against a step-by-step sequential recurrence in tests)."""
from repro.models.ssm import ssd_chunked as ssd_ref  # noqa: F401


def ssd_sequential(x, dt, a_log, b, c):
    """O(T) sequential recurrence — the ground-truth semantics."""
    import jax
    import jax.numpy as jnp
    B, T, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    f32 = jnp.float32
    A = -jnp.exp(a_log.astype(f32))
    dtf = dt.astype(f32)
    bf = jnp.repeat(b.astype(f32), rep, axis=2)
    cf = jnp.repeat(c.astype(f32), rep, axis=2)
    xf = x.astype(f32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        da = jnp.exp(dtt * A)  # (B,H)
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dtt, bt, xt)
        state = state * da[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", ct, state)
        return state, y

    s0 = jnp.zeros((B, H, P, N), f32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          bf.transpose(1, 0, 2, 3), cf.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
