"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation (DESIGN.md): the SSD algorithm is grid-mapped as
(batch, head, chunk) with the chunk axis sequential; the running inter-chunk
state (N, P) lives in VMEM scratch across chunk steps (the TPU-native
replacement for the GPU kernel's cross-block shared-memory handoff).  The
intra-chunk quadratic term is two (Q,Q)x(Q,P) MXU matmuls.

Inputs are pre-scaled (xdt = x*dt, da = dt*A) so the kernel holds the scan
structure; softplus/gating stay in the XLA graph outside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, da_ref, b_ref, c_ref, y_ref, fin_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)     # (Q, P)
    da = da_ref[0, :, 0].astype(jnp.float32)          # (Q,)
    b = b_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)

    cum = jnp.cumsum(da)                              # (Q,)
    diff = cum[:, None] - cum[None, :]
    q_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    q_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(q_j <= q_i, jnp.exp(diff), 0.0)  # (Q, Q)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # (Q,Q)
    y_intra = jax.lax.dot_general(scores * Lmat, xdt,
                                  (((1,), (0,)), ((), ())))       # (Q,P)

    state = state_scr[...]                            # (N, P)
    decay_in = jnp.exp(cum)[:, None]                  # (Q,1)
    y_inter = jax.lax.dot_general(c * decay_in, state,
                                  (((1,), (0,)), ((), ())))

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1] - cum)[:, None]    # (Q,1)
    upd = jax.lax.dot_general(b * decay_to_end, xdt,
                              (((0,), (0,)), ((), ())))           # (N,P)
    state_scr[...] = state * jnp.exp(cum[-1]) + upd

    @pl.when(ci == nc - 1)
    def _fin():
        fin_ref[0, 0, :, :] = state_scr[...]


def ssd_pallas(x, dt, a_log, b, c, chunk: int, *, interpret: bool = False):
    """Same contract as ``repro.models.ssm.ssd_chunked`` (init_state=None).

    x (B,T,H,P); dt (B,T,H) softplus-ed; a_log (H,); b,c (B,T,G,N).
    Returns (y (B,T,H,P) in x.dtype, final_state (B,H,P,N) fp32).
    """
    B, T, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    rep = H // G
    f32 = jnp.float32
    A = -jnp.exp(a_log.astype(f32))
    dtf = dt.astype(f32)
    xdt = x.astype(f32) * dtf[..., None]
    da = dtf * A

    grid = (B, H, nc)
    y, fin = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bb, h, i: (bb, i, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, h, i: (bb, i, h)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda bb, h, i: (bb, i, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda bb, h, i: (bb, i, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bb, h, i: (bb, i, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bb, h, i: (bb, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), f32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), f32)],
        interpret=interpret,
    )(xdt, da, b, c)
    return y, fin.transpose(0, 1, 3, 2)
