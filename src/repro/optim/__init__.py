from repro.optim.optimizers import (  # noqa: F401
    adam, adamw, sgd, OptState, fedprox_grad, cosine_schedule,
)
