"""Minimal optimizer library (no optax offline): SGD, Adam, AdamW with
pytree states, FedProx proximal gradient wrapper, LR schedules.

Each optimizer is (init(params) -> state, update(grads, state, params, lr)
-> (new_params, new_state)) packaged in a small namespace object.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def sgd(momentum: float = 0.0) -> Optimizer:
    def init(params):
        mu = (jax.tree.map(jnp.zeros_like, params) if momentum else None)
        return OptState(jnp.zeros((), jnp.int32), mu, None)

    def update(grads, state, params, lr):
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
            upd = mu
        else:
            mu, upd = None, grads
        new = jax.tree.map(lambda p, u: p - lr * u.astype(p.dtype),
                           params, upd)
        return new, OptState(state.step + 1, mu, None)

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        z2 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), z, z2)

    def update(grads, state, params, lr):
        t = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
            state.nu, grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new = jax.tree.map(upd, params, mu, nu)
        return new, OptState(t, mu, nu)

    return Optimizer(init, update)


def adamw(weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(weight_decay=weight_decay, **kw)


def fedprox_grad(grads, params, global_params, mu: float):
    """FedProx: add mu * (theta - theta_global) to the local gradient."""
    return jax.tree.map(
        lambda g, p, gp: g + mu * (p.astype(jnp.float32)
                                   - gp.astype(jnp.float32)),
        grads, params, global_params)


def cosine_schedule(base_lr: float, total_steps: int,
                    warmup: int = 0) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(
            total_steps - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr
