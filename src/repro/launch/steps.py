"""Step-function builders shared by dryrun / train / serve / fed_train.

Builds jit-able train / prefill / decode steps for any (arch, shape) with
sharding trees derived from the logical-axis rules, plus abstract
(ShapeDtypeStruct) input pytrees for compile-only dry-runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import api
from repro.models.params import (ParamDef, abstract_tree, init_tree, is_def,
                                 pdef, spec_tree)
from repro.optim import adamw, fedprox_grad
from repro.optim.optimizers import OptState
from repro.sharding.rules import (DECODE_RULES, LONG_DECODE_RULES,
                                  TRAIN_RULES, Rules, ShardingCtx)


def production_rules(mesh, phase: str, shape_name: str = "") -> Rules:
    """Adapt the base rule tables to the actual mesh axes.

    Multi-pod ('pod' axis present): batch gains the pod axis (pure DP across
    pods — params replicated per pod, grad all-reduce crosses the pod axis,
    matching the pods-as-federated-clients deployment); the long_500k cache
    spreads its sequence over every axis."""
    if phase == "decode":
        base = dict(LONG_DECODE_RULES if shape_name == "long_500k"
                    else DECODE_RULES)
    else:
        base = dict(TRAIN_RULES)
    if mesh is not None and "pod" in mesh.shape:
        if base.get("batch") == "data":
            base["batch"] = ("pod", "data")
        if shape_name == "long_500k":
            base["cache_seq"] = ("pod", "data", "model")
    return base


def make_ctx(mesh, phase: str, shape_name: str = "",
             run: Optional[RunConfig] = None) -> ShardingCtx:
    rules = production_rules(mesh, phase, shape_name)
    disabled = []
    if run is not None and not run.fsdp_params:
        disabled.append("fsdp")
    if run is not None and not run.seq_shard_activations:
        disabled.append("act_seq")
    return ShardingCtx(mesh=mesh, rules=rules, disabled=tuple(disabled))


def _cast_defs(defs, dtype):
    return jax.tree.map(
        lambda d: ParamDef(d.shape, d.axes, d.init, d.scale, dtype)
        if jnp.issubdtype(d.dtype, jnp.floating) else d,
        defs, is_leaf=is_def)


def opt_defs(param_defs_tree):
    """Adam mu/nu ParamDefs matching params (fp32, same sharding)."""
    f32 = jax.tree.map(
        lambda d: ParamDef(d.shape, d.axes, "zeros", 1.0, jnp.float32),
        param_defs_tree, is_leaf=is_def)
    return {"step": pdef((), (), init="zeros", dtype=jnp.int32),
            "mu": f32, "nu": f32}


# --- step functions -----------------------------------------------------------

def build_train_step(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx,
                     lr: float = 3e-4, prox_mu: float = 0.0):
    """Build the jit-able train step.

    With ``prox_mu > 0`` the step accepts an optional 4th argument
    ``ref_params`` (the round's global params) and adds the FedProx
    proximal gradient ``mu * (params - ref_params)`` before the
    optimizer update; existing 3-arg call sites are unaffected."""
    opt = adamw(weight_decay=0.01)

    def train_step(params, opt_state, batch, ref_params=None):
        def loss_fn(p):
            loss, metrics = api.train_loss(p, batch, cfg, run, ctx)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if prox_mu > 0 and ref_params is not None:
            grads = fedprox_grad(grads, params, ref_params, prox_mu)
        state = OptState(opt_state["step"], opt_state["mu"],
                         opt_state["nu"])
        new_params, new_state = opt.update(grads, state, params, lr)
        new_opt = {"step": new_state.step, "mu": new_state.mu,
                   "nu": new_state.nu}
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step


def build_prefill_step(cfg, run, ctx, shape: ShapeConfig):
    window = api.decode_window(cfg, shape)

    def prefill_step(params, batch):
        return api.prefill(params, batch, cfg, run, ctx, window=window)

    return prefill_step


def build_decode_step(cfg, run, ctx, shape: ShapeConfig):
    window = api.decode_window(cfg, shape)

    def decode_step(params, cache, batch):
        logits, new_cache = api.decode_step(params, batch, cache, cfg, run,
                                            ctx, window=window)
        return logits, new_cache

    return decode_step


# --- abstract inputs + shardings ----------------------------------------------

def step_artifacts(cfg: ModelConfig, shape: ShapeConfig, run: RunConfig,
                   mesh) -> Dict[str, Any]:
    """Everything needed to lower one (arch, shape): the step fn, abstract
    args, and in/out sharding trees."""
    ctx = make_ctx(mesh, shape.phase, shape.name, run)
    pdefs = api.param_defs(cfg)
    idefs = api.input_defs(cfg, shape)
    if shape.phase == "train":
        odefs = opt_defs(pdefs)
        step = build_train_step(cfg, run, ctx)
        abstract = (abstract_tree(ctx, pdefs), abstract_tree(ctx, odefs),
                    abstract_tree(ctx, idefs))
        in_specs = (spec_tree(ctx, pdefs), spec_tree(ctx, odefs),
                    spec_tree(ctx, idefs))
        out_specs = (spec_tree(ctx, pdefs), spec_tree(ctx, odefs), None)
        donate = (0, 1)
    elif shape.phase == "prefill":
        sp_defs = _cast_defs(pdefs, jnp.bfloat16)  # serving params in bf16
        cdefs = api.cache_defs(cfg, shape.global_batch, shape.seq_len)
        step = build_prefill_step(cfg, run, ctx, shape)
        abstract = (abstract_tree(ctx, sp_defs), abstract_tree(ctx, idefs))
        in_specs = (spec_tree(ctx, sp_defs), spec_tree(ctx, idefs))
        out_specs = (None, spec_tree(ctx, cdefs))
        donate = ()
    else:  # decode
        sp_defs = _cast_defs(pdefs, jnp.bfloat16)
        cdefs = api.cache_defs(cfg, shape.global_batch, shape.seq_len)
        step = build_decode_step(cfg, run, ctx, shape)
        abstract = (abstract_tree(ctx, sp_defs), abstract_tree(ctx, cdefs),
                    abstract_tree(ctx, idefs))
        in_specs = (spec_tree(ctx, sp_defs), spec_tree(ctx, cdefs),
                    spec_tree(ctx, idefs))
        out_specs = (None, spec_tree(ctx, cdefs))
        donate = (1,)
    return dict(ctx=ctx, step=step, abstract=abstract, in_specs=in_specs,
                out_specs=out_specs, donate=donate, param_defs=pdefs)


def concrete_inputs(cfg, shape, run, mesh, seed: int = 0):
    """Materialized (small-config) inputs for smoke tests / real runs."""
    import numpy as np
    rng = jax.random.PRNGKey(seed)
    ctx = make_ctx(mesh, shape.phase, shape.name, run)
    pdefs = api.param_defs(cfg)
    params = init_tree(rng, pdefs)
    idefs = api.input_defs(cfg, shape)

    def materialize(d: ParamDef):
        if jnp.issubdtype(d.dtype, jnp.integer):
            if d.shape == ():
                return jnp.zeros((), d.dtype)
            return jax.random.randint(rng, d.shape, 0,
                                      max(cfg.vocab_size, 2), d.dtype)
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        return jax.random.normal(rng, d.shape, jnp.float32).astype(d.dtype)

    batch = jax.tree.map(materialize, idefs, is_leaf=is_def)
    if "mask" in batch:
        batch["mask"] = jnp.ones_like(batch["mask"])
    return ctx, params, batch
