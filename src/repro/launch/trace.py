"""Traced scenario runner: any fed_train / serve_load scenario with the
``repro.obs`` tracing layer on, exporting the trace artifact plus a
per-round / per-tier summary.

One command produces a Chrome-trace / Perfetto file, stamped on the
same virtual clock the FedRuntime and the serve load engine share
(docs/ARCHITECTURE.md §Observability)::

  # (a) a sync federated run
  PYTHONPATH=src python -m repro.launch.trace --mode parametric \\
      --rounds 20 --n-clients 5 --out results/obs/sync

  # (b) an async:K run on a latency model
  PYTHONPATH=src python -m repro.launch.trace --mode parametric \\
      --schedule async:2 --latency lognormal:0.1:0.5 \\
      --out results/obs/async

  # (c) a serve-load sweep
  PYTHONPATH=src python -m repro.launch.trace --mode serve_load \\
      --sweep --deadline 0.05 --out results/obs/sweep

Each run writes ``<out>.jsonl`` (byte-stable event log) and
``<out>.trace.json`` (load it at https://ui.perfetto.dev or
chrome://tracing), then prints the aggregated span/metric summary.
``--export`` overrides the exporter set with explicit
``repro.obs.export.EXPORTERS`` specs (``jsonl:path,chrome:path``).

CI gate (the ``obs-smoke`` job)::

  PYTHONPATH=src python -m repro.launch.trace --smoke

``--smoke`` asserts the non-negotiable contract: traced runs are
**bit-exact** with untraced runs (sync, async, and serve-load parity —
tracing must never perturb the simulation), the JSONL export is
byte-stable and round-trips, and the Chrome export is valid
trace-event JSON (``json.load`` + required keys).  Sample trace
artifacts land in ``results/obs/`` for the CI artifact upload.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import (Tracer, format_summary, get_exporter, jsonl_bytes,
                       summarize, use)

SMOKE_DIR = "results/obs"


# --- scenario runners ---------------------------------------------------------

def _fed_kwargs(args) -> dict:
    return dict(n_clients=args.n_clients, rounds=args.rounds,
                partition=args.partition, participation=args.participation,
                transport=args.transport, schedule=args.schedule,
                latency=args.latency, seed=args.seed,
                n_records=args.n_records, verbose=args.verbose)


def run_fed(mode: str, args, tracer) -> dict:
    """One federated scenario under the tracer (virtual clock)."""
    from repro.launch import fed_train as FT
    kw = _fed_kwargs(args)
    with use(tracer):
        if mode == "parametric":
            return FT.simulate_parametric(model=args.model, **kw)
        if mode == "tree_subset":
            return FT.simulate_tree_subset(**kw)
        if mode == "feature_extract":
            return FT.simulate_feature_extract(**kw)
        if mode == "fed_hist":
            return FT.simulate_fed_hist(**kw)
    raise ValueError(f"unknown fed mode {mode!r}")


def run_serve(args, tracer) -> dict:
    """One serve-load run (or a QPS sweep) under the tracer."""
    from repro.serve.load import (LoadConfig, qps_sweep, simulate_load,
                                  sweep_rates)
    cfg = LoadConfig(arrivals=args.arrivals, n_requests=args.requests,
                     max_wait=args.max_wait, max_queue=args.max_queue,
                     deadline=args.deadline, service=args.service,
                     seed=args.seed)
    with use(tracer):
        if args.sweep:
            from repro.serve.load import get_service
            svc = get_service(args.service, args.seed)
            bmax = max(cfg.bucket_sizes)
            capacity = bmax / svc(bmax, bmax, 0)
            rows, max_qps = qps_sweep(cfg, sweep_rates(capacity, n=6))
            return {"rows": rows, "max_sustainable_qps": max_qps}
        res = simulate_load(cfg)
        return {"row": res.row, "records": res.records,
                "batches": res.batches}


def _export(tracer, args) -> list:
    """Run the exporter set; returns the written paths."""
    specs = (args.export.split(",") if args.export else
             [f"jsonl:{args.out}.jsonl", f"chrome:{args.out}.trace.json"])
    paths = []
    for spec in specs:
        get_exporter(spec)(tracer)
        name, _, path = spec.partition(":")
        if path:
            paths.append(path)
    return paths


# --- the smoke gate -----------------------------------------------------------

def _fed_fingerprint(out) -> str:
    """Bit-exact digest of a fed run: final metrics, full history, the
    ledger events, and the raw bytes of every param/model leaf."""
    import hashlib

    import jax
    import numpy as np
    h = hashlib.sha256()
    h.update(json.dumps(out["metrics"], sort_keys=True).encode())
    h.update(json.dumps(out.get("history", []), sort_keys=True,
                        default=float).encode())
    h.update(json.dumps(out["comm"].events, sort_keys=True).encode())
    for leaf in jax.tree.leaves(out.get("params")):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def _load_fingerprint(out) -> str:
    return json.dumps({"row": out["row"], "records": out["records"],
                       "batches": out["batches"]}, sort_keys=True)


def smoke() -> int:
    """Parity + exporter round-trip + Perfetto validity (CI gate)."""
    import os

    from repro.launch import fed_train as FT
    from repro.serve.load import LoadConfig, simulate_load

    os.makedirs(SMOKE_DIR, exist_ok=True)
    failures = []

    def check(name, fn):
        try:
            fn()
            print(f"  ok   {name}")
        except Exception as e:  # noqa: BLE001 — report all, then fail
            failures.append((name, e))
            print(f"  FAIL {name}: {e}")

    fed_kw = dict(model="logreg", n_clients=3, rounds=3, local_steps=5,
                  n_records=400, seed=0, verbose=False)
    async_kw = dict(fed_kw, schedule="async:2",
                    latency="lognormal:0.05:0.4")
    load_cfg = LoadConfig(arrivals="poisson:2000", n_requests=500,
                          deadline=0.05, max_queue=128, seed=0)
    tracers = {}

    def traced_equals_untraced():
        for label, kw in (("sync", fed_kw), ("async", async_kw)):
            base = _fed_fingerprint(FT.simulate_parametric(**kw))
            tr = Tracer(clock="virtual", meta={"scenario": label})
            with use(tr):
                traced = _fed_fingerprint(FT.simulate_parametric(**kw))
            assert traced == base, f"{label}: traced run diverged"
            assert tr.events, f"{label}: tracer recorded no events"
            tracers[label] = tr
        base = _load_fingerprint(simulate_load(load_cfg).__dict__)
        tr = Tracer(clock="virtual", meta={"scenario": "serve_load"})
        res = simulate_load(load_cfg, tracer=tr)
        assert _load_fingerprint(res.__dict__) == base, \
            "serve_load: traced run diverged"
        assert tr.events, "serve_load: tracer recorded no events"
        tracers["serve_load"] = tr

    def jsonl_round_trip():
        for label, tr in sorted(tracers.items()):
            data = jsonl_bytes(tr)
            assert data == jsonl_bytes(tr), f"{label}: export not stable"
            lines = [json.loads(l) for l in data.decode().splitlines()]
            assert lines[0]["ph"] == "meta" and \
                lines[-1]["ph"] == "metrics", f"{label}: bad framing"
            assert len(lines) == len(tr.events) + 2, \
                f"{label}: event count mismatch"
            with open(f"{SMOKE_DIR}/trace_{label}.jsonl", "wb") as f:
                f.write(data)

    def chrome_is_valid():
        for label, tr in sorted(tracers.items()):
            path = f"{SMOKE_DIR}/trace_{label}.trace.json"
            get_exporter(f"chrome:{path}")(tr)
            with open(path) as f:
                payload = json.load(f)     # Perfetto-format validity
            evs = payload["traceEvents"]
            assert evs, f"{label}: empty traceEvents"
            for ev in evs:
                assert ev["ph"] in ("X", "i", "C", "M"), ev
                if ev["ph"] == "X":
                    assert ev["dur"] >= 0 and "ts" in ev, ev

    def summary_aggregates():
        s = summarize(tracers["sync"])
        assert any(r["name"] == "fed.round" for r in s["spans"]), \
            "sync summary missing fed.round spans"
        assert s["metrics"]["msgs_delivered"]["value"] > 0

    print("trace --smoke (traced==untraced parity + exporter gates)")
    check("traced == untraced (sync, async, serve_load)",
          traced_equals_untraced)
    check("jsonl export byte-stable + round-trips", jsonl_round_trip)
    check("chrome export is valid Perfetto JSON", chrome_is_valid)
    check("summary aggregates spans + metrics", summary_aggregates)
    print(f"trace --smoke: {len(failures)} failures "
          f"(artifacts in {SMOKE_DIR}/)")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description="run a fed_train/serve_load scenario with tracing on")
    ap.add_argument("--mode", default="parametric",
                    choices=["parametric", "tree_subset",
                             "feature_extract", "fed_hist", "serve_load"])
    ap.add_argument("--out", default="results/obs/trace",
                    help="artifact prefix: writes <out>.jsonl + "
                    "<out>.trace.json")
    ap.add_argument("--export", default=None,
                    help="explicit exporter specs (comma-separated "
                    "name[:path]; overrides --out defaults)")
    ap.add_argument("--clock", default="virtual",
                    choices=["virtual", "wall"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", dest="verbose", action="store_false")
    # federated scenario axes (repro.launch.fed_train)
    ap.add_argument("--model", default="logreg")
    ap.add_argument("--n-clients", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--partition", default="iid")
    ap.add_argument("--participation", default="full")
    ap.add_argument("--transport", default="plain")
    ap.add_argument("--schedule", default="sync")
    ap.add_argument("--latency", default=None)
    ap.add_argument("--n-records", type=int, default=4238)
    # serve-load scenario axes (repro.serve.load)
    ap.add_argument("--arrivals", default="poisson:2000")
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--service", default="affine:0.001:0.00001")
    ap.add_argument("--max-wait", type=float, default=0.002)
    ap.add_argument("--max-queue", type=int, default=None)
    ap.add_argument("--deadline", type=float, default=0.05)
    ap.add_argument("--sweep", action="store_true",
                    help="serve_load: traced QPS ladder")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: parity + exporter round-trip + "
                    "Perfetto validity")
    args = ap.parse_args()
    if args.smoke:
        return smoke()
    tracer = Tracer(clock=args.clock,
                    meta={"mode": args.mode, "seed": args.seed,
                          "schedule": args.schedule})
    if args.mode == "serve_load":
        run_serve(args, tracer)
    else:
        run_fed(args.mode, args, tracer)
    paths = _export(tracer, args)
    print(format_summary(summarize(tracer)))
    for p in paths:
        print(f"wrote {p}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
