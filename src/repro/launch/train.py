"""Single-process training driver.

Runs real steps on whatever devices exist (CPU smoke / single host / a real
slice): ``--arch <id> --smoke`` trains the reduced config for a few hundred
steps on synthetic corpus data — the end-to-end example driver.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import registry as R
from repro.configs.base import RunConfig, ShapeConfig
from repro.data.pipeline import CorpusConfig, SyntheticCorpus, lm_batches
from repro.launch.steps import (build_train_step, make_ctx, opt_defs,
                                step_artifacts)
from repro.models import api
from repro.models.params import count_params, init_tree


def train(arch: str, *, smoke: bool = True, steps: int = 200,
          batch: int = 8, seq: int = 128, lr: float = 1e-3,
          log_every: int = 20, ckpt_path: str = "", seed: int = 0,
          run: RunConfig = None):
    cfg = R.get_smoke(arch) if smoke else R.get(arch)
    run = run or RunConfig()
    ctx = make_ctx(None, "train")   # null ctx on CPU; mesh via caller later
    shape = ShapeConfig("custom", seq, batch, "train")

    rng = jax.random.PRNGKey(seed)
    params = init_tree(rng, api.param_defs(cfg))
    odefs = opt_defs(api.param_defs(cfg))
    opt_state = init_tree(rng, odefs)
    n_params = count_params(api.param_defs(cfg))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, "
          f"{steps} steps @ batch {batch} seq {seq}")

    step_fn = jax.jit(build_train_step(cfg, run, ctx, lr=lr))
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size,
                                          seed=seed))
    it = lm_batches(corpus, batch, seq, seed=seed)

    losses = []
    t0 = time.time()
    for i in range(steps):
        b = next(it)
        b = _adapt_batch(b, cfg, batch, seq)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        if (i + 1) % log_every == 0:
            dt = time.time() - t0
            print(f"  step {i+1}: loss {np.mean(losses[-log_every:]):.4f} "
                  f"({dt/log_every*1e3:.0f} ms/step)")
            t0 = time.time()
    if ckpt_path:
        nbytes = save_pytree(ckpt_path, params)
        print(f"  checkpoint -> {ckpt_path} ({nbytes/1e6:.1f} MB)")
    return params, losses


def _adapt_batch(b, cfg, batch, seq):
    """Add stub-frontend inputs for encdec/vlm families."""
    out = {k: jnp.asarray(v) for k, v in b.items()}
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            np.random.default_rng(0).normal(
                0, 1, (batch, cfg.encoder.seq_len, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "vlm":
        img = cfg.encoder.num_image_tokens
        out["patches"] = jnp.asarray(
            np.random.default_rng(0).normal(
                0, 1, (batch, img, cfg.encoder.frontend_dim)), jnp.bfloat16)
        out["tokens"] = out["tokens"][:, :seq - img]
        # image positions don't contribute to the loss
        mask = np.ones((batch, seq), np.float32)
        mask[:, :img] = 0.0
        out["mask"] = jnp.asarray(mask)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="full (non-smoke) config — needs a real slice")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    params, losses = train(args.arch, smoke=not args.full,
                           steps=args.steps, batch=args.batch,
                           seq=args.seq, lr=args.lr, ckpt_path=args.ckpt)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
