"""Serving driver: batched prefill + decode loop with a KV/state cache.

``--arch <id> --smoke`` serves the reduced config on CPU: prefill a batch
of prompts, then greedy-decode N tokens per request — the inference-side
end-to-end example.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.configs.base import RunConfig, ShapeConfig
from repro.launch.steps import make_ctx
from repro.models import api
from repro.models.params import init_tree


def pad_cache(cache, target_seq: int, cfg):
    """Grow self-attn cache seq dim to the serving window."""
    def grow(k, x):
        if k in ("k", "v"):
            pad = target_seq - x.shape[2]
            if pad > 0:
                return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0),
                                   (0, 0)))
        return x
    return {k: grow(k, v) for k, v in cache.items()}


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, gen_len: int = 32, seed: int = 0,
          run: RunConfig = None, greedy: bool = True):
    cfg = R.get_smoke(arch) if smoke else R.get(arch)
    run = run or RunConfig()
    ctx = make_ctx(None, "decode")
    rng = jax.random.PRNGKey(seed)
    params = init_tree(rng, api.param_defs(cfg))

    prompts = jax.random.randint(rng, (batch, prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    pre_batch = {"tokens": prompts}
    if cfg.family == "encdec":
        pre_batch["frames"] = jax.random.normal(
            rng, (batch, cfg.encoder.seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        pre_batch["patches"] = jax.random.normal(
            rng, (batch, cfg.encoder.num_image_tokens,
                  cfg.encoder.frontend_dim), jnp.bfloat16)

    prefill = jax.jit(lambda p, b: api.prefill(p, b, cfg, run, ctx))
    t0 = time.time()
    logits, cache = prefill(params, pre_batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    total = prompt_len + gen_len + (
        cfg.encoder.num_image_tokens if cfg.family == "vlm" else 0)
    cache = pad_cache(cache, total, cfg)

    @jax.jit
    def decode(p, c, tok, pos):
        return api.decode_step(p, {"token": tok, "pos": pos}, c, cfg, run,
                               ctx)

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    start = prompt_len + (cfg.encoder.num_image_tokens
                          if cfg.family == "vlm" else 0)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(gen_len - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(start + i))
        tok = (jnp.argmax(logits, axis=-1).astype(jnp.int32) if greedy
               else jax.random.categorical(
                   jax.random.fold_in(rng, i), logits).astype(jnp.int32))
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"{cfg.name}: prefill({batch}x{prompt_len}) {t_prefill*1e3:.0f}ms,"
          f" decode {gen_len} toks @ {t_decode/max(gen_len-1,1)*1e3:.1f}"
          f" ms/tok")
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    gen = serve(args.arch, smoke=not args.full, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen_len)
    print("sample tokens:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
