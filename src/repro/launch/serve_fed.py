"""Federated-model serving driver: train -> export bundle -> score.

The tabular twin of ``launch/serve.py`` (which decodes language models):
load an exported :class:`~repro.serve.bundle.ModelBundle` — or, under
``--smoke``, freshly train all four federated pipelines on the synthetic
Framingham twin and export each — then drive the bucketed scoring engine
(``repro.serve.engine``) over a request stream and report throughput and
p50/p99 latency.

Run:
  PYTHONPATH=src python -m repro.launch.serve_fed --smoke
  PYTHONPATH=src python -m repro.launch.serve_fed --bundle results/serve/smoke/fed_hist \
      --batch 256 --bucket-sizes 64,256,1024 --requests 50

``--smoke`` is the CI gate: it round-trips a bundle from each pipeline
(parametric, tree_subset, feature_extract, fed_hist), asserts the Pallas
forest-inference kernel matches ``trees.growth.predict_forest`` exactly
in interpret mode, asserts bucketed == unbatched scoring, and exits
non-zero on any mismatch.
"""
from __future__ import annotations

import argparse
import sys

import jax.numpy as jnp
import numpy as np

from repro.core import fed_hist as FH
from repro.core import feature_extract as FE
from repro.core import parametric as P
from repro.core import tree_subset as TS
from repro.data import framingham as F
from repro.kernels.forest_infer.ops import forest_infer
from repro.serve import bundle as B
from repro.serve.engine import ScoringEngine
from repro.trees.growth import predict_forest


def train_smoke_bundles(seed: int = 0, n_records: int = 800):
    """Train all four pipelines fast on the Framingham twin and pack
    each artifact.  Returns (bundles dict, (x_test, y_test))."""
    ds = F.synthesize(n=n_records, seed=seed)
    tr, te = F.train_test_split(ds)
    clients = [(c.x, c.y) for c in F.partition_clients(tr, 3, seed)]

    params, _, _, _ = P.train_federated(
        clients, P.FedParametricConfig(model="logreg", rounds=3,
                                       local_steps=10, seed=seed))
    rf, _, _ = TS.train_federated_rf(
        clients, TS.FedForestConfig(trees_per_client=6, subset=4, depth=3,
                                    n_bins=16, seed=seed))
    fe, _, _ = FE.train_federated_xgb_fe(
        clients, FE.FedXGBConfig(num_rounds=6, shallow_rounds=2, depth=3,
                                 shallow_depth=2, top_features=6,
                                 n_bins=16, seed=seed))
    gb, _, _ = FH.train_federated_xgb_hist(
        clients, FH.FedHistConfig(num_rounds=5, depth=3, n_bins=16,
                                  seed=seed))
    bundles = {
        "parametric": B.pack("parametric", params, model="logreg"),
        "tree_subset": B.pack("tree_subset", rf),
        "feature_extract": B.pack("feature_extract", fe),
        "fed_hist": B.pack("fed_hist", gb),
    }
    return bundles, (te.x, te.y)


def _forests_of(bundle: B.ModelBundle):
    """The stacked Tree forests a bundle carries (for kernel parity)."""
    if bundle.kind == "tree_subset":
        return [bundle.model().forest]
    if bundle.kind == "fed_hist":
        return [bundle.model().forest]
    if bundle.kind == "feature_extract":
        return [m.forest for m in bundle.model().trees]
    return []


def check_kernel_parity(bundle: B.ModelBundle, x) -> None:
    """Pallas forest kernel (interpret) must equal predict_forest bit
    for bit on every forest in the bundle."""
    xj = jnp.asarray(x, jnp.float32)
    for forest in _forests_of(bundle):
        ref = np.asarray(predict_forest(forest, xj))
        pal = np.asarray(forest_infer(forest, xj,
                                      impl="pallas_interpret"))
        xla = np.asarray(forest_infer(forest, xj, impl="xla"))
        np.testing.assert_array_equal(pal, ref)
        np.testing.assert_array_equal(xla, ref)


def serve_bundle(path: str, *, batch: int, bucket_sizes, requests: int,
                 impl: str = "auto", seed: int = 0):
    """Load one bundle and score a synthetic request stream."""
    bundle = B.load_bundle(path)
    ds = F.synthesize(n=max(batch * requests, batch), seed=seed + 1)
    engine = ScoringEngine(bundle, bucket_sizes=bucket_sizes, impl=impl)
    engine.warmup(ds.x.shape[1])
    for i in range(requests):
        engine.score(ds.x[i * batch:(i + 1) * batch])
    st = engine.stats()
    print(f"{bundle.kind}: {st['rows']} rows in {st['calls']} calls  "
          f"{st['rows_per_s']:,.0f} rows/s  p50={st['p50_ms']:.2f}ms "
          f"p99={st['p99_ms']:.2f}ms")
    return st


def smoke(out_dir: str = "results/serve/smoke", *, bucket_sizes=(64, 256),
          seed: int = 0) -> int:
    """Train, export, reload, parity-check, and serve all four kinds.
    Returns a process exit code (CI gate)."""
    failures = []
    bundles, (xt, yt) = train_smoke_bundles(seed)
    for kind, bundle in bundles.items():
        try:
            path = f"{out_dir}/{kind}"
            nbytes = B.save_bundle(path, bundle)
            loaded = B.load_bundle(path)
            assert loaded.kind == kind and loaded.meta == bundle.meta
            for k, v in bundle.arrays.items():
                np.testing.assert_array_equal(np.asarray(loaded.arrays[k]),
                                              np.asarray(v))
            check_kernel_parity(loaded, xt)
            # interpret-mode engine so the CI gate exercises the same
            # kernel program that runs compiled on TPU/GPU
            engine = ScoringEngine(loaded, bucket_sizes=bucket_sizes,
                                   impl="pallas_interpret")
            engine.warmup(xt.shape[1])
            bucketed = engine.score(xt)
            np.testing.assert_array_equal(bucketed,
                                          engine.score_unbatched(xt))
            engine.calibrate(xt, yt)
            assert engine.calibration[0] > 0, "Platt slope must be > 0"
            st = engine.stats()
            print(f"  ok   {kind:16s} ckpt={nbytes / 1024:.1f}KiB  "
                  f"{st['rows_per_s']:,.0f} rows/s  "
                  f"p50={st['p50_ms']:.2f}ms p99={st['p99_ms']:.2f}ms")
        except Exception as e:  # noqa: BLE001 — report all kinds, then fail
            failures.append((kind, e))
            print(f"  FAIL {kind}: {e}")
    print(f"serve_fed --smoke: {len(failures)} failures")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bundle", help="path to an exported bundle dir")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--bucket-sizes", default="64,256,1024",
                    help="comma-separated padding buckets")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--impl", default="auto",
                    help="forest kernel routing: auto | pallas | "
                    "pallas_interpret | xla")
    ap.add_argument("--smoke", action="store_true",
                    help="train+export+parity-gate all four pipelines "
                    "(CI); exits non-zero on mismatch")
    args = ap.parse_args()
    buckets = tuple(int(b) for b in args.bucket_sizes.split(","))
    if args.smoke:
        return smoke(bucket_sizes=buckets)
    if not args.bundle:
        ap.error("--bundle is required unless --smoke")
    serve_bundle(args.bundle, batch=args.batch, bucket_sizes=buckets,
                 requests=args.requests, impl=args.impl)
    return 0


if __name__ == "__main__":
    sys.exit(main())
