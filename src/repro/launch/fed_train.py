"""Federated LM training — the paper's aggregation protocols at pod scale.

Pods = hospitals (DESIGN.md): each pod runs H local steps on its own
(non-IID) data mixture, then a cross-pod aggregation round.  The paper's
tree-subset sampling generalizes to update-subset sampling: only a
compressed wire format of each pod's delta crosses the pod axis
(``repro.core.compression.WIRE_FORMATS``), and the server applies a
named aggregation rule (``repro.core.strategies.STRATEGIES``).

Three entry points:
  * ``simulate`` — runnable federated training of a reduced arch on CPU:
    N virtual pods, vmapped client-parallel local training, strategy
    registry aggregation, wire-format compression, full comm ledger.
  * ``simulate_fed_hist`` — the non-parametric twin: histogram-aggregation
    federated GBDT (``repro.core.fed_hist``) on the Framingham twin —
    shared federated binning, per-round client histograms through the
    ledger, server-side tree growth (``--mode fed_hist`` on the CLI).
  * ``build_fed_round`` — the multi-pod dry-run artifact: params carry a
    leading pod dimension sharded over the 'pod' mesh axis; the local step
    is vmapped over it and the aggregation mean is a real cross-pod
    collective in the lowered HLO.

The round engine is batched end-to-end: client params are stacked with a
leading ``(n_pods, ...)`` axis, local steps run as a ``jax.lax.scan``
inside ``jax.vmap`` over that axis, and one jitted call advances every
pod.  ``engine="sequential"`` keeps the per-pod Python loop as a
reference implementation (the parity test in ``tests/test_fed_engine.py``
checks both paths agree on losses and final params).
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.comm import CommLog, Timer, pytree_bytes
from repro.core.compression import WIRE_FORMATS, compress_update
from repro.core.strategies import STRATEGIES, get_strategy
from repro.data.pipeline import (CorpusConfig, SyntheticCorpus, lm_batches,
                                 pod_mixtures, sync_mixtures)
from repro.launch.steps import build_train_step, make_ctx, opt_defs
from repro.models import api
from repro.models.params import init_tree


# --- batched client-parallel engine -------------------------------------------

def _stack_round_batches(iters, local_steps: int) -> Dict[str, jnp.ndarray]:
    """Prefetch one round of batches from every pod's iterator.

    Returns a dict of arrays with leading ``(n_pods, local_steps)`` axes
    (e.g. tokens ``(n_pods, local_steps, batch, seq)`` int32).  Both
    engines consume these same arrays, so data order is identical."""
    per_pod = []
    for it in iters:
        steps = [next(it) for _ in range(local_steps)]
        per_pod.append({k: np.stack([s[k] for s in steps])
                        for k in steps[0]})
    return {k: jnp.asarray(np.stack([p[k] for p in per_pod]))
            for k in per_pod[0]}


def _build_parallel_round(step_fn, n_pods: int):
    """One jitted call = one federated round of local training, all pods.

    ``step_fn(params, opt, batch, ref) -> (params, opt, metrics)`` is the
    single-pod train step; the returned ``round_fn(global_params,
    stacked_opt, stacked_batches)`` broadcasts the global params to a
    leading ``(n_pods, ...)`` axis, scans ``local_steps`` steps per pod
    under ``jax.vmap``, and returns ``(deltas, losses)`` with shapes
    ``(n_pods, *param)`` / ``(n_pods, local_steps)``."""
    def local(params, opt_state, batches, ref):
        def body(carry, b):
            p, o = carry
            p, o, m = step_fn(p, o, b, ref)
            return (p, o), m["loss"]
        (params, _), losses = jax.lax.scan(body, (params, opt_state),
                                           batches)
        return params, losses

    def round_fn(global_params, stacked_opt, stacked_batches):
        pod_params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape),
            global_params)
        new_p, losses = jax.vmap(local, in_axes=(0, 0, 0, None))(
            pod_params, stacked_opt, stacked_batches, global_params)
        deltas = jax.tree.map(lambda n, g: n - g[None], new_p,
                              global_params)
        return deltas, losses

    return jax.jit(round_fn)


def _pod_slice(tree, i: int):
    """Select pod ``i`` from a pytree with a leading pod axis."""
    return jax.tree.map(lambda x: x[i], tree)


# --- runnable simulation (CPU, reduced configs) -------------------------------

def simulate(arch: str, *, n_pods: int = 3, rounds: int = 10,
             local_steps: int = 10, batch: int = 4, seq: int = 128,
             lr: float = 1e-3, compression: str = "none",
             rho: float = 0.05, rank: int = 8,
             non_iid_alpha: float = 0.5,
             sync_sampler: bool = False, seed: int = 0,
             run: Optional[RunConfig] = None, verbose: bool = True,
             strategy: str = "fedavg", engine: str = "vmap"):
    """Federated training of the reduced ``arch`` across virtual pods.

    Args:
      arch: architecture id from ``repro.configs.registry``.
      n_pods/rounds/local_steps/batch/seq: federation shape; every local
        step consumes a ``(batch, seq)`` int32 token batch.
      lr: local Adam learning rate.
      compression: wire format name from ``WIRE_FORMATS``
        ("none" | "topk" | "lowrank" | "int8" | "int8_sr").
      rho: top-k density (fraction of delta entries kept).
      rank: lowrank sketch rank (2-D leaves only).
      strategy: aggregation rule name from ``STRATEGIES`` ("fedavg" |
        "fedavg_weighted" | "fedprox" | "fedavgm" | "fedadam").
      engine: "vmap" (default; batched client-parallel, one jitted call
        per round) or "sequential" (reference per-pod Python loop).
      non_iid_alpha: Dirichlet concentration of per-pod domain mixtures.
      sync_sampler: synchronize pod samplers (fed-SMOTE analog).

    Returns a dict with ``loss_history`` (per-round mean loss),
    ``comm`` (CommLog, exact bytes up/down per pod per round),
    ``uplink_mb``, ``final_params``, and ``round_s`` (engine wall time).
    """
    if engine not in ("vmap", "sequential"):
        raise ValueError(f"unknown engine {engine!r}; "
                         "use 'vmap' or 'sequential'")
    cfg = R.get_smoke(arch)
    run = run or RunConfig()
    ctx = make_ctx(None, "train")
    strat = get_strategy(strategy)
    rng = jax.random.PRNGKey(seed)
    global_params = init_tree(rng, api.param_defs(cfg))
    step_fn = build_train_step(cfg, run, ctx, lr=lr,
                               prox_mu=strat.client_mu)
    odefs = opt_defs(api.param_defs(cfg))

    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size,
                                          seed=seed))
    mixtures = pod_mixtures(n_pods, corpus.cfg.n_domains,
                            alpha=non_iid_alpha, seed=seed)
    if sync_sampler:  # the fed-SMOTE analog (DESIGN.md)
        m = sync_mixtures(mixtures)
        mixtures = [m for _ in mixtures]
    iters = [lm_batches(corpus, batch, seq, mixture=mixtures[i],
                        seed=seed + i) for i in range(n_pods)]

    if engine == "vmap":
        round_fn = _build_parallel_round(step_fn, n_pods)
    else:
        step_jit = jax.jit(step_fn)

    comm = CommLog()
    timer = Timer()
    ef_states: List[Optional[object]] = [None] * n_pods
    server_state = strat.init_state(global_params)
    sizes = [local_steps * batch * seq] * n_pods  # tokens seen per round
    history = []
    for r in range(rounds):
        batches = _stack_round_batches(iters, local_steps)
        opt_states = [init_tree(jax.random.fold_in(rng, r * 100 + i),
                                odefs)  # fresh local opt each round
                      for i in range(n_pods)]
        for i in range(n_pods):
            comm.log(r, f"pod{i}", "down", pytree_bytes(global_params),
                     "model")

        with timer:
            if engine == "vmap":
                stacked_opt = jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *opt_states)
                deltas, losses = round_fn(global_params, stacked_opt,
                                          batches)
                pod_deltas = [_pod_slice(deltas, i) for i in range(n_pods)]
            else:
                pod_deltas, loss_rows = [], []
                for i in range(n_pods):
                    params, opt_state = global_params, opt_states[i]
                    row = []
                    for s in range(local_steps):
                        b = {k: v[i, s] for k, v in batches.items()}
                        params, opt_state, metrics = step_jit(
                            params, opt_state, b, global_params)
                        row.append(metrics["loss"])
                    pod_deltas.append(jax.tree.map(
                        lambda a, b: a - b, params, global_params))
                    loss_rows.append(jnp.stack(row))
                losses = jnp.stack(loss_rows)
            # JAX dispatch is async: force completion so round_s times
            # the training compute, not the enqueue
            jax.block_until_ready((pod_deltas, losses))

        shipped = []
        for i in range(n_pods):
            d, ef_states[i], wire = compress_update(
                compression, pod_deltas[i], ef_states[i], rho=rho,
                rank=rank, seed=seed * 100003 + r * 1000 + i)
            comm.log(r, f"pod{i}", "up", wire, "delta")
            shipped.append(d)
        update, server_state = strat.aggregate(server_state, shipped,
                                               sizes)
        global_params = jax.tree.map(lambda g, u: g + u, global_params,
                                     update)
        history.append(float(jnp.mean(losses)))
        if verbose:
            print(f"  round {r+1}/{rounds}: loss {history[-1]:.4f} "
                  f"(uplink so far {comm.total_mb('up'):.2f} MB)")
    return {"loss_history": history, "comm": comm,
            "uplink_mb": comm.total_mb("up"),
            "final_params": global_params,
            "strategy": strat.name, "engine": engine,
            "round_s": timer.total_s}


# --- histogram-aggregation federated trees (fed_hist) -------------------------

def simulate_fed_hist(*, n_clients: int = 3, rounds: int = 20,
                      depth: int = 4, n_bins: int = 32,
                      sampling: str = "none", engine: str = "batched",
                      secure_agg: bool = False, dp_epsilon: float = 0.0,
                      hist_impl: str = "auto", seed: int = 0,
                      n_records: int = 4238, verbose: bool = True):
    """Histogram-aggregation federated GBDT on the Framingham twin.

    The tree-side counterpart of ``simulate``: one federated-binning
    round (quantile sketches up, shared edges down), then per boosting
    round every client ships (F, 2^level * n_bins, 2) grad/hess
    histograms and the server grows the tree from the sum — exactly
    centralized GBDT on the pooled shards (``repro.core.fed_hist``).

    Returns a dict with ``metrics`` (test-set binary metrics), ``comm``
    (CommLog), ``uplink_mb``, and ``round_s`` (tree-growth wall time).
    """
    from repro.core import fed_hist as FH
    from repro.data import framingham as F

    ds = F.synthesize(n=n_records, seed=seed)
    tr, te = F.train_test_split(ds)
    clients = [(c.x, c.y) for c in F.partition_clients(tr, n_clients,
                                                       seed)]
    cfg = FH.FedHistConfig(num_rounds=rounds, depth=depth, n_bins=n_bins,
                           sampling=sampling, engine=engine,
                           secure_agg=secure_agg, dp_epsilon=dp_epsilon,
                           hist_impl=hist_impl, seed=seed)
    model, comm, timer = FH.train_federated_xgb_hist(clients, cfg)
    metrics = FH.evaluate_fed_hist(model, te.x, te.y)
    if verbose:
        per_what = {k: f"{v/1e6:.2f}MB"
                    for k, v in comm.per_what_bytes().items()}
        print(f"fed_hist: F1={metrics['f1']:.3f} "
              f"uplink={comm.uplink_mb():.2f}MB {per_what} "
              f"growth {timer.total_s:.2f}s ({engine} engine)")
    return {"metrics": metrics, "comm": comm,
            "uplink_mb": comm.total_mb("up"), "round_s": timer.total_s,
            "engine": engine}


# --- multi-pod dry-run artifact -----------------------------------------------

def build_fed_round(cfg, run: RunConfig, mesh, shape: ShapeConfig,
                    local_steps: int = 4, lr: float = 3e-4):
    """(pod-stacked params, opt, batch) -> aggregated params.

    Leading dim = n_pods, sharded over 'pod'; local steps run vmapped
    (independent per pod), then FedAvg = mean over the pod dim — a real
    all-reduce over the pod axis in the compiled HLO.
    """
    ctx = make_ctx(mesh, "train", shape.name, run)
    step = build_train_step(cfg, run, ctx, lr=lr)

    def local_rounds(params, opt_state, batches):
        def body(carry, b):
            p, o = carry
            p, o, m = step(p, o, b)
            return (p, o), m["loss"]
        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), batches)
        return params, opt_state, losses

    def fed_round(pod_params, pod_opt, pod_batches):
        new_p, new_o, losses = jax.vmap(local_rounds)(pod_params, pod_opt,
                                                      pod_batches)
        delta = jax.tree.map(lambda n, o: n - o, new_p, pod_params)
        agg = jax.tree.map(lambda d: jnp.mean(d, axis=0, keepdims=True),
                           delta)
        synced = jax.tree.map(
            lambda p, d: p + jnp.broadcast_to(d, p.shape), pod_params, agg)
        return synced, new_o, jnp.mean(losses)

    return fed_round


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "fed_hist"],
                    help="lm: federated LM pods; fed_hist: "
                    "histogram-aggregation federated GBDT on the "
                    "Framingham twin")
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--pods", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--compression", default="none",
                    choices=sorted(WIRE_FORMATS))
    ap.add_argument("--rho", type=float, default=0.05)
    ap.add_argument("--rank", type=int, default=8,
                    help="lowrank wire-format sketch rank")
    ap.add_argument("--strategy", default="fedavg",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--engine", default="vmap",
                    help="lm: vmap|sequential; fed_hist: "
                    "batched|sequential")
    ap.add_argument("--sync-sampler", action="store_true")
    # fed_hist knobs
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--n-bins", type=int, default=32)
    ap.add_argument("--sampling", default="none")
    ap.add_argument("--secure-agg", action="store_true")
    ap.add_argument("--dp-epsilon", type=float, default=0.0)
    args = ap.parse_args()
    if args.mode == "fed_hist":
        engine = ("batched" if args.engine == "vmap" else args.engine)
        simulate_fed_hist(n_clients=args.pods, rounds=args.rounds,
                          depth=args.depth, n_bins=args.n_bins,
                          sampling=args.sampling, engine=engine,
                          secure_agg=args.secure_agg,
                          dp_epsilon=args.dp_epsilon)
        return
    out = simulate(args.arch, n_pods=args.pods, rounds=args.rounds,
                   local_steps=args.local_steps,
                   compression=args.compression, rho=args.rho,
                   rank=args.rank,
                   strategy=args.strategy, engine=args.engine,
                   sync_sampler=args.sync_sampler)
    print(f"final round loss {out['loss_history'][-1]:.4f}, "
          f"uplink {out['uplink_mb']:.2f} MB, "
          f"{out['round_s']:.2f}s in local training "
          f"({args.engine} engine, {args.strategy})")


if __name__ == "__main__":
    main()
