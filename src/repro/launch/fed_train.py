"""Federated LM training — the paper's aggregation protocols at pod scale.

Pods = hospitals (DESIGN.md): each pod runs H local steps on its own
(non-IID) data mixture, then a cross-pod FedAvg round.  The paper's
tree-subset sampling generalizes to update-subset sampling: only a top-k
(density rho) magnitude subset of each pod's delta crosses the pod axis,
with error-feedback residuals (``repro.core.compression``).

Two entry points:
  * ``simulate`` — runnable federated training of a reduced arch on CPU:
    N virtual pods, real FedAvg/FedProx + compression + comm ledger.
  * ``build_fed_round`` — the multi-pod dry-run artifact: params carry a
    leading pod dimension sharded over the 'pod' mesh axis; the local step
    is vmapped over it and the aggregation mean is a real cross-pod
    collective in the lowered HLO.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.comm import CommLog, pytree_bytes
from repro.core.compression import TopKState, dense_bytes, topk_compress
from repro.data.pipeline import (CorpusConfig, SyntheticCorpus, lm_batches,
                                 pod_mixtures, sync_mixtures)
from repro.launch.steps import build_train_step, make_ctx, opt_defs
from repro.models import api
from repro.models.params import init_tree


# --- runnable simulation (CPU, reduced configs) -------------------------------

def simulate(arch: str, *, n_pods: int = 3, rounds: int = 10,
             local_steps: int = 10, batch: int = 4, seq: int = 128,
             lr: float = 1e-3, compression: str = "none",
             rho: float = 0.05, non_iid_alpha: float = 0.5,
             sync_sampler: bool = False, seed: int = 0,
             run: Optional[RunConfig] = None, verbose: bool = True):
    """Returns dict with loss history and comm ledger (dense vs shipped)."""
    cfg = R.get_smoke(arch)
    run = run or RunConfig()
    ctx = make_ctx(None, "train")
    rng = jax.random.PRNGKey(seed)
    global_params = init_tree(rng, api.param_defs(cfg))
    step_fn = jax.jit(build_train_step(cfg, run, ctx, lr=lr))
    odefs = opt_defs(api.param_defs(cfg))

    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size,
                                          seed=seed))
    mixtures = pod_mixtures(n_pods, corpus.cfg.n_domains,
                            alpha=non_iid_alpha, seed=seed)
    if sync_sampler:  # the fed-SMOTE analog (DESIGN.md)
        m = sync_mixtures(mixtures)
        mixtures = [m for _ in mixtures]
    iters = [lm_batches(corpus, batch, seq, mixture=mixtures[i],
                        seed=seed + i) for i in range(n_pods)]

    comm = CommLog()
    ef_states: List[Optional[TopKState]] = [None] * n_pods
    history = []
    for r in range(rounds):
        deltas = []
        round_losses = []
        for i in range(n_pods):
            params = global_params
            opt_state = init_tree(jax.random.fold_in(rng, r * 100 + i),
                                  odefs)  # fresh local opt (FedAvg)
            comm.log(r, f"pod{i}", "down", pytree_bytes(global_params),
                     "model")
            for s in range(local_steps):
                b = {k: jnp.asarray(v) for k, v in next(iters[i]).items()}
                params, opt_state, metrics = step_fn(params, opt_state, b)
                round_losses.append(float(metrics["loss"]))
            delta = jax.tree.map(lambda a, b: a - b, params, global_params)
            if compression == "topk":
                delta, ef_states[i], wire = topk_compress(delta, rho,
                                                          ef_states[i])
            else:
                wire = dense_bytes(delta)
            comm.log(r, f"pod{i}", "up", wire, "delta")
            deltas.append(delta)
        mean_delta = jax.tree.map(lambda *xs: sum(xs) / len(xs), *deltas)
        global_params = jax.tree.map(lambda g, d: g + d, global_params,
                                     mean_delta)
        history.append(float(np.mean(round_losses)))
        if verbose:
            print(f"  round {r+1}/{rounds}: loss {history[-1]:.4f} "
                  f"(uplink so far {comm.total_mb('up'):.2f} MB)")
    return {"loss_history": history, "comm": comm,
            "uplink_mb": comm.total_mb("up"),
            "final_params": global_params}


# --- multi-pod dry-run artifact -----------------------------------------------

def build_fed_round(cfg, run: RunConfig, mesh, shape: ShapeConfig,
                    local_steps: int = 4, lr: float = 3e-4):
    """(pod-stacked params, opt, batch) -> aggregated params.

    Leading dim = n_pods, sharded over 'pod'; local steps run vmapped
    (independent per pod), then FedAvg = mean over the pod dim — a real
    all-reduce over the pod axis in the compiled HLO.
    """
    ctx = make_ctx(mesh, "train", shape.name, run)
    step = build_train_step(cfg, run, ctx, lr=lr)

    def local_rounds(params, opt_state, batches):
        def body(carry, b):
            p, o = carry
            p, o, m = step(p, o, b)
            return (p, o), m["loss"]
        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), batches)
        return params, opt_state, losses

    def fed_round(pod_params, pod_opt, pod_batches):
        new_p, new_o, losses = jax.vmap(local_rounds)(pod_params, pod_opt,
                                                      pod_batches)
        delta = jax.tree.map(lambda n, o: n - o, new_p, pod_params)
        agg = jax.tree.map(lambda d: jnp.mean(d, axis=0, keepdims=True),
                           delta)
        synced = jax.tree.map(
            lambda p, d: p + jnp.broadcast_to(d, p.shape), pod_params, agg)
        return synced, new_o, jnp.mean(losses)

    return fed_round


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--pods", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk"])
    ap.add_argument("--rho", type=float, default=0.05)
    ap.add_argument("--sync-sampler", action="store_true")
    args = ap.parse_args()
    out = simulate(args.arch, n_pods=args.pods, rounds=args.rounds,
                   local_steps=args.local_steps,
                   compression=args.compression, rho=args.rho,
                   sync_sampler=args.sync_sampler)
    print(f"final round loss {out['loss_history'][-1]:.4f}, "
          f"uplink {out['uplink_mb']:.2f} MB")


if __name__ == "__main__":
    main()
