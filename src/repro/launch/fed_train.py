"""Federated LM training — the paper's aggregation protocols at pod scale.

Pods = hospitals (DESIGN.md): each pod runs H local steps on its own
(non-IID) data mixture, then a cross-pod aggregation round.  The paper's
tree-subset sampling generalizes to update-subset sampling: only a
compressed wire format of each pod's delta crosses the pod axis
(``repro.core.compression.WIRE_FORMATS``), and the server applies a
named aggregation rule (``repro.core.strategies.STRATEGIES``).

Three entry points:
  * ``simulate`` — runnable federated training of a reduced arch on CPU:
    N virtual pods, vmapped client-parallel local training, strategy
    registry aggregation, wire-format compression, full comm ledger.
  * ``simulate_fed_hist`` — the non-parametric twin: histogram-aggregation
    federated GBDT (``repro.core.fed_hist``) on the Framingham twin —
    shared federated binning, per-round client histograms through the
    ledger, server-side tree growth (``--mode fed_hist`` on the CLI).
  * ``build_fed_round`` — the multi-pod dry-run artifact: params carry a
    leading pod dimension sharded over the 'pod' mesh axis; the local step
    is vmapped over it and the aggregation mean is a real cross-pod
    collective in the lowered HLO.

Both simulations run on the shared :class:`~repro.core.runtime.
FedRuntime`, which owns the round loop, the participation schedule
(``--participation``: full / uniform-k / stratified / dropout with
straggler buffering), the layered wire transport (``--transport``), and
the ledger.  ``--partition`` selects the data partitioner
(``repro.data.partition``): tabular shards for ``--mode fed_hist``,
per-pod domain-mixture rows for ``--mode lm``.

The round engine is batched end-to-end: client params are stacked with a
leading ``(n_active, ...)`` axis, local steps run as a ``jax.lax.scan``
inside ``jax.vmap`` over that axis, and one jitted call advances every
participating pod.  ``engine="sequential"`` keeps the per-pod Python
loop as a reference implementation (the parity test in
``tests/test_fed_engine.py`` checks both paths agree on losses and
final params).
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.comm import (CodecLayer, Transport, get_transport,
                             pytree_bytes)
from repro.core.compression import WIRE_FORMATS
from repro.core.runtime import ClientMsg, ClientWork, FedRuntime, ServerAgg
from repro.core.strategies import STRATEGIES, get_strategy
from repro.data.pipeline import (CorpusConfig, SyntheticCorpus, lm_batches,
                                 pod_mixtures, sync_mixtures)
from repro.launch.steps import build_train_step, make_ctx, opt_defs
from repro.models import api
from repro.models.params import init_tree


# --- batched client-parallel engine -------------------------------------------

def _stack_round_batches(iters, local_steps: int) -> Dict[str, jnp.ndarray]:
    """Prefetch one round of batches from every pod's iterator.

    Returns a dict of arrays with leading ``(n_pods, local_steps)`` axes
    (e.g. tokens ``(n_pods, local_steps, batch, seq)`` int32).  Both
    engines consume these same arrays, so data order is identical."""
    per_pod = []
    for it in iters:
        steps = [next(it) for _ in range(local_steps)]
        per_pod.append({k: np.stack([s[k] for s in steps])
                        for k in steps[0]})
    return {k: jnp.asarray(np.stack([p[k] for p in per_pod]))
            for k in per_pod[0]}


def _build_parallel_round(step_fn, n_pods: int):
    """One jitted call = one federated round of local training, all pods.

    ``step_fn(params, opt, batch, ref) -> (params, opt, metrics)`` is the
    single-pod train step; the returned ``round_fn(global_params,
    stacked_opt, stacked_batches)`` broadcasts the global params to a
    leading ``(n_pods, ...)`` axis, scans ``local_steps`` steps per pod
    under ``jax.vmap``, and returns ``(deltas, losses)`` with shapes
    ``(n_pods, *param)`` / ``(n_pods, local_steps)``."""
    def local(params, opt_state, batches, ref):
        def body(carry, b):
            p, o = carry
            p, o, m = step_fn(p, o, b, ref)
            return (p, o), m["loss"]
        (params, _), losses = jax.lax.scan(body, (params, opt_state),
                                           batches)
        return params, losses

    def round_fn(global_params, stacked_opt, stacked_batches):
        pod_params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape),
            global_params)
        new_p, losses = jax.vmap(local, in_axes=(0, 0, 0, None))(
            pod_params, stacked_opt, stacked_batches, global_params)
        deltas = jax.tree.map(lambda n, g: n - g[None], new_p,
                              global_params)
        return deltas, losses

    return jax.jit(round_fn)


def _pod_slice(tree, i: int):
    """Select pod ``i`` from a pytree with a leading pod axis."""
    return jax.tree.map(lambda x: x[i], tree)


def _lm_transport(transport, compression: str, rho: float,
                  rank: int) -> Transport:
    """``compression`` (the historical knob) prepends a codec layer to
    the ``transport`` stack; specifying a codec in both is an error."""
    t = get_transport(transport, rho=rho, rank=rank)
    if compression == "none":
        return t
    if any(isinstance(l, CodecLayer) for l in t.layers):
        raise ValueError(
            f"both compression={compression!r} and a codec layer in "
            f"transport={t.name!r}; pick one")
    name = t.name if t.layers else compression
    return Transport(name, [CodecLayer(compression, rho=rho, rank=rank)]
                     + list(t.layers))


class _PodWork(ClientWork, ServerAgg):
    """LM pods on the FedRuntime: vmapped (or sequential) local training,
    strategy aggregation, wire-format compression."""

    def __init__(self, *, step_fn, odefs, init_params, strat, iters,
                 local_steps, tokens_per_round, engine, rng, verbose,
                 rounds):
        self.step_fn, self.odefs, self.init_params = step_fn, odefs, \
            init_params
        self.strat, self.iters, self.local_steps = strat, iters, \
            local_steps
        self.tokens_per_round = tokens_per_round
        self.engine, self.rng, self.verbose = engine, rng, verbose
        self.rounds = rounds
        self._round_fns: Dict[int, object] = {}
        self._step_jit = None
        self.ef: Dict[int, object] = {}   # per-pod wire-format state

    def _round_fn(self, k: int):
        if k not in self._round_fns:
            self._round_fns[k] = _build_parallel_round(self.step_fn, k)
        return self._round_fns[k]

    def setup(self, rt: FedRuntime):
        if self._step_jit is None and self.engine == "sequential":
            self._step_jit = jax.jit(self.step_fn)
        return {"params": self.init_params,
                "server": self.strat.init_state(self.init_params),
                "history": []}

    def client_round(self, rt, state, rnd):
        comp, r = rnd.computing, rnd.index
        params = state["params"]
        for i in comp:
            rt.log_down(r, i, pytree_bytes(params), "model")
        batches = _stack_round_batches([self.iters[i] for i in comp],
                                       self.local_steps)
        opt_states = [init_tree(jax.random.fold_in(self.rng, r * 100 + i),
                                self.odefs)  # fresh local opt each round
                      for i in comp]
        with rt.timer:
            if self.engine == "vmap":
                stacked_opt = jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *opt_states)
                deltas, losses = self._round_fn(len(comp))(
                    params, stacked_opt, batches)
                pod_deltas = [_pod_slice(deltas, s)
                              for s in range(len(comp))]
            else:
                pod_deltas, loss_rows = [], []
                for slot in range(len(comp)):
                    p, opt_state = params, opt_states[slot]
                    row = []
                    for s in range(self.local_steps):
                        b = {k: v[slot, s] for k, v in batches.items()}
                        p, opt_state, metrics = self._step_jit(
                            p, opt_state, b, params)
                        row.append(metrics["loss"])
                    pod_deltas.append(jax.tree.map(
                        lambda a, b: a - b, p, params))
                    loss_rows.append(jnp.stack(row))
                losses = jnp.stack(loss_rows)
            # JAX dispatch is async: force completion so round_s times
            # the training compute, not the enqueue
            jax.block_until_ready((pod_deltas, losses))

        msgs = []
        for slot, i in enumerate(comp):
            wire = rt.encode(pod_deltas[slot], round_idx=r, client=i,
                             slot=slot, n_active=len(comp),
                             state=self.ef.get(i))
            self.ef[i] = wire.state
            rt.log_up(r, i, wire.nbytes, "delta")
            msgs.append(ClientMsg(i, wire.payload, wire.nbytes,
                                  weight=self.tokens_per_round))
        state["history"].append(float(jnp.mean(losses)))
        return msgs

    def aggregate(self, rt, state, msgs, rnd):
        upd, state["server"] = self.strat.aggregate(
            state["server"], [m.payload for m in msgs],
            [m.weight for m in msgs])
        # server-side transport tail: identity for plain stacks; for
        # dpnoise-carrying transports this noises the applied update (at
        # sensitivity 1.0 — pair with a clip layer to actually bound
        # per-pod influence), keeping the RDP accountant honest
        upd = rt.post_aggregate(upd, round_idx=rnd.index)
        state["params"] = jax.tree.map(lambda g, u: g + u,
                                       state["params"], upd)
        if self.verbose:
            print(f"  round {rnd.index+1}/{self.rounds}: loss "
                  f"{state['history'][-1]:.4f} "
                  f"(uplink so far {rt.comm.total_mb('up'):.2f} MB)")
        return state

    def finalize(self, rt, state):
        return state


# --- runnable simulation (CPU, reduced configs) -------------------------------

def simulate(arch: str, *, n_pods: int = 3, rounds: int = 10,
             local_steps: int = 10, batch: int = 4, seq: int = 128,
             lr: float = 1e-3, compression: str = "none",
             rho: float = 0.05, rank: int = 8,
             non_iid_alpha: float = 0.5, partition: Optional[str] = None,
             participation: str = "full", transport: str = "plain",
             schedule: str = "sync", latency: Optional[str] = None,
             sync_sampler: bool = False, seed: int = 0,
             run: Optional[RunConfig] = None, verbose: bool = True,
             strategy: str = "fedavg", engine: str = "vmap",
             dp_budget: Optional[float] = None):
    """Federated training of the reduced ``arch`` across virtual pods.

    Args:
      arch: architecture id from ``repro.configs.registry``.
      n_pods/rounds/local_steps/batch/seq: federation shape; every local
        step consumes a ``(batch, seq)`` int32 token batch.
      lr: local Adam learning rate.
      compression: wire format name from ``WIRE_FORMATS``
        ("none" | "topk" | "lowrank" | "int8" | "int8_sr") — prepended
        to the transport stack as a codec layer.
      rho: top-k density (fraction of delta entries kept).
      rank: lowrank sketch rank (2-D leaves only).
      strategy: aggregation rule name from ``STRATEGIES`` ("fedavg" |
        "fedavg_weighted" | "fedprox" | "fedavgm" | "fedadam").
      engine: "vmap" (default; batched client-parallel, one jitted call
        per round) or "sequential" (reference per-pod Python loop).
      partition: pod-mixture partitioner ("iid" | "dirichlet" | "site",
        ``repro.data.partition.pod_mixture_matrix``); None keeps the
        historical Dirichlet mixtures.
      non_iid_alpha: Dirichlet concentration of per-pod domain mixtures.
      participation: schedule spec ("full" | "uniform:k" |
        "stratified:k" | "dropout:p[:p_straggle]") — stragglers deliver
        stale, weight-discounted updates next round.
      transport: wire layer stack spec (``repro.core.comm.TRANSPORTS``).
      schedule: execution mode ("sync" | "async:K",
        ``repro.core.runtime.SCHEDULES``) — async:K aggregates every K
        pod arrivals, staleness-discounted, on the virtual clock.
      latency: per-pod latency/availability model spec
        (``repro.core.latency.LATENCY``, e.g. "lognormal:0:1").
      sync_sampler: synchronize pod samplers (fed-SMOTE analog).
      dp_budget: cumulative RDP epsilon stop criterion (needs a
        dpnoise layer in the transport, e.g. "dp"/"secure_dp").

    Returns a dict with ``loss_history`` (per-aggregation mean loss),
    ``comm`` (CommLog, exact bytes up/down per pod per round),
    ``uplink_mb``, ``final_params``, ``round_s`` (engine wall time), and
    ``timeline`` (per-aggregation virtual-clock records).
    """
    if engine not in ("vmap", "sequential"):
        raise ValueError(f"unknown engine {engine!r}; "
                         "use 'vmap' or 'sequential'")
    # resolve registry specs up front: bad names fail before any compile
    from repro.core.participation import get_participation
    participation = get_participation(participation)
    transport = _lm_transport(transport, compression, rho, rank)
    cfg = R.get_smoke(arch)
    run = run or RunConfig()
    ctx = make_ctx(None, "train")
    strat = get_strategy(strategy)
    rng = jax.random.PRNGKey(seed)
    global_params = init_tree(rng, api.param_defs(cfg))
    step_fn = build_train_step(cfg, run, ctx, lr=lr,
                               prox_mu=strat.client_mu)
    odefs = opt_defs(api.param_defs(cfg))

    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size,
                                          seed=seed))
    if partition is None:
        mixtures = pod_mixtures(n_pods, corpus.cfg.n_domains,
                                alpha=non_iid_alpha, seed=seed)
    else:
        from repro.data.partition import pod_mixture_matrix
        mixtures = pod_mixture_matrix(partition, n_pods,
                                      corpus.cfg.n_domains,
                                      alpha=non_iid_alpha, seed=seed)
    if sync_sampler:  # the fed-SMOTE analog (DESIGN.md)
        m = sync_mixtures(mixtures)
        mixtures = [m for _ in mixtures]
    iters = [lm_batches(corpus, batch, seq, mixture=mixtures[i],
                        seed=seed + i) for i in range(n_pods)]

    work = _PodWork(step_fn=step_fn, odefs=odefs,
                    init_params=global_params, strat=strat, iters=iters,
                    local_steps=local_steps,
                    tokens_per_round=local_steps * batch * seq,
                    engine=engine, rng=rng, verbose=verbose,
                    rounds=rounds)
    rt = FedRuntime(n_clients=n_pods, rounds=rounds,
                    participation=participation, transport=transport,
                    schedule=schedule, latency=latency,
                    seed=seed, dp_budget=dp_budget,
                    client_prefix="pod")
    state = rt.run(work)
    return {"loss_history": state["history"], "comm": rt.comm,
            "uplink_mb": rt.comm.total_mb("up"),
            "final_params": state["params"],
            "strategy": strat.name, "engine": engine,
            "round_s": rt.timer.total_s, "timeline": rt.timeline}


# --- histogram-aggregation federated trees (fed_hist) -------------------------

def simulate_fed_hist(*, n_clients: int = 3, rounds: int = 20,
                      depth: int = 4, n_bins: int = 32,
                      sampling: str = "none", engine: str = "batched",
                      secure_agg: bool = False, dp_epsilon: float = 0.0,
                      hist_impl: str = "auto",
                      partition: str = "iid", alpha: float = 0.5,
                      participation: str = "full",
                      transport: str = "plain",
                      schedule: str = "sync",
                      latency: Optional[str] = None, seed: int = 0,
                      n_records: int = 4238, verbose: bool = True):
    """Histogram-aggregation federated GBDT on the Framingham twin.

    The tree-side counterpart of ``simulate``: one federated-binning
    round (quantile sketches up, shared edges down), then per boosting
    round every *participating* client ships (F, 2^level * n_bins, 2)
    grad/hess histograms and the server grows the tree from the sum —
    under full participation, exactly centralized GBDT on the pooled
    shards (``repro.core.fed_hist``).  ``partition`` shards the twin
    through ``repro.data.partition.PARTITIONERS`` (iid | dirichlet |
    quantity | site).

    Returns a dict with ``metrics`` (test-set binary metrics), ``comm``
    (CommLog), ``uplink_mb``, and ``round_s`` (tree-growth wall time).
    """
    from repro.core import fed_hist as FH
    from repro.data import framingham as F
    from repro.data import partition as P

    ds = F.synthesize(n=n_records, seed=seed)
    tr, te = F.train_test_split(ds)
    if partition == "iid":
        # historical path (seed+2 rng stream) — bit-identical shards
        shards = F.partition_clients(tr, n_clients, seed)
    else:
        kw = {"alpha": alpha} if partition in ("dirichlet",
                                               "quantity") else {}
        shards = P.partition_dataset(partition, tr, n_clients,
                                     seed=seed + 2, **kw)
    clients = [(c.x, c.y) for c in shards]
    cfg = FH.FedHistConfig(num_rounds=rounds, depth=depth, n_bins=n_bins,
                           sampling=sampling, engine=engine,
                           secure_agg=secure_agg, dp_epsilon=dp_epsilon,
                           hist_impl=hist_impl,
                           participation=participation,
                           transport=transport, schedule=schedule,
                           latency=latency, seed=seed)
    model, comm, timer = FH.train_federated_xgb_hist(clients, cfg)
    metrics = FH.evaluate_fed_hist(model, te.x, te.y)
    if verbose:
        per_what = {k: f"{v/1e6:.2f}MB"
                    for k, v in comm.per_what_bytes().items()}
        print(f"fed_hist: F1={metrics['f1']:.3f} "
              f"uplink={comm.uplink_mb():.2f}MB ({tier_summary(comm)}) "
              f"{per_what} growth {timer.total_s:.2f}s ({engine} engine)")
    return {"metrics": metrics, "comm": comm,
            "uplink_mb": comm.total_mb("up"), "round_s": timer.total_s,
            "engine": engine, "timeline": comm.timeline}


def tier_summary(comm) -> str:
    """Per-tier uplink breakdown for the end-of-run summary line:
    ``edge=…MB wan=…MB`` for hierarchical ledgers, ``star=…MB`` (the
    flat total) when untiered — every mode prints it, not just the
    sharded cohort path.  When the run carried a DP ledger
    (``CommLog.privacy``, the runtime's RDP accountant snapshot) the
    cumulative epsilon rides along: ``eps=…@delta=…``."""
    parts = [f"{k}={v/1e6:.2f}MB"
             for k, v in sorted(comm.per_tier_bytes("up").items())]
    p = getattr(comm, "privacy", None)
    if p:
        parts.append(f"eps={p['epsilon']:.2f}@delta={p['delta']:.0e}")
        if "budget_stop_round" in p:
            parts.append(f"dp-budget-stop@r{p['budget_stop_round']}")
    return " ".join(parts)


# --- tabular pipeline drivers (paper C1-C3 on the Framingham twin) ------------

def _tabular_clients(n_clients: int, partition: str, alpha: float,
                     seed: int, n_records: int):
    from repro.data import framingham as F
    from repro.data import partition as P

    ds = F.synthesize(n=n_records, seed=seed)
    tr, te = F.train_test_split(ds)
    if partition == "iid":
        shards = F.partition_clients(tr, n_clients, seed)
    else:
        kw = {"alpha": alpha} if partition in ("dirichlet",
                                               "quantity") else {}
        shards = P.partition_dataset(partition, tr, n_clients,
                                     seed=seed + 2, **kw)
    return [(c.x, c.y) for c in shards], (te.x, te.y)


def simulate_parametric(*, model: str = "logreg", n_clients: int = 3,
                        rounds: int = 20, local_steps: int = 20,
                        sampling: str = "none", strategy: str = "fedavg",
                        partition: str = "iid", alpha: float = 0.5,
                        participation: str = "full",
                        transport: str = "plain",
                        schedule: str = "sync",
                        latency: Optional[str] = None, seed: int = 0,
                        n_records: int = 4238, verbose: bool = True,
                        mesh: Optional[str] = None, silos: int = 1,
                        cohort: Optional[str] = None,
                        secure_agg: bool = False, dp_epsilon: float = 0.0,
                        dp_budget: Optional[float] = None):
    """Parametric FL (paper C1) on the Framingham twin — the CLI face of
    ``repro.core.parametric.train_federated``, sharing the partition /
    participation / transport / schedule axes with every other mode.

    ``cohort`` switches to the population-scale sharded engine
    (``repro.core.parametric.train_federated_sharded``): clients come
    from a synthetic cohort spec (``repro.data.cohort.COHORTS``, e.g.
    ``framingham_like:100000:16``), ``mesh`` shards the client axis over
    a device mesh (``repro.launch.mesh.MESHES``: "single" | "host[:D]"),
    and ``silos`` inserts a hierarchical client→silo→server aggregation
    tier.  Without ``cohort`` the historical per-client engine runs
    bit-identically (``mesh``/``silos`` require ``cohort`` because the
    sharded engine needs equal-sized client shards)."""
    from repro.core import parametric as P

    if cohort is None:
        if mesh is not None or silos != 1:
            raise ValueError(
                "--mesh/--silos need --cohort: the sharded engine runs "
                "on equal-sized synthetic cohort shards "
                "(e.g. --cohort framingham_like:1024:16); Framingham "
                "twin partitions stay on the per-client engine")
        clients, test = _tabular_clients(n_clients, partition, alpha,
                                         seed, n_records)
        cfg = P.FedParametricConfig(model=model, rounds=rounds,
                                    local_steps=local_steps,
                                    sampling=sampling, strategy=strategy,
                                    participation=participation,
                                    transport=transport,
                                    schedule=schedule,
                                    latency=latency, seed=seed,
                                    secure_agg=secure_agg,
                                    dp_epsilon=dp_epsilon,
                                    dp_budget=dp_budget)
        params, comm, history, timer = P.train_federated(clients, cfg,
                                                         test=test)
    else:
        from repro.data.cohort import cohort_testset, get_cohort
        spec = get_cohort(cohort)
        cfg = P.FedParametricConfig(model=model, rounds=rounds,
                                    local_steps=local_steps,
                                    sampling=sampling, strategy=strategy,
                                    participation=participation,
                                    transport=transport,
                                    schedule=schedule,
                                    latency=latency, seed=seed,
                                    secure_agg=secure_agg,
                                    dp_epsilon=dp_epsilon,
                                    dp_budget=dp_budget)
        params, comm, history, timer = P.train_federated_sharded(
            spec, cfg, mesh=mesh, silos=silos,
            test=cohort_testset(seed))
    metrics = history[-1] if history else {}
    if verbose and metrics:
        print(f"parametric/{model}: F1={metrics['f1']:.3f} "
              f"uplink={comm.uplink_mb():.2f}MB ({tier_summary(comm)}) "
              f"agg {timer.total_s:.2f}s ({schedule})")
    return {"params": params, "metrics": metrics, "history": history,
            "comm": comm, "uplink_mb": comm.total_mb("up"),
            "round_s": timer.total_s, "timeline": comm.timeline}


def simulate_tree_subset(*, n_clients: int = 3, trees_per_client: int = 20,
                         subset: Optional[int] = None, depth: int = 6,
                         n_bins: int = 32, sampling: str = "none",
                         engine: str = "batched", hist_impl: str = "auto",
                         partition: str = "iid", alpha: float = 0.5,
                         participation: str = "full",
                         transport: str = "plain",
                         schedule: str = "sync",
                         latency: Optional[str] = None, seed: int = 0,
                         n_records: int = 4238, verbose: bool = True):
    """Tree-subset federated RF (paper C2) on the Framingham twin."""
    from repro.core import tree_subset as TS

    clients, test = _tabular_clients(n_clients, partition, alpha, seed,
                                     n_records)
    cfg = TS.FedForestConfig(trees_per_client=trees_per_client,
                             subset=subset, depth=depth, n_bins=n_bins,
                             sampling=sampling, engine=engine,
                             hist_impl=hist_impl,
                             participation=participation,
                             transport=transport, schedule=schedule,
                             latency=latency, seed=seed)
    model, comm, timer = TS.train_federated_rf(clients, cfg)
    metrics = TS.evaluate_rf(model, test[0], test[1])
    if verbose:
        print(f"tree_subset: F1={metrics['f1']:.3f} "
              f"uplink={comm.uplink_mb():.2f}MB ({tier_summary(comm)}) "
              f"({schedule})")
    return {"model": model, "metrics": metrics, "comm": comm,
            "uplink_mb": comm.total_mb("up"), "round_s": timer.total_s,
            "timeline": comm.timeline}


def simulate_feature_extract(*, n_clients: int = 3, rounds: int = 15,
                             depth: int = 4, n_bins: int = 32,
                             sampling: str = "none",
                             engine: str = "batched",
                             hist_impl: str = "auto",
                             partition: str = "iid", alpha: float = 0.5,
                             participation: str = "full",
                             transport: str = "plain",
                             schedule: str = "sync",
                             latency: Optional[str] = None, seed: int = 0,
                             n_records: int = 4238,
                             verbose: bool = True):
    """XGBoost feature-extraction FL (paper C3) on the Framingham twin."""
    from repro.core import feature_extract as FE

    clients, test = _tabular_clients(n_clients, partition, alpha, seed,
                                     n_records)
    cfg = FE.FedXGBConfig(num_rounds=rounds, depth=depth, n_bins=n_bins,
                          sampling=sampling, engine=engine,
                          hist_impl=hist_impl,
                          participation=participation,
                          transport=transport, schedule=schedule,
                          latency=latency, seed=seed)
    model, comm, timer = FE.train_federated_xgb_fe(clients, cfg)
    metrics = FE.evaluate_fe(model, test[0], test[1])
    if verbose:
        print(f"feature_extract: F1={metrics['f1']:.3f} "
              f"uplink={comm.uplink_mb():.2f}MB ({tier_summary(comm)}) "
              f"({schedule})")
    return {"model": model, "metrics": metrics, "comm": comm,
            "uplink_mb": comm.total_mb("up"), "round_s": timer.total_s,
            "timeline": comm.timeline}


# --- multi-pod dry-run artifact -----------------------------------------------

def build_fed_round(cfg, run: RunConfig, mesh, shape: ShapeConfig,
                    local_steps: int = 4, lr: float = 3e-4):
    """(pod-stacked params, opt, batch) -> aggregated params.

    Leading dim = n_pods, sharded over 'pod'; local steps run vmapped
    (independent per pod), then FedAvg = mean over the pod dim — a real
    all-reduce over the pod axis in the compiled HLO.
    """
    ctx = make_ctx(mesh, "train", shape.name, run)
    step = build_train_step(cfg, run, ctx, lr=lr)

    def local_rounds(params, opt_state, batches):
        def body(carry, b):
            p, o = carry
            p, o, m = step(p, o, b)
            return (p, o), m["loss"]
        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), batches)
        return params, opt_state, losses

    def fed_round(pod_params, pod_opt, pod_batches):
        new_p, new_o, losses = jax.vmap(local_rounds)(pod_params, pod_opt,
                                                      pod_batches)
        delta = jax.tree.map(lambda n, o: n - o, new_p, pod_params)
        agg = jax.tree.map(lambda d: jnp.mean(d, axis=0, keepdims=True),
                           delta)
        synced = jax.tree.map(
            lambda p, d: p + jnp.broadcast_to(d, p.shape), pod_params, agg)
        return synced, new_o, jnp.mean(losses)

    return fed_round


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm",
                    choices=["lm", "parametric", "tree_subset",
                             "feature_extract", "fed_hist"],
                    help="lm: federated LM pods; parametric / "
                    "tree_subset / feature_extract / fed_hist: the four "
                    "paper pipelines on the Framingham twin — all five "
                    "share the partition / participation / transport / "
                    "schedule / latency axes")
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--pods", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--compression", default="none",
                    choices=sorted(WIRE_FORMATS))
    ap.add_argument("--rho", type=float, default=0.05)
    ap.add_argument("--rank", type=int, default=8,
                    help="lowrank wire-format sketch rank")
    ap.add_argument("--strategy", default="fedavg",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--engine", default="vmap",
                    help="lm: vmap|sequential; fed_hist: "
                    "batched|sequential")
    ap.add_argument("--partition", default=None,
                    help="data partitioner (repro.data.partition."
                    "PARTITIONERS): lm mixtures iid|dirichlet|site; "
                    "fed_hist shards iid|dirichlet|quantity|site")
    ap.add_argument("--alpha", type=float, default=0.5,
                    help="partitioner concentration (dirichlet/quantity "
                    "skew; lm pod mixtures)")
    ap.add_argument("--participation", default="full",
                    help="participation schedule spec (repro.core."
                    "participation): full | uniform:k | stratified:k | "
                    "dropout:p[:p_straggle]")
    ap.add_argument("--transport", default="plain",
                    help="wire layer stack (repro.core.comm.TRANSPORTS "
                    "preset or '>'-joined layer spec, e.g. "
                    "'topk>mask>frame')")
    ap.add_argument("--schedule", default="sync",
                    help="execution schedule (repro.core.runtime."
                    "SCHEDULES): sync | async:K (buffered async "
                    "aggregation every K arrivals)")
    ap.add_argument("--latency", default=None,
                    help="client latency/availability model (repro.core."
                    "latency.LATENCY): constant[:t] | lognormal:mu:sigma "
                    "| trace:<file> | dropout:p, composable with '+'")
    ap.add_argument("--sync-sampler", action="store_true")
    # tabular knobs
    ap.add_argument("--model", default="logreg",
                    help="parametric mode: logreg | svm | mlp")
    ap.add_argument("--mesh", default=None,
                    help="parametric mode: device mesh spec (repro."
                    "launch.mesh.MESHES): single | host[:D] — shards "
                    "the client axis over D devices; needs --cohort")
    ap.add_argument("--silos", type=int, default=1,
                    help="parametric mode: hierarchical aggregation "
                    "tiers — clients group into this many silos, silo "
                    "partials cross the WAN; needs --cohort")
    ap.add_argument("--cohort", default=None,
                    help="parametric mode: synthetic cohort spec "
                    "(repro.data.cohort.COHORTS, e.g. "
                    "framingham_like:100000:16) — switches to the "
                    "population-scale sharded engine")
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--n-bins", type=int, default=32)
    ap.add_argument("--sampling", default="none")
    ap.add_argument("--secure-agg", action="store_true")
    ap.add_argument("--dp-epsilon", type=float, default=0.0)
    ap.add_argument("--dp-budget", type=float, default=None,
                    help="cumulative RDP epsilon stop criterion: halt "
                    "training once the accountant's max per-client "
                    "epsilon reaches this (needs a dpnoise transport, "
                    "e.g. --transport dp|secure_dp or --dp-epsilon)")
    args = ap.parse_args()
    axes = dict(partition=args.partition or "iid", alpha=args.alpha,
                participation=args.participation,
                transport=args.transport, schedule=args.schedule,
                latency=args.latency)
    tree_engine = ("batched" if args.engine == "vmap" else args.engine)
    if args.mode == "fed_hist":
        simulate_fed_hist(n_clients=args.pods, rounds=args.rounds,
                          depth=args.depth, n_bins=args.n_bins,
                          sampling=args.sampling, engine=tree_engine,
                          secure_agg=args.secure_agg,
                          dp_epsilon=args.dp_epsilon, **axes)
        return
    if args.mode == "parametric":
        simulate_parametric(model=args.model, n_clients=args.pods,
                            rounds=args.rounds,
                            local_steps=args.local_steps,
                            sampling=args.sampling,
                            strategy=args.strategy, mesh=args.mesh,
                            silos=args.silos, cohort=args.cohort,
                            secure_agg=args.secure_agg,
                            dp_epsilon=args.dp_epsilon,
                            dp_budget=args.dp_budget, **axes)
        return
    if args.mode == "tree_subset":
        simulate_tree_subset(n_clients=args.pods, depth=args.depth,
                             n_bins=args.n_bins, sampling=args.sampling,
                             engine=tree_engine, **axes)
        return
    if args.mode == "feature_extract":
        simulate_feature_extract(n_clients=args.pods, rounds=args.rounds,
                                 depth=args.depth, n_bins=args.n_bins,
                                 sampling=args.sampling,
                                 engine=tree_engine, **axes)
        return
    out = simulate(args.arch, n_pods=args.pods, rounds=args.rounds,
                   local_steps=args.local_steps,
                   compression=args.compression, rho=args.rho,
                   rank=args.rank, partition=args.partition,
                   non_iid_alpha=args.alpha,
                   participation=args.participation,
                   transport=args.transport, schedule=args.schedule,
                   latency=args.latency,
                   strategy=args.strategy, engine=args.engine,
                   sync_sampler=args.sync_sampler,
                   dp_budget=args.dp_budget)
    print(f"final round loss {out['loss_history'][-1]:.4f}, "
          f"uplink {out['uplink_mb']:.2f} MB "
          f"({tier_summary(out['comm'])}), "
          f"{out['round_s']:.2f}s in local training "
          f"({args.engine} engine, {args.strategy})")


if __name__ == "__main__":
    main()
