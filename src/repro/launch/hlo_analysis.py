"""Post-SPMD HLO analysis: collective-bytes extraction + roofline terms.

``cost_analysis`` gives FLOPs and HBM bytes of the per-device partitioned
module; collective traffic is not in it, so we parse the compiled HLO text
and sum result-shape bytes of every collective op.

Ring-model byte accounting (documented convention, docs/EXPERIMENTS.md §Methodology):
  all-gather / all-to-all / collective-permute : 1 x result bytes
  reduce-scatter                               : result bytes x (group-1)
  all-reduce                                   : 2 x result bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "all-to-all", "reduce-scatter",
                "collective-permute")
# e.g. "%ar = (f32[8,16], f32[4]) all-reduce(" or "%ag = bf16[2,4] all-gather("
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9\[\],{}: ]*?)\s*"
    r"(all-reduce|all-gather|all-to-all|reduce-scatter|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    wire_bytes: int = 0           # ring-model bytes on the wire per device

    def add(self, kind: str, result_bytes: int, group: int):
        self.bytes_by_kind[kind] = (self.bytes_by_kind.get(kind, 0)
                                    + result_bytes)
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1
        if kind == "all-reduce":
            wire = 2 * result_bytes
        elif kind == "reduce-scatter":
            wire = result_bytes * max(group - 1, 1)
        else:
            wire = result_bytes
        self.wire_bytes += wire


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        # async pairs: count -start, skip -done (same transfer)
        if f"{m.group(2)}-done(" in line:
            continue
        result_bytes = _shape_bytes(m.group(1))
        stats.add(m.group(2), result_bytes, _group_size(line))
    return stats


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+|[\w.\-]+)\s*=\s*(\([^)]*\)|"
                     r"[a-z0-9\[\],{}: ]*?)\s*([a-z][a-z0-9\-]*)\(")
_ARGS_RE = re.compile(r"%[\w.\-]+")
# ops that genuinely stream HBM on a fused TPU backend
_HBM_OPS = ("dot", "convolution", "scatter", "gather", "sort",
            "dynamic-update-slice")


def fused_memory_bytes(hlo_text: str) -> int:
    """TPU-fusion-aware HBM traffic estimate.

    The CPU backend's ``bytes accessed`` counts every elementwise /
    convert / copy op a TPU backend would fuse away, inflating the memory
    roofline term ~100x (measured; docs/EXPERIMENTS.md §Methodology).  This
    estimate counts only tensors that must stream from/to HBM:

      entry parameters (weights/caches read once)
      + root outputs
      + dot/conv/scatter/gather/sort operands and results
      + collective results.
    """
    defs: Dict[str, int] = {}
    total = 0
    in_entry = False
    entry_depth = 0
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if line.startswith("ENTRY"):
            in_entry = True
            continue
        if in_entry and line.strip() == "}":
            in_entry = False
        if not m:
            continue
        name, shape_txt, op = m.group(1), m.group(2), m.group(3)
        nbytes = _shape_bytes(shape_txt)
        defs[name.lstrip("%")] = nbytes
        if in_entry and op == "parameter":
            total += nbytes
        if in_entry and ("ROOT" in line):
            total += nbytes
        if op in _HBM_OPS:
            total += nbytes  # result
            # operands (resolved via the def map; forward refs are rare)
            tail = line[m.end():]
            for ref in _ARGS_RE.findall(tail.split("metadata=")[0]):
                total += defs.get(ref.lstrip("%"), 0)
        elif any(c in op for c in _COLLECTIVES):
            total += nbytes
    return int(total)


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float,
                   *, peak_flops: float, hbm_bw: float, ici_bw: float,
                   n_links: int = 4,
                   fused_bytes: Optional[float] = None) -> Dict[str, float]:
    """Per-device step-time lower bounds. n_links: v5e torus links per chip
    usable concurrently (2D torus -> ~4; we report the 1-link figure too).

    ``memory_s`` uses the raw (unfused, upper-bound) bytes-accessed;
    ``memory_fused_s`` the fusion-aware estimate — the dominant term is
    judged on the fused figure when available (docs/EXPERIMENTS.md
    §Methodology)."""
    compute_s = flops / peak_flops
    memory_s = hbm_bytes / hbm_bw
    coll_s = wire_bytes / (ici_bw * n_links)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s,
             "collective_s_1link": wire_bytes / ici_bw}
    mem_key = "memory_s"
    if fused_bytes is not None:
        terms["memory_fused_s"] = fused_bytes / hbm_bw
        mem_key = "memory_fused_s"
    dom = max(("compute_s", mem_key, "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms
