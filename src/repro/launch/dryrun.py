import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh),
record memory/cost/collective analysis for §Dry-run and §Roofline.

The two lines above MUST stay first: jax locks the device count at first
initialization (system prompt / DESIGN.md).  Never import this module from
tests — run it as ``python -m repro.launch.dryrun``.
"""  # noqa: E402

import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import registry as R
from repro.configs.base import INPUT_SHAPES, RunConfig
from repro.launch import mesh as M
from repro.launch.hlo_analysis import (fused_memory_bytes,
                                        parse_collectives, roofline_terms)
from repro.launch.steps import step_artifacts


def _jsonable(x):
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    return x


def _reduce_layers(cfg, n: int):
    over = {"num_layers": n}
    if cfg.encoder is not None and cfg.encoder.num_layers:
        over["encoder"] = dataclasses.replace(cfg.encoder, num_layers=n)
    return dataclasses.replace(cfg, **over)


def _lower_compile(cfg, shape, run, mesh):
    art = step_artifacts(cfg, shape, run, mesh)
    t0 = time.time()
    with jax.sharding.set_mesh(mesh):
        jitted = jax.jit(art["step"], in_shardings=art["in_specs"],
                         out_shardings=art["out_specs"],
                         donate_argnums=art["donate"])
        lowered = jitted.lower(*art["abstract"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _costs(compiled):
    cost = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = parse_collectives(txt)
    return {"flops": float(cost.get("flops", 0.0)),
            "hbm": float(cost.get("bytes accessed", 0.0)),
            "fused": float(fused_memory_bytes(txt)),
            "wire": float(coll.wire_bytes),
            "by_kind": coll.bytes_by_kind,
            "counts": coll.count_by_kind}


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               run: Optional[RunConfig] = None, mesh=None,
               save_dir: str = "results/dryrun", tag: str = "baseline",
               verbose: bool = True, pad_vocab: bool = False,
               pad_heads: bool = False) -> Dict:
    cfg = R.get(arch)
    shape = INPUT_SHAPES[shape_name]
    run = run or RunConfig()
    if getattr(run, "pad_vocab", False) or pad_vocab:
        cfg = dataclasses.replace(cfg, pad_vocab=True)
    if pad_heads:
        cfg = dataclasses.replace(cfg, pad_heads=True)
    run = dataclasses.replace(run, scan_unroll=False)
    mesh = mesh or M.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    # Pass A: rolled scans, FULL depth -> proof-of-compile + memory analysis
    # (cost_analysis of a rolled scan counts the body ONCE — see DESIGN.md —
    # so FLOPs/bytes/collectives come from passes B/C below).
    compiled, t_lower, t_compile = _lower_compile(cfg, shape, run, mesh)
    try:
        ma = compiled.memory_analysis()
        mem = {k: int(getattr(ma, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
               if hasattr(ma, k)}
    except Exception as e:  # backend may not support it
        mem = {"error": str(e)}

    # Passes B/C: fully-unrolled 2- and 4-layer variants; per-layer cost is
    # exactly linear for a homogeneous scanned stack, so
    #   cost(L) = cost(2) + (L-2)/2 * (cost(4) - cost(2)).
    run_u = dataclasses.replace(run, scan_unroll=True)
    cB, *_ = _lower_compile(_reduce_layers(cfg, 2), shape, run_u, mesh)
    cC, *_ = _lower_compile(_reduce_layers(cfg, 4), shape, run_u, mesh)
    xB, xC = _costs(cB), _costs(cC)
    L = cfg.num_layers

    def extrap(b, c):
        return b + (L - 2) / 2.0 * (c - b)

    flops = extrap(xB["flops"], xC["flops"])
    hbm_bytes = extrap(xB["hbm"], xC["hbm"])
    fused_bytes = extrap(xB["fused"], xC["fused"])
    wire_bytes = extrap(xB["wire"], xC["wire"])
    by_kind = {k: extrap(xB["by_kind"].get(k, 0), xC["by_kind"].get(k, 0))
               for k in set(xB["by_kind"]) | set(xC["by_kind"])}
    counts = {k: extrap(xB["counts"].get(k, 0), xC["counts"].get(k, 0))
              for k in set(xB["counts"]) | set(xC["counts"])}
    coll_wire = wire_bytes
    terms = roofline_terms(
        flops, hbm_bytes, coll_wire, fused_bytes=fused_bytes,
        peak_flops=M.PEAK_FLOPS_BF16, hbm_bw=M.HBM_BW, ici_bw=M.ICI_BW)

    n_chips = mesh.size
    model_flops = (6 * cfg.num_active_params() * shape.global_batch
                   * shape.seq_len if shape.phase == "train" else
                   2 * cfg.num_active_params() * shape.global_batch
                   * (shape.seq_len if shape.phase == "prefill" else 1))
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "phase": shape.phase, "tag": tag,
        "n_chips": n_chips,
        "params": cfg.num_params(),
        "active_params": cfg.num_active_params(),
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "fused_hbm_bytes_per_device": fused_bytes,
        "collective_wire_bytes": coll_wire,
        "collective_bytes_by_kind": by_kind,
        "collective_count_by_kind": counts,
        "memory_analysis": mem,
        "roofline": {k: _jsonable(v) for k, v in terms.items()},
        "model_flops_global": float(model_flops),
        "model_flops_per_device": float(model_flops / n_chips),
        "useful_flops_ratio": float(model_flops / n_chips / flops)
        if flops else 0.0,
        "lower_s": t_lower, "compile_s": t_compile,
        "cost_2layer": xB, "cost_4layer": xC,
        "run_config": dataclasses.asdict(run),
    }
    if save_dir:
        os.makedirs(save_dir, exist_ok=True)
        fn = f"{save_dir}/{tag}__{mesh_name}__{arch}__{shape_name}.json"
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        r = rec["roofline"]
        print(f"[{mesh_name}] {arch} x {shape_name}: "
              f"compute {r['compute_s']*1e3:.2f}ms  "
              f"memory {r.get('memory_fused_s', r['memory_s'])*1e3:.2f}ms"
              f"(fused; raw {r['memory_s']*1e3:.0f})  "
              f"collective {r['collective_s']*1e3:.2f}ms  "
              f"-> {r['dominant']}  "
              f"(useful-flops {rec['useful_flops_ratio']*100:.0f}%, "
              f"compile {t_compile:.0f}s)")
        if "temp_size_in_bytes" in mem:
            print(f"  memory_analysis: args "
                  f"{mem.get('argument_size_in_bytes',0)/2**30:.2f}GiB "
                  f"out {mem.get('output_size_in_bytes',0)/2**30:.2f}GiB "
                  f"temp {mem.get('temp_size_in_bytes',0)/2**30:.2f}GiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (LM archs)")
    ap.add_argument("--shape", default="all",
                    help="input-shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--save-dir", default="results/dryrun")
    # RunConfig perf levers (§Perf)
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--causal-block-skip", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--moe-impl", default="auto",
                    choices=["auto", "local", "ep"])
    ap.add_argument("--attn-kv-chunk", type=int, default=1024)
    ap.add_argument("--pad-vocab", action="store_true")
    ap.add_argument("--pad-heads", action="store_true")
    ap.add_argument("--gqa-broadcast-kv", action="store_true")
    ap.add_argument("--moe-gather-bf16", action="store_true")
    args = ap.parse_args()

    run = RunConfig(remat=args.remat,
                    causal_block_skip=args.causal_block_skip,
                    seq_shard_activations=not args.no_seq_shard,
                    fsdp_params=not args.no_fsdp,
                    moe_impl=args.moe_impl,
                    attn_kv_chunk=args.attn_kv_chunk,
                    gqa_broadcast_kv=args.gqa_broadcast_kv,
                    moe_gather_bf16=args.moe_gather_bf16)

    archs = R.LM_ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = ([False, True] if args.both_meshes
              else [args.multi_pod])
    failures = []
    for mp in meshes:
        mesh = M.make_production_mesh(multi_pod=mp)
        for arch in archs:
            for shape in shapes:
                try:
                    dryrun_one(arch, shape, run=run, mesh=mesh,
                               save_dir=args.save_dir, tag=args.tag,
                               pad_vocab=args.pad_vocab,
                               pad_heads=args.pad_heads)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mp, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nDRY-RUN: all combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
