"""Production mesh builders (functions, not module constants — importing
this module never touches jax device state).

Two families live here:

* the LM dry-run meshes (:func:`make_production_mesh` — 'data'/'model'
  TP+DP grids, optionally a leading 'pod' federation axis);
* the **federated client mesh** (:data:`MESHES` / :func:`get_fed_mesh`):
  a 1-D ``('clients',)`` mesh the sharded federated runtime
  (``repro.core.runtime.ShardedFedRuntime``) places stacked
  ``(n_clients, ...)`` pytrees over.  On CPU-only hosts, force multiple
  virtual devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
  *before* importing jax (docs/EXPERIMENTS.md §Fed scaling).
"""
from __future__ import annotations

from typing import Optional

import jax

#: federated mesh spec name -> what it builds.  Resolved via
#: :func:`get_fed_mesh` spec strings ("single", "host", "host:D").
MESHES = {
    "single": "no mesh — null sharding ctx, single-device vmap path "
              "(the default; bit-exact with the pre-mesh engine)",
    "host": "host[:D] — 1-D ('clients',) mesh over D local devices "
            "(default: all visible devices)",
}


def get_fed_mesh(spec) -> Optional[jax.sharding.Mesh]:
    """Resolve a federated client-mesh spec.

    ``None`` / ``"single"`` → no mesh (the single-device vmap path);
    ``"host"`` → 1-D ``('clients',)`` mesh over every visible device;
    ``"host:D"`` → over the first D devices (error if fewer exist).
    A prebuilt :class:`jax.sharding.Mesh` passes through unchanged.
    """
    if spec is None or isinstance(spec, jax.sharding.Mesh):
        return spec
    parts = str(spec).split(":")
    name, args = parts[0], parts[1:]
    if name not in MESHES:
        raise KeyError(f"unknown mesh spec {spec!r}; "
                       f"available: {sorted(MESHES)} "
                       f"(spec: single | host[:D])")
    if name == "single":
        if args:
            raise ValueError(f"mesh 'single' takes no args, got {spec!r}")
        return None
    devices = jax.devices()
    d = int(args[0]) if args else len(devices)
    if len(args) > 1 or d < 1:
        raise ValueError(f"bad mesh spec {spec!r}: host[:D] takes one "
                         f"integer D >= 1")
    if d > len(devices):
        raise ValueError(
            f"mesh {spec!r} wants {d} devices but only {len(devices)} "
            f"are visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={d} before "
            f"importing jax")
    return jax.sharding.Mesh(devices[:d], ("clients",))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16) = 256 chips ('data','model').
    Multi-pod: (2,16,16) = 512 chips ('pod','data','model') — the 'pod'
    axis is the federation axis (pods = hospitals, DESIGN.md)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (perf experiments / tests)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


# TPU v5e hardware constants (roofline; see docs/EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
