"""Production mesh builders (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16) = 256 chips ('data','model').
    Multi-pod: (2,16,16) = 512 chips ('pod','data','model') — the 'pod'
    axis is the federation axis (pods = hospitals, DESIGN.md)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (perf experiments / tests)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


# TPU v5e hardware constants (roofline; see docs/EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
