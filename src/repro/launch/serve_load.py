"""Serving load-engine driver: feed a ScoringEngine (or a virtual
service model) from an arrival process and report load curves.

The CLI face of ``repro.serve.load`` (docs/ARCHITECTURE.md §Serving).
Three ways to run it:

Single trace (virtual — no models, no jit; pure simulation)::

  PYTHONPATH=src python -m repro.launch.serve_load \\
      --arrivals poisson:2000 --requests 5000 --service affine:0.001:0.00001 \\
      --max-wait 0.002 --deadline 0.05

QPS sweep on a real exported bundle (service times calibrated by
measuring ``engine.score`` per padding bucket, then simulated on the
measured table so the sweep itself is replayable)::

  PYTHONPATH=src python -m repro.launch.serve_load \\
      --bundle results/serve/smoke/fed_hist --sweep --deadline 0.05

CI gate (the ``serve-load-smoke`` job)::

  PYTHONPATH=src python -m repro.launch.serve_load --smoke

``--smoke`` is virtual-only: it sweeps all three arrival families
through the queue, asserts the queue invariants (work conservation,
FIFO batches, bounded occupancy, deadline consistency), replays every
run twice and fails unless the summary rows are **byte-identical**
(the determinism gate), then writes deterministic gate rows to
``results/serve_load/serve_load_gate.json`` for
``tools/perf_gate.py --check --smoke --current
results/serve_load/serve_load_gate.json --bench BENCH_serve_load.json``.

Summary rows land in ``results/serve_load/load_bench.json``.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.serve import bundle as B
from repro.serve.engine import ScoringEngine
from repro.serve.load import (LoadConfig, calibrate_service, qps_sweep,
                              save_rows, simulate_load, sweep_rates)

OUT = "results/serve_load/load_bench.json"
GATE_OUT = "results/serve_load/serve_load_gate.json"


def _bench_meta() -> dict:
    from benchmarks.kernels_bench import bench_meta
    return bench_meta()


def _gate_row(name: str, us: float, note: str, meta: dict) -> dict:
    return {"name": name, "us": float(us), "note": note, **meta}


def check_invariants(result) -> None:
    """The queue contracts every run must satisfy (the same ones
    tests/test_serve_load.py property-tests over random traces)."""
    served = [r for r in result.records if not r["rejected"]]
    # work conservation: every admitted request is scored exactly once
    assert all(r["t_done"] is not None for r in served), \
        "admitted request never completed"
    assert sum(b["n_requests"] for b in result.batches) == len(served), \
        "batch membership != admitted count"
    # FIFO: batches serve admitted requests in arrival order
    order = []
    for r in result.records:
        if not r["rejected"]:
            order.append(r["id"])
    start_of = {r["id"]: r["t_start"] for r in served}
    starts = [start_of[i] for i in order]
    assert all(a <= b for a, b in zip(starts, starts[1:])), \
        "batch starts out of FIFO order"
    for b in result.batches:
        assert 0 < b["rows"] <= b["bucket"], "batch overflows its bucket"
        assert 0.0 < b["occupancy"] <= 1.0, "occupancy out of (0, 1]"


def run_single(args, engine=None, features=None) -> dict:
    cfg = LoadConfig(arrivals=args.arrivals, n_requests=args.requests,
                     rows=args.rows,
                     bucket_sizes=tuple(int(b) for b in
                                        args.bucket_sizes.split(",")),
                     max_wait=args.max_wait, max_queue=args.max_queue,
                     deadline=args.deadline, service=args.service,
                     seed=args.seed)
    res = simulate_load(cfg, engine=engine, features=features)
    check_invariants(res)
    return res.row


def _load_engine(args):
    """Build the engine + feature stream for --bundle runs."""
    from repro.data import framingham as F
    bundles = [B.load_bundle(p) for p in args.bundle.split(",")]
    buckets = tuple(int(b) for b in args.bucket_sizes.split(","))
    engine = ScoringEngine(bundles, bucket_sizes=buckets, impl=args.impl)
    feats = F.synthesize(n=max(buckets[-1] * 4, 1024),
                         seed=args.seed + 1).x
    engine.warmup(feats.shape[1])
    return engine, feats


def run_sweep(args) -> int:
    """Calibrated QPS sweep on a real bundle (or --service model):
    finds max-sustainable-QPS and writes the rows."""
    engine = features = None
    if args.bundle:
        engine, features = _load_engine(args)
        svc = calibrate_service(engine, features.shape[1])
        engine.reset_stats()
    else:
        from repro.serve.load import get_service
        svc = get_service(args.service, args.seed)
    buckets = tuple(int(b) for b in args.bucket_sizes.split(","))
    full_s = svc(buckets[-1], buckets[-1], 0)
    capacity = buckets[-1] / full_s
    deadline = args.deadline if args.deadline is not None \
        else max(10 * full_s, 0.05)
    cfg = LoadConfig(n_requests=args.requests, rows=args.rows,
                     bucket_sizes=buckets, max_wait=args.max_wait,
                     max_queue=args.max_queue, deadline=deadline,
                     service=svc, seed=args.seed)
    rows, max_qps = qps_sweep(cfg, sweep_rates(capacity), engine=None)
    save_rows(rows, args.out, meta={**_bench_meta(),
                                    "mode": "sweep",
                                    "capacity_qps": capacity,
                                    "max_sustainable_qps": max_qps})
    for r in rows:
        mark = "ok " if r["sustainable"] else "SAT"
        print(f"  {mark} offered={r['offered_qps']:>10.0f}/s "
              f"achieved={r['achieved_qps']:>10.0f}/s "
              f"p99={r['p99_ms']:8.2f}ms miss={r['deadline_miss_rate']:.3f} "
              f"occ={r['mean_occupancy']:.2f}")
    print(f"max sustainable QPS (p99 <= {deadline * 1e3:.0f}ms): "
          f"{max_qps if max_qps is not None else 'none'} "
          f"(capacity ~{capacity:.0f}/s)")
    return 0 if max_qps is not None else 1


def smoke() -> int:
    """Virtual-only CI gate: invariants + byte-identical replays over
    all three arrival families, then deterministic perf-gate rows."""
    failures = []

    def check(name, fn):
        try:
            fn()
            print(f"  ok   {name}")
        except Exception as e:  # noqa: BLE001 — report all, then fail
            failures.append((name, e))
            print(f"  FAIL {name}: {e}")

    base = LoadConfig(n_requests=2000, rows=1, bucket_sizes=(16, 64),
                      max_wait=0.002, max_queue=256, deadline=0.05,
                      service="affine:0.0005:0.000005", seed=0)
    specs = {
        "poisson": "poisson:20000",
        "bursty": "bursty:20000:32:0.25",
    }
    rows = []

    def families_deterministic():
        import dataclasses
        for fam, spec in sorted(specs.items()):
            cfg = dataclasses.replace(base, arrivals=spec)
            a = simulate_load(cfg)
            check_invariants(a)
            b = simulate_load(cfg)
            sa = json.dumps(a.row, sort_keys=True)
            sb = json.dumps(b.row, sort_keys=True)
            assert sa == sb, f"{fam}: two identical-seed runs differ"
            rows.append(a.row)

    def trace_replay_deterministic():
        import dataclasses
        import os
        import tempfile
        # a short recorded-gap trace, cycled over 500 requests
        gaps = np.full(64, 1.0 / 20000.0)
        gaps[::8] = 4.0 / 20000.0      # periodic lulls
        fd, path = tempfile.mkstemp(suffix=".json")
        with os.fdopen(fd, "w") as f:
            json.dump(list(gaps), f)
        try:
            cfg = dataclasses.replace(base, arrivals=f"trace:{path}",
                                      n_requests=500)
            a = simulate_load(cfg)
            check_invariants(a)
            b = simulate_load(cfg)
            assert json.dumps(a.row, sort_keys=True) == \
                json.dumps(b.row, sort_keys=True), \
                "trace replay differs between identical runs"
            rows.append(a.row)
        finally:
            os.unlink(path)

    def sweep_finds_saturation():
        cap = base.bucket_sizes[-1] / (0.0005 + 0.000005 * 64)
        srows, max_qps = qps_sweep(base, sweep_rates(cap, n=8))
        assert max_qps is not None, "no sustainable rate found"
        assert any(not r["sustainable"] for r in srows), \
            "ladder never saturated — sweep range too low"
        # deterministic gate rows: simulated scheduling perf; any
        # batching-policy regression moves these
        meta = {**_bench_meta(), "sim": "virtual"}
        gate = [
            _gate_row("serve_load_sim/max_qps", 1e6 / max_qps,
                      f"max_qps={max_qps:.0f};deadline_ms=50", meta),
        ]
        mid = [r for r in srows if r["sustainable"]]
        gate.append(_gate_row(
            "serve_load_sim/p99_sustained",
            mid[-1]["p99_ms"] * 1e3,
            f"offered_qps={mid[-1]['offered_qps']:.0f}", meta))
        with open(GATE_OUT, "w") as f:
            json.dump({"meta": {**meta, "smoke": True}, "rows": gate}, f,
                      indent=1)
            f.write("\n")
        rows.extend(srows)

    print("serve_load --smoke (virtual determinism gate)")
    import os
    os.makedirs(os.path.dirname(GATE_OUT), exist_ok=True)
    check("arrival families: invariants + byte-identical replay",
          families_deterministic)
    check("trace file replay deterministic", trace_replay_deterministic)
    check("virtual QPS sweep saturates + gate rows",
          sweep_finds_saturation)
    save_rows(rows, OUT, meta={**_bench_meta(), "mode": "smoke"})
    print(f"serve_load --smoke: {len(failures)} failures "
          f"({len(rows)} rows -> {OUT})")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description="trace-driven load engine over the scoring engine")
    ap.add_argument("--arrivals", default="poisson:2000",
                    help="arrival process spec (poisson:rate | "
                    "bursty:rate:burst:duty | trace:file)")
    ap.add_argument("--service", default="affine:0.001:0.00001",
                    help="service-time model (constant:t | "
                    "affine:base:per_row | measured)")
    ap.add_argument("--requests", type=int, default=5000)
    ap.add_argument("--rows", default="1",
                    help="rows per request: int or uniform:lo:hi")
    ap.add_argument("--bucket-sizes", default="64,256,1024")
    ap.add_argument("--max-wait", type=float, default=0.002,
                    help="continuous-batching timeout on the head "
                    "request (virtual seconds)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission control: max waiting requests")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request latency budget (seconds)")
    ap.add_argument("--bundle", default=None,
                    help="exported bundle dir(s), comma-separated — "
                    "service times calibrated from the real engine")
    ap.add_argument("--impl", default="auto")
    ap.add_argument("--sweep", action="store_true",
                    help="QPS ladder -> max-sustainable-QPS")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--smoke", action="store_true",
                    help="virtual-only CI gate: invariants + "
                    "determinism + perf-gate rows")
    args = ap.parse_args()
    if args.smoke:
        return smoke()
    if args.sweep:
        return run_sweep(args)
    engine = features = None
    if args.bundle:
        engine, features = _load_engine(args)
        args.service = "measured"
    row = run_single(args, engine=engine, features=features)
    save_rows([row], args.out, meta={**_bench_meta(), "mode": "single"})
    print(json.dumps(row, indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
