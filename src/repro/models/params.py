"""Parameter definition trees.

Model code declares parameters as ``ParamDef`` leaves carrying shape,
initializer, and *logical axis names*; the same tree then yields

  * ``init_tree``   -> concrete parameter pytree,
  * ``spec_tree``   -> matching pytree of PartitionSpec (via a ShardingCtx),
  * ``abstract_tree`` -> ShapeDtypeStruct pytree with shardings for dry-runs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis names, len == ndim
    init: str = "normal"                 # 'normal' | 'zeros' | 'ones' | 'scaled'
    scale: float = 1.0
    dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def pdef(shape, axes, init="normal", scale=1.0, dtype=jnp.float32) -> ParamDef:
    return ParamDef(tuple(shape), tuple(axes), init, scale, dtype)


def dense_def(d_in: int, d_out: int, ax_in: Optional[str],
              ax_out: Optional[str], dtype=jnp.float32) -> ParamDef:
    # fan-in scaled normal init
    return pdef((d_in, d_out), (ax_in, ax_out), init="scaled",
                scale=1.0 / np.sqrt(d_in), dtype=dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(key, d: ParamDef):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    scale = d.scale if d.init == "scaled" else 0.02 * d.scale
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(d.dtype)


def init_tree(key, defs):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def spec_tree(ctx, defs):
    """Pytree of PartitionSpec matching ``defs``."""
    return jax.tree.map(
        lambda d: ctx.spec(d.axes, d.shape), defs, is_leaf=is_def)


def sharding_tree(ctx, defs):
    return jax.tree.map(
        lambda d: ctx.sharding(d.axes, d.shape), defs, is_leaf=is_def)


def abstract_tree(ctx, defs):
    """ShapeDtypeStruct pytree (dry-run stand-in, no allocation)."""
    def mk(d: ParamDef):
        sh = ctx.sharding(d.axes, d.shape) if ctx.active else None
        return jax.ShapeDtypeStruct(d.shape, d.dtype, sharding=sh)
    return jax.tree.map(mk, defs, is_leaf=is_def)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))


def stack_layer_defs(layer_def, num_layers: int):
    """Scan-over-layers: prepend a 'layers' dim to every leaf."""
    return jax.tree.map(
        lambda d: ParamDef((num_layers,) + d.shape, (None,) + d.axes,
                           d.init, d.scale, d.dtype),
        layer_def, is_leaf=is_def)
