"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD forward in pure jnp (the Pallas TPU kernel in
``repro.kernels.ssd`` implements the same contract and is validated against
``ssd_chunked`` below), causal depthwise conv, gated RMSNorm, and the O(1)
single-token decode recurrence.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import pdef


def ssm_dims(cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.num_heads(d)
    gn = s.num_groups * s.state_size
    conv_ch = di + 2 * gn
    in_dim = 2 * di + 2 * gn + nh  # z, x, B, C, dt
    return di, nh, gn, conv_ch, in_dim


def ssm_defs(cfg):
    s = cfg.ssm
    d = cfg.d_model
    di, nh, gn, conv_ch, in_dim = ssm_dims(cfg)
    return {
        "in_proj": pdef((d, in_dim), ("fsdp", "ssm_heads"), init="scaled",
                        scale=d ** -0.5),
        "conv_w": pdef((s.conv_width, conv_ch), (None, "ssm_heads"),
                       init="scaled", scale=s.conv_width ** -0.5),
        "conv_b": pdef((conv_ch,), ("ssm_heads",), init="zeros"),
        "a_log": pdef((nh,), ("ssm_heads",), init="zeros"),
        "dt_bias": pdef((nh,), ("ssm_heads",), init="zeros"),
        "d_skip": pdef((nh,), ("ssm_heads",), init="ones"),
        "gate_norm": pdef((di,), ("ssm_heads",), init="ones"),
        "out_proj": pdef((di, d), ("ssm_heads", "fsdp"), init="scaled",
                         scale=di ** -0.5),
    }


def _segsum(x):
    """x (..., Q) -> (..., Q, Q) lower-triangular cumulative sums:
    out[i,j] = sum_{j < t <= i} x[t], -inf above diagonal."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                unroll: bool = False):
    """Chunked SSD scan.

    x  (B, T, H, P)   per-head inputs
    dt (B, T, H)      softplus-ed timesteps (>0)
    a_log (H,)        A = -exp(a_log)
    b, c (B, T, G, N) input/output projections (G groups broadcast over H)
    Returns (y (B,T,H,P), final_state (B,H,P,N)).
    """
    Bsz, T, H, Pd = x.shape
    G, N = b.shape[2], b.shape[3]
    assert H % G == 0
    Q = min(chunk, T)
    assert T % Q == 0, (T, Q)
    nc = T // Q
    f32 = jnp.float32
    A = -jnp.exp(a_log.astype(f32))  # (H,) negative
    dtf = dt.astype(f32)
    da = dtf * A  # (B,T,H) log-decay per step

    xr = (x.astype(f32) * dtf[..., None]).reshape(Bsz, nc, Q, H, Pd)
    dar = da.reshape(Bsz, nc, Q, H)
    # broadcast groups -> heads
    rep = H // G
    br = jnp.repeat(b.astype(f32), rep, axis=2).reshape(Bsz, nc, Q, H, N)
    cr = jnp.repeat(c.astype(f32), rep, axis=2).reshape(Bsz, nc, Q, H, N)

    # intra-chunk (quadratic within chunk)
    Lmat = jnp.exp(_segsum(dar.transpose(0, 1, 3, 2)))  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bnqhs,bnkhs->bnhqk", cr, br)   # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bnhqk,bnhqk,bnkhp->bnqhp", scores, Lmat, xr)

    # chunk-final states: S_n = sum_j exp(sum_{t>j} da) * b_j x_j
    cum = jnp.cumsum(dar, axis=2)                       # (B,nc,Q,H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)     # (B,nc,Q,H)
    S = jnp.einsum("bnqh,bnqhs,bnqhp->bnhps", decay_to_end, br, xr)

    # inter-chunk recurrence over chunks
    total = jnp.exp(cum[:, :, -1, :])                   # (B,nc,H)

    def step(carry, inp):
        s_prev = carry
        s_new, tot = inp
        s_next = s_prev * tot[:, :, None, None] + s_new
        return s_next, s_prev

    s0 = (jnp.zeros((Bsz, H, Pd, N), f32) if init_state is None
          else init_state.astype(f32))
    final, s_prevs = jax.lax.scan(
        step, s0, (S.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
        unroll=nc if unroll else 1)
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)          # (B,nc,H,P,N)

    decay_in = jnp.exp(cum)                             # (B,nc,Q,H)
    y_inter = jnp.einsum("bnqhs,bnqh,bnhps->bnqhp", cr, decay_in, s_prevs)
    y = (y_intra + y_inter).reshape(Bsz, T, H, Pd)
    return y.astype(x.dtype), final


def causal_conv(x, w, b):
    """Depthwise causal conv. x (B,T,C), w (W,C), b (C,)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    return out + b[None, None, :]


def _split_proj(proj, cfg):
    di, nh, gn, conv_ch, in_dim = ssm_dims(cfg)
    z = proj[..., :di]
    xbc = proj[..., di:di + conv_ch]
    dt = proj[..., di + conv_ch:]
    return z, xbc, dt


def _split_xbc(xbc, cfg):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    gn = s.num_groups * s.state_size
    return (xbc[..., :di], xbc[..., di:di + gn], xbc[..., di + gn:])


def ssm_block(p, h, cfg, run, *, return_state: bool = False,
              init_state=None, init_conv=None):
    """Full Mamba2 mixer. h (B,T,d) -> (B,T,d) [, (final_state, conv_tail)]."""
    s = cfg.ssm
    di, nh, gn, conv_ch, in_dim = ssm_dims(cfg)
    dt_ = h.dtype
    proj = h @ p["in_proj"].astype(dt_)
    z, xbc, dt_raw = _split_proj(proj, cfg)
    if init_conv is not None:
        xbc_in = jnp.concatenate([init_conv.astype(dt_), xbc], axis=1)
        conv_out = causal_conv(xbc_in, p["conv_w"].astype(dt_),
                               p["conv_b"].astype(dt_))
        conv_out = conv_out[:, s.conv_width - 1:, :]
    else:
        conv_out = causal_conv(xbc, p["conv_w"].astype(dt_),
                               p["conv_b"].astype(dt_))
    xbc_act = jax.nn.silu(conv_out)
    xs, b, c = _split_xbc(xbc_act, cfg)
    Bsz, T, _ = h.shape
    xh = xs.reshape(Bsz, T, nh, s.head_dim)
    bm = b.reshape(Bsz, T, s.num_groups, s.state_size)
    cm = c.reshape(Bsz, T, s.num_groups, s.state_size)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    y, final_state = ssd_chunked(xh, dt, p["a_log"], bm, cm, s.chunk_size,
                                 init_state=init_state,
                                 unroll=run.scan_unroll)
    y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bsz, T, di)
    y = L.rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    if return_state:
        conv_tail = xbc[:, T - (s.conv_width - 1):, :]
        return out, (final_state.astype(jnp.float32), conv_tail)
    return out


def ssm_decode_block(p, h, cfg, state, conv_cache):
    """Single-token recurrence.

    h (B,1,d); state (B,H,P,N) fp32; conv_cache (B,W-1,conv_ch).
    Returns (out (B,1,d), new_state, new_conv_cache).
    """
    s = cfg.ssm
    di, nh, gn, conv_ch, in_dim = ssm_dims(cfg)
    dt_ = h.dtype
    proj = h @ p["in_proj"].astype(dt_)          # (B,1,in_dim)
    z, xbc, dt_raw = _split_proj(proj, cfg)
    window = jnp.concatenate([conv_cache.astype(dt_), xbc], axis=1)
    new_conv = window[:, 1:, :]
    conv_out = (jnp.sum(window * p["conv_w"].astype(dt_)[None], axis=1)
                + p["conv_b"].astype(dt_))[:, None, :]
    xbc_act = jax.nn.silu(conv_out)
    xs, b, c = _split_xbc(xbc_act, cfg)
    Bsz = h.shape[0]
    xh = xs.reshape(Bsz, nh, s.head_dim).astype(jnp.float32)
    bm = b.reshape(Bsz, s.num_groups, s.state_size).astype(jnp.float32)
    cm = c.reshape(Bsz, s.num_groups, s.state_size).astype(jnp.float32)
    rep = nh // s.num_groups
    bm = jnp.repeat(bm, rep, axis=1)             # (B,H,N)
    cm = jnp.repeat(cm, rep, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * A)                         # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, bm, xh)
    new_state = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", cm, new_state)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bsz, 1, di).astype(dt_)
    y = L.rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    return out, new_state, new_conv
