"""Unified model API — dispatches by config family.

Every architecture supports:
  * ``param_defs(cfg)``                      -> ParamDef pytree
  * ``train_loss / prefill / decode_step``   -> jit-able step fns
  * ``input_defs(cfg, shape)``               -> ParamDef-style input specs
  * ``cache_defs(cfg, batch, seq)``          -> decode cache specs
  * ``decode_window(cfg, shape)``            -> sliding window (long_500k
    policy, DESIGN.md): None natively sub-quadratic or short decode.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer, vlm
from repro.models.params import pdef


def _mod(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec
    if cfg.family == "vlm":
        return vlm
    return transformer


def param_defs(cfg: ModelConfig):
    return _mod(cfg).model_defs(cfg)


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Sliding-window policy: long_500k runs windowed attention for archs
    whose native attention is full (dense/moe/vlm/encdec/hybrid-attn-heads);
    SSM needs nothing (state is O(1))."""
    if shape.name == "long_500k" and not cfg.attn_free:
        return cfg.long_context_window
    return None


def train_loss(params, batch, cfg, run, ctx):
    return _mod(cfg).train_loss(params, batch, cfg, run, ctx)


def prefill(params, batch, cfg, run, ctx, window=None):
    return _mod(cfg).prefill(params, batch, cfg, run, ctx, window=window)


def decode_step(params, batch, caches, cfg, run, ctx, window=None):
    return _mod(cfg).decode_step(params, batch, caches, cfg, run, ctx,
                                 window=window)


def cache_defs(cfg, batch: int, seq: int):
    return _mod(cfg).cache_defs(cfg, batch, seq)


def input_defs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ParamDef trees for every model input of the given phase
    (weak-type-correct, shardable, no allocation — dry-run stand-ins)."""
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.phase == "decode":
        return {"token": pdef((B,), ("batch",), dtype=i32),
                "pos": pdef((), (), dtype=i32)}
    toks = T
    extra: Dict = {}
    if cfg.family == "encdec":
        extra["frames"] = pdef(
            (B, cfg.encoder.seq_len, cfg.d_model),
            ("batch", "enc_seq", "embed"), dtype=jnp.bfloat16)
    if cfg.family == "vlm":
        img = cfg.encoder.num_image_tokens
        toks = T - img
        extra["patches"] = pdef(
            (B, img, cfg.encoder.frontend_dim),
            ("batch", None, "frontend"), dtype=jnp.bfloat16)
    specs = dict(extra)
    specs["tokens"] = pdef((B, toks), ("batch", "act_seq"), dtype=i32)
    if shape.phase == "train":
        specs["targets"] = pdef((B, T), ("batch", "act_seq"), dtype=i32)
        specs["mask"] = pdef((B, T), ("batch", "act_seq"),
                             dtype=jnp.float32)
    return specs
