"""Attention: GQA with RoPE/qk-norm; chunked online-softmax for train &
prefill (flash-style, bounded memory, pure XLA — the Pallas TPU kernel in
``repro.kernels.flash_attention`` implements the same contract); masked
full-cache read for decode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import dense_def, pdef

NEG_INF = -1e30


def attention_defs(cfg, cross: bool = False):
    d, H, K, dh = (cfg.d_model, cfg.padded_num_heads, cfg.num_kv_heads,
                   cfg.head_dim_)
    defs = {
        "wq": pdef((d, H, dh), ("fsdp", "heads", "head_dim"),
                   init="scaled", scale=d ** -0.5),
        "wk": pdef((d, K, dh), ("fsdp", "kv_heads", "head_dim"),
                   init="scaled", scale=d ** -0.5),
        "wv": pdef((d, K, dh), ("fsdp", "kv_heads", "head_dim"),
                   init="scaled", scale=d ** -0.5),
        "wo": pdef((H, dh, d), ("heads", "head_dim", "fsdp"),
                   init="scaled", scale=(H * dh) ** -0.5),
    }
    if cfg.qk_norm and not cross:
        defs["q_norm"] = pdef((dh,), (None,), init="ones")
        defs["k_norm"] = pdef((dh,), (None,), init="ones")
    return defs


def _project_qkv(p, x, kv_x, cfg, sin, cos, *, rope: bool = True):
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(dt))
    if cfg.qk_norm and "q_norm" in p:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope and sin is not None:
        q = L.apply_rope(q, sin, cos)
        k = L.apply_rope(k, sin, cos)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                      kv_chunk: int = 1024, q_chunk: int = 1024,
                      block_skip: bool = False, unroll: bool = False,
                      broadcast_kv: bool = False):
    """Online-softmax attention.

    q (B,T,H,dh), k/v (B,S,K,dh) with H = G*K (GQA).  Returns (B,T,H,dh).

    ``block_skip``: statically unroll over q chunks so fully-masked kv
    blocks above the causal diagonal are never computed (halves prefill
    attention FLOPs; a §Perf lever — the scan path is the baseline).
    """
    B, T, H, dh = q.shape
    S, K = k.shape[1], k.shape[2]
    if broadcast_kv and K != H:
        # repeat kv heads to q heads: the (H)->(K,G) reshape below would
        # split a model-sharded H dim and force per-layer q resharding;
        # broadcasting kv keeps every einsum local (§Perf lever).
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
        K = H
    G = H // K
    scale = dh ** -0.5
    qg = q.reshape(B, T, K, G, dh).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if block_skip and causal and T == S:
        q_chunk = min(q_chunk, T)
        assert T % q_chunk == 0
        outs = []
        for qi in range(T // q_chunk):
            q_lo, q_hi = qi * q_chunk, (qi + 1) * q_chunk
            o = _attend_block(qg[:, q_lo:q_hi], kf[:, :q_hi], vf[:, :q_hi],
                              q_offset=q_lo, causal=True, window=window,
                              kv_chunk=kv_chunk, unroll=unroll)
            outs.append(o)
        out = jnp.concatenate(outs, axis=1)
    else:
        out = _attend_block(qg, kf, vf, q_offset=0, causal=causal,
                            window=window, kv_chunk=kv_chunk, unroll=unroll)
    return out.reshape(B, T, H, dh).astype(q.dtype)


def _attend_block(qg, kf, vf, *, q_offset: int, causal: bool,
                  window: Optional[int], kv_chunk: int,
                  unroll: bool = False):
    """Online-softmax scan over kv chunks. qg (B,Tq,K,G,dh) fp32 pre-scaled."""
    B, Tq, K, G, dh = qg.shape
    S = kf.shape[1]
    kv_chunk = min(kv_chunk, S)
    pad = (-S) % kv_chunk
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkv = kf.shape[1] // kv_chunk
    ks = kf.reshape(B, nkv, kv_chunk, K, dh).transpose(1, 0, 2, 3, 4)
    vs = vf.reshape(B, nkv, kv_chunk, K, dh).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Tq)

    def body(carry, inp):
        m, l, acc = carry
        j, kc, vc = inp
        # logits (B, Tq, K, G, kc)
        logits = jnp.einsum("btkgd,bskd->btkgs", qg, kc)
        kv_pos = j * kv_chunk + jnp.arange(kv_chunk)
        mask = kv_pos[None, :] < S  # padding
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("btkgs,bskd->btkgd", p, vc)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Tq, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Tq, K, G), jnp.float32)
    a0 = jnp.zeros((B, Tq, K, G, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nkv), ks, vs),
        unroll=nkv if unroll else 1)
    return acc / jnp.maximum(l, 1e-30)[..., None]


def decode_attention(q, cache_k, cache_v, pos, *,
                     window: Optional[int] = None):
    """Single-token attention against a full cache with position masking.

    q (B,H,dh); cache_k/v (B,S,K,dh); pos () current index (tokens written
    so far == pos+1 after update).  Masked full-cache read: shardable over
    cache_seq and memory-roofline-honest (see DESIGN.md long_500k policy).
    """
    B, H, dh = q.shape
    S, K = cache_k.shape[1], cache_k.shape[2]
    G = H // K
    qg = (q.reshape(B, K, G, dh).astype(jnp.float32)) * dh ** -0.5
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k.astype(jnp.float32))
    kv_pos = jnp.arange(S)
    mask = kv_pos <= pos
    if window is not None:
        mask = mask & (kv_pos > pos - window)
    logits = jnp.where(mask[None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, cache_v.astype(jnp.float32))
    return out.reshape(B, H, dh).astype(q.dtype)


def attn_block(p, x, sin, cos, cfg, run, *, causal=True, window=None,
               kv_x=None, rope=True):
    """Full attention sub-block (projections + attention + output proj)."""
    kv_inp = x if kv_x is None else kv_x
    q, k, v = _project_qkv(p, x, kv_inp, cfg, sin, cos, rope=rope)
    out = chunked_attention(
        q, k, v, causal=causal, window=window,
        kv_chunk=run.attn_kv_chunk, q_chunk=run.attn_q_chunk,
        block_skip=run.causal_block_skip, unroll=run.scan_unroll,
        broadcast_kv=run.gqa_broadcast_kv)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))


def attn_decode_block(p, x, cache_k, cache_v, pos, sin, cos, cfg, *,
                      window=None, cross=False):
    """Decode-step attention.

    x (B,1,d). Returns (out (B,1,d), new_k, new_v). For cross attention the
    cache holds precomputed encoder k/v and is not updated.
    """
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    if cfg.qk_norm and "q_norm" in p:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
    if not cross:
        k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(dt))
        v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(dt))
        if cfg.qk_norm and "k_norm" in p:
            k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
        if sin is not None:
            q = L.apply_rope(q, sin, cos)
            k = L.apply_rope(k, sin, cos)
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), pos, axis=1)
        att_pos = pos
    else:
        att_pos = cache_k.shape[1] - 1  # attend over all encoder states
        window = None
    out = decode_attention(q[:, 0], cache_k, cache_v, att_pos, window=window)
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(dt))[:, None]
    return out, cache_k, cache_v
