"""Decoder-only LM covering the dense / moe / ssm / hybrid families.

One scanned block per architecture (homogeneous stacks), full/selectable
remat, Megatron-SP style boundary sharding constraints, chunked CE loss.
Exposes train / prefill / decode entry points used by ``models.api``.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.params import pdef, stack_layer_defs


# --- parameter definitions ---------------------------------------------------

def gelu_mlp_defs(d, d_ff):
    return {
        "w_in": pdef((d, d_ff), ("fsdp", "mlp"), init="scaled",
                     scale=d ** -0.5),
        "w_out": pdef((d_ff, d), ("mlp", "fsdp"), init="scaled",
                      scale=d_ff ** -0.5),
    }


def gelu_mlp(p, x):
    dt = x.dtype
    return jax.nn.gelu(x @ p["w_in"].astype(dt)) @ p["w_out"].astype(dt)


def block_defs(cfg, *, cross_attention: bool = False):
    d = cfg.d_model
    defs: Dict = {}
    fam = cfg.family
    if fam != "ssm":
        defs["ln1"] = L.rmsnorm_def(d)
        defs["attn"] = A.attention_defs(cfg)
    if fam == "ssm":
        defs["ln1"] = L.rmsnorm_def(d)
        defs["ssm"] = S.ssm_defs(cfg)
        return defs
    if fam == "hybrid":
        defs["ssm"] = S.ssm_defs(cfg)
        defs["attn_out_norm"] = L.rmsnorm_def(d)
        defs["ssm_out_norm"] = L.rmsnorm_def(d)
    if cross_attention:
        defs["ln_cross"] = L.rmsnorm_def(d)
        defs["cross"] = A.attention_defs(cfg, cross=True)
    defs["ln2"] = L.rmsnorm_def(d)
    if cfg.moe is not None:
        defs["moe"] = M.moe_defs(cfg)
    elif fam == "encdec":
        defs["mlp"] = gelu_mlp_defs(d, cfg.d_ff)
    else:
        defs["mlp"] = L.swiglu_defs(d, cfg.d_ff)
    return defs


def model_defs(cfg):
    d = cfg.d_model
    defs = {
        "embed": L.embed_def(cfg.padded_vocab_size, d),
        "layers": stack_layer_defs(block_defs(cfg), cfg.num_layers),
        "final_norm": L.rmsnorm_def(d),
    }
    if not cfg.tie_embeddings:
        defs["head"] = pdef((d, cfg.padded_vocab_size), ("fsdp", "vocab"),
                            init="scaled", scale=d ** -0.5)
    return defs


# --- forward blocks ----------------------------------------------------------

def _mixer(p, h, sin, cos, cfg, run, *, window=None, collect_kv=False):
    """Sequence mixer for train/prefill; returns (out, cache_slice)."""
    fam = cfg.family
    cache = {}
    if fam == "ssm":
        x = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
        if collect_kv:
            out, (state, conv_tail) = S.ssm_block(
                p["ssm"], x, cfg, run, return_state=True)
            cache = {"state": state, "conv": conv_tail}
        else:
            out = S.ssm_block(p["ssm"], x, cfg, run)
        return out, cache
    x = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
    kv_inp = x
    q, k, v = A._project_qkv(p["attn"], x, kv_inp, cfg, sin, cos)
    attn_out = A.chunked_attention(
        q, k, v, causal=True, window=window,
        kv_chunk=run.attn_kv_chunk, q_chunk=run.attn_q_chunk,
        block_skip=run.causal_block_skip, unroll=run.scan_unroll,
        broadcast_kv=run.gqa_broadcast_kv)
    attn_out = jnp.einsum("bthk,hkd->btd", attn_out,
                          p["attn"]["wo"].astype(h.dtype))
    if collect_kv:
        cache = {"k": k, "v": v}
    if fam == "hybrid":
        ssm_out = S.ssm_block(p["ssm"], x, cfg, run,
                              return_state=collect_kv)
        if collect_kv:
            ssm_out, (state, conv_tail) = ssm_out
            cache.update({"state": state, "conv": conv_tail})
        out = 0.5 * (L.rmsnorm(attn_out, p["attn_out_norm"], cfg.norm_eps)
                     + L.rmsnorm(ssm_out, p["ssm_out_norm"], cfg.norm_eps))
        return out, cache
    return attn_out, cache


def _channel_mix(p, h, cfg, run, ctx):
    """MLP / MoE half of the block. Returns (out, aux)."""
    if cfg.family == "ssm":
        return jnp.zeros_like(h), jnp.float32(0.0)
    x = L.rmsnorm(h, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        out, aux = M.moe_apply(p["moe"], x, cfg, run, ctx)
        return out, aux
    if cfg.family == "encdec":
        return gelu_mlp(p["mlp"], x), jnp.float32(0.0)
    return L.swiglu(p["mlp"], x), jnp.float32(0.0)


def block_apply(p, h, sin, cos, cfg, run, ctx, *, window=None,
                collect_kv=False):
    """Pre-norm residual block. Returns (h, cache_slice, aux)."""
    mix, cache = _mixer(p, h, sin, cos, cfg, run, window=window,
                        collect_kv=collect_kv)
    h = h + mix
    ch, aux = _channel_mix(p, h, cfg, run, ctx)
    h = h + ch
    h = ctx.constrain(h, "batch", "act_seq", "embed")
    return h, cache, aux


def block_decode(p, h, cache, pos, sin, cos, cfg, run, ctx, *, window=None):
    """Single-token block step. cache: per-layer slice dict."""
    fam = cfg.family
    new_cache = {}
    x = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
    if fam == "ssm":
        out, st, cv = S.ssm_decode_block(p["ssm"], x, cfg, cache["state"],
                                         cache["conv"])
        h = h + out
        new_cache = {"state": st, "conv": cv}
        return h, new_cache
    attn_out, ck, cvv = A.attn_decode_block(
        p["attn"], x, cache["k"], cache["v"], pos, sin, cos, cfg,
        window=window)
    new_cache.update({"k": ck, "v": cvv})
    if fam == "hybrid":
        ssm_out, st, cv = S.ssm_decode_block(p["ssm"], x, cfg,
                                             cache["state"], cache["conv"])
        new_cache.update({"state": st, "conv": cv})
        mix = 0.5 * (L.rmsnorm(attn_out, p["attn_out_norm"], cfg.norm_eps)
                     + L.rmsnorm(ssm_out, p["ssm_out_norm"], cfg.norm_eps))
    else:
        mix = attn_out
    h = h + mix
    ch, _ = _channel_mix(p, h, cfg, run, ctx)
    h = h + ch
    return h, new_cache


# --- stacks ------------------------------------------------------------------

def _remat_wrap(fn, run):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    if run.remat == "moe_save":
        pol = jax.checkpoint_policies.save_only_these_names("moe_out")
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def run_stack(params, h, sin, cos, cfg, run, ctx, *, window=None,
              collect_kv=False):
    """Scan the layer stack. Returns (h, stacked_cache, aux_total)."""

    def body(carry, layer_p):
        hh, aux = carry
        hh, cache, a = block_apply(layer_p, hh, sin, cos, cfg, run, ctx,
                                   window=window, collect_kv=collect_kv)
        return (hh, aux + a), cache

    body = _remat_wrap(body, run)
    (h, aux), caches = jax.lax.scan(body, (h, jnp.float32(0.0)),
                                    params["layers"],
                                    unroll=cfg.num_layers
                                    if run.scan_unroll else 1)
    return h, caches, aux


def run_stack_decode(params, h, caches, pos, sin, cos, cfg, run, ctx, *,
                     window=None):
    def body(hh, xs):
        layer_p, cache = xs
        hh, new_cache = block_decode(layer_p, hh, cache, pos, sin, cos,
                                     cfg, run, ctx, window=window)
        return hh, new_cache

    h, new_caches = jax.lax.scan(body, h, (params["layers"], caches),
                                 unroll=cfg.num_layers
                                 if run.scan_unroll else 1)
    return h, new_caches


# --- entry points ------------------------------------------------------------

def _rope_for(cfg, positions):
    if cfg.attn_free:
        return None, None
    return L.rope_tables(positions, cfg.head_dim_, cfg.rope_theta)


def _head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def embed_tokens(params, tokens, cfg, ctx):
    h = L.embed_lookup(params["embed"], tokens, cfg.activation_dtype)
    return ctx.constrain(h, "batch", "act_seq", "embed")


def train_loss_from_embeds(params, h, targets, mask, cfg, run, ctx, *,
                           window=None):
    T = h.shape[1]
    sin, cos = _rope_for(cfg, jnp.arange(T))
    h, _, aux = run_stack(params, h, sin, cos, cfg, run, ctx, window=window)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    loss, wt = L.cross_entropy_chunked(
        h, _head_weight(params, cfg).astype(h.dtype), targets, mask,
        run.loss_chunk, ctx, unroll=run.scan_unroll,
        valid_vocab=cfg.vocab_size)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux / cfg.num_layers
    return loss, {"ce": loss, "aux": aux, "tokens": wt}


def train_loss(params, batch, cfg, run, ctx, *, window=None):
    h = embed_tokens(params, batch["tokens"], cfg, ctx)
    return train_loss_from_embeds(params, h, batch["targets"],
                                  batch["mask"], cfg, run, ctx,
                                  window=window)


def prefill_from_embeds(params, h, cfg, run, ctx, *, window=None):
    """Returns (last-token logits, cache dict with stacked layer caches)."""
    B, T, _ = h.shape
    sin, cos = _rope_for(cfg, jnp.arange(T))
    h, caches, _ = run_stack(params, h, sin, cos, cfg, run, ctx,
                             window=window, collect_kv=True)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = (h[:, -1] @ _head_weight(params, cfg).astype(h.dtype))
    return logits.astype(jnp.float32)[:, :cfg.vocab_size], caches


def prefill(params, batch, cfg, run, ctx, *, window=None):
    h = embed_tokens(params, batch["tokens"], cfg, ctx)
    return prefill_from_embeds(params, h, cfg, run, ctx, window=window)


def decode_step(params, batch, caches, cfg, run, ctx, *, window=None):
    """batch: {'token': (B,) int32, 'pos': () int32}. One-step decode."""
    tok = batch["token"][:, None]
    pos = batch["pos"]
    h = L.embed_lookup(params["embed"], tok, cfg.activation_dtype)
    sin, cos = (None, None)
    if not cfg.attn_free:
        sin, cos = L.rope_tables(pos[None].astype(jnp.int32),
                                 cfg.head_dim_, cfg.rope_theta)
    h, new_caches = run_stack_decode(params, h, caches, pos, sin, cos,
                                     cfg, run, ctx, window=window)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h[:, 0] @ _head_weight(params, cfg).astype(h.dtype)
    return logits.astype(jnp.float32)[:, :cfg.vocab_size], new_caches


# --- cache definitions (for input_specs / dry-run) ---------------------------

def cache_defs(cfg, batch: int, seq: int):
    """ParamDef tree describing the decode cache (stacked over layers)."""
    Ldim = cfg.num_layers
    defs = {}
    fam = cfg.family
    if fam != "ssm":
        K, dh = cfg.num_kv_heads, cfg.head_dim_
        kv = pdef((Ldim, batch, seq, K, dh),
                  (None, "batch", "cache_seq", "kv_heads", "head_dim"),
                  init="zeros", dtype=jnp.bfloat16)
        defs.update({"k": kv, "v": kv})
    if fam in ("ssm", "hybrid"):
        s = cfg.ssm
        nh = s.num_heads(cfg.d_model)
        di, _, gn, conv_ch, _ = S.ssm_dims(cfg)
        defs["state"] = pdef((Ldim, batch, nh, s.head_dim, s.state_size),
                             (None, "batch", "ssm_heads", None, "ssm_state"),
                             init="zeros", dtype=jnp.float32)
        defs["conv"] = pdef((Ldim, batch, s.conv_width - 1, conv_ch),
                            (None, "batch", None, "conv"),
                            init="zeros", dtype=jnp.bfloat16)
    return defs
