"""InternVL2-style VLM backbone (arXiv:2404.16821).

Frontend carve-out (DESIGN.md): the InternViT vision tower is a stub —
``input_specs`` supplies pre-embedded patch features (B, num_image_tokens,
frontend_dim). The real parts built here: the 2-layer MLP projector and the
InternLM2-style GQA language model (shared with ``models.transformer``).
Image embeddings are prepended to the text sequence; loss is masked to text
positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.params import pdef


def model_defs(cfg):
    defs = T.model_defs(cfg)
    f, d = cfg.encoder.frontend_dim, cfg.d_model
    defs["projector"] = {
        "w1": pdef((f, d), ("frontend", "fsdp"), init="scaled",
                   scale=f ** -0.5),
        "b1": pdef((d,), (None,), init="zeros"),
        "w2": pdef((d, d), ("fsdp", None), init="scaled", scale=d ** -0.5),
        "b2": pdef((d,), (None,), init="zeros"),
    }
    return defs


def project_patches(params, patches, cfg):
    dt = cfg.activation_dtype
    p = params["projector"]
    h = patches.astype(dt) @ p["w1"].astype(dt) + p["b1"].astype(dt)
    h = jax.nn.gelu(h)
    return h @ p["w2"].astype(dt) + p["b2"].astype(dt)


def _fuse(params, batch, cfg, ctx):
    """Prepend projected image tokens to embedded text tokens."""
    img = project_patches(params, batch["patches"], cfg)
    txt = L.embed_lookup(params["embed"], batch["tokens"],
                         cfg.activation_dtype)
    h = jnp.concatenate([img, txt], axis=1)
    return ctx.constrain(h, "batch", "act_seq", "embed")


def train_loss(params, batch, cfg, run, ctx):
    """batch: patches (B,I,f), tokens (B,T_text), targets/mask (B,I+T_text)
    with image positions masked out of the loss."""
    h = _fuse(params, batch, cfg, ctx)
    return T.train_loss_from_embeds(params, h, batch["targets"],
                                    batch["mask"], cfg, run, ctx)


def prefill(params, batch, cfg, run, ctx, *, window=None):
    h = _fuse(params, batch, cfg, ctx)
    return T.prefill_from_embeds(params, h, cfg, run, ctx, window=window)


# decode is identical to the text LM: image tokens live in the kv cache.
decode_step = T.decode_step
cache_defs = T.cache_defs
