"""Shared layers: norms, RoPE, SwiGLU MLP, embeddings, losses."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import pdef, dense_def


# --- norms ------------------------------------------------------------------

def rmsnorm_def(d: int, axis: Optional[str] = None):
    return pdef((d,), (axis,), init="ones")


def rmsnorm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dt)


def layernorm_def(d: int):
    return {"scale": pdef((d,), (None,), init="ones"),
            "bias": pdef((d,), (None,), init="zeros")}


def layernorm(x, p, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# --- RoPE -------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_tables(positions, head_dim: int, theta: float):
    """positions (...,) -> (sin, cos) each (..., head_dim//2) fp32."""
    freqs = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x (..., T, H, dh); sin/cos (T, dh//2) or broadcastable (..., T, dh//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if sin.ndim == 2:  # (T, dh//2) -> broadcast over batch and heads
        sin = sin[:, None, :]
        cos = cos[:, None, :]
    else:  # (..., T, dh//2) -> add heads dim
        sin = sin[..., None, :]
        cos = cos[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


# --- MLP --------------------------------------------------------------------

def swiglu_defs(d: int, d_ff: int, fsdp: Optional[str] = "fsdp"):
    return {
        "w_gate": dense_def(d, d_ff, fsdp, "mlp"),
        "w_up": dense_def(d, d_ff, fsdp, "mlp"),
        "w_down": dense_def(d_ff, d, "mlp", fsdp),
    }


def swiglu(p, x, dtype=None):
    dt = dtype or x.dtype
    g = x @ p["w_gate"].astype(dt)
    u = x @ p["w_up"].astype(dt)
    return (jax.nn.silu(g) * u) @ p["w_down"].astype(dt)


# --- embedding / head -------------------------------------------------------

def embed_def(vocab: int, d: int):
    return pdef((vocab, d), ("vocab", "fsdp"), init="normal")


def embed_lookup(table, ids, dtype):
    return jnp.take(table.astype(dtype), ids, axis=0)


def cross_entropy_chunked(h, w_head, labels, mask, chunk: int,
                          ctx=None, unroll: bool = False,
                          valid_vocab: int = 0):
    """Next-token CE computed in token chunks to bound live logits.

    h       (B, T, d)  final hidden states
    w_head  (d, V)
    labels  (B, T) int32 (next-token targets)
    mask    (B, T) 1.0 where the position contributes to the loss
    Returns (mean loss fp32, total weight).
    """
    B, T, d = h.shape
    V = w_head.shape[1]
    h2 = h.reshape(B * T, d)
    l2 = labels.reshape(B * T)
    m2 = mask.reshape(B * T).astype(jnp.float32)
    n = B * T
    chunk = min(chunk, n)
    pad = (-n) % chunk
    if pad:
        h2 = jnp.pad(h2, ((0, pad), (0, 0)))
        l2 = jnp.pad(l2, (0, pad))
        m2 = jnp.pad(m2, (0, pad))
    nchunks = h2.shape[0] // chunk
    h3 = h2.reshape(nchunks, chunk, d)
    l3 = l2.reshape(nchunks, chunk)
    m3 = m2.reshape(nchunks, chunk)

    def body(carry, inp):
        hs, ls, ms = inp
        logits = (hs @ w_head.astype(hs.dtype)).astype(jnp.float32)
        if valid_vocab and valid_vocab < logits.shape[-1]:
            logits = logits[:, :valid_vocab]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[:, None], axis=-1)[:, 0]
        loss = jnp.sum((lse - gold) * ms)
        tot, wt = carry
        return (tot + loss, wt + jnp.sum(ms)), None

    (tot, wt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                (h3, l3, m3), unroll=nchunks if unroll else 1)
    return tot / jnp.maximum(wt, 1.0), wt
