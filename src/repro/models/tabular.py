"""The paper's parametric models for tabular CVD prediction.

* Logistic regression — L2(λ=0.01), trained full-batch (L-BFGS in the paper;
  we use Adam full-batch to the same optimum — convex objective).
* SVM — the paper says "polynomial kernel of degree 3 ... aggregates
  gradients", which is only consistent with a *primal* SVM on an explicit
  degree-3 polynomial feature map (kernel SVMs are non-parametric and not
  gradient-aggregatable); we implement exactly that (C=1.0 hinge loss).
  Substitution recorded in DESIGN.md §Changed-assumptions.
* Neural network — one hidden layer, 16 sigmoid units (trained with FedProx
  in the federated pipeline).
"""
from __future__ import annotations

import itertools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --- polynomial feature map (degree 3, with interactions) --------------------

def poly3_indices(n_features: int):
    pairs = list(itertools.combinations_with_replacement(range(n_features), 2))
    triples = list(
        itertools.combinations_with_replacement(range(n_features), 3))
    return np.array(pairs, np.int32), np.array(triples, np.int32)


def poly3_features(x, pairs, triples):
    """x (n, F) -> (n, F + |pairs| + |triples|)."""
    xp = x[:, pairs[:, 0]] * x[:, pairs[:, 1]]
    xt = (x[:, triples[:, 0]] * x[:, triples[:, 1]] * x[:, triples[:, 2]])
    return jnp.concatenate([x, xp, xt], axis=-1)


def poly3_dim(n_features: int) -> int:
    p, t = poly3_indices(n_features)
    return n_features + len(p) + len(t)


# --- models -------------------------------------------------------------------

def logreg_init(rng, n_features: int):
    return {"w": jnp.zeros((n_features,), jnp.float32),
            "b": jnp.zeros((), jnp.float32)}


def logreg_logits(params, x):
    return x @ params["w"] + params["b"]


def logreg_loss(params, x, y, l2: float = 0.01):
    logits = logreg_logits(params, x)
    ce = jnp.mean(_bce(logits, y))
    return ce + l2 * jnp.sum(params["w"] ** 2)


def svm_init(rng, n_features: int):
    """n_features is the ALREADY poly-expanded dim (the federated runner
    applies poly3_features before init)."""
    w = jax.random.normal(rng, (n_features,), jnp.float32) * 0.01
    return {"w": w, "b": jnp.zeros((), jnp.float32)}


def svm_margin(params, xphi):
    return xphi @ params["w"] + params["b"]


def svm_loss(params, xphi, y, C: float = 1.0):
    """Primal hinge loss; y in {0,1} mapped to {-1,+1}."""
    ys = 2.0 * y - 1.0
    margins = svm_margin(params, xphi)
    hinge = jnp.mean(jnp.maximum(0.0, 1.0 - ys * margins))
    return 0.5 * jnp.sum(params["w"] ** 2) / xphi.shape[0] + C * hinge


def mlp_init(rng, n_features: int, hidden: int = 16):
    k1, k2 = jax.random.split(rng)
    s1 = 1.0 / np.sqrt(n_features)
    return {
        "w1": jax.random.normal(k1, (n_features, hidden), jnp.float32) * s1,
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden,), jnp.float32) / np.sqrt(hidden),
        "b2": jnp.zeros((), jnp.float32),
    }


def mlp_logits(params, x):
    h = jax.nn.sigmoid(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, x, y):
    return jnp.mean(_bce(mlp_logits(params, x), y))


def _bce(logits, y):
    # softplus(z) - z*y: numerically stable AND smooth — the max/abs
    # formulation has a non-differentiable corner exactly at z=0, where
    # autodiff subgradients come out 0 and zero-initialized models with
    # one-sided labels never move.
    return jax.nn.softplus(logits) - logits * y


# 'proba' is the serving/score head: a monotone [0,1] score per row
# (sigmoid of the logit/margin — for the SVM this is a Platt-style
# squashing of the margin, not a true posterior; calibrate downstream
# via repro.serve.engine.fit_platt when probabilities matter).
MODELS: Dict[str, Dict] = {
    "logreg": dict(init=logreg_init, loss=logreg_loss,
                   predict=lambda p, x: logreg_logits(p, x) > 0,
                   proba=lambda p, x: jax.nn.sigmoid(logreg_logits(p, x)),
                   needs_poly=False),
    "svm": dict(init=svm_init, loss=svm_loss,
                predict=lambda p, x: svm_margin(p, x) > 0,
                proba=lambda p, x: jax.nn.sigmoid(svm_margin(p, x)),
                needs_poly=True),
    "mlp": dict(init=mlp_init, loss=mlp_loss,
                predict=lambda p, x: mlp_logits(p, x) > 0,
                proba=lambda p, x: jax.nn.sigmoid(mlp_logits(p, x)),
                needs_poly=False),
}


def param_bytes(params) -> int:
    return int(sum(np.prod(v.shape) * v.dtype.itemsize
                   for v in jax.tree.leaves(params)))
