"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Frontend carve-out (DESIGN.md): the mel-spectrogram + conv feature extractor
is a stub — ``input_specs`` supplies pre-embedded audio frames
(B, enc_seq, d_model). Positional scheme normalized to RoPE (backbone-shape
faithful; Whisper's learned absolute embeddings don't change the systems
behaviour). Encoder: bidirectional attention + GELU MLP; decoder: causal
self-attention + cross-attention + GELU MLP.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.params import pdef, stack_layer_defs


def enc_block_defs(cfg):
    d = cfg.d_model
    return {
        "ln1": L.rmsnorm_def(d),
        "attn": A.attention_defs(cfg),
        "ln2": L.rmsnorm_def(d),
        "mlp": T.gelu_mlp_defs(d, cfg.d_ff),
    }


def model_defs(cfg):
    d = cfg.d_model
    return {
        "embed": L.embed_def(cfg.padded_vocab_size, d),
        "enc_layers": stack_layer_defs(enc_block_defs(cfg),
                                       cfg.encoder.num_layers),
        "enc_norm": L.rmsnorm_def(d),
        "layers": stack_layer_defs(
            T.block_defs(cfg, cross_attention=True), cfg.num_layers),
        "final_norm": L.rmsnorm_def(d),
        "head": pdef((d, cfg.padded_vocab_size), ("fsdp", "vocab"),
                     init="scaled", scale=d ** -0.5),
    }


def encode(params, frames, cfg, run, ctx):
    """frames (B, S_enc, d) -> encoder states (B, S_enc, d)."""
    h = frames.astype(cfg.activation_dtype)
    h = ctx.constrain(h, "batch", "enc_seq", "embed")
    S = h.shape[1]
    sin, cos = L.rope_tables(jnp.arange(S), cfg.head_dim_, cfg.rope_theta)

    def body(hh, layer_p):
        x = L.rmsnorm(hh, layer_p["ln1"], cfg.norm_eps)
        attn = A.attn_block(layer_p["attn"], x, sin, cos, cfg, run,
                            causal=False)
        hh = hh + attn
        x = L.rmsnorm(hh, layer_p["ln2"], cfg.norm_eps)
        hh = hh + T.gelu_mlp(layer_p["mlp"], x)
        hh = ctx.constrain(hh, "batch", "enc_seq", "embed")
        return hh, None

    body = T._remat_wrap(body, run)
    h, _ = jax.lax.scan(body, h, params["enc_layers"],
                        unroll=cfg.encoder.num_layers
                        if run.scan_unroll else 1)
    return L.rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def _dec_block(p, h, enc_h, sin, cos, enc_sin, enc_cos, cfg, run, ctx, *,
               collect_kv=False):
    cache: Dict = {}
    x = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
    q, k, v = A._project_qkv(p["attn"], x, x, cfg, sin, cos)
    attn = A.chunked_attention(q, k, v, causal=True,
                               kv_chunk=run.attn_kv_chunk,
                               q_chunk=run.attn_q_chunk,
                               block_skip=run.causal_block_skip,
                               unroll=run.scan_unroll)
    attn = jnp.einsum("bthk,hkd->btd", attn, p["attn"]["wo"].astype(h.dtype))
    h = h + attn
    if collect_kv:
        cache.update({"k": k, "v": v})
    x = L.rmsnorm(h, p["ln_cross"], cfg.norm_eps)
    qc, kc, vc = A._project_qkv(p["cross"], x, enc_h, cfg, None, None,
                                rope=False)
    cross = A.chunked_attention(qc, kc, vc, causal=False,
                                kv_chunk=run.attn_kv_chunk,
                                q_chunk=run.attn_q_chunk,
                                unroll=run.scan_unroll)
    cross = jnp.einsum("bthk,hkd->btd", cross,
                       p["cross"]["wo"].astype(h.dtype))
    h = h + cross
    if collect_kv:
        cache.update({"cross_k": kc, "cross_v": vc})
    x = L.rmsnorm(h, p["ln2"], cfg.norm_eps)
    h = h + T.gelu_mlp(p["mlp"], x)
    h = ctx.constrain(h, "batch", "act_seq", "embed")
    return h, cache


def train_loss(params, batch, cfg, run, ctx):
    enc_h = encode(params, batch["frames"], cfg, run, ctx)
    tokens = batch["tokens"]
    h = L.embed_lookup(params["embed"], tokens, cfg.activation_dtype)
    h = ctx.constrain(h, "batch", "act_seq", "embed")
    Tlen = tokens.shape[1]
    sin, cos = L.rope_tables(jnp.arange(Tlen), cfg.head_dim_, cfg.rope_theta)

    def body(hh, layer_p):
        hh, _ = _dec_block(layer_p, hh, enc_h, sin, cos, None, None,
                           cfg, run, ctx)
        return hh, None

    body = T._remat_wrap(body, run)
    h, _ = jax.lax.scan(body, h, params["layers"],
                        unroll=cfg.num_layers if run.scan_unroll else 1)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    loss, wt = L.cross_entropy_chunked(
        h, params["head"].astype(h.dtype), batch["targets"], batch["mask"],
        run.loss_chunk, ctx, unroll=run.scan_unroll,
        valid_vocab=cfg.vocab_size)
    return loss, {"ce": loss, "tokens": wt}


def prefill(params, batch, cfg, run, ctx, *, window=None):
    """Encode + run decoder prompt; returns (last logits, caches)."""
    del window  # prompt-phase windowing not used for the enc-dec backbone
    enc_h = encode(params, batch["frames"], cfg, run, ctx)
    tokens = batch["tokens"]
    h = L.embed_lookup(params["embed"], tokens, cfg.activation_dtype)
    h = ctx.constrain(h, "batch", "act_seq", "embed")
    Tlen = tokens.shape[1]
    sin, cos = L.rope_tables(jnp.arange(Tlen), cfg.head_dim_, cfg.rope_theta)

    def body(hh, layer_p):
        hh, cache = _dec_block(layer_p, hh, enc_h, sin, cos, None, None,
                               cfg, run, ctx, collect_kv=True)
        return hh, cache

    h, caches = jax.lax.scan(body, h, params["layers"],
                             unroll=cfg.num_layers if run.scan_unroll else 1)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h[:, -1] @ params["head"].astype(h.dtype)
    return logits.astype(jnp.float32)[:, :cfg.vocab_size], caches


def decode_step(params, batch, caches, cfg, run, ctx, *, window=None):
    tok = batch["token"][:, None]
    pos = batch["pos"]
    h = L.embed_lookup(params["embed"], tok, cfg.activation_dtype)
    sin, cos = L.rope_tables(pos[None].astype(jnp.int32), cfg.head_dim_,
                             cfg.rope_theta)

    def body(hh, xs):
        layer_p, cache = xs
        x = L.rmsnorm(hh, layer_p["ln1"], cfg.norm_eps)
        attn, ck, cv = A.attn_decode_block(
            layer_p["attn"], x, cache["k"], cache["v"], pos, sin, cos, cfg,
            window=window)
        hh = hh + attn
        x = L.rmsnorm(hh, layer_p["ln_cross"], cfg.norm_eps)
        cross, _, _ = A.attn_decode_block(
            layer_p["cross"], x, cache["cross_k"], cache["cross_v"], pos,
            None, None, cfg, cross=True)
        hh = hh + cross
        x = L.rmsnorm(hh, layer_p["ln2"], cfg.norm_eps)
        hh = hh + T.gelu_mlp(layer_p["mlp"], x)
        new_cache = {"k": ck, "v": cv, "cross_k": cache["cross_k"],
                     "cross_v": cache["cross_v"]}
        return hh, new_cache

    h, new_caches = jax.lax.scan(body, h, (params["layers"], caches),
                                 unroll=cfg.num_layers
                                 if run.scan_unroll else 1)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h[:, 0] @ params["head"].astype(h.dtype)
    return logits.astype(jnp.float32)[:, :cfg.vocab_size], new_caches


def cache_defs(cfg, batch: int, seq: int):
    Ldim = cfg.num_layers
    K, dh = cfg.num_kv_heads, cfg.head_dim_
    kv = pdef((Ldim, batch, seq, K, dh),
              (None, "batch", "cache_seq", "kv_heads", "head_dim"),
              init="zeros", dtype=jnp.bfloat16)
    ckv = pdef((Ldim, batch, cfg.encoder.seq_len, K, dh),
               (None, "batch", "enc_seq", "kv_heads", "head_dim"),
               init="zeros", dtype=jnp.bfloat16)
    return {"k": kv, "v": kv, "cross_k": ckv, "cross_v": ckv}
