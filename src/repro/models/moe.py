"""Mixture-of-Experts layer: top-k router + capacity-bounded dispatch.

Two implementations of the same math (cross-checked in tests):

* ``local``  — sort-based dispatch in plain jnp. Runs on a single device and
  under pjit auto-SPMD (expert dim sharded over 'data', XLA inserts the
  collectives). Used for smoke tests and for long_500k (global_batch=1
  cannot feed the shard_map grid).
* ``ep``     — shard_map expert parallelism over ('data','model'): tokens
  stay sharded over both axes, each cell routes locally, `lax.all_to_all`
  over 'data' moves token slots to their expert's owner row, expert weights
  (stored f-sharded over 'model' for FSDP-style memory) are all-gathered
  per layer, outputs return via the reverse all_to_all.  This is the
  TPU-native adaptation of the paper's "ship a structured subset" insight:
  only capacity-bounded token slots travel, never full activations.
"""
from __future__ import annotations

import functools
from typing import Optional

from jax.ad_checkpoint import checkpoint_name

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.params import dense_def, pdef


def moe_defs(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    return {
        "router": pdef((d, e), (None, "experts"), init="scaled",
                       scale=d ** -0.5),
        "w_gate": pdef((e, d, f), ("experts", None, "mlp"), init="scaled",
                       scale=d ** -0.5),
        "w_up": pdef((e, d, f), ("experts", None, "mlp"), init="scaled",
                     scale=d ** -0.5),
        "w_down": pdef((e, f, d), ("experts", "mlp", None), init="scaled",
                       scale=f ** -0.5),
    }


def _route(p, x, cfg):
    """x (n, d) -> (weights (n,k), expert_idx (n,k), aux_loss)."""
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # switch-style load-balance aux: E * sum_e f_e * p_e
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0)) / k
    return top_w.astype(x.dtype), top_i, aux


def _capacity(n_tokens: int, cfg) -> int:
    e, k, cf = (cfg.moe.num_experts, cfg.moe.top_k,
                cfg.moe.capacity_factor)
    return max(int(n_tokens * k * cf / e + 0.999), 1)


def _dispatch_indices(expert_idx, n_experts: int, capacity: int):
    """Flatten (n,k) assignments into per-expert slots.

    Returns (slot (n*k,), keep (n*k,), token (n*k,)) where slot is the
    destination index in an (E*C,) buffer; dropped assignments get slot E*C
    (scattered with mode='drop').
    """
    n, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)
    token = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n * k) - starts[sorted_e]
    keep_sorted = rank < capacity
    slot_sorted = jnp.where(keep_sorted, sorted_e * capacity + rank,
                            n_experts * capacity)
    inv = jnp.argsort(order, stable=True)
    return slot_sorted[inv], keep_sorted[inv], token


def _expert_ffn(w_gate, w_up, w_down, xs):
    """xs (E, C, d) -> (E, C, d); batched SwiGLU over experts.

    The output carries a named checkpoint ('moe_out') so the
    remat='moe_save' policy can keep expert outputs across the backward
    pass — the generic dots policies skip batched (e...) einsums, so full
    remat would otherwise recompute the whole expert FFN (§Perf).
    """
    dt = xs.dtype
    g = jnp.einsum("ecd,edf->ecf", xs, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xs, w_up.astype(dt))
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(dt))
    return checkpoint_name(out, "moe_out")


def moe_local(p, x, cfg):
    """Sort-based dispatch on whatever device set pjit gives us.

    x (B, T, d). Returns (out (B,T,d), aux_loss).
    """
    B, T, d = x.shape
    n = B * T
    xt = x.reshape(n, d)
    w, idx, aux = _route(p, xt, cfg)
    e = cfg.moe.num_experts
    cap = _capacity(n, cfg)
    slot, keep, token = _dispatch_indices(idx, e, cap)
    buf = jnp.zeros((e * cap, d), x.dtype).at[slot].set(
        xt[token], mode="drop")
    out_buf = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"],
                          buf.reshape(e, cap, d)).reshape(e * cap, d)
    gathered = jnp.where(keep[:, None],
                         out_buf.at[slot].get(mode="fill", fill_value=0.0),
                         0.0)
    wf = w.reshape(-1)[:, None].astype(gathered.dtype)
    out = jnp.zeros((n, d), x.dtype).at[token].add(gathered * wf)
    return out.reshape(B, T, d), aux


def moe_ep(p, x, cfg, ctx, run=None):
    """shard_map expert-parallel dispatch (see module docstring).

    x (B, T, d) sharded (batch->data, seq->model).
    """
    mesh = ctx.mesh
    dsize = ctx.axis_size("data")
    e = cfg.moe.num_experts
    assert e % dsize == 0, (e, dsize)
    B, T, d = x.shape

    gather_bf16 = run is not None and run.moe_gather_bf16

    def cell(router, w_gate, w_up, w_down, xl):
        # xl (B_l, T_l, d): this cell's tokens. Weights arrive f-sharded
        # over 'model' and expert-sharded over 'data' -> gather both so the
        # cell owns its experts' full matrices (FSDP-style layer gather).
        if gather_bf16:
            w_gate = w_gate.astype(jnp.bfloat16)
            w_up = w_up.astype(jnp.bfloat16)
            w_down = w_down.astype(jnp.bfloat16)
        w_gate = jax.lax.all_gather(w_gate, "model", axis=2, tiled=True)
        w_up = jax.lax.all_gather(w_up, "model", axis=2, tiled=True)
        w_down = jax.lax.all_gather(w_down, "model", axis=1, tiled=True)
        router = jax.lax.all_gather(router, "data", axis=1, tiled=True)
        bl, tl, _ = xl.shape
        n = bl * tl
        xt = xl.reshape(n, d)
        w, idx, aux = _route({"router": router}, xt, cfg)
        cap = _capacity(n, cfg)
        slot, keep, token = _dispatch_indices(idx, e, cap)
        buf = jnp.zeros((e * cap, d), xl.dtype).at[slot].set(
            xt[token], mode="drop")
        # (E, cap, d) --all_to_all over data--> (E_l, dsize*cap, d):
        # each row of the data axis receives the slots bound for its experts.
        el = e // dsize
        buf = jax.lax.all_to_all(buf.reshape(e, cap, d), "data",
                                 split_axis=0, concat_axis=1, tiled=True)
        out = _expert_ffn(w_gate, w_up, w_down, buf)
        out = jax.lax.all_to_all(out, "data", split_axis=1, concat_axis=0,
                                 tiled=True)
        out = out.reshape(e * cap, d)
        gathered = jnp.where(
            keep[:, None], out.at[slot].get(mode="fill", fill_value=0.0), 0.0)
        wf = w.reshape(-1)[:, None].astype(gathered.dtype)
        yl = jnp.zeros((n, d), xl.dtype).at[token].add(gathered * wf)
        aux = jax.lax.pmean(aux, ("data", "model"))
        return yl.reshape(bl, tl, d), aux

    seq_over_model = x.shape[1] % max(ctx.axis_size("model"), 1) == 0
    x_spec = P("data", "model" if seq_over_model else None, None)
    f = jax.shard_map(
        cell, mesh=mesh,
        in_specs=(
            P(None, "data"),            # router (d, E): E over data
            P("data", None, "model"),   # w_gate (E, d, f)
            P("data", None, "model"),   # w_up
            P("data", "model", None),   # w_down (E, f, d)
            x_spec,                     # x (B, T, d)
        ),
        out_specs=(x_spec, P()),
        check_vma=False)
    return f(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)


def moe_apply(p, x, cfg, run, ctx):
    impl = run.moe_impl
    if impl == "auto":
        use_ep = (ctx.active and "data" in ctx.mesh.shape
                  and x.shape[0] % ctx.axis_size("data") == 0
                  and x.shape[1] % ctx.axis_size("model") == 0
                  and cfg.moe.num_experts % ctx.axis_size("data") == 0
                  and cfg.d_ff % ctx.axis_size("model") == 0)
        impl = "ep" if use_ep else "local"
    if impl == "ep":
        return moe_ep(p, x, cfg, ctx, run)
    return moe_local(p, x, cfg)
