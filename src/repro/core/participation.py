"""Client participation schedules — who computes and who delivers, per
federated round.

Real multi-institutional deployments never see every hospital every
round: sites sample in (cross-device FedAvg), drop out (network loss),
or straggle (deliver a *stale* update one round late).  A schedule is a
pure function of ``(round_idx, n_clients, rng)`` returning a
:class:`RoundPlan`; the :class:`~repro.core.runtime.FedRuntime` owns the
rng stream, buffers straggler messages, and discounts their combine
weight before handing them to the aggregator (the stale-update handling
that keeps stateful server optimizers — fedavgm / fedadam — from
integrating outdated directions at full strength).

Select by name through :data:`PARTICIPATION` / :func:`get_participation`.
Spec strings carry parameters after colons::

    full                 every client, every round
    uniform:2            2 clients uniformly without replacement
    uniform:0.5          half the clients (at least 1)
    stratified:4         4 clients, round-robin across contiguous strata
    dropout:0.3          each client drops with p=0.3
    dropout:0.3:0.5      ... and a dropped client straggles (delivers
                         next round, stale) with p=0.5
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List

import numpy as np


@dataclass
class RoundPlan:
    """One round's participation: ``arrive`` compute and deliver this
    round; ``stragglers`` compute this round but deliver *next* round
    (their updates arrive with staleness 1)."""
    arrive: List[int]
    stragglers: List[int] = field(default_factory=list)


@dataclass(frozen=True)
class Participation:
    """A named schedule: ``plan(round_idx, n_clients, rng)`` →
    :class:`RoundPlan`.  ``rng`` is the runtime's dedicated stream, so a
    fixed runtime seed gives a deterministic participation trace.
    ``may_straggle`` marks schedules that can produce late deliveries —
    the runtime uses it to reject transports whose secure-agg masks
    could not cancel across rounds."""
    name: str
    plan_fn: Callable[[int, int, np.random.Generator], RoundPlan]
    may_straggle: bool = False

    def plan(self, round_idx: int, n_clients: int,
             rng: np.random.Generator) -> RoundPlan:
        return self.plan_fn(round_idx, n_clients, rng)


def _full(r, n, rng) -> RoundPlan:
    return RoundPlan(list(range(n)))


def _resolve_k(k: float, n: int) -> int:
    kk = int(round(k * n)) if 0 < k < 1 else int(k)
    return max(1, min(n, kk))


def _uniform(k: float):
    def plan(r, n, rng):
        kk = _resolve_k(k, n)
        return RoundPlan(sorted(rng.choice(n, kk, replace=False).tolist()))
    return plan


def _stratified(k: float):
    """k clients spread round-robin over contiguous client strata (e.g.
    hospitals grouped by region/size): every stratum is represented
    before any stratum contributes twice."""
    def plan(r, n, rng):
        kk = _resolve_k(k, n)
        strata = np.array_split(np.arange(n), min(kk, n))
        picked: List[int] = []
        pools = [rng.permutation(s).tolist() for s in strata]
        i = 0
        while len(picked) < kk:
            pool = pools[i % len(pools)]
            if pool:
                picked.append(int(pool.pop()))
            i += 1
        return RoundPlan(sorted(picked))
    return plan


def _dropout(p_drop: float, p_straggle: float = 0.0):
    """Every client starts active; drops with ``p_drop``.  A dropped
    client straggles (computes now, delivers next round, stale) with
    ``p_straggle``, else its round is lost entirely."""
    def plan(r, n, rng):
        arrive, stragglers = [], []
        for i in range(n):
            if rng.random() >= p_drop:
                arrive.append(i)
            elif rng.random() < p_straggle:
                stragglers.append(i)
        if not arrive and not stragglers:  # keep the round alive
            arrive.append(int(rng.integers(n)))
        return RoundPlan(arrive, stragglers)
    return plan


#: schedule name -> factory(*args) -> plan function. Resolved via
#: :func:`get_participation` spec strings ("uniform:2", "dropout:0.3:0.5").
PARTICIPATION: Dict[str, Callable] = {
    "full": lambda: _full,
    "uniform": _uniform,
    "stratified": _stratified,
    "dropout": _dropout,
}


def get_participation(spec) -> Participation:
    """Resolve a schedule from a spec string (or pass one through)."""
    if isinstance(spec, Participation):
        return spec
    parts = str(spec).split(":")
    name, args = parts[0], [float(a) for a in parts[1:]]
    if name not in PARTICIPATION:
        raise KeyError(f"unknown participation {spec!r}; "
                       f"available: {sorted(PARTICIPATION)} "
                       f"(spec: name[:arg[:arg]], e.g. 'uniform:2')")
    try:
        plan_fn = PARTICIPATION[name](*args)
    except TypeError as e:
        raise ValueError(f"bad participation spec {spec!r}: {e}") from e
    may_straggle = name == "dropout" and len(args) > 1 and args[1] > 0
    return Participation(str(spec), plan_fn, may_straggle)
