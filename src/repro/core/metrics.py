"""Binary-classification metrics (paper's primary: F1; plus P/R/acc)."""
from __future__ import annotations

from typing import Dict

import numpy as np


def binary_metrics(pred, y) -> Dict[str, float]:
    pred = np.asarray(pred).astype(bool)
    y = np.asarray(y).astype(bool)
    tp = int(np.sum(pred & y))
    fp = int(np.sum(pred & ~y))
    fn = int(np.sum(~pred & y))
    tn = int(np.sum(~pred & ~y))
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-12)
    acc = (tp + tn) / max(len(y), 1)
    return {"f1": f1, "precision": prec, "recall": rec, "accuracy": acc,
            "tp": tp, "fp": fp, "fn": fn, "tn": tn}
