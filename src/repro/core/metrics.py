"""Binary-classification metrics (paper's primary: F1; plus P/R/acc,
and threshold-free ROC-AUC / Brier when scores are available)."""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


def roc_auc(scores, y) -> float:
    """Rank-based (Mann-Whitney) ROC-AUC with tie-averaged ranks.

    scores: any monotone score (probability or margin); y: {0,1}.
    Returns NaN when only one class is present."""
    s = np.asarray(scores, np.float64)
    y = np.asarray(y).astype(bool)
    n_pos = int(y.sum())
    n_neg = len(y) - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    order = np.argsort(s, kind="mergesort")
    _, inv, counts = np.unique(s[order], return_inverse=True,
                               return_counts=True)
    starts = np.cumsum(counts) - counts
    avg_rank = starts + (counts + 1) / 2.0         # 1-based, tie-averaged
    ranks = np.empty(len(s), np.float64)
    ranks[order] = avg_rank[inv]
    return float((ranks[y].sum() - n_pos * (n_pos + 1) / 2.0)
                 / (n_pos * n_neg))


def brier_score(probs, y) -> float:
    """Mean squared error of predicted probabilities (clipped to [0,1])."""
    p = np.clip(np.asarray(probs, np.float64), 0.0, 1.0)
    y = np.asarray(y).astype(np.float64)
    return float(np.mean((p - y) ** 2))


def binary_metrics(pred, y,
                   scores: Optional[np.ndarray] = None) -> Dict[str, float]:
    """Thresholded metrics from ``pred`` (bool); when ``scores`` (a
    probability or monotone margin per row) is given, threshold-free
    ``roc_auc`` and ``brier`` are added."""
    pred = np.asarray(pred).astype(bool)
    y = np.asarray(y).astype(bool)
    tp = int(np.sum(pred & y))
    fp = int(np.sum(pred & ~y))
    fn = int(np.sum(~pred & y))
    tn = int(np.sum(~pred & ~y))
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-12)
    acc = (tp + tn) / max(len(y), 1)
    out = {"f1": f1, "precision": prec, "recall": rec, "accuracy": acc,
           "tp": tp, "fp": fp, "fn": fn, "tn": tn}
    if scores is not None:
        out["roc_auc"] = roc_auc(scores, y)
        out["brier"] = brier_score(scores, y)
    return out
