"""Histogram-aggregation federated GBDT — the ``fed_hist`` mode.

Unlike the tree-shipping protocols (C2 ships tree subsets, C3 ships
shallow feature-extracted ensembles), ``fed_hist`` never ships trees up:
after one federated-binning round fixes shared bin edges
(``repro.trees.binning.fed_fit_bins``), every boosting round has clients
ship their per-level (F, 2^level * n_bins, 2) grad/hess histograms and
the server grows the tree from the sum.  Because all clients bin with the
same edges, the summed histogram equals the histogram of the union of
shards — so federated training is **exactly** centralized GBDT on the
pooled shards (tested to numerical tolerance), at a communication cost that
depends on (F, n_bins, depth) but **not** on the number of samples.

The boosting loop runs on the shared :class:`~repro.core.runtime.
FedRuntime`: the binning round happens in ``setup``, then each runtime
round grows one tree from the *participating* clients' histograms
(``cfg.participation``; inactive shards contribute zero weight that
round, and every client still receives the broadcast tree so margins
stay in sync).  Stragglers are treated as drops (histogram aggregation
is fused into the jitted growth, so a one-round-late histogram of stale
margins cannot be replayed).

Privacy hooks mirror the parametric pipeline (``core/privacy.py``) and
can come from either the config flags or a ``cfg.transport`` stack
(mask / dpnoise / frame layers; codec layers don't apply to in-jit
histograms and raise):

* ``secure_agg=True`` simulates Bonawitz-style pairwise masking on the
  shipped histograms — ring masks m_i - m_{i+1} cancel in the server's
  sum, so the server only sees the aggregate (HE stand-in, DESIGN.md
  §Changed-assumptions).
* ``dp_epsilon > 0`` adds Gaussian noise calibrated by
  ``privacy.gaussian_sigma(eps, delta, sensitivity)`` to the aggregated
  histogram of every level (per-histogram sensitivity = the max
  grad/hess contribution of one sample).

Every byte crossing a client boundary — sketches, histograms, the
broadcast trees — goes through the CommLog ledger.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import binary_metrics
from repro.core.privacy import gaussian_sigma
from repro.core.runtime import ClientMsg, ClientWork, FedRuntime, ServerAgg
from repro.data import sampling as S
from repro.trees import binning, gbdt
from repro.trees.growth import (fed_hist_bytes, grow_tree_fed, nbytes,
                                predict_tree, stack_trees)


@dataclass
class FedHistConfig:
    num_rounds: int = 50
    depth: int = 6
    n_bins: int = 64
    learning_rate: float = 0.3
    lam: float = 1.0
    sketch_size: int = 128       # federated-binning sketch points/feature
    sampling: str = "none"
    hist_impl: str = "auto"      # histogram kernel routing: auto | pallas
    # | pallas_interpret | xla (see repro.kernels.hist.ops)
    engine: str = "batched"      # 'batched' (client-axis kernel) |
    # 'sequential' (per-client loop inside growth — the parity reference)
    secure_agg: bool = False
    dp_epsilon: float = 0.0      # 0 -> no DP noise
    dp_delta: float = 1e-5
    dp_sensitivity: float = 1.0
    participation: str = "full"  # repro.core.participation spec
    transport: str = "plain"     # mask/dpnoise/frame layers (no codecs)
    schedule: str = "sync"       # repro.core.runtime.SCHEDULES spec
    latency: Optional[str] = None  # repro.core.latency.LATENCY spec
    seed: int = 0


def _masked_noisy_sum(hists, key, *, sigma: float, secure: bool):
    """Aggregate per-client histograms: optional ring-mask secure agg
    (masks cancel in the sum) + optional Gaussian DP noise on the sum."""
    ks, kn = (jax.random.split(key) if key is not None else (None, None))
    if secure:
        scale = jnp.std(hists) + 1e-3
        m = jax.random.normal(ks, hists.shape, hists.dtype) * scale
        hists = hists + m - jnp.roll(m, -1, axis=0)
    total = jnp.sum(hists, axis=0)
    if sigma > 0.0:
        total = total + jax.random.normal(kn, total.shape,
                                          total.dtype) * sigma
    return total


def _pad_stack(arrs, n_max: int):
    def pad(a):
        width = [(0, n_max - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(jnp.asarray(a), width)
    return jnp.stack([pad(a) for a in arrs])


def stack_client_shards(clients: Sequence[Tuple[np.ndarray, np.ndarray]],
                        edges):
    """Bin each shard with the shared edges and pad-stack to (C, n_max).

    Returns (x (C,n,F), y (C,n), bins (C,n,F), valid_w (C,n)) with
    valid_w = 0 marking pad rows (excluded from growth by weight)."""
    n_max = max(len(y) for _, y in clients)
    xs = [jnp.asarray(x, jnp.float32) for x, _ in clients]
    x_c = _pad_stack(xs, n_max)
    y_c = _pad_stack([jnp.asarray(y, jnp.float32) for _, y in clients],
                     n_max)
    bins_c = _pad_stack([binning.apply_bins(x, edges) for x in xs], n_max)
    w_c = _pad_stack([jnp.ones(len(y), jnp.float32) for _, y in clients],
                     n_max)
    return x_c, y_c, bins_c, w_c


@dataclass
class _HistWork(ClientWork, ServerAgg):
    clients: Sequence
    cfg: FedHistConfig
    fed_stats: object = None

    def setup(self, rt: FedRuntime):
        cfg = self.cfg
        if cfg.engine not in ("batched", "sequential"):
            raise ValueError(f"unknown engine {cfg.engine!r}; "
                             "use 'batched' or 'sequential'")
        tp = rt.transport.hist_params()   # rejects codec layers
        sampled = [S.apply_strategy(cfg.sampling, x, y, cfg.seed + i,
                                    fed_stats=self.fed_stats)
                   for i, (x, y) in enumerate(self.clients)]
        self.C = len(sampled)
        self.F = sampled[0][0].shape[1]

        # round 0: federated binning — sketches up, shared edges down
        edges = binning.fed_fit_bins([x for x, _ in sampled], cfg.n_bins,
                                     sketch_size=cfg.sketch_size,
                                     comm=rt.comm)
        x_c, y_c, bins_c, w_c = stack_client_shards(sampled, edges)

        # base margin from global label counts (two scalars per client)
        n_pos = sum(float(np.sum(y)) for _, y in sampled)
        n_tot = sum(len(y) for _, y in sampled)
        for i in range(self.C):
            rt.log_up(0, i, 8, "label-counts")
        pos = float(np.clip(n_pos / n_tot, 1e-4, 1 - 1e-4))
        base = float(np.log(pos / (1 - pos)))

        secure = cfg.secure_agg or tp["secure"]
        eps = cfg.dp_epsilon if cfg.dp_epsilon > 0 else tp["dp_epsilon"]
        delta = cfg.dp_delta if cfg.dp_epsilon > 0 else tp["dp_delta"]
        hist_agg = None
        if secure or eps > 0:
            sigma = (gaussian_sigma(eps, delta, cfg.dp_sensitivity)
                     if eps > 0 else 0.0)
            # functools.partial first so sigma/secure stay Python
            # constants (trace-time branches); tree_util.Partial makes
            # it a jit-able arg
            hist_agg = jax.tree_util.Partial(
                functools.partial(_masked_noisy_sum, sigma=sigma,
                                  secure=secure))
        self.edges, self.x_c, self.y_c = edges, x_c, y_c
        self.bins_c, self.w_c, self.hist_agg = bins_c, w_c, hist_agg
        self.key = jax.random.PRNGKey(cfg.seed)
        self.up_per_tree = (fed_hist_bytes(self.F, cfg.n_bins, cfg.depth)
                            + tp["frame_overhead"])
        return {"margin": jnp.full(y_c.shape, base, jnp.float32),
                "trees": [], "base": base}

    def client_round(self, rt, state, rnd):
        # boosting-round ledger indices start at 1 (round 0 = binning);
        # up_per_tree already carries the transport frame overhead
        for i in rnd.computing:
            rt.comm.log(rnd.index + 1, f"{rt.client_prefix}{i}", "up",
                        self.up_per_tree, "grad-hess-histograms")
        return [ClientMsg(i, None, self.up_per_tree,
                          what="grad-hess-histograms")
                for i in rnd.computing]

    def aggregate(self, rt, state, msgs, rnd):
        cfg, r = self.cfg, rnd.index
        active = np.zeros(self.C, np.float32)
        active[[m.client for m in msgs]] = 1.0
        w_round = self.w_c * jnp.asarray(active)[:, None]
        p = jax.nn.sigmoid(state["margin"])
        grad = p - self.y_c
        hess = p * (1 - p)
        with rt.timer:
            tree = grow_tree_fed(
                self.bins_c, self.edges, grad, hess, w_round,
                depth=cfg.depth, n_bins=cfg.n_bins, lam=cfg.lam,
                hist_impl=cfg.hist_impl, hist_agg=self.hist_agg,
                agg_key=jax.random.fold_in(self.key, r),
                batch_clients=(cfg.engine == "batched"))
            state["margin"] = state["margin"] + cfg.learning_rate \
                * jax.vmap(predict_tree, in_axes=(None, 0))(tree, self.x_c)
            jax.block_until_ready(state["margin"])
        state["trees"].append(tree)
        down = nbytes(tree)
        for i in range(self.C):
            rt.log_down(r + 1, i, down, "tree")
        return state

    def finalize(self, rt, state):
        return gbdt.GBDT(stack_trees(state["trees"]),
                         self.cfg.learning_rate, state["base"])


def train_federated_xgb_hist(clients: Sequence[Tuple[np.ndarray,
                                                     np.ndarray]],
                             cfg: FedHistConfig, fed_stats=None):
    """Histogram-aggregation federated GBDT.  Returns (model, comm, timer).

    The returned model is one global ``gbdt.GBDT`` (the server's trees) —
    identical on every client after the final broadcast.
    """
    work = _HistWork(clients, cfg, fed_stats)
    rt = FedRuntime(n_clients=len(clients), rounds=cfg.num_rounds,
                    participation=cfg.participation,
                    transport=cfg.transport, schedule=cfg.schedule,
                    latency=cfg.latency, seed=cfg.seed,
                    allow_stale=False)
    model = rt.run(work)
    return model, rt.comm, rt.timer


def evaluate_fed_hist(model: gbdt.GBDT, x, y):
    xj = jnp.asarray(x)
    return binary_metrics(np.asarray(gbdt.predict(model, xj)), y,
                          scores=np.asarray(gbdt.predict_proba(model, xj)))
