"""Tree-subset sampling for federated Random Forest (paper C2, Theorem 1).

Each client trains k trees locally and ships only s of them; the global
ensemble is the union, predicting by majority vote.  Comm drops from
O(N*k) to O(N*s); with s = floor(sqrt(k)) this is the Theorem-1 rate, and
the in-repo baseline (s = k, FedTree-style full shipping) is measured by
the same ledger so the 70 % claim is a real before/after.

Local training runs under two engines: ``engine="batched"`` (default)
stacks client shards on a leading client axis, draws each client's
bootstrap with its own rng *before* padding, and grows every client's
forest in one ``vmap(clients) ∘ vmap(trees)`` call — the histogram hot
path runs client-batched through ``repro.kernels.hist``.
``engine="sequential"`` keeps the per-client Python loop as the parity
reference (identical forests; ``tests/test_fed_hist.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import CommLog, Timer
from repro.core.metrics import binary_metrics
from repro.data import sampling as S
from repro.trees import binning
from repro.trees import forest as RF
from repro.trees.growth import (Tree, concat_forests, nbytes, predict_forest,
                                take_trees)


@dataclass
class FedForestConfig:
    trees_per_client: int = 100
    subset: Optional[int] = None      # None -> floor(sqrt(k)); k -> dense
    selection: str = "best"           # 'best' (local acc) | 'random'
    depth: int = 10
    n_bins: int = 64
    sampling: str = "none"
    feature_frac: float = 0.8
    hist_impl: str = "auto"           # histogram kernel routing: auto |
    # pallas | pallas_interpret | xla (see repro.kernels.hist.ops)
    engine: str = "batched"           # 'batched' (client-axis vmap) |
    # 'sequential' (per-client loop — the parity reference)
    seed: int = 0


def _select(forest: Tree, x, y, s: int, how: str, seed: int):
    k = forest.feature.shape[0]
    if s >= k:
        return forest, np.arange(k)
    if how == "random":
        idx = np.random.default_rng(seed).choice(k, s, replace=False)
    else:  # per-tree local accuracy
        vals = predict_forest(forest, jnp.asarray(x)) + 0.5   # (k, n)
        acc = np.asarray(jnp.mean(((vals > 0.5) == (jnp.asarray(y) > 0.5)),
                                  axis=1))
        idx = np.argsort(-acc)[:s]
    return take_trees(forest, jnp.asarray(np.sort(idx))), idx


def _local_forests(sampled, cfg: FedForestConfig) -> List[RF.RandomForest]:
    """Train each client's local forest under the configured engine.

    Both engines consume identical per-client (edges, bins, bootstrap
    weights, feature masks) — the batched path only pads shards to a
    common length (pad rows carry zero bootstrap weight) and vmaps the
    growth over the client axis."""
    if cfg.engine == "sequential":
        return [RF.fit(jnp.asarray(xs), jnp.asarray(ys),
                       num_trees=cfg.trees_per_client, depth=cfg.depth,
                       n_bins=cfg.n_bins, feature_frac=cfg.feature_frac,
                       hist_impl=cfg.hist_impl,
                       rng=jax.random.PRNGKey(cfg.seed + 17 * i))
                for i, (xs, ys) in enumerate(sampled)]
    if cfg.engine != "batched":
        raise ValueError(f"unknown engine {cfg.engine!r}; "
                         "use 'batched' or 'sequential'")
    F = sampled[0][0].shape[1]
    n_max = max(len(ys) for _, ys in sampled)
    bins_l, edges_l, y_l, w_l, fm_l = [], [], [], [], []
    for i, (xs, ys) in enumerate(sampled):
        xs = jnp.asarray(xs)
        n = len(ys)
        edges = binning.fit_bins(xs, cfg.n_bins)
        bins = binning.apply_bins(xs, edges)
        w, fm = RF.bootstrap_masks(jax.random.PRNGKey(cfg.seed + 17 * i),
                                   cfg.trees_per_client, n, F,
                                   cfg.feature_frac)
        pad = n_max - n
        bins_l.append(jnp.pad(bins, ((0, pad), (0, 0))))
        edges_l.append(edges)
        y_l.append(jnp.pad(jnp.asarray(ys, jnp.float32), (0, pad)))
        w_l.append(jnp.pad(w, ((0, 0), (0, pad))))
        fm_l.append(fm)
    return RF.fit_batched(jnp.stack(bins_l), jnp.stack(edges_l),
                          jnp.stack(y_l), jnp.stack(w_l), jnp.stack(fm_l),
                          depth=cfg.depth, n_bins=cfg.n_bins,
                          hist_impl=cfg.hist_impl)


def train_federated_rf(clients: Sequence[Tuple[np.ndarray, np.ndarray]],
                       cfg: FedForestConfig,
                       fed_stats=None):
    """Returns (global_forest, comm, timer). One-shot protocol (trees are
    not iterative): a single up/down round as in the paper."""
    comm = CommLog()
    timer = Timer()
    s = cfg.subset or int(np.floor(np.sqrt(cfg.trees_per_client)))
    sampled = [S.apply_strategy(cfg.sampling, x, y, cfg.seed + i,
                                fed_stats=fed_stats)
               for i, (x, y) in enumerate(clients)]
    locals_ = _local_forests(sampled, cfg)
    subsets: List[Tree] = []
    for i, ((xs, ys), local) in enumerate(zip(sampled, locals_)):
        sel, _ = _select(local.forest, xs, ys, s, cfg.selection,
                         cfg.seed + i)
        comm.log(0, f"c{i}", "up", nbytes(sel), "trees")
        subsets.append(sel)
    with timer:
        glob = concat_forests(subsets)
    for i in range(len(clients)):
        comm.log(0, f"c{i}", "down", nbytes(glob), "global-forest")
    return RF.RandomForest(glob), comm, timer


def evaluate_rf(model: RF.RandomForest, x, y):
    pred = np.asarray(RF.predict_votes(model, jnp.asarray(x)))
    return binary_metrics(pred, y)
