"""Tree-subset sampling for federated Random Forest (paper C2, Theorem 1).

Each client trains k trees locally and ships only s of them; the global
ensemble is the union, predicting by majority vote.  Comm drops from
O(N*k) to O(N*s); with s = floor(sqrt(k)) this is the Theorem-1 rate, and
the in-repo baseline (s = k, FedTree-style full shipping) is measured by
the same ledger so the 70 % claim is a real before/after.

The one-shot protocol runs as a single :class:`~repro.core.runtime.
FedRuntime` round: ``cfg.participation`` decides which clients
contribute trees (uniform-k models hospitals that never enroll), and
``cfg.transport`` applies size-level wire layers (framing) to the
shipped forests — float codec layers don't apply to tree payloads and
raise.

Local training runs under two engines: ``engine="batched"`` (default)
stacks client shards on a leading client axis, draws each client's
bootstrap with its own rng *before* padding, and grows every client's
forest in one ``vmap(clients) ∘ vmap(trees)`` call — the histogram hot
path runs client-batched through ``repro.kernels.hist``.
``engine="sequential"`` keeps the per-client Python loop as the parity
reference (identical forests; ``tests/test_fed_hist.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import binary_metrics
from repro.core.runtime import ClientMsg, ClientWork, FedRuntime, ServerAgg
from repro.data import sampling as S
from repro.trees import binning
from repro.trees import forest as RF
from repro.trees.growth import (Tree, concat_forests, nbytes, predict_forest,
                                take_trees)


@dataclass
class FedForestConfig:
    trees_per_client: int = 100
    subset: Optional[int] = None      # None -> floor(sqrt(k)); k -> dense
    selection: str = "best"           # 'best' (local acc) | 'random'
    depth: int = 10
    n_bins: int = 64
    sampling: str = "none"
    feature_frac: float = 0.8
    hist_impl: str = "auto"           # histogram kernel routing: auto |
    # pallas | pallas_interpret | xla (see repro.kernels.hist.ops)
    engine: str = "batched"           # 'batched' (client-axis vmap) |
    # 'sequential' (per-client loop — the parity reference)
    participation: str = "full"       # repro.core.participation spec
    transport: str = "plain"          # size-level layers only (framing)
    schedule: str = "sync"            # repro.core.runtime.SCHEDULES spec
    latency: Optional[str] = None     # repro.core.latency.LATENCY spec
    seed: int = 0


def _select(forest: Tree, x, y, s: int, how: str, seed: int):
    k = forest.feature.shape[0]
    if s >= k:
        return forest, np.arange(k)
    if how == "random":
        idx = np.random.default_rng(seed).choice(k, s, replace=False)
    else:  # per-tree local accuracy
        vals = predict_forest(forest, jnp.asarray(x)) + 0.5   # (k, n)
        acc = np.asarray(jnp.mean(((vals > 0.5) == (jnp.asarray(y) > 0.5)),
                                  axis=1))
        idx = np.argsort(-acc)[:s]
    return take_trees(forest, jnp.asarray(np.sort(idx))), idx


def _local_forests(sampled, cfg: FedForestConfig,
                   ids: Optional[Sequence[int]] = None
                   ) -> List[RF.RandomForest]:
    """Train each client's local forest under the configured engine.

    ``ids`` are the *global* client indices of ``sampled`` (bootstrap
    rngs are keyed by global id, so a client grows the same forest
    whether or not its peers participate).  Both engines consume
    identical per-client (edges, bins, bootstrap weights, feature
    masks) — the batched path only pads shards to a common length (pad
    rows carry zero bootstrap weight) and vmaps the growth over the
    client axis."""
    ids = list(ids) if ids is not None else list(range(len(sampled)))
    if cfg.engine == "sequential":
        return [RF.fit(jnp.asarray(xs), jnp.asarray(ys),
                       num_trees=cfg.trees_per_client, depth=cfg.depth,
                       n_bins=cfg.n_bins, feature_frac=cfg.feature_frac,
                       hist_impl=cfg.hist_impl,
                       rng=jax.random.PRNGKey(cfg.seed + 17 * i))
                for i, (xs, ys) in zip(ids, sampled)]
    if cfg.engine != "batched":
        raise ValueError(f"unknown engine {cfg.engine!r}; "
                         "use 'batched' or 'sequential'")
    F = sampled[0][0].shape[1]
    n_max = max(len(ys) for _, ys in sampled)
    bins_l, edges_l, y_l, w_l, fm_l = [], [], [], [], []
    for i, (xs, ys) in zip(ids, sampled):
        xs = jnp.asarray(xs)
        n = len(ys)
        edges = binning.fit_bins(xs, cfg.n_bins)
        bins = binning.apply_bins(xs, edges)
        w, fm = RF.bootstrap_masks(jax.random.PRNGKey(cfg.seed + 17 * i),
                                   cfg.trees_per_client, n, F,
                                   cfg.feature_frac)
        pad = n_max - n
        bins_l.append(jnp.pad(bins, ((0, pad), (0, 0))))
        edges_l.append(edges)
        y_l.append(jnp.pad(jnp.asarray(ys, jnp.float32), (0, pad)))
        w_l.append(jnp.pad(w, ((0, 0), (0, pad))))
        fm_l.append(fm)
    return RF.fit_batched(jnp.stack(bins_l), jnp.stack(edges_l),
                          jnp.stack(y_l), jnp.stack(w_l), jnp.stack(fm_l),
                          depth=cfg.depth, n_bins=cfg.n_bins,
                          hist_impl=cfg.hist_impl)


@dataclass
class _ForestWork(ClientWork, ServerAgg):
    clients: Sequence
    cfg: FedForestConfig
    fed_stats: object = None

    def setup(self, rt: FedRuntime):
        rt.transport.require_bytes_only("tree_subset")
        cfg = self.cfg
        self.sampled = [S.apply_strategy(cfg.sampling, x, y, cfg.seed + i,
                                         fed_stats=self.fed_stats)
                        for i, (x, y) in enumerate(self.clients)]
        self.s = cfg.subset or int(np.floor(np.sqrt(cfg.trees_per_client)))
        return {"model": None}

    def client_round(self, rt, state, rnd):
        cfg = self.cfg
        shards = [self.sampled[i] for i in rnd.computing]
        locals_ = _local_forests(shards, cfg, ids=rnd.computing)
        msgs = []
        for slot, i in enumerate(rnd.computing):
            xs, ys = shards[slot]
            sel, _ = _select(locals_[slot].forest, xs, ys, self.s,
                             cfg.selection, cfg.seed + i)
            wire = rt.encode(sel, nbytes=nbytes(sel), round_idx=rnd.index,
                             client=i, slot=slot,
                             n_active=len(rnd.computing))
            rt.log_up(rnd.index, i, wire.nbytes, "trees")
            msgs.append(ClientMsg(i, sel, wire.nbytes, weight=len(ys),
                                  what="trees"))
        return msgs

    def aggregate(self, rt, state, msgs, rnd):
        with rt.timer:
            glob = concat_forests([m.payload for m in msgs])
        for i in range(len(self.clients)):
            rt.log_down(rnd.index, i, nbytes(glob), "global-forest")
        state["model"] = RF.RandomForest(glob)
        return state

    def finalize(self, rt, state):
        return state["model"]


def train_federated_rf(clients: Sequence[Tuple[np.ndarray, np.ndarray]],
                       cfg: FedForestConfig,
                       fed_stats=None):
    """Returns (global_forest, comm, timer). One-shot protocol (trees are
    not iterative): a single FedRuntime round, up (subsets) then down
    (the union forest broadcast), as in the paper."""
    work = _ForestWork(clients, cfg, fed_stats)
    rt = FedRuntime(n_clients=len(clients), rounds=1,
                    participation=cfg.participation,
                    transport=cfg.transport, schedule=cfg.schedule,
                    latency=cfg.latency, seed=cfg.seed,
                    allow_stale=False)
    model = rt.run(work)
    return model, rt.comm, rt.timer


def evaluate_rf(model: RF.RandomForest, x, y):
    xj = jnp.asarray(x)
    pred = np.asarray(RF.predict_votes(model, xj))
    return binary_metrics(pred, y,
                          scores=np.asarray(RF.predict_proba(model, xj)))
