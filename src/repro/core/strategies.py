"""Server aggregation strategies — a registry so federated engines pick
aggregation by name, not by code.

A :class:`Strategy` splits a federated round's server side into two
halves that compose with secure aggregation and DP:

* ``combine(deltas, sizes)`` — weighted mean of client update pytrees
  (uniform for plain FedAvg, |D_i|-proportional for the weighted
  variants).  Runs *before* DP noise is added.
* ``server_update(state, avg)`` — the server-side optimizer applied to
  the (possibly noised) average delta: identity for FedAvg/FedProx,
  heavy-ball momentum for FedAvgM, Adam for FedAdam (Reddi et al. 2021,
  "Adaptive Federated Optimization").

``client_mu > 0`` marks a strategy as FedProx: engines add the proximal
gradient ``mu * (theta - theta_global)`` during *local* training; the
server side is identical to FedAvg.

All pytrees share the structure of the model params; deltas and the
returned update are in parameter units (the engine applies
``params + update``).  Use :func:`get_strategy` to resolve a name from
:data:`STRATEGIES`, optionally overriding hyperparameters::

    strat = get_strategy("fedadam", server_lr=0.05)
    state = strat.init_state(global_params)
    update, state = strat.aggregate(state, deltas, sizes)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Strategy:
    """One server aggregation rule. Frozen — override via ``replace``.

    Attributes:
      name: registry key.
      client_mu: FedProx proximal coefficient; >0 means engines must add
        ``mu * (theta - theta_global)`` to local gradients.
      weighted: weight client deltas by sample count instead of uniformly.
      server_lr: scale applied to the server-side update (eta in FedOpt).
      momentum: heavy-ball coefficient for FedAvgM (0 disables).
      adam: use server-side Adam (FedAdam); overrides ``momentum``.
      beta1/beta2/eps: FedAdam moment coefficients / stability term
        (eps is Reddi et al.'s tau, in delta units).
    """
    name: str
    client_mu: float = 0.0
    weighted: bool = False
    server_lr: float = 1.0
    momentum: float = 0.0
    adam: bool = False
    beta1: float = 0.9
    beta2: float = 0.99
    eps: float = 1e-3

    # -- state ------------------------------------------------------------

    def init_state(self, global_params) -> Optional[Dict[str, Any]]:
        """Server optimizer state: None for stateless strategies, else a
        dict of pytrees shaped like ``global_params`` (all zeros)."""
        if self.adam:
            z = jax.tree.map(jnp.zeros_like, global_params)
            return {"m": z, "v": jax.tree.map(jnp.zeros_like, global_params)}
        if self.momentum > 0:
            return {"m": jax.tree.map(jnp.zeros_like, global_params)}
        return None

    # -- round halves -----------------------------------------------------

    def norm_weights(self, sizes: Sequence[float]) -> List[float]:
        """Per-client combine weights, summing to 1.

        sizes: per-client sample counts (any consistent unit)."""
        n = len(sizes)
        if not self.weighted:
            return [1.0 / n] * n
        total = float(sum(sizes))
        if total <= 0:
            return [1.0 / n] * n
        return [float(s) / total for s in sizes]

    def combine(self, deltas: Sequence[Any], sizes: Sequence[float]):
        """Weighted mean of client delta pytrees (parameter units)."""
        if len(deltas) == 0:
            raise ValueError("combine() needs at least one client delta")
        ws = self.norm_weights(sizes)
        return jax.tree.map(
            lambda *xs: sum(w * x for w, x in zip(ws, xs)), *deltas)

    def server_update(self, state, avg) -> Tuple[Any, Optional[Dict]]:
        """Map the averaged delta through the server optimizer.

        Returns (update, new_state); update is what the engine adds to
        the global params."""
        if self.adam:
            m = jax.tree.map(lambda m, g: self.beta1 * m
                             + (1 - self.beta1) * g, state["m"], avg)
            v = jax.tree.map(lambda v, g: self.beta2 * v
                             + (1 - self.beta2) * g * g, state["v"], avg)
            upd = jax.tree.map(
                lambda m, v: self.server_lr * m / (jnp.sqrt(v) + self.eps),
                m, v)
            return upd, {"m": m, "v": v}
        if self.momentum > 0:
            m = jax.tree.map(lambda m, g: self.momentum * m + g,
                             state["m"], avg)
            return jax.tree.map(lambda m: self.server_lr * m, m), {"m": m}
        return jax.tree.map(lambda g: self.server_lr * g, avg), state

    def aggregate(self, state, deltas: Sequence[Any],
                  sizes: Sequence[float]) -> Tuple[Any, Optional[Dict]]:
        """combine + server_update in one call (no secure-agg / DP path).

        Returns (update, new_state)."""
        return self.server_update(state, self.combine(deltas, sizes))


STRATEGIES: Dict[str, Strategy] = {
    "fedavg": Strategy("fedavg"),
    "fedavg_weighted": Strategy("fedavg_weighted", weighted=True),
    "fedprox": Strategy("fedprox", client_mu=0.01),
    "fedavgm": Strategy("fedavgm", momentum=0.9),
    "fedadam": Strategy("fedadam", adam=True, server_lr=0.1),
}


def register(strategy: Strategy) -> Strategy:
    """Add a strategy to the registry (name collision overwrites)."""
    STRATEGIES[strategy.name] = strategy
    return strategy


def get_strategy(name: str, **overrides) -> Strategy:
    """Resolve a strategy by name; kwargs override hyperparameters.

    Raises KeyError listing valid names for an unknown strategy."""
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"available: {sorted(STRATEGIES)}")
    s = STRATEGIES[name]
    return dataclasses.replace(s, **overrides) if overrides else s
