"""Communication accounting: bytes-on-wire per round, per client, per
direction — the paper's Comm(MB) columns and the 70% / 3.2x claims are
measured against this ledger (never against constants)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List

import jax
import numpy as np


def pytree_bytes(tree) -> int:
    return int(sum(np.asarray(x).size * np.asarray(x).dtype.itemsize
                   for x in jax.tree.leaves(tree)))


@dataclass
class CommLog:
    events: List[Dict] = field(default_factory=list)

    def log(self, round_idx: int, client: str, direction: str,
            nbytes: int, what: str = ""):
        self.events.append(dict(round=round_idx, client=client,
                                direction=direction, bytes=int(nbytes),
                                what=what))

    def total_bytes(self, direction: str = None) -> int:
        return sum(e["bytes"] for e in self.events
                   if direction is None or e["direction"] == direction)

    def total_mb(self, direction: str = None) -> float:
        return self.total_bytes(direction) / 1e6

    def uplink_mb(self) -> float:
        return self.total_mb("up")

    def per_round_mb(self) -> Dict[int, float]:
        out: Dict[int, float] = {}
        for e in self.events:
            out[e["round"]] = out.get(e["round"], 0.0) + e["bytes"] / 1e6
        return out

    def per_what_bytes(self) -> Dict[str, int]:
        """Ledger breakdown by payload kind (e.g. 'quantile-sketch',
        'grad-hess-histograms', 'trees') — the comm-vs-accuracy tables
        cite these, never constants."""
        out: Dict[str, int] = {}
        for e in self.events:
            out[e["what"]] = out.get(e["what"], 0) + e["bytes"]
        return out


@dataclass
class Timer:
    """Aggregation wall-time accounting (paper reports 0.8s vs 4.2s)."""
    total_s: float = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.total_s += time.perf_counter() - self._t0
