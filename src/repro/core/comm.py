"""Communication accounting and the layered wire transport.

Ledger: bytes-on-wire per round, per client, per direction — the paper's
Comm(MB) columns and the 70% / 3.2x claims are measured against this
ledger (never against constants).

Transport: every client→server payload crosses a declarative **layer
stack** (codec/sparsifier → secure-agg mask → DP noise → frame).  Each
layer transforms the payload and/or its exact wire size; the engine logs
the size the *last* layer reports, so every byte still lands in the same
``CommLog``.  Stacks are composed from :data:`LAYERS` by a ``>``-joined
spec string and selected by name through :data:`TRANSPORTS` /
:func:`get_transport` — shared by the parametric pipelines (float update
pytrees) and the tree pipelines (histograms, shipped forests).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def pytree_bytes(tree) -> int:
    """Exact dense wire size of a pytree (per-round ledger hot path —
    each leaf is inspected once, without materializing a copy)."""
    total = 0
    for x in jax.tree.leaves(tree):
        if not (hasattr(x, "size") and hasattr(x, "dtype")):
            x = np.asarray(x)
        total += x.size * np.dtype(x.dtype).itemsize
    return int(total)


@dataclass
class CommLog:
    events: List[Dict] = field(default_factory=list)
    #: per-aggregation records in the unified timeline schema
    #: (``FedRuntime._timeline_record``: round / t / n_clients /
    #: staleness / bytes); empty for ledgers not driven by a runtime
    timeline: List[Dict] = field(default_factory=list)
    #: cumulative DP ledger (``repro.core.privacy.RDPAccountant
    #: .summary()``: epsilon / delta / noise_multiplier / steps /
    #: per_client), refreshed by the runtime at every aggregation —
    #: ``None`` for runs whose transport carries no dpnoise layer
    privacy: Optional[Dict] = None

    def log(self, round_idx: int, client: str, direction: str,
            nbytes: int, what: str = "", t: Optional[float] = None,
            tier: Optional[str] = None):
        """``t`` is the virtual wall-clock stamp — recorded by the
        runtime when a latency model or the async schedule is active,
        omitted otherwise so untimed ledgers stay bit-identical to the
        pre-virtual-time format.  ``tier`` names the aggregation-tree
        edge a hierarchical topology moved these bytes over ('edge' =
        client↔silo LAN, 'wan' = silo↔server) — omitted by the flat-star
        engines, so their ledgers are likewise unchanged."""
        e = dict(round=round_idx, client=client, direction=direction,
                 bytes=int(nbytes), what=what)
        if t is not None:
            e["t"] = float(t)
        if tier is not None:
            e["tier"] = tier
        self.events.append(e)

    def total_bytes(self, direction: str = None) -> int:
        return sum(e["bytes"] for e in self.events
                   if direction is None or e["direction"] == direction)

    def total_mb(self, direction: str = None) -> float:
        return self.total_bytes(direction) / 1e6

    def uplink_mb(self) -> float:
        return self.total_mb("up")

    def per_round_mb(self) -> Dict[int, float]:
        out: Dict[int, float] = {}
        for e in self.events:
            out[e["round"]] = out.get(e["round"], 0.0) + e["bytes"] / 1e6
        return out

    def per_what_bytes(self) -> Dict[str, int]:
        """Ledger breakdown by payload kind (e.g. 'quantile-sketch',
        'grad-hess-histograms', 'trees') — the comm-vs-accuracy tables
        cite these, never constants."""
        out: Dict[str, int] = {}
        for e in self.events:
            out[e["what"]] = out.get(e["what"], 0) + e["bytes"]
        return out

    def per_tier_bytes(self, direction: str = None) -> Dict[str, int]:
        """Ledger breakdown by aggregation-tree tier ('edge' =
        client↔silo, 'wan' = silo↔server; flat-star events land under
        'star').  The hierarchical scaling claim — WAN uplink scales
        with silos, not clients — is read off this split."""
        out: Dict[str, int] = {}
        for e in self.events:
            if direction is not None and e["direction"] != direction:
                continue
            tier = e.get("tier", "star")
            out[tier] = out.get(tier, 0) + e["bytes"]
        return out


@dataclass
class Timer:
    """Aggregation wall-time accounting (paper reports 0.8s vs 4.2s)."""
    total_s: float = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.total_s += time.perf_counter() - self._t0


# --- layered wire transport ---------------------------------------------------

@dataclass
class WireCtx:
    """Per-message context a layer may consult.

    ``client`` is the global client id; ``slot``/``n_active`` locate the
    client inside *this round's* active set (pairwise secure-agg masks
    must cancel among the clients that actually ship), ``weight_scale``
    is the pre-folded combine weight for weighted strategies, and
    ``sensitivity`` calibrates server-side DP noise.

    ``cohort`` numbers the dispatch cohort the message belongs to —
    the async engine opens a fresh cohort per dispatch group so mask
    seeds are never reused across re-dispatches at the same server
    version; the sync engine always uses cohort 0.

    ``tracer``/``t`` are set by the runtime only when tracing is enabled
    (``repro.obs``): :meth:`Transport.encode` then records per-layer
    bytes in/out events.  Both default to ``None`` so untraced encoding
    does no observability work at all."""
    round: int = 0
    client: int = 0
    slot: int = 0
    n_active: int = 1
    seed: int = 0
    cohort: int = 0
    weight_scale: float = 1.0
    sensitivity: float = 1.0
    tracer: Any = None
    t: Optional[float] = None


@dataclass
class WireMsg:
    """A payload in flight: dense (decodable) representation + the exact
    bytes it occupies on the wire + per-client codec state (e.g. top-k
    error-feedback residuals) threaded round-to-round."""
    payload: Any
    nbytes: int
    state: Any = None


class TransportLayer:
    """One stage of the client→server pipeline.

    ``encode`` runs client-side before upload; ``post_aggregate`` runs
    server-side on the aggregated payload (e.g. DP noise on the mean).
    ``kind`` is 'float' for layers that transform float update pytrees
    and 'bytes' for layers that only touch the wire size — only 'bytes'
    layers apply to opaque payloads (shipped forests, histograms)."""
    name = "layer"
    kind = "float"

    def encode(self, msg: WireMsg, ctx: WireCtx) -> WireMsg:
        return msg

    def post_aggregate(self, payload, ctx: WireCtx):
        return payload


class CodecLayer(TransportLayer):
    """Wire-format codec/sparsifier from ``compression.WIRE_FORMATS``
    (topk / lowrank / int8 / int8_sr).  Sets ``nbytes`` to the format's
    true serialized size; at most one codec per stack (each reports the
    size of its *input* representation, so stacking them double-counts)."""

    def __init__(self, fmt: str, rho: float = 0.05, rank: int = 8):
        from repro.core.compression import WIRE_FORMATS
        if fmt not in WIRE_FORMATS:
            raise KeyError(f"unknown wire format {fmt!r}; "
                           f"available: {sorted(WIRE_FORMATS)}")
        self.name, self.fmt, self.rho, self.rank = fmt, fmt, rho, rank

    def encode(self, msg, ctx):
        from repro.core.compression import compress_update
        approx, state, nb = compress_update(
            self.fmt, msg.payload, msg.state, rho=self.rho, rank=self.rank,
            seed=ctx.seed * 100003 + ctx.round * 1000 + ctx.client)
        return WireMsg(approx, nb, state)


class ClipLayer(TransportLayer):
    """Client-side L2 clip (the DP sensitivity bound)."""
    name = "clip"

    def __init__(self, clip: float = 1.0):
        self.clip = clip

    def encode(self, msg, ctx):
        from repro.core import privacy
        clipped, _ = privacy.clip_update(msg.payload, self.clip)
        return replace(msg, payload=clipped)


class WeightLayer(TransportLayer):
    """Fold the client's combine weight into the payload *before* any
    masking, so the masked sum is already the weighted sum."""
    name = "weight"

    def encode(self, msg, ctx):
        w = ctx.weight_scale
        return replace(msg, payload=jax.tree.map(lambda t: t * w,
                                                 msg.payload))


class MaskLayer(TransportLayer):
    """Bonawitz-style pairwise secure-agg masks over this round's
    dispatch cohort; masks cancel in the server's sum
    (``privacy.mask_update``), and the cohort's pair seeds are Shamir
    t-of-n shared (``privacy.SeedShareBook``) so the runtime can
    reconstruct the terms of members that never reach an aggregation.

    ``threshold`` sets the Shamir t: ``0`` (default) resolves to a
    majority of the cohort (n//2 + 1), a fraction in (0, 1) to
    ``ceil(f * n)``, an int >= 1 is used as-is (clamped to the
    cohort)."""
    name = "mask"

    def __init__(self, threshold: float = 0.0):
        if threshold < 0:
            raise ValueError(f"mask: threshold must be >= 0, "
                             f"got {threshold!r}")
        self.threshold = threshold

    def resolve_threshold(self, n_active: int) -> int:
        t = self.threshold
        if t == 0:
            t = n_active // 2 + 1
        elif t < 1:
            t = math.ceil(t * n_active)
        return int(min(max(1, t), n_active))

    def encode(self, msg, ctx):
        from repro.core import privacy
        masked = privacy.mask_update(
            msg.payload, ctx.slot, ctx.n_active,
            privacy.mask_round_seed(ctx.seed, ctx.round, ctx.cohort))
        return replace(msg, payload=masked)


class DPNoiseLayer(TransportLayer):
    """Server-side Gaussian DP noise on the aggregated payload,
    calibrated by ``ctx.sensitivity`` (the engine supplies
    ``clip * max(weight)``).  ``epsilon``/``delta`` are the *per-round*
    target; the cumulative cost of repeated releases is tracked by the
    runtime's ``privacy.RDPAccountant`` at :attr:`noise_multiplier`."""
    name = "dpnoise"

    def __init__(self, epsilon: float = 0.5, delta: float = 1e-5):
        if not epsilon > 0:
            raise ValueError(f"dpnoise: epsilon must be > 0, "
                             f"got {epsilon!r}")
        if not 0 < delta < 1:
            raise ValueError(f"dpnoise: delta must be in (0, 1), "
                             f"got {delta!r}")
        self.epsilon, self.delta = float(epsilon), float(delta)

    @property
    def noise_multiplier(self) -> float:
        """sigma / sensitivity — the accountant's calibration knob."""
        from repro.core import privacy
        return privacy.gaussian_sigma(self.epsilon, self.delta, 1.0)

    def post_aggregate(self, payload, ctx):
        from repro.core import privacy
        return privacy.add_dp_noise(payload, self.epsilon, self.delta,
                                    ctx.sensitivity,
                                    ctx.seed * 31 + ctx.round)


class HELayer(TransportLayer):
    """Paillier-shaped additively-homomorphic transport *cost model*.

    No actual encryption happens (DESIGN.md §Changed-assumptions) — the
    layer models what an additively-homomorphic pipeline would do to the
    payload and the wire:

    * **payload**: fixed-point plaintext encoding — each scalar is
      quantized to ``frac_bits`` fractional bits with magnitudes clipped
      at ``2^int_bits`` (quantize → dequantize, so downstream layers and
      the aggregator still see floats; the quantization error, bounded
      by ``2^-(frac_bits+1)`` per scalar, is the fidelity price);
    * **bytes**: scalars pack into ciphertext slots of
      ``int_bits + frac_bits + 1`` sign ``+ ceil(log2(n_active))``
      sum-headroom bits (so homomorphic sums cannot overflow a slot),
      ``key_bits // slot_bits`` slots per ciphertext, and every Paillier
      ciphertext occupies ``2 * key_bits`` bits on the wire — the
      honest ciphertext-expansion accounting the bench reports.
    """
    name = "he"

    def __init__(self, key_bits: int = 2048, frac_bits: int = 16,
                 int_bits: int = 8):
        if key_bits < 256:
            raise ValueError(f"he: key_bits must be >= 256, "
                             f"got {key_bits!r}")
        if frac_bits < 1 or int_bits < 1:
            raise ValueError(f"he: frac_bits and int_bits must be >= 1, "
                             f"got frac_bits={frac_bits!r}, "
                             f"int_bits={int_bits!r}")
        if int_bits + frac_bits + 1 > key_bits:
            raise ValueError(f"he: one slot ({int_bits + frac_bits + 1} "
                             f"bits) cannot exceed key_bits={key_bits}")
        self.key_bits = int(key_bits)
        self.frac_bits = int(frac_bits)
        self.int_bits = int(int_bits)

    def wire_bytes(self, n_scalars: int, n_active: int) -> int:
        headroom = max(1, int(n_active)).bit_length()
        slot_bits = self.int_bits + self.frac_bits + 1 + headroom
        slots_per_ct = max(1, self.key_bits // slot_bits)
        n_ct = -(-int(n_scalars) // slots_per_ct)
        return n_ct * (2 * self.key_bits // 8)

    def encode(self, msg, ctx):
        scale = float(1 << self.frac_bits)
        qmax = float((1 << (self.int_bits + self.frac_bits)) - 1)

        def quantize(x):
            a = np.asarray(x, dtype=np.float64)
            v = np.clip(np.rint(a * scale), -qmax, qmax) / scale
            return jnp.asarray(v, dtype=jnp.asarray(x).dtype)

        payload = jax.tree.map(quantize, msg.payload)
        n = sum(int(np.prod(np.shape(x), dtype=np.int64))
                for x in jax.tree.leaves(msg.payload))
        return WireMsg(payload, self.wire_bytes(n, ctx.n_active),
                       msg.state)


class FrameLayer(TransportLayer):
    """Wire framing overhead: per-message header (length + sequence +
    auth tag).  A 'bytes' layer — applies to any payload kind."""
    name = "frame"
    kind = "bytes"

    def __init__(self, header: int = 28):
        self.header = header

    def encode(self, msg, ctx):
        return replace(msg, nbytes=msg.nbytes + self.header)


#: layer name -> factory(cfg dict) -> TransportLayer.  cfg keys are the
#: engine's transport knobs (rho/rank for codecs, dp_* for privacy,
#: frame_header for framing); unknown keys are ignored per layer.
LAYERS: Dict[str, Callable[[dict], TransportLayer]] = {
    "topk": lambda c: CodecLayer("topk", rho=c.get("rho", 0.05)),
    "lowrank": lambda c: CodecLayer("lowrank", rank=c.get("rank", 8)),
    "int8": lambda c: CodecLayer("int8"),
    "int8_sr": lambda c: CodecLayer("int8_sr"),
    "clip": lambda c: ClipLayer(c.get("dp_clip", 1.0)),
    "weight": lambda c: WeightLayer(),
    "mask": lambda c: MaskLayer(c.get("mask_threshold", 0.0)),
    "dpnoise": lambda c: DPNoiseLayer(c.get("dp_epsilon", 0.5),
                                      c.get("dp_delta", 1e-5)),
    "he": lambda c: HELayer(c.get("he_key_bits", 2048),
                            c.get("he_frac_bits", 16),
                            c.get("he_int_bits", 8)),
    "frame": lambda c: FrameLayer(c.get("frame_header", 28)),
}

#: named transport presets -> '>'-joined layer specs.  Any spec string
#: built from :data:`LAYERS` names is also accepted directly.
TRANSPORTS: Dict[str, str] = {
    "plain": "",
    "framed": "frame",
    "sparse": "topk",
    "quant": "int8_sr",
    "secure": "mask",
    "dp": "clip>dpnoise",
    "secure_dp": "clip>mask>dpnoise",
    "he": "clip>he",
    "he_dp": "clip>he>dpnoise",
    "full_stack": "topk>clip>mask>dpnoise>frame",
}


@dataclass
class Transport:
    """An ordered layer stack.  ``encode`` runs the client side and
    returns the final :class:`WireMsg` (its ``nbytes`` is what the
    ledger records); ``post_aggregate`` runs the server side on the
    aggregated payload."""
    name: str
    layers: List[TransportLayer]

    def encode(self, payload, *, nbytes: Optional[int] = None,
               state: Any = None, ctx: Optional[WireCtx] = None) -> WireMsg:
        msg = WireMsg(payload,
                      pytree_bytes(payload) if nbytes is None else nbytes,
                      state)
        ctx = ctx or WireCtx()
        tr = ctx.tracer
        for layer in self.layers:
            b_in = msg.nbytes if tr else 0
            msg = layer.encode(msg, ctx)
            if tr:  # per-layer wire accounting (repro.obs)
                tr.instant("comm.layer", track="comm", t=ctx.t,
                           layer=layer.name, round=ctx.round,
                           client=ctx.client, bytes_in=b_in,
                           bytes_out=msg.nbytes)
        return msg

    def post_aggregate(self, payload, ctx: Optional[WireCtx] = None):
        ctx = ctx or WireCtx()
        for layer in self.layers:
            payload = layer.post_aggregate(payload, ctx)
        return payload

    @property
    def frame_overhead(self) -> int:
        """Per-message byte overhead from 'bytes' layers (framing)."""
        return sum(l.header for l in self.layers
                   if isinstance(l, FrameLayer))

    def require_bytes_only(self, pipeline: str):
        """Tree-shipping pipelines move opaque forest payloads: only
        size-level layers apply; float-transform layers are an error."""
        bad = [l.name for l in self.layers if l.kind != "bytes"]
        if bad:
            raise ValueError(
                f"transport {self.name!r} has float-payload layers {bad} "
                f"which do not apply to the {pipeline} pipeline "
                f"(shipped trees are not float update pytrees); use "
                f"size-level layers only (e.g. 'frame')")

    def hist_params(self) -> Dict[str, Any]:
        """Map the stack onto fed_hist's in-jit histogram aggregation.

        Histogram aggregation runs fused inside ``grow_tree_fed``, so
        mask/dpnoise layers are executed there (same math: ring masks
        cancel in the sum, Gaussian noise on the aggregate) rather than
        through ``encode``.  Clip layers are no-ops (per-sample
        grad/hess contributions are already bounded — the configured DP
        sensitivity covers them); codec layers are unsupported."""
        codecs = [l.name for l in self.layers
                  if isinstance(l, (CodecLayer, HELayer))]
        if codecs:
            raise ValueError(
                f"transport {self.name!r}: codec/HE layers {codecs} are "
                f"not supported for histogram payloads (fed_hist "
                f"histograms aggregate inside the jitted tree growth); "
                f"use mask/dpnoise/frame layers")
        dp = next((l for l in self.layers if isinstance(l, DPNoiseLayer)),
                  None)
        return {"secure": any(isinstance(l, MaskLayer)
                              for l in self.layers),
                "dp_epsilon": dp.epsilon if dp else 0.0,
                "dp_delta": dp.delta if dp else 1e-5,
                "frame_overhead": self.frame_overhead}


def get_transport(spec, **cfg) -> Transport:
    """Resolve a transport: a :class:`Transport` (returned as-is), a
    preset name from :data:`TRANSPORTS`, or a ``>``-joined spec string of
    :data:`LAYERS` names (``"topk>mask>frame"``).  ``cfg`` carries layer
    knobs (rho, rank, dp_clip, dp_epsilon, dp_delta, frame_header)."""
    if isinstance(spec, Transport):
        return spec
    name = spec if spec else "plain"
    resolved = TRANSPORTS.get(name, name if spec else "")
    tokens = [t.strip() for t in resolved.split(">") if t.strip()]
    unknown = [t for t in tokens if t not in LAYERS]
    if unknown:
        raise KeyError(f"unknown transport {spec!r} (layers {unknown}); "
                       f"presets: {sorted(TRANSPORTS)}, "
                       f"layers: {sorted(LAYERS)}")
    layers = [LAYERS[t](cfg) for t in tokens]
    n_codecs = sum(isinstance(l, CodecLayer) for l in layers)
    if n_codecs > 1:
        raise ValueError(f"transport {spec!r} stacks {n_codecs} codec "
                         f"layers; each codec reports the wire size of "
                         f"its input representation, so at most one is "
                         f"allowed per stack")
    return Transport(name, layers)
