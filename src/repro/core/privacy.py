"""Security layer (paper C6).

* Secure aggregation: pairwise additive masks (Bonawitz-style, simulated)
  — client i adds PRG(seed_ij)*sign(i-j) for every peer j; masks cancel in
  the server's sum, so the server only ever sees the aggregate.  Stand-in
  for the paper's homomorphic encryption (DESIGN.md §Changed-assumptions;
  the ``he`` transport layer models the HE *cost* separately).
* Dropout tolerance: every pair seed is Shamir t-of-n secret-shared over
  the dispatch cohort (:class:`SeedShareBook`), so the server can
  reconstruct — and subtract — the mask terms of clients whose uploads
  never reach an aggregation (drops, stragglers, async cohort mixing).
  The share round is *simulated honestly*: shares are derived
  deterministically rather than exchanged over authenticated channels,
  and every cohort member is assumed to answer the reconstruction
  request (so recovery needs ``threshold`` <= cohort size, which
  :meth:`SeedShareBook.recover_seed` enforces).
* Differential privacy: Gaussian noise on the aggregated update with
  sigma = sqrt(2 ln(1.25/delta)) * sensitivity / epsilon  (eps=0.5,
  delta=1e-5 per the paper), plus an :class:`RDPAccountant` that tracks
  the cumulative Rényi-DP cost of repeated releases with subsampling
  amplification from the per-round participation fraction.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: Shamir field modulus (Mersenne prime 2^127-1): large enough that the
#: 128-bit pair seeds reduced into it keep full PRG entropy, cheap to
#: invert with ``pow(x, P-2, P)``.
SHAMIR_PRIME = (1 << 127) - 1


class MaskRecoveryError(RuntimeError):
    """Mask recovery is impossible: fewer live cohort members than the
    Shamir threshold — the aggregate for this cohort cannot be opened."""


def mask_round_seed(seed: int, round_idx: int, cohort: int = 0) -> int:
    """Per-cohort root seed for a round's pairwise masks.  ``cohort``
    disambiguates multiple dispatch cohorts at the same server version
    (the async engine re-dispatches clients while a version is open);
    ``cohort=0`` reproduces the pre-cohort seeds exactly."""
    return seed * 7919 + round_idx + (cohort << 41)


def pair_seed(round_seed: int, lo: int, hi: int) -> int:
    """Collision-free seed for the (lo, hi) pair mask.

    The legacy formula ``round_seed*1000003 + lo*1009 + hi`` is
    non-injective once ``hi`` can exceed 1009 — e.g. (0, 2018) and
    (1, 1009) collide — which silently *reuses one mask across distinct
    pairs* at cohort scale (a one-time pad reused; the sum still cancels
    pair-by-pair, but the server can difference colliding uploads).
    ``np.random.SeedSequence`` hashes the tuple injectively instead.
    The result is reduced mod :data:`SHAMIR_PRIME` so the seed is
    directly secret-sharable."""
    ss = np.random.SeedSequence(
        (int(round_seed) % (1 << 64), int(lo), int(hi)))
    a, b = ss.generate_state(2, np.uint64)
    return (int(a) | (int(b) << 64)) % SHAMIR_PRIME


def _pair_mask(seed: int, tree):
    rng = np.random.default_rng(seed)
    return jax.tree.map(
        lambda x: jnp.asarray(rng.normal(0, 1.0, np.shape(x)),
                              dtype=jnp.asarray(x).dtype), tree)


def mask_update(update, client_idx: int, n_clients: int, round_seed: int):
    """Add pairwise-cancelling masks to one client's update.

    Single pass over the flattened leaves: one accumulator list, one
    mask leaf materialized at a time — O(n_clients) leaf allocations
    instead of the old per-peer full-pytree copies (O(n_clients^2)
    allocations per round across the cohort).  Per-leaf accumulation
    order matches the old per-peer loop, so results are bit-identical
    (tests/test_privacy.py gates parity against a reference loop)."""
    leaves, treedef = jax.tree.flatten(update)
    shapes = [np.shape(x) for x in leaves]
    dtypes = [jnp.asarray(x).dtype for x in leaves]
    acc = list(leaves)
    for j in range(n_clients):
        if j == client_idx:
            continue
        lo, hi = min(client_idx, j), max(client_idx, j)
        rng = np.random.default_rng(pair_seed(round_seed, lo, hi))
        sgn = 1.0 if client_idx < j else -1.0
        for k in range(len(acc)):
            m = jnp.asarray(rng.normal(0, 1.0, shapes[k]),
                            dtype=dtypes[k])
            acc[k] = acc[k] + sgn * m
    return jax.tree.unflatten(treedef, acc)


def secure_sum(updates: Sequence):
    """Server: sum of masked updates == sum of true updates."""
    total = updates[0]
    for u in updates[1:]:
        total = jax.tree.map(lambda a, b: a + b, total, u)
    return total


# --- Shamir t-of-n seed sharing (dropout recovery) ----------------------------

def shamir_share(secret: int, n_shares: int, threshold: int,
                 rng: np.random.Generator) -> List[Tuple[int, int]]:
    """Split ``secret`` (mod :data:`SHAMIR_PRIME`) into ``n_shares``
    points of a random degree-(threshold-1) polynomial; any
    ``threshold`` of them reconstruct, fewer reveal nothing."""
    if not 1 <= threshold <= n_shares:
        raise ValueError(f"shamir: need 1 <= threshold <= n_shares, got "
                         f"t={threshold}, n={n_shares}")
    P = SHAMIR_PRIME
    coeffs = [int(secret) % P]
    coeffs += [int.from_bytes(rng.bytes(16), "little") % P
               for _ in range(threshold - 1)]
    shares = []
    for x in range(1, n_shares + 1):
        y = 0
        for c in reversed(coeffs):       # Horner, mod P
            y = (y * x + c) % P
        shares.append((x, y))
    return shares


def shamir_reconstruct(shares: Sequence[Tuple[int, int]]) -> int:
    """Lagrange-interpolate the polynomial at 0 from >= threshold
    shares.  (With fewer than threshold shares this returns a value, but
    not the secret — callers enforce the threshold.)"""
    P = SHAMIR_PRIME
    xs = [x for x, _ in shares]
    if len(set(xs)) != len(xs):
        raise ValueError("shamir: duplicate share points")
    secret = 0
    for xi, yi in shares:
        num = den = 1
        for xj in xs:
            if xj == xi:
                continue
            num = num * (-xj) % P
            den = den * (xi - xj) % P
        secret = (secret + yi * num * pow(den, P - 2, P)) % P
    return secret


class SeedShareBook:
    """Shamir share book for one dispatch cohort's pair seeds.

    Honest simulation of the Bonawitz share-distribution round: at
    dispatch, each of the cohort's ``n`` members notionally splits every
    pair seed it owns into ``n`` shares at threshold ``t`` and deals one
    to each peer.  Here the shares are derived deterministically from
    the cohort's ``round_seed`` (no authenticated channels), and every
    live member is assumed to answer a reconstruction request — so
    recovery of a pair's seed needs only that at least ``t`` cohort
    members exist, which :meth:`recover_seed` enforces (raising
    :class:`MaskRecoveryError` otherwise).

    Shares are generated lazily per pair (only recovered pairs ever pay
    for them) and :attr:`shares_pulled` counts every share consumed, so
    the runtime can charge the reconstruction traffic to the comm
    ledger at :data:`SHARE_NBYTES` per share."""

    #: wire size of one share: 16-byte field element + 4-byte point index
    SHARE_NBYTES = 20

    def __init__(self, round_seed: int, n_active: int, threshold: int):
        if not 1 <= threshold <= n_active:
            raise ValueError(f"seed share book: need 1 <= threshold <= "
                             f"n_active, got t={threshold}, n={n_active}")
        self.round_seed = int(round_seed)
        self.n = int(n_active)
        self.t = int(threshold)
        self._shares: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        self.shares_pulled = 0

    def _pair_shares(self, lo: int, hi: int) -> List[Tuple[int, int]]:
        key = (lo, hi)
        if key not in self._shares:
            # share polynomial rng: distinct SeedSequence stream from
            # the pair seed itself (extra tuple element)
            rng = np.random.default_rng(np.random.SeedSequence(
                (self.round_seed % (1 << 64), int(lo), int(hi), 0x5EED)))
            self._shares[key] = shamir_share(
                pair_seed(self.round_seed, lo, hi), self.n, self.t, rng)
        return self._shares[key]

    def recover_seed(self, lo: int, hi: int,
                     respondents: Optional[Iterable[int]] = None) -> int:
        """Reconstruct the (lo, hi) pair seed from the shares held by
        ``respondents`` (cohort slots; default: the whole cohort —
        the honest-simulation assumption that everyone answers)."""
        resp = (sorted(set(respondents)) if respondents is not None
                else list(range(self.n)))
        if len(resp) < self.t:
            raise MaskRecoveryError(
                f"cannot recover pair ({lo}, {hi}) seed: "
                f"{len(resp)} respondents < threshold {self.t}")
        shares = self._pair_shares(lo, hi)
        use = [shares[s] for s in resp[:self.t]]
        self.shares_pulled += self.t
        return shamir_reconstruct(use)


def strip_missing_masks(payload, book: SeedShareBook, slot: int,
                        present: Set[int]):
    """Subtract from one delivered masked payload every pair-mask term
    whose peer slot is absent from this aggregation batch.

    Pair terms between two slots in the *same* batch cancel in the sum
    and are left in place (they still blind the individual payloads);
    every other term is reconstructed through the cohort's share book
    and removed — so a batch's masked sum equals its plain sum under any
    drop/straggle/async-mixing pattern.  Returns ``(payload,
    n_recovered_seeds)``."""
    missing = [d for d in range(book.n) if d != slot and d not in present]
    if not missing:
        return payload, 0
    leaves, treedef = jax.tree.flatten(payload)
    shapes = [np.shape(x) for x in leaves]
    dtypes = [jnp.asarray(x).dtype for x in leaves]
    for d in missing:
        lo, hi = min(slot, d), max(slot, d)
        rng = np.random.default_rng(book.recover_seed(lo, hi))
        sgn = 1.0 if slot < d else -1.0
        for k in range(len(leaves)):
            m = jnp.asarray(rng.normal(0, 1.0, shapes[k]),
                            dtype=dtypes[k])
            leaves[k] = leaves[k] - sgn * m
    return jax.tree.unflatten(treedef, leaves), len(missing)


# --- differential privacy -----------------------------------------------------

def gaussian_sigma(epsilon: float, delta: float,
                   sensitivity: float = 1.0) -> float:
    if not epsilon > 0:
        raise ValueError(f"gaussian_sigma: epsilon must be > 0, "
                         f"got {epsilon!r}")
    if not 0 < delta < 1:
        raise ValueError(f"gaussian_sigma: delta must be in (0, 1), "
                         f"got {delta!r}")
    return float(np.sqrt(2 * np.log(1.25 / delta)) * sensitivity / epsilon)


def clip_update(update, max_norm: float):
    leaves = jax.tree.leaves(update)
    nrm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                       for x in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(nrm, 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), update), nrm


def add_dp_noise(tree, epsilon: float, delta: float, sensitivity: float,
                 seed: int):
    sigma = gaussian_sigma(epsilon, delta, sensitivity)
    rng = np.random.default_rng(seed)
    return jax.tree.map(
        lambda x: x + jnp.asarray(
            rng.normal(0, sigma, np.shape(x)),
            dtype=jnp.asarray(x).dtype), tree)


# --- Rényi-DP accounting ------------------------------------------------------

#: integer Rényi orders the accountant optimizes the (eps, delta)
#: conversion over — dense where the optimum usually lands, sparse tail
#: for very small noise multipliers
DEFAULT_RDP_ORDERS: Tuple[int, ...] = tuple(range(2, 33)) + (48, 64,
                                                             128, 256)


def _log_binom(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1)
            - math.lgamma(n - k + 1))


def subsampled_gaussian_rdp(q: float, noise_multiplier: float,
                            order: int) -> float:
    """RDP epsilon of one subsampled-Gaussian release at integer order.

    Exact integer-order expression for Poisson subsampling at rate
    ``q`` with noise multiplier ``z = sigma / sensitivity``::

        eps(a) = log( sum_{k=0..a} C(a,k) (1-q)^(a-k) q^k
                      * exp((k^2 - k) / (2 z^2)) ) / (a - 1)

    At ``q = 1`` this reduces to the plain Gaussian's ``a / (2 z^2)``
    (the closed form tests/test_privacy.py spot-checks)."""
    if order < 2 or int(order) != order:
        raise ValueError(f"integer order >= 2 required, got {order!r}")
    if not noise_multiplier > 0:
        raise ValueError(f"noise_multiplier must be > 0, "
                         f"got {noise_multiplier!r}")
    if q <= 0:
        return 0.0
    z2 = 2.0 * noise_multiplier * noise_multiplier
    if q >= 1.0:
        return order / z2
    terms = [_log_binom(order, k) + (order - k) * math.log1p(-q)
             + k * math.log(q) + (k * k - k) / z2
             for k in range(order + 1)]
    m = max(terms)
    log_sum = m + math.log(sum(math.exp(t - m) for t in terms))
    return max(0.0, log_sum / (order - 1))


class RDPAccountant:
    """Cumulative Rényi-DP ledger over repeated noisy aggregations.

    Each server release is one subsampled-Gaussian mechanism at the
    round's participation fraction ``q``; :meth:`step` adds its RDP
    vector (cached per distinct ``q``) to the accumulator of every
    client that *actually participated* — individual-accounting
    semantics: a client's loss accrues only in rounds it is sampled
    into, with amplification from the sampling rate, so heterogeneous
    participation yields heterogeneous per-client epsilon.  The headline
    :meth:`epsilon` is the max over clients (equals the uniform bound
    under full participation).  Conversion to (eps, delta) optimizes
    ``rdp(a) + log(1/delta)/(a-1)`` over :data:`DEFAULT_RDP_ORDERS`."""

    def __init__(self, noise_multiplier: float, delta: float = 1e-5,
                 orders: Sequence[int] = DEFAULT_RDP_ORDERS):
        if not noise_multiplier > 0:
            raise ValueError(f"rdp accountant: noise_multiplier must be "
                             f"> 0, got {noise_multiplier!r}")
        if not 0 < delta < 1:
            raise ValueError(f"rdp accountant: delta must be in (0, 1), "
                             f"got {delta!r}")
        self.noise_multiplier = float(noise_multiplier)
        self.delta = float(delta)
        self.orders = tuple(int(a) for a in orders)
        self._rdp_cache: Dict[float, np.ndarray] = {}
        self._per_client: Dict[int, np.ndarray] = {}
        self.steps = 0

    def _rdp_vec(self, q: float) -> np.ndarray:
        key = round(float(q), 12)
        if key not in self._rdp_cache:
            self._rdp_cache[key] = np.array(
                [subsampled_gaussian_rdp(key, self.noise_multiplier, a)
                 for a in self.orders])
        return self._rdp_cache[key]

    def step(self, clients: Iterable[int], q: float):
        """Record one release over ``clients`` at sampling rate ``q``."""
        if not 0 < q <= 1:
            raise ValueError(f"participation fraction q must be in "
                             f"(0, 1], got {q!r}")
        vec = self._rdp_vec(q)
        for c in clients:
            acc = self._per_client.get(c)
            self._per_client[c] = vec.copy() if acc is None else acc + vec
        self.steps += 1

    def _eps(self, vec: np.ndarray, delta: float) -> float:
        return float(min(v + math.log(1.0 / delta) / (a - 1)
                         for a, v in zip(self.orders, vec)))

    def epsilon(self, client: Optional[int] = None,
                delta: Optional[float] = None) -> float:
        """Cumulative (eps, delta)-DP epsilon — for one client, or the
        max over all tracked clients (0.0 before any step)."""
        delta = self.delta if delta is None else delta
        if client is not None:
            vec = self._per_client.get(client)
            return 0.0 if vec is None else self._eps(vec, delta)
        if not self._per_client:
            return 0.0
        return max(self._eps(v, delta)
                   for v in self._per_client.values())

    def summary(self) -> Dict:
        """Ledger-attachable snapshot (``CommLog.privacy``)."""
        return {"epsilon": self.epsilon(),
                "delta": self.delta,
                "noise_multiplier": self.noise_multiplier,
                "steps": self.steps,
                "per_client": {c: self._eps(v, self.delta)
                               for c, v in
                               sorted(self._per_client.items())}}
