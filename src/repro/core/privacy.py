"""Security layer (paper C6).

* Secure aggregation: pairwise additive masks (Bonawitz-style, simulated)
  — client i adds PRG(seed_ij)*sign(i-j) for every peer j; masks cancel in
  the server's sum, so the server only ever sees the aggregate.  Stand-in
  for the paper's homomorphic encryption (DESIGN.md §Changed-assumptions).
* Differential privacy: Gaussian noise on the aggregated update with
  sigma = sqrt(2 ln(1.25/delta)) * sensitivity / epsilon  (eps=0.5,
  delta=1e-5 per the paper).
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _pair_mask(seed: int, tree):
    rng = np.random.default_rng(seed)
    return jax.tree.map(
        lambda x: jnp.asarray(rng.normal(0, 1.0, np.shape(x)),
                              dtype=jnp.asarray(x).dtype), tree)


def mask_update(update, client_idx: int, n_clients: int, round_seed: int):
    """Add pairwise-cancelling masks to one client's update."""
    masked = update
    for j in range(n_clients):
        if j == client_idx:
            continue
        lo, hi = min(client_idx, j), max(client_idx, j)
        m = _pair_mask(round_seed * 1000003 + lo * 1009 + hi, update)
        sgn = 1.0 if client_idx < j else -1.0
        masked = jax.tree.map(lambda a, b: a + sgn * b, masked, m)
    return masked


def secure_sum(updates: Sequence):
    """Server: sum of masked updates == sum of true updates."""
    total = updates[0]
    for u in updates[1:]:
        total = jax.tree.map(lambda a, b: a + b, total, u)
    return total


def gaussian_sigma(epsilon: float, delta: float,
                   sensitivity: float = 1.0) -> float:
    return float(np.sqrt(2 * np.log(1.25 / delta)) * sensitivity / epsilon)


def clip_update(update, max_norm: float):
    leaves = jax.tree.leaves(update)
    nrm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                       for x in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(nrm, 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), update), nrm


def add_dp_noise(tree, epsilon: float, delta: float, sensitivity: float,
                 seed: int):
    sigma = gaussian_sigma(epsilon, delta, sensitivity)
    rng = np.random.default_rng(seed)
    return jax.tree.map(
        lambda x: x + jnp.asarray(
            rng.normal(0, sigma, np.shape(x)),
            dtype=jnp.asarray(x).dtype), tree)
