"""Update compression — the beyond-paper generalization of Theorem 1.

The paper ships a sqrt(k)-subset of each client's trees.  For parametric
models the analogous structured subset of a model *delta* is:

* ``topk``    — magnitude top-k (density rho) with error-feedback residual
  accumulation (keeps the bias bounded the way |ΔF1|<=0.03 bounds C2);
* ``lowrank`` — rank-r sketch of every 2-D delta (the analog of C3's
  "train a small model on the top-p important directions");
* ``int8``    — per-tensor affine quantization.

``compressed_bytes`` gives exact wire size for the comm ledger.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class TopKState:
    residual: object  # pytree matching params


def topk_compress(delta, rho: float, state: Optional[TopKState] = None):
    """Keep the top rho-fraction by |value| per tensor; error feedback.

    Returns (sparse_delta_dense_representation, new_state, wire_bytes)."""
    if state is not None:
        delta = jax.tree.map(lambda d, r: d + r, delta, state.residual)

    def one(x):
        n = x.size
        k = max(int(np.ceil(rho * n)), 1)
        flat = jnp.abs(x.reshape(-1))
        thr = jax.lax.top_k(flat, k)[0][-1]
        mask = (jnp.abs(x) >= thr).astype(x.dtype)
        kept = x * mask
        return kept, x - kept, k

    kept_tree, resid_tree, bytes_total = {}, {}, 0
    leaves, treedef = jax.tree.flatten(delta)
    kepts, resids = [], []
    for x in leaves:
        kept, resid, k = one(x)
        kepts.append(kept)
        resids.append(resid)
        bytes_total += k * (x.dtype.itemsize + 4)  # value + int32 index
    return (jax.tree.unflatten(treedef, kepts),
            TopKState(jax.tree.unflatten(treedef, resids)),
            int(bytes_total))


def lowrank_compress(delta, rank: int):
    """Rank-r SVD sketch for 2-D leaves (others shipped dense).

    Returns (approx_delta, wire_bytes)."""
    def one(x):
        if x.ndim != 2 or min(x.shape) <= rank:
            return x, x.size * x.dtype.itemsize
        u, s, vt = jnp.linalg.svd(x.astype(jnp.float32),
                                  full_matrices=False)
        u, s, vt = u[:, :rank], s[:rank], vt[:rank]
        approx = (u * s) @ vt
        nbytes = (u.size + s.size + vt.size) * 4
        return approx.astype(x.dtype), nbytes

    leaves, treedef = jax.tree.flatten(delta)
    outs, nb = [], 0
    for x in leaves:
        a, b = one(x)
        outs.append(a)
        nb += b
    return jax.tree.unflatten(treedef, outs), int(nb)


def int8_compress(delta):
    """Per-tensor affine int8 quant/dequant. Returns (approx, bytes)."""
    def one(x):
        amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
        scale = amax / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return (q.astype(x.dtype) * scale).astype(x.dtype), x.size + 4

    leaves, treedef = jax.tree.flatten(delta)
    outs, nb = [], 0
    for x in leaves:
        a, b = one(x)
        outs.append(a)
        nb += b
    return jax.tree.unflatten(treedef, outs), int(nb)


def dense_bytes(tree) -> int:
    return int(sum(np.asarray(x).size * np.asarray(x).dtype.itemsize
                   for x in jax.tree.leaves(tree)))
