"""Update compression — the beyond-paper generalization of Theorem 1.

The paper ships a sqrt(k)-subset of each client's trees.  For parametric
models the analogous structured subset of a model *delta* is:

* ``topk``    — magnitude top-k (density rho) with error-feedback residual
  accumulation (keeps the bias bounded the way |ΔF1|<=0.03 bounds C2);
* ``lowrank`` — rank-r sketch of every 2-D delta (the analog of C3's
  "train a small model on the top-p important directions");
* ``int8``    — per-tensor affine quantization (round-to-nearest);
* ``int8_sr`` — per-tensor int8 with *stochastic rounding*: unbiased
  (E[dequant] == input), so quantization error averages out across
  clients/rounds instead of accumulating.

Every format reports its exact wire size so the ``CommLog`` ledger (and
the 3.2x-style claims) stay measured, never asserted.  Engines select a
format by name through :data:`WIRE_FORMATS` / :func:`compress_update`,
which normalizes all formats to one stateful interface.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class TopKState:
    residual: object  # pytree matching params


def topk_compress(delta, rho: float, state: Optional[TopKState] = None):
    """Keep the top rho-fraction by |value| per tensor; error feedback.

    Returns (sparse_delta_dense_representation, new_state, wire_bytes)."""
    if state is not None:
        delta = jax.tree.map(lambda d, r: d + r, delta, state.residual)

    def one(x):
        n = x.size
        k = max(int(np.ceil(rho * n)), 1)
        flat = jnp.abs(x.reshape(-1))
        thr = jax.lax.top_k(flat, k)[0][-1]
        mask = (jnp.abs(x) >= thr).astype(x.dtype)
        kept = x * mask
        return kept, x - kept, k

    kept_tree, resid_tree, bytes_total = {}, {}, 0
    leaves, treedef = jax.tree.flatten(delta)
    kepts, resids = [], []
    for x in leaves:
        kept, resid, k = one(x)
        kepts.append(kept)
        resids.append(resid)
        bytes_total += k * (x.dtype.itemsize + 4)  # value + int32 index
    return (jax.tree.unflatten(treedef, kepts),
            TopKState(jax.tree.unflatten(treedef, resids)),
            int(bytes_total))


def lowrank_compress(delta, rank: int):
    """Rank-r SVD sketch for 2-D leaves (others shipped dense).

    Returns (approx_delta, wire_bytes)."""
    def one(x):
        if x.ndim != 2 or min(x.shape) <= rank:
            return x, x.size * x.dtype.itemsize
        u, s, vt = jnp.linalg.svd(x.astype(jnp.float32),
                                  full_matrices=False)
        u, s, vt = u[:, :rank], s[:rank], vt[:rank]
        approx = (u * s) @ vt
        nbytes = (u.size + s.size + vt.size) * 4
        return approx.astype(x.dtype), nbytes

    leaves, treedef = jax.tree.flatten(delta)
    outs, nb = [], 0
    for x in leaves:
        a, b = one(x)
        outs.append(a)
        nb += b
    return jax.tree.unflatten(treedef, outs), int(nb)


def int8_compress(delta):
    """Per-tensor affine int8 quant/dequant (round-to-nearest).

    delta: pytree of float arrays.  Returns (approx, wire_bytes) where
    wire_bytes = 1 byte/element + 4 bytes/tensor for the fp32 scale."""
    def one(x):
        amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
        scale = amax / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return (q.astype(x.dtype) * scale).astype(x.dtype), x.size + 4

    leaves, treedef = jax.tree.flatten(delta)
    outs, nb = [], 0
    for x in leaves:
        a, b = one(x)
        outs.append(a)
        nb += b
    return jax.tree.unflatten(treedef, outs), int(nb)


def int8_sr_quantize(x, key):
    """The int8_sr codec's quantization half: one tensor -> (q, scale).

    ``x/scale`` is rounded to ``floor(x/scale) + Bernoulli(frac)`` so the
    dequantized value ``q.astype(f) * scale`` is unbiased
    (``E[dequant] == x``) with per-element error < 1 quantization step
    (``scale = amax/127``).  Exposed separately from
    :func:`int8_sr_compress` so consumers that want to *keep* the int8
    representation resident (the serving engine's memory-bound scoring
    path, ``repro.serve.engine``) share the exact codec arithmetic with
    the wire format.  Returns (q int8 array, scale f32 scalar)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    scaled = x / scale
    lo = jnp.floor(scaled)
    frac = scaled - lo
    up = jax.random.uniform(key, x.shape) < frac
    q = jnp.clip(lo + up.astype(x.dtype), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_sr_compress(delta, seed: int = 0):
    """Per-tensor int8 quantization with *stochastic rounding*.

    Quantization itself lives in :func:`int8_sr_quantize` (unbiased:
    ``E[dequant] == input``, so quantization error averages out across
    clients/rounds instead of accumulating).

    delta: pytree of float arrays; seed: int controlling the rounding
    draws (engines should vary it per round/client).  Returns
    (approx, wire_bytes); wire bytes match :func:`int8_compress`
    (1 byte/element + 4 bytes/tensor scale)."""
    key = jax.random.PRNGKey(seed)

    def one(x, k):
        q, scale = int8_sr_quantize(x, k)
        return (q.astype(x.dtype) * scale).astype(x.dtype), x.size + 4

    leaves, treedef = jax.tree.flatten(delta)
    outs, nb = [], 0
    for i, x in enumerate(leaves):
        a, b = one(x, jax.random.fold_in(key, i))
        outs.append(a)
        nb += b
    return jax.tree.unflatten(treedef, outs), int(nb)


def dense_bytes(tree) -> int:
    """Exact uncompressed wire size of a pytree, in bytes."""
    from repro.core.comm import pytree_bytes
    return pytree_bytes(tree)


# --- wire-format registry -----------------------------------------------------

def _wf_none(delta, state, *, rho, rank, seed):
    return delta, state, dense_bytes(delta)


def _wf_topk(delta, state, *, rho, rank, seed):
    return topk_compress(delta, rho, state)


def _wf_lowrank(delta, state, *, rho, rank, seed):
    approx, nb = lowrank_compress(delta, rank)
    return approx, state, nb


def _wf_int8(delta, state, *, rho, rank, seed):
    approx, nb = int8_compress(delta)
    return approx, state, nb


def _wf_int8_sr(delta, state, *, rho, rank, seed):
    approx, nb = int8_sr_compress(delta, seed)
    return approx, state, nb


#: name -> fn(delta, state, *, rho, rank, seed) -> (approx, state', bytes).
#: ``state`` is per-client (error-feedback residuals for topk; None
#: elsewhere) and must be threaded round-to-round by the engine.
WIRE_FORMATS: Dict[str, Callable] = {
    "none": _wf_none,
    "topk": _wf_topk,
    "lowrank": _wf_lowrank,
    "int8": _wf_int8,
    "int8_sr": _wf_int8_sr,
}


def compress_update(name: str, delta, state=None, *, rho: float = 0.05,
                    rank: int = 8, seed: int = 0
                    ) -> Tuple[Any, Any, int]:
    """Apply wire format ``name`` to one client's update pytree.

    Returns (approx_delta, new_state, wire_bytes).  ``wire_bytes`` is
    what the ``CommLog`` ledger should record for the uplink; the
    returned delta is the dense dequantized/densified representation the
    server aggregates.  Raises KeyError listing valid formats."""
    if name not in WIRE_FORMATS:
        raise KeyError(f"unknown wire format {name!r}; "
                       f"available: {sorted(WIRE_FORMATS)}")
    return WIRE_FORMATS[name](delta, state, rho=rho, rank=rank, seed=seed)
