"""Parametric FL pipeline (paper C1): LR / poly-SVM / NN with FedAvg,
FedProx for the NN, optional secure aggregation + DP, full comm ledger.
Also provides the pooled-data centralized baselines for Table 5.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import privacy
from repro.core.comm import CommLog, Timer, pytree_bytes
from repro.core.metrics import binary_metrics
from repro.core.strategies import get_strategy
from repro.data import sampling as S
from repro.models import tabular
from repro.optim import adam, fedprox_grad


@dataclass
class FedParametricConfig:
    model: str = "logreg"            # logreg | svm | mlp
    rounds: int = 30
    local_steps: int = 40
    lr: float = 0.05
    sampling: str = "none"           # none | ros | rus | smote | fed_smote
    strategy: str = "fedavg"         # repro.core.strategies.STRATEGIES name
    fedprox_mu: float = 0.0          # >0 -> FedProx (paper: NN); overrides
    # the strategy's client_mu when set
    secure_agg: bool = False
    dp_epsilon: float = 0.0          # >0 -> DP noise on the aggregate
    dp_delta: float = 1e-5
    dp_clip: float = 1.0
    seed: int = 0


def _prep(model_name: str, x):
    if tabular.MODELS[model_name]["needs_poly"]:
        pairs, triples = tabular.poly3_indices(x.shape[1])
        return np.asarray(tabular.poly3_features(jnp.asarray(x), pairs,
                                                 triples))
    return x


def _local_train(model_name, params, x, y, steps, lr, global_params=None,
                 mu=0.0):
    spec = tabular.MODELS[model_name]
    loss_fn = spec["loss"]
    opt = adam()
    state = opt.init(params)
    xd, yd = jnp.asarray(x), jnp.asarray(y)

    @jax.jit
    def step(params, state):
        grads = jax.grad(loss_fn)(params, xd, yd)
        if mu > 0 and global_params is not None:
            grads = fedprox_grad(grads, params, global_params, mu)
        return opt.update(grads, state, params, lr)

    for _ in range(steps):
        params, state = step(params, state)
    return params


def _fed_sampling(clients, strategy, seed, comm: CommLog, round_idx=0):
    """Apply a sampling strategy locally; fed_smote also syncs stats."""
    if strategy != "fed_smote":
        return [S.apply_strategy(strategy, x, y, seed + i)
                for i, (x, y) in enumerate(clients)], None
    stats = [S.minority_stats(x, y) for (x, y) in clients]
    for i in range(len(clients)):
        comm.log(round_idx, f"c{i}", "up",
                 S.stats_bytes(clients[i][0].shape[1]), "smote-stats")
        comm.log(round_idx, f"c{i}", "down",
                 S.stats_bytes(clients[i][0].shape[1]), "smote-stats")
    agg = S.aggregate_stats(stats)
    return [S.fed_smote(x, y, agg[0], agg[1], seed + i)
            for i, (x, y) in enumerate(clients)], agg


def train_federated(clients: Sequence[Tuple[np.ndarray, np.ndarray]],
                    cfg: FedParametricConfig,
                    test: Optional[Tuple[np.ndarray, np.ndarray]] = None):
    """Federated training of one tabular model.

    Aggregation follows ``cfg.strategy`` (see
    ``repro.core.strategies.STRATEGIES``).  Weighted strategies fold the
    normalized client weight into each update *before* secure-agg
    masking, so the masked sum still cancels; server-side optimizers
    (FedAvgM/FedAdam) act on the averaged — and, under DP, noised —
    update.  DP noise sensitivity is ``dp_clip * max(weight)``, which
    reduces to the classic ``dp_clip / n_clients`` for uniform weights.

    Returns (global_params, comm: CommLog, history, agg_timer)."""
    comm = CommLog()
    timer = Timer()
    spec = tabular.MODELS[cfg.model]
    strat = get_strategy(cfg.strategy)
    mu = cfg.fedprox_mu if cfg.fedprox_mu > 0 else strat.client_mu
    clients = [(_prep(cfg.model, x), y) for x, y in clients]
    if test is not None:
        test = (_prep(cfg.model, test[0]), test[1])
    clients, _ = _fed_sampling(clients, cfg.sampling, cfg.seed, comm)
    ws = strat.norm_weights([len(y) for _, y in clients])
    n_feat = clients[0][0].shape[1]
    rng = jax.random.PRNGKey(cfg.seed)
    global_params = spec["init"](rng, n_feat)
    server_state = strat.init_state(global_params)
    history = []
    for r in range(cfg.rounds):
        updates = []
        for i, (x, y) in enumerate(clients):
            comm.log(r, f"c{i}", "down", pytree_bytes(global_params),
                     "model")
            local = _local_train(cfg.model, global_params, x, y,
                                 cfg.local_steps, cfg.lr,
                                 global_params=global_params, mu=mu)
            update = jax.tree.map(lambda a, b: a - b, local, global_params)
            if cfg.dp_epsilon > 0:
                update, _ = privacy.clip_update(update, cfg.dp_clip)
            if strat.weighted:  # fold weight in pre-masking (sum of
                # masked, weighted updates == weighted sum)
                w = ws[i] * len(clients)
                update = jax.tree.map(lambda t: t * w, update)
            if cfg.secure_agg:
                update = privacy.mask_update(update, i, len(clients),
                                             cfg.seed * 7919 + r)
            comm.log(r, f"c{i}", "up", pytree_bytes(update), "update")
            updates.append(update)
        with timer:
            total = privacy.secure_sum(updates)
            mean_update = jax.tree.map(lambda t: t / len(clients), total)
            if cfg.dp_epsilon > 0:
                mean_update = privacy.add_dp_noise(
                    mean_update, cfg.dp_epsilon, cfg.dp_delta,
                    cfg.dp_clip * max(ws), cfg.seed * 31 + r)
            mean_update, server_state = strat.server_update(server_state,
                                                            mean_update)
            global_params = jax.tree.map(lambda g, u: g + u, global_params,
                                         mean_update)
        if test is not None:
            pred = np.asarray(spec["predict"](global_params,
                                              jnp.asarray(test[0])))
            history.append(binary_metrics(pred, test[1]))
    return global_params, comm, history, timer


def train_centralized(x, y, cfg: FedParametricConfig,
                      test: Optional[Tuple] = None):
    """Pooled-data baseline with matched optimization budget."""
    spec = tabular.MODELS[cfg.model]
    xp = _prep(cfg.model, x)
    xs, ys = S.apply_strategy(
        cfg.sampling if cfg.sampling != "fed_smote" else "smote",
        xp, y, cfg.seed)
    rng = jax.random.PRNGKey(cfg.seed)
    params = spec["init"](rng, xp.shape[1])
    params = _local_train(cfg.model, params, xs, ys,
                          cfg.rounds * cfg.local_steps, cfg.lr)
    out = {}
    if test is not None:
        xt = _prep(cfg.model, test[0])
        pred = np.asarray(spec["predict"](params, jnp.asarray(xt)))
        out = binary_metrics(pred, test[1])
    return params, out


def evaluate(model_name: str, params, x, y) -> Dict[str, float]:
    spec = tabular.MODELS[model_name]
    xp = _prep(model_name, x)
    pred = np.asarray(spec["predict"](params, jnp.asarray(xp)))
    return binary_metrics(pred, y)
