"""Parametric FL pipeline (paper C1): LR / poly-SVM / NN with FedAvg,
FedProx for the NN, optional secure aggregation + DP, full comm ledger.
Also provides the pooled-data centralized baselines for Table 5.

Runs on the shared :class:`~repro.core.runtime.FedRuntime`: the round
loop, client sampling (``cfg.participation``), straggler handling, and
ledger live in the runtime; this module contributes the
``ClientWork``/``ServerAgg`` halves (local Adam/FedProx training and
strategy aggregation).  The privacy pipeline — DP clip → weight fold →
secure-agg mask → DP noise on the aggregate — is expressed as transport
layers (``repro.core.comm``), composed after any user-selected
``cfg.transport`` codec layers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm as CM
from repro.core import privacy
from repro.core.comm import CommLog, Timer, pytree_bytes
from repro.core.metrics import binary_metrics
from repro.core.runtime import (ClientMsg, ClientWork, FedRuntime,
                                ServerAgg, ShardedFedRuntime)
from repro.core.strategies import get_strategy
from repro.data import sampling as S
from repro.models import tabular
from repro.optim import adam, fedprox_grad


@dataclass
class FedParametricConfig:
    model: str = "logreg"            # logreg | svm | mlp
    rounds: int = 30
    local_steps: int = 40
    lr: float = 0.05
    sampling: str = "none"           # none | ros | rus | smote | fed_smote
    strategy: str = "fedavg"         # repro.core.strategies.STRATEGIES name
    fedprox_mu: float = 0.0          # >0 -> FedProx (paper: NN); overrides
    # the strategy's client_mu when set
    secure_agg: bool = False
    dp_epsilon: float = 0.0          # >0 -> DP noise on the aggregate
    dp_delta: float = 1e-5
    dp_clip: float = 1.0
    dp_budget: Optional[float] = None  # cumulative RDP epsilon stop
    participation: str = "full"      # repro.core.participation spec
    transport: str = "plain"         # repro.core.comm.TRANSPORTS spec
    schedule: str = "sync"           # repro.core.runtime.SCHEDULES spec
    latency: Optional[str] = None    # repro.core.latency.LATENCY spec
    seed: int = 0


def _prep(model_name: str, x):
    if tabular.MODELS[model_name]["needs_poly"]:
        pairs, triples = tabular.poly3_indices(x.shape[1])
        return np.asarray(tabular.poly3_features(jnp.asarray(x), pairs,
                                                 triples))
    return x


def _local_train(model_name, params, x, y, steps, lr, global_params=None,
                 mu=0.0):
    spec = tabular.MODELS[model_name]
    loss_fn = spec["loss"]
    opt = adam()
    state = opt.init(params)
    xd, yd = jnp.asarray(x), jnp.asarray(y)

    @jax.jit
    def step(params, state):
        grads = jax.grad(loss_fn)(params, xd, yd)
        if mu > 0 and global_params is not None:
            grads = fedprox_grad(grads, params, global_params, mu)
        return opt.update(grads, state, params, lr)

    for _ in range(steps):
        params, state = step(params, state)
    return params


def _fed_sampling(clients, strategy, seed, comm: CommLog, round_idx=0):
    """Apply a sampling strategy locally; fed_smote also syncs stats."""
    if strategy != "fed_smote":
        return [S.apply_strategy(strategy, x, y, seed + i)
                for i, (x, y) in enumerate(clients)], None
    stats = [S.minority_stats(x, y) for (x, y) in clients]
    for i in range(len(clients)):
        comm.log(round_idx, f"c{i}", "up",
                 S.stats_bytes(clients[i][0].shape[1]), "smote-stats")
        comm.log(round_idx, f"c{i}", "down",
                 S.stats_bytes(clients[i][0].shape[1]), "smote-stats")
    agg = S.aggregate_stats(stats)
    return [S.fed_smote(x, y, agg[0], agg[1], seed + i)
            for i, (x, y) in enumerate(clients)], agg


def _parametric_transport(cfg: FedParametricConfig, strat) -> CM.Transport:
    """User transport stack + the cfg-driven privacy layers in their
    canonical positions: codec/sparsifier → DP clip → weight fold →
    secure-agg mask → (server) DP noise."""
    eps = cfg.dp_epsilon if cfg.dp_epsilon > 0 else 0.5
    base = CM.get_transport(cfg.transport, dp_clip=cfg.dp_clip,
                            dp_epsilon=eps, dp_delta=cfg.dp_delta)
    layers = list(base.layers)

    def has(cls):
        return any(isinstance(l, cls) for l in layers)

    def insert_before(cls_tuple, layer):
        pos = next((i for i, l in enumerate(layers)
                    if isinstance(l, cls_tuple)), len(layers))
        layers.insert(pos, layer)

    if cfg.dp_epsilon > 0 and not has(CM.ClipLayer):
        insert_before((CM.WeightLayer, CM.MaskLayer),
                      CM.ClipLayer(cfg.dp_clip))
    if strat.weighted and not has(CM.WeightLayer):
        insert_before((CM.MaskLayer,), CM.WeightLayer())
    if cfg.secure_agg and not has(CM.MaskLayer):
        layers.append(CM.MaskLayer())
    if cfg.dp_epsilon > 0 and not has(CM.DPNoiseLayer):
        layers.append(CM.DPNoiseLayer(cfg.dp_epsilon, cfg.dp_delta))
    return CM.Transport(base.name, layers)


@dataclass
class _ParametricWork(ClientWork, ServerAgg):
    """One tabular model across hospital shards, one plugin."""
    clients: Sequence
    cfg: FedParametricConfig
    strat: object
    mu: float
    test: Optional[Tuple] = None
    history: List[Dict] = field(default_factory=list)

    def setup(self, rt: FedRuntime):
        cfg, spec = self.cfg, tabular.MODELS[self.cfg.model]
        clients = [(_prep(cfg.model, x), y) for x, y in self.clients]
        clients, _ = _fed_sampling(clients, cfg.sampling, cfg.seed,
                                   rt.comm)
        self.clients = clients
        if self.test is not None:
            self.test = (_prep(cfg.model, self.test[0]), self.test[1])
        rng = jax.random.PRNGKey(cfg.seed)
        params = spec["init"](rng, clients[0][0].shape[1])
        return {"params": params,
                "server": self.strat.init_state(params),
                "codec": {},           # per-client wire-format state
                "max_w": 1.0}          # DP sensitivity scale, per round

    def client_round(self, rt, state, rnd):
        cfg, params = self.cfg, state["params"]
        n_active = len(rnd.computing)
        if rt.schedule_mode == "async":
            # buffered aggregation: the server averages over whatever K
            # messages fill the buffer, which need not be this dispatch
            # cohort — so fold a cohort-independent weight (normalized
            # over ALL clients, scaled by n_clients) that stays
            # consistent across a client's re-dispatches.  With zero
            # latency and K = n the cohort IS all clients, so this
            # reduces to the sync fold bit-exactly.
            ws_all = self.strat.norm_weights(
                [len(y) for _, y in self.clients])
            ws = [ws_all[i] for i in rnd.computing]
            state["max_w"] = max(ws_all)
            scale = rt.n_clients
        else:
            ws = self.strat.norm_weights(
                [len(self.clients[i][1]) for i in rnd.computing])
            state["max_w"] = max(ws)
            scale = n_active
        msgs = []
        for slot, i in enumerate(rnd.computing):
            x, y = self.clients[i]
            rt.log_down(rnd.index, i, pytree_bytes(params), "model")
            local = _local_train(cfg.model, params, x, y, cfg.local_steps,
                                 cfg.lr, global_params=params, mu=self.mu)
            update = jax.tree.map(lambda a, b: a - b, local, params)
            wire = rt.encode(update, round_idx=rnd.index, client=i,
                             slot=slot, n_active=n_active,
                             state=state["codec"].get(i),
                             weight_scale=ws[slot] * scale)
            state["codec"][i] = wire.state
            rt.log_up(rnd.index, i, wire.nbytes, "update")
            msgs.append(ClientMsg(i, wire.payload, wire.nbytes,
                                  weight=len(y)))
        return msgs

    def aggregate(self, rt, state, msgs, rnd):
        with rt.timer:
            total = privacy.secure_sum([m.payload for m in msgs])
            mean = jax.tree.map(lambda t: t / len(msgs), total)
            mean = rt.post_aggregate(
                mean, round_idx=rnd.index,
                sensitivity=self.cfg.dp_clip * state["max_w"])
            upd, state["server"] = self.strat.server_update(state["server"],
                                                            mean)
            state["params"] = jax.tree.map(lambda g, u: g + u,
                                           state["params"], upd)
        if self.test is not None:
            spec = tabular.MODELS[self.cfg.model]
            xt = jnp.asarray(self.test[0])
            pred = np.asarray(spec["predict"](state["params"], xt))
            scores = np.asarray(spec["proba"](state["params"], xt))
            entry = binary_metrics(pred, self.test[1], scores=scores)
            if rt._stamp() is not None:  # virtual-time runs: stamp the
                # metrics trace so time-to-target curves fall out of the
                # history directly (untimed runs keep the legacy dicts)
                entry = dict(entry, t=rt.now, round=rnd.index)
            self.history.append(entry)
        return state

    def finalize(self, rt, state):
        return state["params"]


def train_federated(clients: Sequence[Tuple[np.ndarray, np.ndarray]],
                    cfg: FedParametricConfig,
                    test: Optional[Tuple[np.ndarray, np.ndarray]] = None):
    """Federated training of one tabular model on the FedRuntime.

    Aggregation follows ``cfg.strategy`` (see
    ``repro.core.strategies.STRATEGIES``).  Weighted strategies fold the
    normalized client weight into each update *before* secure-agg
    masking, so the masked sum still cancels; server-side optimizers
    (FedAvgM/FedAdam) act on the averaged — and, under DP, noised —
    update.  DP noise sensitivity is ``dp_clip * max(weight)``, which
    reduces to the classic ``dp_clip / n_clients`` for uniform weights.

    ``cfg.participation`` schedules clients per round ("full",
    "uniform:k", "stratified:k", "dropout:p[:p_straggle]"); stale
    straggler updates are weight-discounted by the runtime.
    ``cfg.transport`` prepends wire layers (codec/framing) to the
    privacy stack.  Under full participation + plain transport this is
    byte- and loss-identical to the pre-runtime loop
    (``tests/test_runtime.py``).

    Returns (global_params, comm: CommLog, history, agg_timer)."""
    strat = get_strategy(cfg.strategy)
    mu = cfg.fedprox_mu if cfg.fedprox_mu > 0 else strat.client_mu
    work = _ParametricWork(clients, cfg, strat, mu, test)
    rt = FedRuntime(n_clients=len(clients), rounds=cfg.rounds,
                    participation=cfg.participation,
                    transport=_parametric_transport(cfg, strat),
                    schedule=cfg.schedule, latency=cfg.latency,
                    seed=cfg.seed, dp_budget=cfg.dp_budget)
    params = rt.run(work)
    return params, rt.comm, work.history, rt.timer


def build_local_delta(model_name: str, local_steps: int, lr: float,
                      mu: float = 0.0):
    """The per-client local round as one pure, vmappable function:
    ``local_fn(global_params, x, y) → delta`` — ``local_steps``
    full-batch Adam steps (FedProx term when ``mu > 0``) as a
    ``lax.scan``, the same math as the plugin path's ``_local_train``
    but traceable under ``jax.vmap`` over a stacked client axis."""
    loss_fn = tabular.MODELS[model_name]["loss"]
    opt = adam()

    def local_fn(global_params, x, y):
        def body(carry, _):
            p, s = carry
            g = jax.grad(loss_fn)(p, x, y)
            if mu > 0:
                g = fedprox_grad(g, p, global_params, mu)
            p, s = opt.update(g, s, p, lr)
            return (p, s), None
        (p, _), _ = jax.lax.scan(body, (global_params,
                                        opt.init(global_params)),
                                 None, length=local_steps)
        return jax.tree.map(lambda a, b: a - b, p, global_params)

    return local_fn


def train_federated_sharded(data, cfg: FedParametricConfig, *,
                            mesh=None, silos: int = 1,
                            test: Optional[Tuple] = None):
    """Population-scale federated training on the
    :class:`~repro.core.runtime.ShardedFedRuntime`.

    ``data`` is either a cohort spec (``"framingham_like:n:rows"`` /
    :class:`~repro.data.cohort.CohortSpec` — materialized via
    ``repro.data.cohort.build_cohort``) or a prebuilt
    ``(xs, ys)`` pair of stacked client-axis arrays
    ``(n_clients, rows, F)`` / ``(n_clients, rows)``.  ``mesh`` is a
    ``repro.launch.mesh.MESHES`` spec ("single" | "host[:D]") or a
    prebuilt Mesh; ``silos`` groups clients into contiguous equal silos
    for the hierarchical client → silo → server tree-reduce.

    The sharded engine is the iid + full-participation + plain-wire
    fast path: per-client sampling strategies, secure aggregation, DP,
    float-transform transports, partial participation, and async
    schedules all stay on :func:`train_federated` (they are per-client
    Python).  Configs requesting them raise rather than silently
    degrade.  Single-device runs of the same config match
    :func:`train_federated` to the documented reduction-order tolerance
    (``ShardedFedRuntime.PARITY_ATOL`` per round).

    Returns ``(global_params, comm, history, timer)`` — the
    :func:`train_federated` contract, with a tiered CommLog."""
    for knob, want in (("sampling", "none"), ("participation", "full"),
                       ("schedule", "sync")):
        if getattr(cfg, knob) != want:
            raise ValueError(
                f"sharded parametric training supports {knob}={want!r} "
                f"only (got {getattr(cfg, knob)!r}); use "
                f"train_federated for the plugin engine")
    if cfg.secure_agg or cfg.dp_epsilon > 0:
        raise ValueError("sharded parametric training has no secure-agg"
                         "/DP path; use train_federated")
    if isinstance(data, tuple):
        xs, ys = data
    else:
        from repro.data.cohort import build_cohort
        xs, ys = build_cohort(data, seed=cfg.seed)
    xs, ys = np.asarray(xs), np.asarray(ys)
    n_clients, rows, n_feat = xs.shape
    spec = tabular.MODELS[cfg.model]
    if spec["needs_poly"]:
        xs = np.asarray(_prep(cfg.model, xs.reshape(-1, n_feat))) \
            .reshape(n_clients, rows, -1)
    if test is not None:
        test = (_prep(cfg.model, test[0]), test[1])

    strat = get_strategy(cfg.strategy)
    mu = cfg.fedprox_mu if cfg.fedprox_mu > 0 else strat.client_mu
    rt = ShardedFedRuntime(n_clients=n_clients, rounds=cfg.rounds,
                           n_silos=silos, mesh=mesh, strategy=strat,
                           transport=cfg.transport, seed=cfg.seed)
    local_fn = build_local_delta(cfg.model, cfg.local_steps, cfg.lr, mu)
    params = spec["init"](jax.random.PRNGKey(cfg.seed), xs.shape[-1])

    eval_fn = None
    if test is not None:
        xt = jnp.asarray(test[0])

        def eval_fn(p):
            pred = np.asarray(spec["predict"](p, xt))
            scores = np.asarray(spec["proba"](p, xt))
            return binary_metrics(pred, test[1], scores=scores)

    params, history = rt.run(local_fn, params, xs, ys, eval_fn=eval_fn)
    return params, rt.comm, history, rt.timer


def train_centralized(x, y, cfg: FedParametricConfig,
                      test: Optional[Tuple] = None):
    """Pooled-data baseline with matched optimization budget."""
    spec = tabular.MODELS[cfg.model]
    xp = _prep(cfg.model, x)
    xs, ys = S.apply_strategy(
        cfg.sampling if cfg.sampling != "fed_smote" else "smote",
        xp, y, cfg.seed)
    rng = jax.random.PRNGKey(cfg.seed)
    params = spec["init"](rng, xp.shape[1])
    params = _local_train(cfg.model, params, xs, ys,
                          cfg.rounds * cfg.local_steps, cfg.lr)
    out = {}
    if test is not None:
        xt = jnp.asarray(_prep(cfg.model, test[0]))
        pred = np.asarray(spec["predict"](params, xt))
        out = binary_metrics(pred, test[1],
                             scores=np.asarray(spec["proba"](params, xt)))
    return params, out


def evaluate(model_name: str, params, x, y) -> Dict[str, float]:
    spec = tabular.MODELS[model_name]
    xp = jnp.asarray(_prep(model_name, x))
    pred = np.asarray(spec["predict"](params, xp))
    return binary_metrics(pred, y,
                          scores=np.asarray(spec["proba"](params, xp)))
