"""FedRuntime — the single round-based engine every federated pipeline
plugs into.

Before this module the repo carried four hand-rolled round loops
(parametric ``fed_train.simulate``, tree-subset RF, XGBoost feature
extraction, ``fed_hist`` GBDT), each with its own client scheduling and
comm accounting.  ``FedRuntime`` owns the parts they shared:

* the **round loop** — ``rounds`` iterations over a
  :class:`~repro.core.participation.Participation` plan (full /
  uniform-k / stratified / dropout with stragglers);
* **straggler buffering** — messages from straggling clients are held
  one round and delivered stale, their payloads scaled by
  ``stale_discount ** staleness`` (the stale-update handling that
  keeps fedavgm/fedadam server state from integrating outdated
  directions at full strength; payload scaling makes the discount hold
  under any aggregator normalization);
* the **ledger** — one :class:`~repro.core.comm.CommLog` + aggregation
  :class:`~repro.core.comm.Timer` per run, with helpers that route every
  logged byte through the configured
  :class:`~repro.core.comm.Transport` stack.

Pipelines implement the two plugin halves:

* :class:`ClientWork` — local training for this round's computing
  clients, returning one :class:`ClientMsg` per client (payload + exact
  wire bytes, already transport-encoded via :meth:`FedRuntime.encode`);
* :class:`ServerAgg` — folds delivered messages into global state.

A single class may implement both (``runtime.run(work)``), which is how
the in-repo pipelines do it.  Under ``participation='full'`` and
``transport='plain'`` every refactored pipeline reproduces its
pre-runtime losses/forests/ledger bytes exactly
(``tests/test_runtime.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import numpy as np

import jax

from repro.core.comm import (CommLog, MaskLayer, Timer, Transport, WireCtx,
                             WireMsg, get_transport)
from repro.core.participation import Participation, get_participation


@dataclass
class ClientMsg:
    """One client's uplink for a round: the (decoded) payload the server
    aggregates (scaled down by the runtime when delivered stale), the
    exact bytes it occupied on the wire, the combine weight (sample
    count), and staleness (0 = fresh, 1 = delivered one round late by a
    straggler)."""
    client: int
    payload: Any
    nbytes: int
    weight: float = 1.0
    staleness: int = 0
    what: str = "update"


@dataclass
class RoundInfo:
    """One round's schedule, as seen by the plugins.  ``computing`` =
    ``arrive`` ∪ ``stragglers`` (every client running local work);
    only ``arrive`` messages reach the aggregator this round."""
    index: int
    computing: List[int]
    arrive: List[int]
    stragglers: List[int]


class ClientWork:
    """Client half of a pipeline.  ``setup`` builds the run state (and
    may log setup-phase traffic, e.g. federated binning); ``client_round``
    runs local work for ``rnd.computing`` and returns their messages;
    ``finalize`` shapes the returned result."""

    def setup(self, rt: "FedRuntime") -> Any:
        raise NotImplementedError

    def client_round(self, rt: "FedRuntime", state: Any,
                     rnd: RoundInfo) -> List[ClientMsg]:
        raise NotImplementedError

    def finalize(self, rt: "FedRuntime", state: Any) -> Any:
        return state


class ServerAgg:
    """Server half: fold this round's delivered messages into state."""

    def aggregate(self, rt: "FedRuntime", state: Any,
                  msgs: List[ClientMsg], rnd: RoundInfo) -> Any:
        raise NotImplementedError


@dataclass
class FedRuntime:
    """The engine.  ``participation`` / ``transport`` accept registry
    spec strings (see :data:`~repro.core.participation.PARTICIPATION`,
    :data:`~repro.core.comm.TRANSPORTS`) or prebuilt objects;
    ``transport_cfg`` carries layer knobs (rho, rank, dp_*,
    frame_header).  ``allow_stale=False`` turns stragglers into plain
    drops for pipelines whose payloads cannot be replayed a round late
    (histogram aggregation fused into tree growth)."""
    n_clients: int
    rounds: int
    participation: Any = "full"
    transport: Any = "plain"
    seed: int = 0
    stale_discount: float = 0.5
    allow_stale: bool = True
    client_prefix: str = "c"
    comm: CommLog = field(default_factory=CommLog)
    timer: Timer = field(default_factory=Timer)
    transport_cfg: Optional[dict] = None

    def __post_init__(self):
        self.participation = get_participation(self.participation)
        self.transport = get_transport(self.transport,
                                       **(self.transport_cfg or {}))
        if (self.allow_stale and self.participation.may_straggle
                and any(isinstance(l, MaskLayer)
                        for l in self.transport.layers)):
            raise ValueError(
                f"participation {self.participation.name!r} can deliver "
                f"straggler updates a round late, but transport "
                f"{self.transport.name!r} carries secure-agg masks keyed "
                f"to the compute round's active set — the pairwise masks "
                f"would never cancel in the server sum.  Use "
                f"'dropout:p' (stragglers lost, p_straggle=0) or drop "
                f"the mask layer")
        self._rng = np.random.default_rng([self.seed, 0xFED])

    # -- ledger helpers ----------------------------------------------------

    def log_up(self, round_idx: int, client: int, nbytes: int, what: str):
        self.comm.log(round_idx, f"{self.client_prefix}{client}", "up",
                      nbytes, what)

    def log_down(self, round_idx: int, client: int, nbytes: int,
                 what: str):
        """Broadcast accounting; framing overhead applies to the
        downlink too."""
        self.comm.log(round_idx, f"{self.client_prefix}{client}", "down",
                      nbytes + self.transport.frame_overhead, what)

    # -- transport helpers -------------------------------------------------

    def encode(self, payload, *, round_idx: int, client: int, slot: int,
               n_active: int, state: Any = None,
               nbytes: Optional[int] = None, weight_scale: float = 1.0
               ) -> WireMsg:
        """Run one client's payload through the transport stack."""
        ctx = WireCtx(round=round_idx, client=client, slot=slot,
                      n_active=n_active, seed=self.seed,
                      weight_scale=weight_scale)
        return self.transport.encode(payload, nbytes=nbytes, state=state,
                                     ctx=ctx)

    def post_aggregate(self, payload, *, round_idx: int,
                       sensitivity: float = 1.0):
        """Server-side transport tail (DP noise on the aggregate)."""
        ctx = WireCtx(round=round_idx, seed=self.seed,
                      sensitivity=sensitivity)
        return self.transport.post_aggregate(payload, ctx)

    # -- the round loop ----------------------------------------------------

    def run(self, work: ClientWork, agg: Optional[ServerAgg] = None):
        agg = agg if agg is not None else work
        state = work.setup(self)
        pending: List[ClientMsg] = []
        for r in range(self.rounds):
            plan = self.participation.plan(r, self.n_clients, self._rng)
            arrive = sorted(plan.arrive)
            if self.allow_stale:
                stragglers = sorted(plan.stragglers)
            else:
                # stragglers are lost — but keep the round alive if the
                # schedule scheduled nobody else
                stragglers = []
                if not arrive and plan.stragglers:
                    arrive = sorted(plan.stragglers)[:1]
            computing = sorted(set(arrive) | set(stragglers))
            rnd = RoundInfo(r, computing, arrive, stragglers)
            msgs = (work.client_round(self, state, rnd)
                    if computing else [])
            late_set = set(stragglers)
            fresh = [m for m in msgs if m.client not in late_set]
            late = [m for m in msgs if m.client in late_set]
            for m in late:
                m.staleness += 1
            for m in pending:  # stale-update handling: discount the
                # payload itself, so the reduced contribution holds for
                # every aggregator (uniform means, weighted combines,
                # server optimizers) regardless of how it normalizes
                f = self.stale_discount ** m.staleness
                m.payload = jax.tree.map(lambda x: x * f, m.payload)
            deliver = fresh + pending
            pending = late
            if deliver:
                state = agg.aggregate(self, state, deliver, rnd)
        return work.finalize(self, state)
