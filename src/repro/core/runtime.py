"""FedRuntime — the single round-based engine every federated pipeline
plugs into.

Before this module the repo carried four hand-rolled round loops
(parametric ``fed_train.simulate``, tree-subset RF, XGBoost feature
extraction, ``fed_hist`` GBDT), each with its own client scheduling and
comm accounting.  ``FedRuntime`` owns the parts they shared:

* the **round loop** — ``rounds`` iterations over a
  :class:`~repro.core.participation.Participation` plan (full /
  uniform-k / stratified / dropout with stragglers);
* **straggler buffering** — messages from straggling clients are held
  one round and delivered stale, their payloads scaled by
  ``stale_discount ** staleness`` (the stale-update handling that
  keeps fedavgm/fedadam server state from integrating outdated
  directions at full strength; payload scaling makes the discount hold
  under any aggregator normalization);
* the **ledger** — one :class:`~repro.core.comm.CommLog` + aggregation
  :class:`~repro.core.comm.Timer` per run, with helpers that route every
  logged byte through the configured
  :class:`~repro.core.comm.Transport` stack.

Pipelines implement the two plugin halves:

* :class:`ClientWork` — local training for this round's computing
  clients, returning one :class:`ClientMsg` per client (payload + exact
  wire bytes, already transport-encoded via :meth:`FedRuntime.encode`);
* :class:`ServerAgg` — folds delivered messages into global state.

A single class may implement both (``runtime.run(work)``), which is how
the in-repo pipelines do it.  Under ``participation='full'`` and
``transport='plain'`` every refactored pipeline reproduces its
pre-runtime losses/forests/ledger bytes exactly
(``tests/test_runtime.py``).

**Schedules** (:data:`SCHEDULES`): the engine runs the same plugins
under two execution modes, selected by ``schedule``:

* ``sync`` (default) — the round loop above, bit-exact with every
  pre-runtime pipeline.  When a ``latency`` model is set the virtual
  clock advances by the *slowest* computing client per round (the
  synchronous barrier), so sync and async runs are comparable on the
  same virtual timeline.
* ``async:K`` — FedBuff-style buffered asynchronous aggregation on a
  deterministic virtual-clock event loop.  Every client computes
  continuously: it is dispatched with the current model, its upload
  arrives after a delay drawn from its
  :mod:`~repro.core.latency` model, and the server aggregates whenever
  **K** uploads have arrived.  A message aggregated ``s`` server
  versions after its dispatch is delivered with ``staleness=s`` and its
  payload scaled by ``stale_discount ** s`` — the same stale-update
  machinery the sync loop applies to straggler deliveries, generalized
  from one-round buffering to arbitrary staleness.  With zero latency
  and ``K = n_clients`` the event loop reduces to the synchronous round
  loop bit-exactly (``tests/test_async.py``, CI-gated).

Every aggregation appends to :attr:`FedRuntime.timeline` (server
version, virtual time, arrivals, staleness), and ledger events carry a
``t`` stamp whenever a latency model or the async schedule is active —
the time-to-target-F1 rows in ``benchmarks/fed_engine_bench.py`` are
read from exactly these records.

**Population scale** (:class:`ShardedFedRuntime`): the plugin engine
above is message-passing-faithful — per-client Python objects through a
layered transport — which tops out at tens of clients.  The sharded
runtime trades that fidelity for scale: stacked ``(n_clients, ...)``
client-axis pytrees are placed over a 1-D ``('clients',)`` device mesh
(``repro.launch.mesh.get_fed_mesh`` + ``repro.sharding.rules.FED_RULES``)
and one jitted call advances *every* client — vmapped local training,
then a hierarchical client → silo → server tree-reduce whose cross-silo
combine runs through the same strategy registry.  Ledger accounting is
per aggregation tier from shape/dtype metadata only (never a
device-to-host gather), so the CommLog records what the silo topology
— not a flat star — would move.  See docs/ARCHITECTURE.md §Sharded
federation.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import privacy
from repro.core.comm import (CommLog, DPNoiseLayer, MaskLayer, Timer,
                             Transport, WireCtx, WireMsg, get_transport,
                             pytree_bytes)
from repro.core.latency import Draw, get_latency
from repro.core.participation import Participation, get_participation
from repro.core.strategies import get_strategy
from repro.obs import current as _ambient_tracer


#: schedule name -> what the mode does.  Resolved via
#: :func:`get_schedule` spec strings ("sync", "async:K").
SCHEDULES: Dict[str, str] = {
    "sync": "round-synchronous: every round barriers on all scheduled "
            "arrivals before aggregating",
    "async": "async[:K] — buffered asynchronous aggregation: the server "
             "aggregates every K arrivals (default 1), staleness-"
             "discounted; clients compute continuously on a virtual "
             "clock driven by their latency models",
}


def get_schedule(spec) -> tuple:
    """Resolve a schedule spec to ``(mode, K)``: ``"sync"`` → ``("sync",
    0)``; ``"async"`` / ``"async:K"`` → ``("async", K)`` (default K=1,
    clamped to ``n_clients`` by the runtime)."""
    parts = str(spec).split(":")
    name, args = parts[0], parts[1:]
    if name not in SCHEDULES:
        raise KeyError(f"unknown schedule {spec!r}; "
                       f"available: {sorted(SCHEDULES)} "
                       f"(spec: sync | async[:K])")
    if name == "sync":
        if args:
            raise ValueError(f"schedule 'sync' takes no args, got {spec!r}")
        return "sync", 0
    k = int(args[0]) if args else 1
    if k < 1 or len(args) > 1:
        raise ValueError(f"bad schedule spec {spec!r}: async:K needs one "
                         f"integer K >= 1")
    return "async", k


@dataclass
class ClientMsg:
    """One client's uplink for a round: the (decoded) payload the server
    aggregates (scaled down by the runtime when delivered stale), the
    exact bytes it occupied on the wire, the combine weight (sample
    count), and staleness (0 = fresh, 1 = delivered one round late by a
    straggler)."""
    client: int
    payload: Any
    nbytes: int
    weight: float = 1.0
    staleness: int = 0
    what: str = "update"
    #: secure-agg bookkeeping, set by ``FedRuntime._annotate_masks`` on
    #: messages whose payload was mask-encoded: the share-book key of
    #: the dispatch cohort and the client's slot in it.  Cleared once
    #: the message's masks have been reconciled (``_recover_masks``).
    mask_key: Any = None
    mask_slot: int = -1


@dataclass
class RoundInfo:
    """One round's schedule, as seen by the plugins.  ``computing`` =
    ``arrive`` ∪ ``stragglers`` (every client running local work);
    only ``arrive`` messages reach the aggregator this round."""
    index: int
    computing: List[int]
    arrive: List[int]
    stragglers: List[int]


class ClientWork:
    """Client half of a pipeline.  ``setup`` builds the run state (and
    may log setup-phase traffic, e.g. federated binning); ``client_round``
    runs local work for ``rnd.computing`` and returns their messages;
    ``finalize`` shapes the returned result."""

    def setup(self, rt: "FedRuntime") -> Any:
        raise NotImplementedError

    def client_round(self, rt: "FedRuntime", state: Any,
                     rnd: RoundInfo) -> List[ClientMsg]:
        raise NotImplementedError

    def finalize(self, rt: "FedRuntime", state: Any) -> Any:
        return state


class ServerAgg:
    """Server half: fold this round's delivered messages into state."""

    def aggregate(self, rt: "FedRuntime", state: Any,
                  msgs: List[ClientMsg], rnd: RoundInfo) -> Any:
        raise NotImplementedError


@dataclass
class FedRuntime:
    """The engine.  ``participation`` / ``transport`` / ``schedule`` /
    ``latency`` accept registry spec strings (see
    :data:`~repro.core.participation.PARTICIPATION`,
    :data:`~repro.core.comm.TRANSPORTS`, :data:`SCHEDULES`,
    :data:`~repro.core.latency.LATENCY`) or prebuilt objects;
    ``transport_cfg`` carries layer knobs (rho, rank, dp_*,
    frame_header).  ``allow_stale=False`` turns stragglers into plain
    drops for pipelines whose payloads cannot be replayed a round late
    (histogram aggregation fused into tree growth).  ``allow_stale``
    governs only the sync straggler path: under ``async`` staleness is
    inherent and every late payload is discounted — safe for the
    in-repo ``allow_stale=False`` pipelines because their message
    *content* is either computed at aggregation time from current
    server state (fed_hist histograms; the ``None`` payload makes the
    discount a no-op) or structurally fresh (one-shot protocols run a
    single server version, so staleness is always 0; ``async:K`` there
    means "publish from the first K uploads").

    ``rounds`` is the number of *server aggregations* in both schedule
    modes, so sync and ``async:K`` runs of the same config do the same
    amount of server work and are comparable on the shared virtual
    clock (:attr:`now` / :attr:`timeline`)."""
    n_clients: int
    rounds: int
    participation: Any = "full"
    transport: Any = "plain"
    schedule: Any = "sync"
    latency: Any = None
    seed: int = 0
    stale_discount: float = 0.5
    allow_stale: bool = True
    #: stop criterion on the cumulative RDP epsilon: once the
    #: accountant's max-over-clients epsilon reaches this budget the
    #: run halts after the offending aggregation (recorded in
    #: ``comm.privacy['budget_stop_round']``).  Requires a dpnoise
    #: layer in the transport.
    dp_budget: Optional[float] = None
    client_prefix: str = "c"
    comm: CommLog = field(default_factory=CommLog)
    timer: Timer = field(default_factory=Timer)
    transport_cfg: Optional[dict] = None
    #: ``None`` resolves to the ambient :func:`repro.obs.current` tracer
    #: (the falsy NULL_TRACER unless a run installed one), so existing
    #: entry points pick up tracing without signature churn.  Every hot
    #: path guards with ``if tr:`` — traced-off runs are bit-exact with
    #: untraced ones (tests/test_obs.py).
    tracer: Any = None

    def __post_init__(self):
        self.participation = get_participation(self.participation)
        self.transport = get_transport(self.transport,
                                       **(self.transport_cfg or {}))
        self.schedule_mode, self.agg_every = get_schedule(self.schedule)
        self.latency = get_latency(self.latency, seed=self.seed)
        self.now = 0.0            # virtual wall clock (seconds)
        # one record per aggregation, shared with the comm ledger so
        # entry points that only hold the CommLog can surface it
        self.timeline: List[Dict] = self.comm.timeline
        if (self.schedule_mode == "async"
                and self.participation.name != "full"):
            raise ValueError(
                f"schedule 'async' needs participation 'full' (got "
                f"{self.participation.name!r}): who computes when is "
                f"driven by the latency/availability model, not a "
                f"round schedule")
        # secure-agg mask recovery state: masked payloads whose cohort
        # peers miss an aggregation (stragglers, async cohort mixing,
        # transit drops) are repaired by reconstructing the absent
        # pair seeds from the cohort's Shamir share book — see
        # docs/ARCHITECTURE.md §Privacy
        self._mask_layer = next(
            (l for l in self.transport.layers
             if isinstance(l, MaskLayer)), None)
        self._mask_books: Dict[tuple, privacy.SeedShareBook] = {}
        self._mask_slots: Dict[tuple, int] = {}
        self._cohort = 0          # current dispatch cohort (sync: 0)
        self._next_cohort = 0     # async: fresh cohort per dispatch
        dp = next((l for l in self.transport.layers
                   if isinstance(l, DPNoiseLayer)), None)
        self.dp_accountant = (
            privacy.RDPAccountant(dp.noise_multiplier, dp.delta)
            if dp is not None else None)
        if self.dp_budget is not None and self.dp_accountant is None:
            raise ValueError(
                f"dp_budget={self.dp_budget} needs a 'dpnoise' layer in "
                f"transport {self.transport.name!r} — there is no DP "
                f"mechanism to account for")
        self._rng = np.random.default_rng([self.seed, 0xFED])
        if self.tracer is None:
            self.tracer = _ambient_tracer()

    # -- ledger helpers ----------------------------------------------------

    def _stamp(self) -> Optional[float]:
        """Virtual-time ledger stamp — recorded whenever time is being
        modeled (async schedule, or sync with a latency model)."""
        if self.schedule_mode == "async" or self.latency is not None:
            return self.now
        return None

    def log_up(self, round_idx: int, client: int, nbytes: int, what: str):
        self.comm.log(round_idx, f"{self.client_prefix}{client}", "up",
                      nbytes, what, t=self._stamp())
        if self.tracer:
            self.tracer.metrics.inc("bytes_up", nbytes)

    def log_down(self, round_idx: int, client: int, nbytes: int,
                 what: str):
        """Broadcast accounting; framing overhead applies to the
        downlink too."""
        wire = nbytes + self.transport.frame_overhead
        self.comm.log(round_idx, f"{self.client_prefix}{client}", "down",
                      wire, what, t=self._stamp())
        if self.tracer:
            self.tracer.metrics.inc("bytes_down", wire)

    # -- transport helpers -------------------------------------------------

    def encode(self, payload, *, round_idx: int, client: int, slot: int,
               n_active: int, state: Any = None,
               nbytes: Optional[int] = None, weight_scale: float = 1.0
               ) -> WireMsg:
        """Run one client's payload through the transport stack."""
        ctx = WireCtx(round=round_idx, client=client, slot=slot,
                      n_active=n_active, seed=self.seed,
                      cohort=self._cohort, weight_scale=weight_scale)
        if self._mask_layer is not None and n_active > 1:
            # open (or join) the dispatch cohort's Shamir share book and
            # remember which slot this client masked under, so delivery
            # batches can locate and reconcile the message's masks
            key = (round_idx, self._cohort)
            if key not in self._mask_books:
                self._mask_books[key] = privacy.SeedShareBook(
                    privacy.mask_round_seed(self.seed, round_idx,
                                            self._cohort),
                    n_active,
                    self._mask_layer.resolve_threshold(n_active))
            self._mask_slots[(key, client)] = slot
        if self.tracer:  # per-layer byte events (repro.obs)
            ctx.tracer, ctx.t = self.tracer, self.now
        return self.transport.encode(payload, nbytes=nbytes, state=state,
                                     ctx=ctx)

    def post_aggregate(self, payload, *, round_idx: int,
                       sensitivity: float = 1.0):
        """Server-side transport tail (DP noise on the aggregate)."""
        ctx = WireCtx(round=round_idx, seed=self.seed,
                      sensitivity=sensitivity)
        return self.transport.post_aggregate(payload, ctx)

    # -- secure-agg mask recovery ------------------------------------------

    def _annotate_masks(self, msgs: List[ClientMsg], round_idx: int):
        """Tag messages produced under the current dispatch cohort with
        their share-book key/slot so delivery batches can reconcile
        their masks (no-op for unmasked transports and payload-free
        messages, e.g. fed_hist's in-jit histograms)."""
        if self._mask_layer is None:
            return
        key = (round_idx, self._cohort)
        for m in msgs:
            slot = self._mask_slots.get((key, m.client))
            if slot is not None and m.payload is not None:
                m.mask_key, m.mask_slot = key, slot

    def _recover_masks(self, msgs: List[ClientMsg], round_idx: int):
        """Reconcile secure-agg masks for one delivery group.

        Per dispatch cohort represented in ``msgs``: pair terms between
        two members of the *same* group cancel in the aggregate sum and
        are left in place (they keep blinding the individual payloads);
        terms against every absent cohort member are reconstructed from
        the cohort's Shamir share book and subtracted — so the group's
        masked sum equals its plain sum under any drop / straggler /
        async-mixing pattern.  Reconstruction traffic (threshold shares
        per recovered seed) is charged to the ledger as 'mask-shares'.
        Must run *before* staleness discounting: mask terms subtract at
        full scale, and the surviving in-group terms scale together
        (one cohort dispatch = one staleness) so they still cancel."""
        if self._mask_layer is None:
            return
        groups: Dict[Any, List[ClientMsg]] = {}
        for m in msgs:
            if m.mask_key is not None:
                groups.setdefault(m.mask_key, []).append(m)
        tr = self.tracer
        for key, group in groups.items():
            book = self._mask_books[key]
            present = {m.mask_slot for m in group}
            pulled0, n_rec = book.shares_pulled, 0
            for m in group:
                m.payload, n = privacy.strip_missing_masks(
                    m.payload, book, m.mask_slot, present)
                m.mask_key = None
                n_rec += n
            if n_rec:
                nb = (book.shares_pulled - pulled0) * book.SHARE_NBYTES
                self.comm.log(round_idx, f"{self.client_prefix}*", "up",
                              nb, "mask-shares", t=self._stamp())
                if tr:
                    tr.instant("fed.mask_recover", track="server",
                               t=self.now, round=round_idx,
                               cohort=key[1], seeds=n_rec, bytes=nb)
                    tr.metrics.inc("bytes_up", nb)

    def _dp_budget_hit(self, round_idx: int) -> bool:
        """True once the cumulative RDP epsilon reaches ``dp_budget``
        (checked after each aggregation; the stop round is recorded in
        the ledger's privacy snapshot)."""
        if self.dp_budget is None or self.dp_accountant is None:
            return False
        eps = self.dp_accountant.epsilon()
        if eps < self.dp_budget:
            return False
        if self.comm.privacy is not None:
            self.comm.privacy["budget"] = self.dp_budget
            self.comm.privacy["budget_stop_round"] = round_idx
        if self.tracer:
            self.tracer.instant("fed.dp_budget_stop", track="server",
                                t=self.now, round=round_idx,
                                epsilon=eps, budget=self.dp_budget)
        return True

    # -- timeline ----------------------------------------------------------

    def _timeline_record(self, round_idx: int, msgs: List[ClientMsg]):
        """Append one per-aggregation timeline record with the unified
        schema shared by the sync and async paths: ``round``, ``t``
        (virtual clock), ``n_clients`` (messages folded into this
        aggregation), ``staleness`` (per message), ``bytes`` (wire bytes
        those messages occupied).  ``n_msgs`` is kept as a legacy alias
        of ``n_clients`` — tests/test_obs.py gates the schema."""
        self.timeline.append(
            {"round": round_idx, "t": self.now,
             "n_clients": len(msgs), "n_msgs": len(msgs),
             "staleness": [m.staleness for m in msgs],
             "bytes": sum(m.nbytes for m in msgs)})
        if self.dp_accountant is not None and msgs:
            # one subsampled-Gaussian release per aggregation: the
            # participation fraction is the amplification rate, and only
            # the clients actually folded in accrue loss (individual
            # accounting — privacy.RDPAccountant)
            part = {m.client for m in msgs}
            self.dp_accountant.step(
                part, min(1.0, len(part) / max(self.n_clients, 1)))
            self.comm.privacy = self.dp_accountant.summary()
        tr = self.tracer
        if tr:
            tr.metrics.inc("msgs_delivered", len(msgs))
            for m in msgs:
                if m.staleness > 0:
                    tr.metrics.observe("staleness_rounds", m.staleness)

    # -- the round loop ----------------------------------------------------

    def run(self, work: ClientWork, agg: Optional[ServerAgg] = None):
        agg = agg if agg is not None else work
        state = work.setup(self)
        self._n_dispatch = [0] * self.n_clients
        if self.schedule_mode == "async":
            state = self._run_async(work, agg, state)
        else:
            state = self._run_sync(work, agg, state)
        return work.finalize(self, state)

    def _draw(self, client: int) -> Draw:
        """One latency draw for the client's next dispatch (zero-delay,
        never-dropped when no model is configured)."""
        k = self._n_dispatch[client]
        self._n_dispatch[client] = k + 1
        return (self.latency.draw(client, k)
                if self.latency is not None else Draw(0.0))

    def _run_sync(self, work: ClientWork, agg: ServerAgg, state):
        pending: List[ClientMsg] = []
        tr = self.tracer
        for r in range(self.rounds):
            plan = self.participation.plan(r, self.n_clients, self._rng)
            arrive = sorted(plan.arrive)
            if self.allow_stale:
                stragglers = sorted(plan.stragglers)
            else:
                # stragglers are lost — but keep the round alive if the
                # schedule scheduled nobody else
                stragglers = []
                if not arrive and plan.stragglers:
                    arrive = sorted(plan.stragglers)[:1]
                if tr:
                    for c in sorted(set(plan.stragglers) - set(arrive)):
                        tr.instant("fed.drop", track=f"c{c}", t=self.now,
                                   round=r, reason="straggler")
            computing = sorted(set(arrive) | set(stragglers))
            rnd = RoundInfo(r, computing, arrive, stragglers)
            t_start = self.now
            msgs = (work.client_round(self, state, rnd)
                    if computing else [])
            self._annotate_masks(msgs, r)
            # the synchronous barrier: the round takes as long as the
            # slowest computing client (drops are a participation-axis
            # concern in sync mode, so the dropped flag is ignored)
            if self.latency is not None and computing:
                delays = [self._draw(c).delay for c in computing]
                self.now += max(delays)
            else:
                delays = None
                self.now += 1.0
            if tr:
                for j, c in enumerate(computing):
                    dt = delays[j] if delays is not None else 1.0
                    tr.span_at("client.compute", t_start, t_start + dt,
                               track=f"c{c}", round=r,
                               straggler=c in stragglers)
            late_set = set(stragglers)
            fresh = [m for m in msgs if m.client not in late_set]
            late = [m for m in msgs if m.client in late_set]
            for m in late:
                m.staleness += 1
                if tr:
                    tr.instant("fed.straggle", track=f"c{m.client}",
                               t=self.now, round=r,
                               staleness=m.staleness)
            if self._mask_layer is not None:
                # reconcile cohort masks per delivery group: the fresh
                # batch loses its straggler terms, the held batch loses
                # its fresh terms (mutual straggler terms survive — they
                # cancel when pending is delivered together next round)
                self._recover_masks(fresh, r)
                self._recover_masks(late, r)
            for m in pending:  # stale-update handling: discount the
                # payload itself, so the reduced contribution holds for
                # every aggregator (uniform means, weighted combines,
                # server optimizers) regardless of how it normalizes
                f = self.stale_discount ** m.staleness
                m.payload = jax.tree.map(lambda x: x * f, m.payload)
            deliver = fresh + pending
            pending = late
            if deliver:
                state = agg.aggregate(self, state, deliver, rnd)
                self._timeline_record(r, deliver)
            if tr:
                tr.span_at("fed.round", t_start, self.now,
                           track="server", round=r,
                           n_computing=len(computing),
                           n_delivered=len(deliver),
                           n_stragglers=len(stragglers),
                           bytes=sum(m.nbytes for m in deliver))
                tr.metrics.observe("round_s", self.now - t_start)
            if deliver and self._dp_budget_hit(r):
                break
        return state

    def _run_async(self, work: ClientWork, agg: ServerAgg, state):
        """Deterministic virtual-clock event loop (FedBuff-style).

        Every client computes continuously: dispatched with the current
        model, its upload arrives ``delay`` virtual seconds later (its
        :mod:`~repro.core.latency` draw) and is buffered; every
        ``agg_every``-th arrival triggers an aggregation and bumps the
        server version.  A message dispatched at version ``v0`` and
        aggregated at version ``v`` carries ``staleness = v - v0`` and
        its payload is scaled by ``stale_discount ** staleness``.
        Clients re-enter the dispatch pool when their upload is consumed
        (or lost — a dropped upload is retried on the then-current
        model).  Arrivals are totally ordered by ``(time, dispatch
        seq)``, so a fixed seed replays the identical event sequence.
        """
        K = min(self.agg_every, self.n_clients)
        heap: List[tuple] = []   # (arrival_t, seq, client, msg|None, v0)
        buffer: List[ClientMsg] = []
        ready = list(range(self.n_clients))
        version, seq = 0, 0
        tr = self.tracer
        # open client.compute span handles by dispatch seq (explicit
        # begin/end: a span opened at dispatch closes many events later)
        open_spans: Dict[int, Any] = {}
        last_agg_t = 0.0
        # with a drop-everything availability model arrivals never come;
        # bound total dispatches so the loop fails loudly instead
        budget = 64 * (self.rounds + 1) * max(self.n_clients, 1)
        dispatched = 0
        while version < self.rounds:
            if ready:
                group = sorted(ready)
                ready = []
                dispatched += len(group)
                if dispatched > budget:
                    raise RuntimeError(
                        f"async runtime exceeded {budget} dispatches "
                        f"before {self.rounds} aggregations — the "
                        f"latency model "
                        f"{getattr(self.latency, 'name', None)!r} drops "
                        f"(almost) every upload")
                # fresh dispatch cohort: mask seeds must differ between
                # dispatch groups even at the same server version (a
                # client retrying after a transit drop would otherwise
                # reuse its pair masks — a one-time pad reused)
                self._cohort = self._next_cohort
                self._next_cohort += 1
                rnd = RoundInfo(version, group, list(group), [])
                msgs = work.client_round(self, state, rnd)
                self._annotate_masks(msgs, version)
                for m in msgs:
                    d = self._draw(m.client)
                    heapq.heappush(heap, (self.now + d.delay, seq,
                                          m.client,
                                          None if d.dropped else m,
                                          version))
                    if tr:
                        open_spans[seq] = tr.begin(
                            "client.compute", track=f"c{m.client}",
                            t=self.now, version=version)
                    seq += 1
                continue
            if not heap:
                raise RuntimeError("async runtime stalled: no client "
                                   "ready and nothing in flight")
            t, s, client, msg, v0 = heapq.heappop(heap)
            self.now = max(self.now, t)
            if msg is None:          # upload lost in transit: the bytes
                if tr:                # were spent; the client retries
                    tr.end(open_spans.pop(s), t=self.now, dropped=True)
                    tr.instant("fed.drop", track=f"c{client}",
                               t=self.now, version=version,
                               reason="transit")
                    tr.metrics.inc("msgs_dropped")
                ready.append(client)
                continue              # on the then-current model
            msg.staleness = version - v0
            if tr:
                tr.end(open_spans.pop(s), t=self.now,
                       staleness=msg.staleness)
            buffer.append(msg)
            if len(buffer) < K:
                continue
            # reconcile masks before discounting: cohort members absent
            # from this buffer (still in flight, dropped, or already
            # aggregated earlier) get their pair terms reconstructed
            # and subtracted; in-buffer cohort peers share a dispatch
            # (same staleness), so their surviving mutual terms scale
            # together and still cancel
            self._recover_masks(buffer, version)
            for m in buffer:
                if m.staleness > 0:  # same stale-update discounting as
                    # the sync loop's straggler path (payload scaling
                    # holds under any aggregator normalization)
                    f = self.stale_discount ** m.staleness
                    m.payload = jax.tree.map(lambda x: x * f, m.payload)
            arrived = sorted(m.client for m in buffer)
            rnd = RoundInfo(version, arrived, arrived, [])
            state = agg.aggregate(self, state, buffer, rnd)
            self._timeline_record(version, buffer)
            if tr:
                tr.instant("fed.aggregate", track="server", t=self.now,
                           version=version, n_msgs=len(buffer),
                           staleness=[m.staleness for m in buffer],
                           bytes=sum(m.nbytes for m in buffer))
                tr.metrics.observe("round_s",
                                   max(self.now - last_agg_t, 0.0))
                last_agg_t = self.now
            version += 1
            ready.extend(m.client for m in buffer)
            buffer = []
            if self._dp_budget_hit(version - 1):
                break
        if tr:
            # the run stops mid-flight once `rounds` aggregations land;
            # truncate still-open compute spans at the final clock so
            # traces never leak open spans (tests/test_obs.py)
            for s in sorted(open_spans, reverse=True):
                tr.end(open_spans.pop(s), t=self.now, inflight=True)
        return state


# --- population-scale sharded runtime -----------------------------------------

@dataclass
class ShardedFedRuntime:
    """Client-axis-sharded federated engine with hierarchical silo
    aggregation.

    Instead of per-client :class:`ClientMsg` objects, the whole
    federation lives in stacked pytrees with a leading
    ``(n_clients, ...)`` axis, sharded over a 1-D ``('clients',)`` mesh
    (``mesh`` accepts a :class:`jax.sharding.Mesh` or a
    ``repro.launch.mesh.MESHES`` spec string — ``None``/"single" runs
    the identical jitted program on one device).  One jitted round:

    1. vmapped local training — ``local_fn(params, x_i, y_i) → delta_i``
       runs for every client, per-device shards in parallel;
    2. **silo tier** — clients group contiguously into ``n_silos``
       equal silos; each silo mean-reduces its clients' deltas (a
       shard-local reduction when silos align with device boundaries);
    3. **server tier** — silo partials combine (uniform mean over
       equal-size silos, exactly the registry strategy's weighting for
       equal shards) and pass through the strategy's server optimizer
       (fedavgm / fedadam state lives inside the jitted step).

    Semantics are the sync engine's under iid + full participation +
    plain transport, and ``benchmarks/fed_scale_bench.py --smoke``
    gates mesh-vs-single-device parity in CI.  Reduction *order* does
    differ (a silo tree-reduce vs one flat mean), so parity is gated at
    a documented float32 tolerance (``PARITY_ATOL``), not bit-exactness
    — see docs/ARCHITECTURE.md §Sharded federation.

    The ledger is per aggregation **tier**, computed purely from
    shape/dtype metadata (``jax.eval_shape`` — never a device-to-host
    gather; regression-tested in ``tests/test_shard_fed.py``):
    ``n_silos > 1`` logs 'edge' (client↔silo) and 'wan' (silo↔server)
    events per round; ``n_silos == 1`` is the flat star every client
    crossing the WAN to the server.  Transports are restricted to
    bytes-level layers (framing): float-transform layers are per-client
    Python and would defeat the point of sharding.
    """
    n_clients: int
    rounds: int
    n_silos: int = 1
    mesh: Any = None
    strategy: Any = "fedavg"
    transport: Any = "plain"
    seed: int = 0
    comm: CommLog = field(default_factory=CommLog)
    timer: Timer = field(default_factory=Timer)
    #: ``None`` resolves to the ambient tracer; per-round spans use the
    #: process wall clock (this runtime has no virtual clock) and the
    #: per-tier byte events come from the same metadata-only plan the
    #: ledger uses — tracing never adds a device-to-host gather
    #: (regression-tested in tests/test_obs.py).
    tracer: Any = None

    #: documented mesh-vs-single-device parity tolerance (float32): the
    #: silo tree-reduce reorders the cross-client sum, which perturbs
    #: each round's mean by O(eps * n_clients^0.5) relative ulps.
    PARITY_ATOL = 1e-6

    def __post_init__(self):
        from repro.launch.mesh import get_fed_mesh
        from repro.sharding.rules import ShardingCtx, rules_for_phase
        if self.n_silos < 1 or self.n_clients % self.n_silos:
            raise ValueError(
                f"n_silos={self.n_silos} must divide n_clients="
                f"{self.n_clients} (contiguous equal silos)")
        self.mesh = get_fed_mesh(self.mesh)
        self.ctx = (ShardingCtx(mesh=self.mesh,
                                rules=rules_for_phase("fed"))
                    if self.mesh is not None else ShardingCtx.null())
        if isinstance(self.strategy, str):
            self.strategy = get_strategy(self.strategy)
        self.transport = get_transport(self.transport)
        self.transport.require_bytes_only("sharded")
        if self.tracer is None:
            self.tracer = _ambient_tracer()

    @property
    def n_devices(self) -> int:
        return self.mesh.size if self.mesh is not None else 1

    # -- placement ---------------------------------------------------------

    def place(self, tree):
        """Place a stacked client-axis pytree: axis 0 sharded over
        'clients' (degrading to replication when n_clients does not
        divide the mesh — ``FED_RULES`` via ``ShardingCtx``)."""
        def put(x):
            x = jnp.asarray(x)
            sh = self.ctx.sharding(
                ["clients"] + [None] * (x.ndim - 1), x.shape)
            return x if sh is None else jax.device_put(x, sh)
        return jax.tree.map(put, tree)

    # -- the jitted hierarchical round -------------------------------------

    def build_round(self, local_fn: Callable) -> Callable:
        """``round_fn(params, server_state, xs, ys) → (params,
        server_state)``, one jitted call for all clients and both
        aggregation tiers."""
        n_silos = self.n_silos
        per_silo = self.n_clients // n_silos
        ctx, strat = self.ctx, self.strategy

        def silo_reduce(d):
            d = ctx.constrain(d, "clients", *[None] * (d.ndim - 1))
            s = d.reshape((n_silos, per_silo) + d.shape[1:])
            return s.mean(axis=1)

        def round_fn(params, server_state, xs, ys):
            deltas = jax.vmap(local_fn, in_axes=(None, 0, 0))(
                params, xs, ys)
            silo = jax.tree.map(silo_reduce, deltas)      # (n_silos, …)
            mean = jax.tree.map(lambda s: s.mean(axis=0), silo)
            upd, server_state = strat.server_update(server_state, mean)
            params = jax.tree.map(lambda g, u: g + u, params, upd)
            return params, server_state

        return jax.jit(round_fn)

    # -- tiered ledger (metadata only) -------------------------------------

    def _tier_plan(self, local_fn, params, xs, ys) -> List[tuple]:
        """Per-round ledger events from ``jax.eval_shape`` metadata —
        the payloads themselves are never gathered to host."""
        pstruct = jax.eval_shape(lambda p: p, params)
        row = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape[1:],
                                                          a.dtype), xs)
        yrow = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape[1:],
                                                           a.dtype), ys)
        dstruct = jax.eval_shape(local_fn, pstruct, row, yrow)
        pb = pytree_bytes(pstruct) + self.transport.frame_overhead
        ub = pytree_bytes(dstruct) + self.transport.frame_overhead
        n, s = self.n_clients, self.n_silos
        if s == 1:  # flat star: every client crosses the WAN
            return [("c*", "down", n * pb, "model", "wan"),
                    ("c*", "up", n * ub, "update", "wan")]
        return [("s*", "down", s * pb, "model", "wan"),
                ("c*", "down", n * pb, "model", "edge"),
                ("c*", "up", n * ub, "update", "edge"),
                ("s*", "up", s * ub, "update", "wan")]

    # -- the round loop ----------------------------------------------------

    def run(self, local_fn: Callable, params, xs, ys,
            eval_fn: Optional[Callable] = None):
        """Run ``rounds`` hierarchical rounds.

        ``xs``/``ys`` are stacked client-axis arrays (leading dim
        ``n_clients``) — e.g. from ``repro.data.cohort.build_cohort``;
        ``eval_fn(params) → dict`` (optional) is recorded per round.
        Returns ``(params, history)``."""
        xs, ys = self.place(xs), self.place(ys)
        plan = self._tier_plan(local_fn, params, xs, ys)
        round_fn = self.build_round(local_fn)
        server_state = self.strategy.init_state(params)
        history: List[Dict] = []
        tr = self.tracer
        for r in range(self.rounds):
            t0 = time.perf_counter() if tr else 0.0
            with self.timer:
                params, server_state = round_fn(params, server_state,
                                                xs, ys)
                jax.block_until_ready(params)
            for client, direction, nbytes, what, tier in plan:
                self.comm.log(r, client, direction, nbytes, what,
                              tier=tier)
            if tr:  # spans from the same metadata-only plan as the
                # ledger — never a device-to-host gather
                t1 = time.perf_counter()
                tr.span_at("fed.round", t0, t1, track="server", round=r,
                           n_clients=self.n_clients,
                           n_silos=self.n_silos)
                tr.metrics.observe("round_s", t1 - t0)
                for client, direction, nbytes, what, tier in plan:
                    tr.instant("fed.tier", track=f"tier:{tier}", t=t1,
                               round=r, client=client,
                               direction=direction, bytes=nbytes,
                               what=what)
                    tr.metrics.inc("bytes_up" if direction == "up"
                                   else "bytes_down", nbytes)
            if eval_fn is not None:
                history.append(dict(eval_fn(params), round=r))
        return params, history
