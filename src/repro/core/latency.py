"""Client latency / availability models for the virtual-time runtime.

Real cross-silo federations (the FedCVD++ setting) pair a handful of
well-resourced hospitals with sites on shared clusters and flaky links:
a synchronous round idles on the slowest site, and the async runtime
(``repro.core.runtime`` ``--schedule async:K``) exists to quantify that.
Both schedules need the same ingredient — a per-client model of how long
one local round takes on the (virtual) wall clock, and whether the
resulting upload ever arrives.

A model maps ``(client, k)`` — the client's *k*-th dispatch — to a
:class:`Draw` (virtual seconds + a dropped flag).  Draws are pure
functions of ``(seed, client, k)``: the same spec + seed replays the
same trace regardless of event-processing order, which is what makes
async runs deterministic and resumable.

Select by name through :data:`LATENCY` / :func:`get_latency`.  Spec
strings carry parameters after colons and compose with ``+`` (delays
add; a dispatch is dropped if *any* component drops)::

    constant              every round takes 1.0 virtual seconds
    constant:3.5          ... or a fixed 3.5 s
    lognormal:0:0.5       heavy-tailed per-dispatch delay exp(N(mu, sigma))
    trace:lat.json        per-client delays from a recorded trace file
    dropout:0.1           the upload is lost with p=0.1 (delay 0)
    lognormal:0:1+dropout:0.05   heterogeneous compute AND a lossy uplink

``trace`` files are JSON: either a list (``[1.0, 4.0, 2.5]`` — constant
per-client delay, indexed modulo clients) or a dict of per-client delay
sequences (``{"0": [1.0, 1.2], "1": [4.0]}`` — cycled over dispatches).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclass
class Draw:
    """One dispatch's fate: the local round occupies ``delay`` virtual
    seconds; ``dropped`` means the upload never reaches the server (the
    client still computed and re-enters the dispatch pool)."""
    delay: float
    dropped: bool = False


@dataclass(frozen=True)
class LatencyModel:
    """A named model: ``draw(client, k)`` → :class:`Draw` for the
    client's k-th dispatch, deterministic in the construction seed."""
    name: str
    draw_fn: Callable[[int, int], Draw]

    def draw(self, client: int, k: int) -> Draw:
        return self.draw_fn(client, k)


def _rng(seed: int, comp: int, client: int, k: int) -> np.random.Generator:
    # keyed per (component, client, dispatch): draws are order-free
    return np.random.default_rng([seed, 0x1A7, comp, client, k])


def _constant(t: float = 1.0):
    def make(seed: int, comp: int) -> Callable[[int, int], Draw]:
        return lambda client, k: Draw(float(t))
    return make


def _lognormal(mu: float = 0.0, sigma: float = 0.5):
    def make(seed: int, comp: int):
        def draw(client, k):
            return Draw(float(_rng(seed, comp, client, k)
                              .lognormal(mu, sigma)))
        return draw
    return make


def _dropout(p: float):
    def make(seed: int, comp: int):
        def draw(client, k):
            return Draw(0.0,
                        dropped=bool(_rng(seed, comp, client, k).random()
                                     < p))
        return draw
    return make


def _trace(path: str):
    """Per-client delays from a recorded JSON trace (list: one constant
    delay per client, indexed modulo; dict: per-client sequences cycled
    over dispatches)."""
    with open(path) as f:
        data = json.load(f)
    if not data:
        raise ValueError(f"trace {path!r} is empty")

    keys = sorted(data) if isinstance(data, dict) else None

    def make(seed: int, comp: int):
        def draw(client, k):
            if keys is not None:
                # exact client key if recorded, else cycle over the
                # recorded clients (keys need not be contiguous)
                key = (str(client) if str(client) in data
                       else keys[client % len(keys)])
                seq = data[key]
                if not seq:
                    raise KeyError(f"trace {path!r}: empty delay "
                                   f"sequence for client key {key!r}")
                return Draw(float(seq[k % len(seq)]))
            return Draw(float(data[client % len(data)]))
        return draw
    return make


#: model name -> factory(*args) -> (seed, component_idx) -> draw fn.
#: Resolved via :func:`get_latency` spec strings, composable with '+'
#: ("lognormal:0:1+dropout:0.05").
LATENCY: Dict[str, Callable] = {
    "constant": _constant,
    "lognormal": _lognormal,
    "trace": _trace,
    "dropout": _dropout,
}


def get_latency(spec, seed: int = 0) -> Optional[LatencyModel]:
    """Resolve a latency model from a spec string (or pass one through).

    ``None`` / ``"none"`` / ``"zero"`` mean no model: zero delay, no
    drops — the bit-exact-reduction default."""
    if spec is None or isinstance(spec, LatencyModel):
        return spec
    text = str(spec)
    if text in ("none", "zero", ""):
        return None
    draws: List[Callable[[int, int], Draw]] = []
    for comp, part in enumerate(text.split("+")):
        tokens = part.strip().split(":")
        name, args = tokens[0], tokens[1:]
        if name not in LATENCY:
            raise KeyError(f"unknown latency model {part!r} in {spec!r}; "
                           f"available: {sorted(LATENCY)} (spec: "
                           f"name[:arg...], composed with '+')")
        coerced = [a if name == "trace" else float(a) for a in args]
        try:
            draws.append(LATENCY[name](*coerced)(seed, comp))
        except TypeError as e:
            raise ValueError(f"bad latency spec {part!r}: {e}") from e

    def combined(client: int, k: int) -> Draw:
        delay, dropped = 0.0, False
        for d in draws:
            out = d(client, k)
            delay += out.delay
            dropped = dropped or out.dropped
        return Draw(delay, dropped)

    return LatencyModel(text, combined)
