"""XGBoost-based feature extraction for lightweight federated ensembles
(paper C3): clients fit a local GBDT, rank features by gain importance,
train a small shallow-tree ensemble on the top-p features, and ship only
that. The server predicts by data-size-weighted voting:
f(x) = sum |D_i|/|D| T_i(x).  (The paper's own comm table — 6.9 MB shipped
vs 22.3 MB dense, 3.2x — implies the shallow model is a reduced ensemble,
not a single tree; see docs/EXPERIMENTS.md.)

A dense federated-XGBoost baseline (every boosted tree shipped, clients'
margins averaged) is implemented alongside so the 3.2x reduction is a
measured before/after.

Both protocols are one-shot rounds on the shared
:class:`~repro.core.runtime.FedRuntime` (``cfg.participation`` selects
the contributing clients, ``cfg.transport`` applies size-level wire
layers to the shipped ensembles).

Local boosting runs under two engines (``FedXGBConfig.engine``):
``"batched"`` (default) pads client shards to a common length and boosts
every client in lockstep through ``gbdt.fit_batched`` — one vmapped
``grow_tree`` per round, client-batched histograms — while
``"sequential"`` keeps the per-client ``gbdt.fit`` loop as the parity
reference.  For *exact* federated GBDT over shared bins (histograms
shipped instead of trees) see ``repro.core.fed_hist``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import binary_metrics
from repro.core.runtime import ClientMsg, ClientWork, FedRuntime, ServerAgg
from repro.data import sampling as S
from repro.trees import binning, gbdt
from repro.trees.growth import nbytes


@dataclass
class FedXGBConfig:
    num_rounds: int = 50
    depth: int = 6
    shallow_depth: int = 4
    shallow_rounds: int = 0      # 0 -> num_rounds // 3 (the paper's own
    # comm numbers — 6.9 MB vs 22.3 MB, a 3.2x cut — imply the shipped
    # "shallow" model is a small boosted ensemble, not a single tree)
    top_features: int = 8
    n_bins: int = 64
    learning_rate: float = 0.3
    sampling: str = "none"
    hist_impl: str = "auto"      # histogram kernel routing: auto | pallas
    # | pallas_interpret | xla (see repro.kernels.hist.ops)
    engine: str = "batched"      # 'batched' (client-axis vmap) |
    # 'sequential' (per-client loop — the parity reference)
    participation: str = "full"  # repro.core.participation spec
    transport: str = "plain"     # size-level layers only (framing)
    schedule: str = "sync"       # repro.core.runtime.SCHEDULES spec
    latency: Optional[str] = None  # repro.core.latency.LATENCY spec
    seed: int = 0

    @property
    def shallow_rounds_(self) -> int:
        return self.shallow_rounds or max(self.num_rounds // 3, 1)


def _prep_batched(sampled, n_bins: int):
    """Per-client local bins + padding onto the client axis, computed
    once per training run (the full-depth and shallow passes reuse it)."""
    n_max = max(len(ys) for _, ys in sampled)
    x_l, y_l, bins_l, edges_l, w_l = [], [], [], [], []
    for xs, ys in sampled:
        xs = jnp.asarray(xs, jnp.float32)
        n = len(ys)
        edges = binning.fit_bins(xs, n_bins)
        pad = n_max - n
        x_l.append(jnp.pad(xs, ((0, pad), (0, 0))))
        y_l.append(jnp.pad(jnp.asarray(ys, jnp.float32), (0, pad)))
        bins_l.append(jnp.pad(binning.apply_bins(xs, edges),
                              ((0, pad), (0, 0))))
        edges_l.append(edges)
        w_l.append(jnp.pad(jnp.ones(n, jnp.float32), (0, pad)))
    return tuple(jnp.stack(a) for a in (x_l, y_l, bins_l, edges_l, w_l))


def _fit_clients(sampled, cfg: FedXGBConfig, *, num_rounds: int,
                 depth: int,
                 feature_masks: Optional[List[np.ndarray]] = None,
                 prepped=None) -> List[gbdt.GBDT]:
    """Fit one local GBDT per client under the configured engine.

    Both engines see identical per-client (edges, bins); the batched
    path pads shards to a common length (pad rows carry zero sample
    weight, via ``prepped`` = ``_prep_batched(sampled, ...)``) and
    boosts all clients in lockstep."""
    if cfg.engine == "sequential":
        out = []
        for i, (xs, ys) in enumerate(sampled):
            fm = (None if feature_masks is None
                  else jnp.asarray(feature_masks[i]))
            out.append(gbdt.fit(jnp.asarray(xs), jnp.asarray(ys),
                                num_rounds=num_rounds, depth=depth,
                                n_bins=cfg.n_bins,
                                learning_rate=cfg.learning_rate,
                                feature_mask=fm,
                                hist_impl=cfg.hist_impl))
        return out
    if cfg.engine != "batched":
        raise ValueError(f"unknown engine {cfg.engine!r}; "
                         "use 'batched' or 'sequential'")
    x_c, y_c, bins_c, edges_c, w_c = (prepped if prepped is not None
                                      else _prep_batched(sampled,
                                                         cfg.n_bins))
    fm = (None if feature_masks is None
          else jnp.asarray(np.stack(feature_masks)))
    return gbdt.fit_batched(x_c, y_c, bins_c, edges_c, w_c,
                            num_rounds=num_rounds,
                            depth=depth, n_bins=cfg.n_bins,
                            learning_rate=cfg.learning_rate,
                            feature_mask=fm, hist_impl=cfg.hist_impl)


@dataclass
class FeatureExtractEnsemble:
    trees: List[gbdt.GBDT]       # one shallow boosted ensemble per client
    weights: List[float]         # |D_i| / |D|
    base_margins: List[float]
    top_features: List[np.ndarray]


@dataclass
class _XGBWork(ClientWork, ServerAgg):
    """Shared one-shot scaffolding for both C3 protocols: ``mode='fe'``
    ships the shallow feature-extracted ensemble, ``mode='dense'`` ships
    the full boosted ensemble."""
    clients: Sequence
    cfg: FedXGBConfig
    mode: str = "fe"
    fed_stats: object = None

    def setup(self, rt: FedRuntime):
        rt.transport.require_bytes_only("feature_extract")
        cfg = self.cfg
        self.sampled = [S.apply_strategy(cfg.sampling, x, y, cfg.seed + i,
                                         fed_stats=self.fed_stats)
                        for i, (x, y) in enumerate(self.clients)]
        return {"model": None}

    def client_round(self, rt, state, rnd):
        cfg = self.cfg
        shards = [self.sampled[i] for i in rnd.computing]
        prepped = (_prep_batched(shards, cfg.n_bins)
                   if cfg.engine == "batched" else None)
        locals_ = _fit_clients(shards, cfg, num_rounds=cfg.num_rounds,
                               depth=cfg.depth, prepped=prepped)
        if self.mode == "dense":
            ship, extras = locals_, [0] * len(locals_)
        else:
            masks, tops = [], []
            for (xs, _), local in zip(shards, locals_):
                phi = np.asarray(gbdt.feature_importance(local))
                top = np.argsort(-phi)[:cfg.top_features]
                mask = np.zeros(xs.shape[1], np.float32)
                mask[top] = 1.0
                masks.append(mask)
                tops.append(top)
            ship = _fit_clients(shards, cfg,
                                num_rounds=cfg.shallow_rounds_,
                                depth=cfg.shallow_depth,
                                feature_masks=masks, prepped=prepped)
            # keyed by global client id: the aggregation cohort need not
            # equal the dispatch cohort (async buffered aggregation)
            by_client = dict(getattr(self, "tops", {}))
            by_client.update(zip(rnd.computing, tops))
            self.tops = by_client
            extras = [4 + 4 * len(t) for t in tops]  # count + feature ids
        msgs = []
        for slot, i in enumerate(rnd.computing):
            model = ship[slot]
            wire = rt.encode(model, nbytes=nbytes(model.forest)
                             + extras[slot], round_idx=rnd.index,
                             client=i, slot=slot,
                             n_active=len(rnd.computing))
            what = "gbdt" if self.mode == "dense" else "shallow-gbdt"
            rt.log_up(rnd.index, i, wire.nbytes, what)
            msgs.append(ClientMsg(i, model, wire.nbytes,
                                  weight=len(self.clients[i][1]),
                                  what=what))
        return msgs

    def aggregate(self, rt, state, msgs, rnd):
        total = sum(m.weight for m in msgs)
        models = [m.payload for m in msgs]
        weights = [m.weight / total for m in msgs]
        with rt.timer:
            pass  # aggregation is a concat; vote happens at predict time
        down = sum(nbytes(m.forest) for m in models) \
            + (8 * len(models) if self.mode == "fe" else 0)
        for i in range(len(self.clients)):
            rt.log_down(rnd.index, i, down, "ensemble")
        if self.mode == "dense":
            state["model"] = FedXGBEnsemble(models, weights)
        else:
            state["model"] = FeatureExtractEnsemble(
                models, weights, [m.base_margin for m in models],
                [self.tops[m.client] for m in msgs])
        return state

    def finalize(self, rt, state):
        return state["model"]


def _run_one_shot(clients, cfg: FedXGBConfig, mode: str, fed_stats=None):
    work = _XGBWork(clients, cfg, mode, fed_stats)
    rt = FedRuntime(n_clients=len(clients), rounds=1,
                    participation=cfg.participation,
                    transport=cfg.transport, schedule=cfg.schedule,
                    latency=cfg.latency, seed=cfg.seed,
                    allow_stale=False)
    model = rt.run(work)
    return model, rt.comm, rt.timer


def train_federated_xgb_fe(clients: Sequence[Tuple[np.ndarray, np.ndarray]],
                           cfg: FedXGBConfig, fed_stats=None):
    """Returns (ensemble, comm, timer)."""
    return _run_one_shot(clients, cfg, "fe", fed_stats)


def score_fe(ens: FeatureExtractEnsemble, x) -> np.ndarray:
    """Data-size-weighted vote probability in [0,1]."""
    xj = jnp.asarray(x)
    score = np.zeros(x.shape[0])
    for model, w in zip(ens.trees, ens.weights):
        p = jax.nn.sigmoid(gbdt.predict_margin(model, xj))
        score += w * np.asarray(p)
    return score


def predict_fe(ens: FeatureExtractEnsemble, x) -> np.ndarray:
    return score_fe(ens, x) > 0.5


def evaluate_fe(ens, x, y):
    scores = score_fe(ens, x)
    return binary_metrics(scores > 0.5, y, scores=scores)


# --- dense federated XGBoost baseline ----------------------------------------

@dataclass
class FedXGBEnsemble:
    models: List[gbdt.GBDT]
    weights: List[float]


def train_federated_xgb(clients, cfg: FedXGBConfig, fed_stats=None):
    """Every client ships its full boosted ensemble; margins averaged
    (data-size weighted). The paper's 'Federated XGBoost' rows."""
    return _run_one_shot(clients, cfg, "dense", fed_stats)


def margin_fed_xgb(ens: FedXGBEnsemble, x) -> np.ndarray:
    xj = jnp.asarray(x)
    margin = np.zeros(x.shape[0])
    for m, w in zip(ens.models, ens.weights):
        margin += w * np.asarray(gbdt.predict_margin(m, xj))
    return margin


def predict_fed_xgb(ens: FedXGBEnsemble, x) -> np.ndarray:
    return margin_fed_xgb(ens, x) > 0


def evaluate_fed_xgb(ens, x, y):
    margin = margin_fed_xgb(ens, x)
    return binary_metrics(margin > 0, y,
                          scores=1.0 / (1.0 + np.exp(-margin)))
