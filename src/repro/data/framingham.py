"""Synthetic statistical twin of the Framingham CHD dataset.

DATA GATE (DESIGN.md): the Kaggle CSV (dileep070/heart-disease-prediction-
using-logistic-regression) is unavailable offline. This generator matches
the published dataset card: n=4,238, 15 clinical attributes, 15.2 %
TenYearCHD-positive, and induces the paper's Table-1 feature-importance
ordering through a calibrated logit teacher with non-linear terms (so
tree models genuinely outperform linear ones, as in the paper's tables).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

FEATURES = [
    "male", "age", "education", "currentSmoker", "cigsPerDay", "BPMeds",
    "prevalentStroke", "prevalentHyp", "diabetes", "totChol", "sysBP",
    "diaBP", "BMI", "heartRate", "glucose",
]

# Table-1 importance scores (paper) for the features it lists; education/BMI
# (present in the Kaggle schema, absent from Table 1) get small weights.
IMPORTANCE = {
    "age": 0.89, "sysBP": 0.82, "glucose": 0.78, "totChol": 0.75,
    "diaBP": 0.66, "heartRate": 0.47, "male": 0.41, "currentSmoker": 0.38,
    "cigsPerDay": 0.34, "prevalentHyp": 0.32, "diabetes": 0.30,
    "BPMeds": 0.29, "prevalentStroke": 0.24, "education": 0.10, "BMI": 0.15,
}


# teacher mix calibration (see synthesize())
LIN_SCALE = 0.5
NONLIN_SCALE = 2.0


@dataclass
class Dataset:
    x: np.ndarray          # (n, 15) float32, standardized
    y: np.ndarray          # (n,) float32 {0,1}
    raw: np.ndarray        # (n, 15) unstandardized
    feature_names: List[str]


def raw_columns(rng: np.random.Generator, n: int) -> np.ndarray:
    """The twin's unstandardized feature matrix, ``(n, 15)`` in
    :data:`FEATURES` order.  One rng, fixed draw order — both
    :func:`synthesize` and the population-scale cohort generator
    (``repro.data.cohort``) draw through this single function, so their
    marginals agree by construction."""
    cols: Dict[str, np.ndarray] = {}
    cols["male"] = (rng.random(n) < 0.43).astype(np.float64)
    cols["age"] = np.clip(rng.normal(49.6, 8.6, n), 32, 70)
    cols["education"] = rng.choice([1, 2, 3, 4], n,
                                   p=[0.42, 0.30, 0.17, 0.11]).astype(float)
    cols["currentSmoker"] = (rng.random(n) < 0.49).astype(np.float64)
    cols["cigsPerDay"] = cols["currentSmoker"] * np.clip(
        rng.normal(18, 12, n), 1, 70)
    cols["BPMeds"] = (rng.random(n) < 0.03).astype(np.float64)
    cols["prevalentStroke"] = (rng.random(n) < 0.006).astype(np.float64)
    cols["prevalentHyp"] = (rng.random(n) < 0.31).astype(np.float64)
    cols["diabetes"] = (rng.random(n) < 0.026).astype(np.float64)
    cols["totChol"] = np.clip(rng.normal(237, 45, n), 110, 600)
    sys_bp = np.clip(rng.normal(132, 22, n)
                     + 14 * cols["prevalentHyp"], 85, 295)
    cols["sysBP"] = sys_bp
    cols["diaBP"] = np.clip(0.45 * sys_bp + rng.normal(23, 8, n), 48, 143)
    cols["BMI"] = np.clip(rng.normal(25.8, 4.1, n), 15, 57)
    cols["heartRate"] = np.clip(rng.normal(75.9, 12, n), 44, 143)
    cols["glucose"] = np.clip(rng.normal(82, 24, n)
                              + 80 * cols["diabetes"], 40, 400)
    return np.stack([cols[f] for f in FEATURES], axis=1)


def teacher_parts(z: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic halves of the logit teacher on standardized
    features: ``(lin, nonlin)`` scores, no rng."""
    # logit teacher: linear part proportional to Table-1 importances
    w = np.array([IMPORTANCE[f] for f in FEATURES])
    sign = np.ones(len(FEATURES))
    sign[FEATURES.index("education")] = -1.0
    lin = LIN_SCALE * (z @ (w * sign))
    zi = {f: z[:, FEATURES.index(f)] for f in FEATURES}
    nonlin = NONLIN_SCALE * (
        0.55 * zi["age"] * zi["sysBP"]
        + 0.45 * zi["currentSmoker"] * np.maximum(zi["cigsPerDay"], 0)
        + 0.65 * np.maximum(zi["glucose"] - 1.0, 0.0) * 2.0
        + 0.40 * np.maximum(zi["sysBP"] - 1.2, 0.0) * 2.0
        + 0.35 * zi["male"] * zi["age"])
    return lin, nonlin


def synthesize(n: int = 4238, positive_rate: float = 0.152,
               seed: int = 0, noise: float = 0.3) -> Dataset:
    rng = np.random.default_rng(seed)
    raw = raw_columns(rng, n)
    mu, sd = raw.mean(0), raw.std(0) + 1e-9
    z = (raw - mu) / sd

    # calibration (docs/EXPERIMENTS.md §Methodology): LIN_SCALE/NONLIN_SCALE/
    # noise are set so that on the twin, centralized XGBoost lands at the
    # paper's F1=0.78 while linear models trail trees as in the paper.
    lin, nonlin = teacher_parts(z)
    score = lin + nonlin + rng.normal(0, noise, n) * np.sqrt(
        lin.var() + nonlin.var())
    thr = np.quantile(score, 1 - positive_rate)
    y = (score > thr).astype(np.float32)
    return Dataset(z.astype(np.float32), y, raw.astype(np.float32),
                   list(FEATURES))


def train_test_split(ds: Dataset, train_frac: float = 0.8,
                     seed: int = 0) -> Tuple[Dataset, Dataset]:
    rng = np.random.default_rng(seed + 1)
    idx = rng.permutation(len(ds.y))
    cut = int(train_frac * len(ds.y))
    tr, te = idx[:cut], idx[cut:]
    mk = lambda ii: Dataset(ds.x[ii], ds.y[ii], ds.raw[ii],
                            ds.feature_names)
    return mk(tr), mk(te)


def partition_clients(ds: Dataset, n_clients: int = 3, seed: int = 0,
                      alpha: float = 0.0) -> List[Dataset]:
    """Stratified even split (paper's setup); alpha>0 -> Dirichlet non-IID.

    Thin shim over the partitioner registry
    (``repro.data.partition.PARTITIONERS``): alpha<=0 -> ``iid``,
    alpha>0 -> ``dirichlet``.  The ``seed + 2`` offset preserves the
    historical rng stream so shards are bit-identical to earlier PRs."""
    from repro.data import partition as P
    if alpha <= 0:
        return P.partition_dataset("iid", ds, n_clients, seed=seed + 2)
    return P.partition_dataset("dirichlet", ds, n_clients, seed=seed + 2,
                               alpha=alpha)
