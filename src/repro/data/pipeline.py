"""LM-side data pipeline: synthetic token corpora, sharded batch iterators,
per-pod (federated-client) partitioning, and the fed-SMOTE analog for LM
pods — mixture-weight synchronization of the per-pod data sampler through
sufficient statistics (DESIGN.md §Beyond-the-paper).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np


@dataclass
class CorpusConfig:
    vocab_size: int
    n_domains: int = 4          # synthetic "domains" with distinct unigram
    zipf_a: float = 1.2
    seed: int = 0


class SyntheticCorpus:
    """Zipfian token streams with domain structure so that (a) loss actually
    decreases under training and (b) per-pod mixtures can differ (non-IID)."""

    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        base = ranks ** -cfg.zipf_a
        self.domain_probs = []
        for d in range(cfg.n_domains):
            perm = rng.permutation(V)
            p = base[perm]
            self.domain_probs.append(p / p.sum())

    def sample_tokens(self, n: int, mixture: np.ndarray,
                      seed: int) -> np.ndarray:
        """Markov-ish stream: domain chosen per 64-token span."""
        rng = np.random.default_rng(seed)
        out = np.empty(n, np.int64)
        span = 64
        for i in range(0, n, span):
            d = rng.choice(self.cfg.n_domains, p=mixture)
            m = min(span, n - i)
            out[i:i + m] = rng.choice(self.cfg.vocab_size, size=m,
                                      p=self.domain_probs[d])
        return out


def lm_batches(corpus: SyntheticCorpus, batch: int, seq: int,
               mixture: Optional[np.ndarray] = None, seed: int = 0
               ) -> Iterator[Dict[str, np.ndarray]]:
    mix = (mixture if mixture is not None
           else np.ones(corpus.cfg.n_domains) / corpus.cfg.n_domains)
    step = 0
    while True:
        toks = corpus.sample_tokens(batch * (seq + 1), mix,
                                    seed * 100003 + step)
        toks = toks.reshape(batch, seq + 1).astype(np.int32)
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:],
               "mask": np.ones((batch, seq), np.float32)}
        step += 1


def pod_mixtures(n_pods: int, n_domains: int, alpha: float = 0.5,
                 seed: int = 0) -> List[np.ndarray]:
    """Dirichlet non-IID domain mixtures, one per pod (hospital)."""
    rng = np.random.default_rng(seed)
    return [rng.dirichlet([alpha] * n_domains) for _ in range(n_pods)]


def sync_mixtures(mixtures: List[np.ndarray]) -> np.ndarray:
    """The fed-SMOTE analog at LM scale: pods share their domain-frequency
    sufficient statistics; the synchronized sampler is the mean mixture
    (no raw data crosses pods)."""
    return np.mean(np.stack(mixtures), axis=0)
