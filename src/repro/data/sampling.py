"""Class-imbalance resolution: ROS, RUS, SMOTE (local), and the paper's
federated SMOTE synchronization (C4) via shared sufficient statistics."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def ros(x, y, seed: int = 0):
    """Random oversampling of the minority class to parity."""
    rng = np.random.default_rng(seed)
    pos, neg = np.where(y == 1)[0], np.where(y == 0)[0]
    mino, majo = (pos, neg) if len(pos) < len(neg) else (neg, pos)
    extra = rng.choice(mino, size=len(majo) - len(mino), replace=True)
    idx = np.concatenate([np.arange(len(y)), extra])
    rng.shuffle(idx)
    return x[idx], y[idx]


def rus(x, y, seed: int = 0):
    """Random undersampling of the majority class to parity."""
    rng = np.random.default_rng(seed)
    pos, neg = np.where(y == 1)[0], np.where(y == 0)[0]
    mino, majo = (pos, neg) if len(pos) < len(neg) else (neg, pos)
    keep = rng.choice(majo, size=len(mino), replace=False)
    idx = np.concatenate([mino, keep])
    rng.shuffle(idx)
    return x[idx], y[idx]


def _knn_indices(xm: np.ndarray, kk: int, chunk: int = 256) -> np.ndarray:
    """Exact kNN over minority rows, (chunk, m) blocks at a time.

    Same arithmetic as the dense (m, m) distance matrix (per-element
    squared differences, row-wise argsort) but peak memory is
    O(chunk * m) instead of O(m^2) — large minority classes no longer
    materialize an m×m float64 array."""
    m = len(xm)
    nn = np.empty((m, kk), np.int64)
    for s in range(0, m, chunk):
        rows = xm[s:s + chunk]
        d2 = ((rows[:, None, :] - xm[None, :, :]) ** 2).sum(-1)
        d2[np.arange(len(rows)), np.arange(s, s + len(rows))] = np.inf
        nn[s:s + chunk] = np.argsort(d2, axis=1)[:, :kk]
    return nn


def smote(x, y, k: int = 5, seed: int = 0):
    """Classic SMOTE: synthesize minority points on kNN line segments."""
    rng = np.random.default_rng(seed)
    pos, neg = np.where(y == 1)[0], np.where(y == 0)[0]
    mino, majo = (pos, neg) if len(pos) < len(neg) else (neg, pos)
    need = len(majo) - len(mino)
    if need <= 0 or len(mino) < 2:
        return x, y
    xm = x[mino]
    kk = min(k, len(mino) - 1)
    nn = _knn_indices(xm, kk)                    # (m, k)
    base = rng.integers(0, len(mino), need)
    pick = nn[base, rng.integers(0, kk, need)]
    lam = rng.random((need, 1))
    synth = xm[base] + lam * (xm[pick] - xm[base])
    ys = np.full(need, y[mino[0]], y.dtype)
    return (np.concatenate([x, synth.astype(x.dtype)]),
            np.concatenate([y, ys]))


# --- federated SMOTE synchronization (paper C4) -----------------------------

def minority_stats(x, y) -> Tuple[np.ndarray, np.ndarray, int]:
    """Client-side: local minority-class mean/variance (the only thing
    shared with the server — never raw rows). Clients with <2 minority
    rows report zeros with count 0 (they contribute nothing to the
    aggregate — exactly the clients fed-SMOTE rescues)."""
    pos, neg = np.where(y == 1)[0], np.where(y == 0)[0]
    mino = pos if len(pos) < len(neg) else neg
    if len(mino) < 2:
        return (np.zeros(x.shape[1]), np.zeros(x.shape[1]), 0)
    xm = x[mino]
    return xm.mean(0), xm.var(0), len(mino)


def aggregate_stats(stats: List[Tuple[np.ndarray, np.ndarray, int]]):
    """Server-side: mu_g = mean(mu_i), sigma_g^2 = mean(sigma_i^2)
    (the paper's unweighted aggregation over contributing clients)."""
    live = [s for s in stats if s[2] > 0]
    if not live:
        raise ValueError("no client holds minority samples")
    mus = np.stack([s[0] for s in live])
    vars_ = np.stack([s[1] for s in live])
    return mus.mean(0), vars_.mean(0)


def fed_smote(x, y, mu_g, var_g, seed: int = 0):
    """Client-side: augment with synthetic minority draws from
    N(mu_g, sigma_g^2)."""
    rng = np.random.default_rng(seed)
    pos, neg = np.where(y == 1)[0], np.where(y == 0)[0]
    mino, majo = (pos, neg) if len(pos) < len(neg) else (neg, pos)
    need = len(majo) - len(mino)
    if need <= 0:
        return x, y
    synth = rng.normal(mu_g, np.sqrt(np.maximum(var_g, 1e-12)),
                       size=(need, x.shape[1]))
    label = 1.0 if len(pos) <= len(neg) else 0.0  # works at 0 local rows
    ys = np.full(need, label, y.dtype)
    return (np.concatenate([x, synth.astype(x.dtype)]),
            np.concatenate([y, ys]))


def stats_bytes(n_features: int) -> int:
    """Bytes shipped per client for fed-SMOTE sync (mu, var, count)."""
    return n_features * 4 * 2 + 4


def apply_strategy(name: str, x, y, seed: int = 0, fed_stats=None):
    if name in (None, "none"):
        return x, y
    if name == "ros":
        return ros(x, y, seed)
    if name == "rus":
        return rus(x, y, seed)
    if name == "smote":
        return smote(x, y, seed=seed)
    if name == "fed_smote":
        assert fed_stats is not None
        return fed_smote(x, y, fed_stats[0], fed_stats[1], seed)
    raise ValueError(name)
