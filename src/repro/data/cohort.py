"""Population-scale synthetic cohorts — inflate the Framingham twin to
10⁵–10⁶ clients for the sharded federated runtime.

The real twin (``repro.data.framingham``) is one pooled 4,238-row draw
that gets *partitioned* into a handful of hospital shards.  Population
scale needs the opposite construction: a registry of cohort specs
(:data:`COHORTS`) that *generates* per-client shards directly, so the
simulation's client axis can grow without ever materializing a pooled
table or re-drawing existing clients.

``framingham_like:n_clients:rows_per_client`` draws every client's rows
through the twin's own column generator and logit teacher
(:func:`~repro.data.framingham.raw_columns` /
:func:`~repro.data.framingham.teacher_parts`), standardized and labeled
against **reference statistics** fitted once on a 4,238-row reference
draw — per-feature mean/std, the teacher-score label threshold, and the
noise scale are population constants, so every client shares one
labeling function and the cohort is iid across clients by construction
(the non-IID axes stay the partitioners' job).

Determinism contract (property-tested in ``tests/test_cohort.py``):

* draws are keyed ``[seed, 0xC001, chunk]`` with a **fixed** generation
  chunk of :data:`CHUNK` clients — chunk ``i`` is always generated in
  full and sliced, so client ``c``'s rows depend only on
  ``(seed, rows_per_client, c)``: growing ``n_clients`` never changes
  earlier clients' data (prefix stability, the same contract as
  ``LATENCY`` / ``ARRIVALS`` draws);
* chunked vectorized generation keeps 10⁵-client builds at a few
  hundred numpy calls instead of tens of per-client calls each.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, Tuple

import numpy as np

from repro.data import framingham as F

#: SeedSequence tag isolating cohort draws from every other seeded
#: stream in the repo (latency 0x1A7, runtime 0xFED, load 0x10AD).
_TAG = 0xC001

#: Fixed generation chunk (clients per rng stream).  Part of the
#: determinism contract: changing it changes every cohort.
CHUNK = 256

#: Rows in the reference draw the standardization stats / label
#: threshold are fitted on (the twin's published n).
REF_ROWS = 4238

#: cohort spec name -> what it generates.  Resolved via
#: :func:`get_cohort` spec strings ("framingham_like:n:rows").
COHORTS: Dict[str, str] = {
    "framingham_like": "framingham_like:n_clients:rows_per_client — "
                       "per-client shards drawn from the Framingham "
                       "twin's marginals and logit teacher, labeled "
                       "against reference stats fitted on a 4,238-row "
                       "draw; prefix-stable in n_clients",
}


@dataclass(frozen=True)
class CohortSpec:
    """A parsed cohort spec: ``n_clients`` shards of ``rows_per_client``
    rows each, ``n_features`` wide."""
    name: str
    n_clients: int
    rows_per_client: int

    @property
    def n_features(self) -> int:
        return len(F.FEATURES)

    @property
    def total_rows(self) -> int:
        return self.n_clients * self.rows_per_client


def get_cohort(spec) -> CohortSpec:
    """Resolve a cohort spec string (or pass a :class:`CohortSpec`
    through): ``"framingham_like:1000:16"`` → 1000 clients × 16 rows."""
    if isinstance(spec, CohortSpec):
        return spec
    parts = str(spec).split(":")
    name, args = parts[0], parts[1:]
    if name not in COHORTS:
        raise KeyError(f"unknown cohort {spec!r}; "
                       f"available: {sorted(COHORTS)} "
                       f"(spec: framingham_like:n_clients:rows)")
    if len(args) != 2:
        raise ValueError(f"bad cohort spec {spec!r}: "
                         f"{name}:n_clients:rows_per_client needs two "
                         f"integer args")
    n_clients, rows = int(args[0]), int(args[1])
    if n_clients < 1 or rows < 1:
        raise ValueError(f"bad cohort spec {spec!r}: n_clients and "
                         f"rows_per_client must be >= 1")
    return CohortSpec(name, n_clients, rows)


@lru_cache(maxsize=8)
def reference_stats(seed: int = 0, positive_rate: float = 0.152,
                    noise: float = 0.3) -> Tuple[np.ndarray, np.ndarray,
                                                 float, float]:
    """Population constants every client shares: ``(mu, sd, thr, sig)``.

    Fitted on one :data:`REF_ROWS`-row reference draw (its own rng
    stream, ``[seed, 0xC001]``): per-feature mean/std of the raw
    columns, the teacher-score threshold hitting ``positive_rate``, and
    the noise scale ``sig = noise * sqrt(var(lin) + var(nonlin))`` —
    frozen so client labels never depend on cohort composition."""
    rng = np.random.default_rng([int(seed), _TAG])
    raw = F.raw_columns(rng, REF_ROWS)
    mu, sd = raw.mean(0), raw.std(0) + 1e-9
    lin, nonlin = F.teacher_parts((raw - mu) / sd)
    sig = float(noise * np.sqrt(lin.var() + nonlin.var()))
    score = lin + nonlin + rng.normal(0, 1.0, REF_ROWS) * sig
    thr = float(np.quantile(score, 1 - positive_rate))
    return mu, sd, thr, sig


def _chunk_rows(seed: int, chunk_idx: int, rows: int,
                mu: np.ndarray, sd: np.ndarray, thr: float, sig: float
                ) -> Tuple[np.ndarray, np.ndarray]:
    """One full generation chunk: ``(CHUNK, rows, F)`` standardized x
    and ``(CHUNK, rows)`` labels, a pure function of
    ``(seed, chunk_idx, rows)``."""
    rng = np.random.default_rng([int(seed), _TAG, int(chunk_idx)])
    m = CHUNK * rows
    z = (F.raw_columns(rng, m) - mu) / sd
    lin, nonlin = F.teacher_parts(z)
    score = lin + nonlin + rng.normal(0, 1.0, m) * sig
    x = z.astype(np.float32).reshape(CHUNK, rows, len(F.FEATURES))
    y = (score > thr).astype(np.float32).reshape(CHUNK, rows)
    return x, y


def build_cohort(spec, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Materialize a cohort as stacked client-axis arrays:
    ``x (n_clients, rows, 15) float32``, ``y (n_clients, rows) float32``
    — the layout the sharded runtime places over the 'clients' mesh
    axis.  Prefix-stable: the first k clients of any larger cohort with
    the same seed and rows_per_client are bit-identical."""
    c = get_cohort(spec)
    mu, sd, thr, sig = reference_stats(seed)
    x = np.empty((c.n_clients, c.rows_per_client, c.n_features),
                 np.float32)
    y = np.empty((c.n_clients, c.rows_per_client), np.float32)
    for i in range((c.n_clients + CHUNK - 1) // CHUNK):
        cx, cy = _chunk_rows(seed, i, c.rows_per_client, mu, sd, thr, sig)
        lo, hi = i * CHUNK, min((i + 1) * CHUNK, c.n_clients)
        x[lo:hi], y[lo:hi] = cx[:hi - lo], cy[:hi - lo]
    return x, y


def cohort_testset(seed: int = 0, n: int = 1024
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """A held-out pooled test set from the same population (its own rng
    stream ``[seed, 0xC001, 2**31-1]`` — never collides with a
    generation chunk, which is bounded by n_clients/CHUNK)."""
    mu, sd, thr, sig = reference_stats(seed)
    rng = np.random.default_rng([int(seed), _TAG, 2 ** 31 - 1])
    z = (F.raw_columns(rng, n) - mu) / sd
    lin, nonlin = F.teacher_parts(z)
    score = lin + nonlin + rng.normal(0, 1.0, n) * sig
    return z.astype(np.float32), (score > thr).astype(np.float32)
