"""Client data partitioners — how a pooled dataset shards across
hospitals.

Every partitioner is a pure function ``(x, y, n_clients, rng, **kw) →
list of index arrays`` that preserves each row exactly once (checked by
:func:`check_partition`; property-tested in ``tests/test_partition.py``).
Select by name through :data:`PARTITIONERS` / :func:`partition_indices`:

* ``iid`` — stratified even split (the paper's setup): each class is
  shuffled and dealt round-robin, so shards match in size and base rate.
* ``dirichlet`` — clinically-shaped label skew: the majority (healthy)
  class spreads evenly while the minority (CHD+) follows a
  Dirichlet(alpha) draw — small alpha leaves some hospitals with almost
  no positive cases, the regime federated-SMOTE targets (paper Fig 3).
* ``quantity`` — quantity skew: shard *sizes* follow Dirichlet(alpha)
  (some hospitals are 10x larger), labels stratified within each shard.
* ``site`` — site shift: rows sorted by a covariate (default: age,
  column 1 of the Framingham twin) and cut into contiguous blocks, so
  every hospital sees a different patient population.

The LM engine's analog maps the same names onto per-pod domain-mixture
rows (:func:`pod_mixture_matrix`), replacing the ad-hoc Dirichlet-only
mixtures previously hard-coded in ``repro.launch.fed_train``.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np


def _iid(x, y, n_clients: int, rng: np.random.Generator) -> List[np.ndarray]:
    """Stratified even split: shuffle each class, deal round-robin."""
    parts: List[list] = [[] for _ in range(n_clients)]
    for cls in np.unique(y):
        idx = np.where(y == cls)[0]
        rng.shuffle(idx)
        for i, j in enumerate(idx):
            parts[i % n_clients].append(j)
    return [np.array(sorted(p)) for p in parts]


def _dirichlet(x, y, n_clients: int, rng: np.random.Generator,
               alpha: float = 0.5) -> List[np.ndarray]:
    """Majority class even, minority class Dirichlet(alpha)-skewed."""
    parts: List[list] = [[] for _ in range(n_clients)]
    majo = np.where(y == 0)[0]
    rng.shuffle(majo)
    for i, j in enumerate(majo):
        parts[i % n_clients].append(j)
    mino = np.where(y == 1)[0]
    rng.shuffle(mino)
    probs = rng.dirichlet([alpha] * n_clients)
    cuts = (np.cumsum(probs)[:-1] * len(mino)).astype(int)
    for i, chunk in enumerate(np.split(mino, cuts)):
        parts[i].extend(chunk)
    return [np.array(sorted(p), dtype=np.int64) for p in parts]


def _quantity(x, y, n_clients: int, rng: np.random.Generator,
              alpha: float = 0.5) -> List[np.ndarray]:
    """Dirichlet(alpha) shard *sizes*; rows stratified-shuffled first so
    every shard keeps roughly the global base rate."""
    n = len(y)
    # spread each class uniformly over [0, 1) so every contiguous slice
    # of the order carries ~the global base rate
    keys = np.empty(n)
    for cls in np.unique(y):
        idx = np.where(y == cls)[0]
        rng.shuffle(idx)
        keys[idx] = (np.arange(len(idx)) + rng.random(len(idx))) \
            / len(idx)
    order = np.argsort(keys, kind="stable")
    probs = rng.dirichlet([alpha] * n_clients)
    # cumulative cuts, then nudge so every client keeps >= 1 row
    sizes = np.maximum((probs * n).astype(int), 1)
    while sizes.sum() > n:
        sizes[int(np.argmax(sizes))] -= 1
    sizes[int(np.argmax(sizes))] += n - sizes.sum()
    cuts = np.cumsum(sizes)[:-1]
    return [np.sort(p) for p in np.split(order, cuts)]


def _site(x, y, n_clients: int, rng: np.random.Generator,
          shift_feature: int = 1) -> List[np.ndarray]:
    """Contiguous blocks along a covariate: hospital 0 gets the youngest
    patients, hospital n-1 the oldest (covariate shift across sites)."""
    order = np.argsort(np.asarray(x)[:, shift_feature], kind="stable")
    return [np.sort(p) for p in np.array_split(order, n_clients)]


#: partitioner name -> fn(x, y, n_clients, rng, **kw) -> index arrays.
PARTITIONERS: Dict[str, Callable] = {
    "iid": _iid,
    "dirichlet": _dirichlet,
    "quantity": _quantity,
    "site": _site,
}


def check_partition(parts: List[np.ndarray], n_rows: int):
    """Every row lands in exactly one shard — raise otherwise."""
    allidx = np.concatenate([np.asarray(p) for p in parts]) if parts else \
        np.array([], dtype=int)
    if len(allidx) != n_rows or len(np.unique(allidx)) != n_rows:
        raise ValueError(
            f"partition loses/duplicates rows: {n_rows} rows -> "
            f"{len(allidx)} assignments, {len(np.unique(allidx))} unique")


def partition_indices(name: str, x, y, n_clients: int, seed: int = 0,
                      **kw) -> List[np.ndarray]:
    """Partition rows by registry name; validated to preserve every row
    exactly once.  Deterministic in ``seed``."""
    if name not in PARTITIONERS:
        raise KeyError(f"unknown partitioner {name!r}; "
                       f"available: {sorted(PARTITIONERS)}")
    rng = np.random.default_rng(seed)
    parts = PARTITIONERS[name](np.asarray(x), np.asarray(y), n_clients,
                               rng, **kw)
    check_partition(parts, len(y))
    return parts


def partition_dataset(name: str, ds, n_clients: int, seed: int = 0, **kw):
    """Partition a ``framingham.Dataset`` into per-client Datasets."""
    from repro.data.framingham import Dataset
    parts = partition_indices(name, ds.x, ds.y, n_clients, seed, **kw)
    return [Dataset(ds.x[p], ds.y[p], ds.raw[p], ds.feature_names)
            for p in parts]


def partition_shards(name: str, x, y, n_clients: int, seed: int = 0,
                     **kw) -> List:
    """Partition raw (x, y) arrays into ``[(x_i, y_i), ...]`` shards."""
    parts = partition_indices(name, x, y, n_clients, seed, **kw)
    return [(np.asarray(x)[p], np.asarray(y)[p]) for p in parts]


def pod_mixture_matrix(name: str, n_pods: int, n_domains: int,
                       alpha: float = 0.5, seed: int = 0
                       ) -> List[np.ndarray]:
    """The LM-engine analog: per-pod domain-mixture rows.

    ``iid`` → uniform mixtures; ``dirichlet`` → Dirichlet(alpha) rows
    (the classic non-IID pods); ``site`` → each pod concentrated on a
    home domain (hard domain shift).  ``quantity`` has no mixture analog
    (all pods run the same token budget) and raises."""
    if name == "iid":
        return [np.ones(n_domains) / n_domains for _ in range(n_pods)]
    if name == "dirichlet":
        from repro.data.pipeline import pod_mixtures
        return pod_mixtures(n_pods, n_domains, alpha=alpha, seed=seed)
    if name == "site":
        out = []
        for i in range(n_pods):
            m = np.full(n_domains, 0.15 / max(n_domains - 1, 1))
            m[i % n_domains] = 0.85
            out.append(m / m.sum())
        return out
    if name == "quantity":
        raise ValueError(
            "partitioner 'quantity' has no LM-mixture analog (pods share "
            "one token budget); use iid | dirichlet | site for --mode lm")
    raise KeyError(f"unknown partitioner {name!r}; "
                   f"available: {sorted(PARTITIONERS)}")
