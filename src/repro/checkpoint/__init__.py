from repro.checkpoint.ckpt import save_pytree, load_pytree  # noqa: F401
