"""Pytree checkpoints: msgpack + zstd (zlib fallback), with
structure-validated restore.

Arrays are serialized as (dtype, shape, raw bytes); the tree structure is
round-tripped through flatten-with-path so restore can validate against a
template (and re-shard: pass ``shardings`` matching the template to place
leaves on a mesh at load time).

``zstandard`` is an optional dependency: when absent, checkpoints are
framed with a ``RPZL`` magic prefix + zlib payload instead of a raw zstd
frame.  Load sniffs the leading bytes, so either framing restores on any
machine that can decompress it (zstd checkpoints still require
``zstandard`` at load time).
"""
from __future__ import annotations

import os
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # optional dep — fall back to zlib framing
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"   # standard zstd frame header
_ZLIB_MAGIC = b"RPZL"               # our zlib-fallback frame header


def _key_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def save_pytree(path: str, tree: Any, *, level: int = 3) -> int:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = {}
    for kpath, leaf in leaves:
        arr = np.asarray(leaf)
        payload[_key_str(kpath)] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    raw = msgpack.packb(payload, use_bin_type=True)
    if zstandard is not None:
        comp = zstandard.ZstdCompressor(level=level).compress(raw)
    else:
        comp = _ZLIB_MAGIC + zlib.compress(raw, min(level, 9))
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        f.write(comp)
    return len(comp)


def load_pytree(path: str, template: Any,
                shardings: Optional[Any] = None) -> Any:
    with open(path, "rb") as f:
        comp = f.read()
    if comp.startswith(_ZLIB_MAGIC):
        raw = zlib.decompress(comp[len(_ZLIB_MAGIC):])
    elif comp.startswith(_ZSTD_MAGIC):
        if zstandard is None:
            raise ImportError(
                f"{path} is a zstd checkpoint but 'zstandard' is not "
                "installed; install it or re-save with the zlib fallback")
        raw = zstandard.ZstdDecompressor().decompress(comp)
    else:
        raise ValueError(f"{path}: unrecognized checkpoint framing")
    payload = msgpack.unpackb(raw, raw=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    out = []
    for (kpath, tmpl), shd in zip(flat, shard_flat):
        key = _key_str(kpath)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key}")
        rec = payload[key]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(
            rec["shape"])
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs "
                f"template {np.shape(tmpl)}")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
